"""Telemetry overhead guard: disabled-mode tracing must be free.

The telemetry layer (``runtime/telemetry.py``) instruments every hot
dispatch path — planner, ProgramCache, PlanExecutor steps, the flusher
thread, service workers. That is only acceptable if the *disabled*
no-op path costs nothing: this bench measures it three ways and
ASSERTS the disabled-mode overhead stays under 2% of the smoke-recon
wall (the hard bound from the tier-1 acceptance criteria):

  1. micro: per-call cost of a disabled ``span()`` enter/exit
     (shared ``_NULL`` singleton — no allocation, no clock read);
  2. bound: (spans one traced recon emits) x (no-op cost) as a
     fraction of the untraced recon wall — the analytic ceiling on
     what disabled telemetry can cost the real path;
  3. direct: untraced warm recon wall, re-measured, vs itself across
     enable/disable toggling (reported, not asserted — smoke-size
     walls are noisy at the sub-percent level).

Enabled-mode overhead (full event recording) is reported alongside so
the trajectory tracks the cost of *running* traced.

    PYTHONPATH=src python -m benchmarks.bench_telemetry
"""

from __future__ import annotations

import time

import numpy as np

import jax.numpy as jnp

from repro.core import standard_geometry
from repro.runtime import telemetry
from repro.runtime.executor import PlanExecutor, ProgramCache
from repro.runtime.planner import plan_reconstruction

from . import common

# the acceptance bound: disabled-mode telemetry < 2% of recon wall
MAX_DISABLED_OVERHEAD = 0.02

_NOOP_CALLS = 200_000


def _noop_span_cost_s() -> float:
    """Per-call wall of one disabled span enter/exit."""
    assert not telemetry.enabled()
    t0 = time.perf_counter()
    for _ in range(_NOOP_CALLS):
        with telemetry.span("noop", x=1):
            pass
    return (time.perf_counter() - t0) / _NOOP_CALLS


def run(n: int = 24, n_det: int = 32, n_proj: int = 16, nb: int = 4) -> None:
    geom = standard_geometry(n=n, n_det=n_det, n_proj=n_proj)
    rng = np.random.RandomState(0)
    proj = jnp.asarray(
        rng.rand(n_proj, geom.nh, geom.nw).astype(np.float32))
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=nb)
    ex = PlanExecutor(geom, plan, ProgramCache())

    telemetry.disable()

    # 1. the no-op path itself
    t_noop = _noop_span_cost_s()
    common.emit("telemetry/noop_span", t_noop * 1e6,
                f"ns_per_call={t_noop * 1e9:.0f}")

    # 2. untraced warm recon wall (programs compiled by time_fn warmup)
    w_off = common.time_fn(ex.reconstruct, proj, iters=5)
    common.emit("telemetry/recon_untraced", w_off * 1e6, "traced=no")

    # 3. traced warm recon: wall + how many events one run emits
    with telemetry.tracing():
        w_on = common.time_fn(ex.reconstruct, proj, iters=5)
        telemetry.clear()
        ex.reconstruct(proj)
        n_events = len(telemetry.events())
    enabled_frac = (w_on - w_off) / w_off
    common.emit("telemetry/recon_traced", w_on * 1e6,
                f"events_per_recon={n_events} "
                f"enabled_overhead={enabled_frac * 100:+.1f}%")

    # the guard: even if EVERY event of a traced run were a span on the
    # untraced path (it is an upper bound — instants are cheaper), the
    # disabled no-op cost must stay under the 2% acceptance bound
    bound = n_events * t_noop / w_off
    common.emit("telemetry/disabled_overhead_bound", bound * w_off * 1e6,
                f"fraction={bound * 100:.4f}% bound={MAX_DISABLED_OVERHEAD * 100:.0f}%")
    assert bound < MAX_DISABLED_OVERHEAD, (
        f"disabled-mode telemetry overhead bound {bound:.4f} exceeds "
        f"{MAX_DISABLED_OVERHEAD} of smoke-recon wall "
        f"({n_events} events x {t_noop * 1e9:.0f} ns vs {w_off * 1e3:.1f} ms)")


if __name__ == "__main__":
    run()
