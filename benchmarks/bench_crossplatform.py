"""Paper Fig. 11 analogue: cross-platform comparison.

The paper compares A64FX against P100/V100 GPUs (including host->device
transfer overhead, and P10 not fitting GPU memory). Without those devices
we reproduce the comparison as a bandwidth-limited MODEL — legitimate
because the paper itself establishes back-projection is bandwidth-bound:

    t(platform) ~ N_mem_bytes / effective_bw
    GUPS(platform) ~ updates / t

with published peak bandwidths, plus the PCIe transfer term for GPUs
(projections must cross the bus; the paper's Fig. 11 protocol). The
memory-capacity gate reproduces the paper's P10 observation.
"""

from __future__ import annotations

from repro.configs.ct_paper import PROBLEMS

from .common import emit

PLATFORMS = {
    # name: (mem_bw GB/s, mem_capacity GB, pcie GB/s or None)
    "A64FX": (1024.0, 32.0, None),          # HBM2, host-resident
    "V100": (900.0, 16.0, 12.0),
    "P100": (732.0, 16.0, 12.0),
    "TPUv5e-chip": (819.0, 16.0, None),     # this repo's target
    "Gold6140x2": (250.0, 384.0, None),
}


def run(nb: int = 32):
    for prob in PROBLEMS:
        updates = prob.updates
        vol_bytes = prob.vol ** 3 * 4
        proj_bytes = prob.det ** 2 * prob.n_proj * 4
        # paper's N_mem model (bytes): (4 reads of proj + 1/nb vol) * 4B
        n_mem = (4 + 1 / nb) * updates * 4
        for name, (bw, cap, pcie) in PLATFORMS.items():
            need = (2 * vol_bytes + proj_bytes) / 1e9
            if need > cap:
                emit(f"xplat/{prob.label}/{name}", 0.0,
                     f"OOM need={need:.1f}GB cap={cap:.0f}GB")
                continue
            t = n_mem / (bw * 1e9)
            if pcie:
                t += proj_bytes / (pcie * 1e9)
            emit(f"xplat/{prob.label}/{name}", t * 1e6,
                 f"model_gups={updates / t / 1e9:.1f}")


def main():
    run()


if __name__ == "__main__":
    main()
