"""Smoke-size perf snapshot: variant ladder + tiled sweep -> JSON.

Seeds the repo's perf trajectory (BENCH_PR2.json and successors): runs
the optimization-ladder timing (``bench_variants``) and the tiled-engine
sweep (``bench_tiled``) at sizes small enough for CI, and dumps every
emitted row as structured JSON via ``common.write_json``. Wired as a
NON-GATING stage of tests/run_tier1.sh (`make bench-smoke`): a perf
regression shows up in the trajectory diff, not as a red build.

    PYTHONPATH=src python -m benchmarks.bench_smoke --json BENCH_PR2.json
"""

from __future__ import annotations

import argparse

from . import bench_tiled, bench_variants, common

# Smoke sizes: big enough that tiling/batching structure is exercised
# (several tiles, several nb-batches), small enough for a CI stage.
SMOKE = dict(n=24, n_det=32, n_proj=16, nb=4)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write emitted rows as a perf-trajectory JSON")
    ap.add_argument("--n", type=int, default=SMOKE["n"])
    ap.add_argument("--n-det", type=int, default=SMOKE["n_det"])
    ap.add_argument("--n-proj", type=int, default=SMOKE["n_proj"])
    ap.add_argument("--nb", type=int, default=SMOKE["nb"])
    args = ap.parse_args(argv)

    common.reset_records()
    sizes = dict(n=args.n, n_det=args.n_det, n_proj=args.n_proj, nb=args.nb)
    print("# --- variants (smoke) ---")
    bench_variants.run(**sizes)
    print("# --- tiled (smoke) ---")
    bench_tiled.run(**sizes)
    if args.json:
        common.write_json(args.json, meta={"suite": "bench_smoke", **sizes})


if __name__ == "__main__":
    main()
