"""Smoke-size perf snapshot: variant ladder + tiled sweep -> JSON (+diff).

Seeds the repo's perf trajectory (BENCH_PR2.json, BENCH_PR3.json, ...):
runs the optimization-ladder timing (``bench_variants``), the
tiled-engine sweep (``bench_tiled``) — which now also times the
step-major vs chunk-major executor schedules on multi-chunk streamed
FDK — the serving-layer cold/warm + pipeline-overlap numbers
(``bench_service``), the bounded-budget autotune smoke
(``bench_autotune`` — heuristic-vs-tuned wall + search cost; the
winners persist in the tuning cache at ``$REPRO_TUNING_CACHE``, which
CI uploads as an artifact), the streaming-ingestion overlap numbers
(``bench_stream`` — last-view-to-volume tail vs offline wall and the
hidden fraction of a simulated scanner run), the iterative-solver
loops (``bench_solvers`` — warm amortized per-iteration wall vs the
compile-heavy first iteration, plus the bf16 precision axis), the
telemetry overhead guard (``bench_telemetry`` — asserts disabled-mode
span overhead stays under 2% of the smoke-recon wall and reports the
enabled-mode cost), and a bigger-size
re-measure of the symmetry
family (the BENCH_PR2 ``symmetry_mp`` 0.48x number was part real
regression — fixed by the affine-fold mirror in core/backproject.py —
and part smoke-size dispatch noise, so the wall claim is re-checked
where arithmetic dominates). Every emitted row is dumped as structured
JSON via ``common.write_json``; ``--diff`` prints per-variant wall/GUPS
deltas against a prior BENCH_*.json and ``--warn-regress`` flags
(without failing — the tier-1 stage is non-gating; ``--strict``, the
nightly CI mode, escalates to a nonzero exit) any wall regression
beyond the given fraction. ``--json auto`` derives the next snapshot
name from the committed BENCH_PR<N>.json sequence
(:func:`next_snapshot_path`) so no caller hardcodes it.

    PYTHONPATH=src python -m benchmarks.bench_smoke \
        --json auto --diff auto --warn-regress 0.25
"""

from __future__ import annotations

import argparse
import glob
import os
import re
import subprocess

import numpy as np

import jax.numpy as jnp

from repro.core import projection_matrices, standard_geometry, \
    transpose_projections
from repro.core.variants import get_variant

from . import bench_autotune, bench_service, bench_solvers, bench_stream, \
    bench_telemetry, bench_tiled, bench_variants, common

# Smoke sizes: big enough that tiling/batching structure is exercised
# (several tiles, several nb-batches), small enough for a CI stage.
SMOKE = dict(n=24, n_det=32, n_proj=16, nb=4)

# Re-measure sizes for the symmetry family: large enough that kernel
# arithmetic, not per-call dispatch, dominates the wall clock.
BIG = dict(n=48, n_det=64, n_proj=32, nb=8)


def symmetry_recheck(n: int, n_det: int, n_proj: int, nb: int) -> None:
    """Wall-only re-measure of the O3 symmetry family vs share_mp."""
    geom = standard_geometry(n=n, n_det=n_det, n_proj=n_proj)
    rng = np.random.RandomState(0)
    img = jnp.asarray(rng.rand(n_proj, geom.nh, geom.nw).astype(np.float32))
    img_t = transpose_projections(img)
    mats = projection_matrices(geom)
    shape = geom.volume_shape_xyz
    t_share = common.time_fn(
        lambda: get_variant("share_mp")(img_t, mats, shape))
    common.emit("variants_big/share_mp", t_share * 1e6,
                f"gups={common.gups(geom, t_share):.3f} vs_share=1.00x")
    for name in ("symmetry_mp", "algorithm1_mp"):
        fn = get_variant(name)
        t = common.time_fn(lambda: fn(img_t, mats, shape, nb=nb))
        common.emit(f"variants_big/{name}", t * 1e6,
                    f"gups={common.gups(geom, t):.3f} "
                    f"vs_share={t_share / t:.2f}x")


def next_snapshot_path() -> str:
    """``BENCH_PR<N+1>.json`` where N is the highest COMMITTED snapshot
    number — the ONE place the per-PR snapshot name is derived.

    Both callers (`make bench-smoke` and tests/run_tier1.sh stage 3)
    pass ``--json auto``, so each PR writes the next snapshot without
    either file being edited. Committed names (``git ls-files``) beat a
    directory glob so repeated local runs keep overwriting the same
    not-yet-committed snapshot instead of marching the number forward;
    the glob is the fallback outside a git checkout.
    """
    try:
        listed = subprocess.run(
            ["git", "ls-files", "BENCH_*.json"], capture_output=True,
            text=True, check=True).stdout.split()
    except (OSError, subprocess.CalledProcessError):
        listed = glob.glob("BENCH_*.json")
    ns = [int(m.group(1)) for p in listed
          if (m := re.fullmatch(r"BENCH_PR(\d+)\.json",
                                os.path.basename(p)))]
    return f"BENCH_PR{max(ns, default=0) + 1}.json"


def auto_prior(out_path) -> str | None:
    """Newest committed BENCH_*.json that is not this run's own output
    — the ONE definition of the trajectory-diff base (used by both
    `make bench-smoke` and tests/run_tier1.sh via ``--diff auto``).
    Newest = highest numeric suffix (BENCH_PR10 sorts after BENCH_PR9).
    """
    skip = os.path.abspath(out_path) if out_path else None
    cands = [p for p in glob.glob("BENCH_*.json")
             if os.path.abspath(p) != skip]
    if not cands:
        return None
    return max(cands, key=lambda p: ([int(x) for x in re.findall(r"\d+", p)],
                                     p))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write emitted rows as a perf-trajectory JSON; "
                         "'auto' derives the next committed snapshot "
                         "name (next_snapshot_path -> BENCH_PR<N>.json)")
    ap.add_argument("--diff", metavar="PRIOR_JSON", default=None,
                    help="print per-variant deltas vs a prior "
                         "BENCH_*.json; 'auto' picks the newest one "
                         "that is not --json's output")
    ap.add_argument("--warn-regress", type=float, default=0.25,
                    metavar="FRAC",
                    help="with --diff: warn (never fail) when a row's "
                         "wall time regresses beyond this fraction")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on any --warn-regress hit "
                         "(reserved for the nightly CI job; the per-PR "
                         "tier-1 stage stays non-gating)")
    ap.add_argument("--n", type=int, default=SMOKE["n"])
    ap.add_argument("--n-det", type=int, default=SMOKE["n_det"])
    ap.add_argument("--n-proj", type=int, default=SMOKE["n_proj"])
    ap.add_argument("--nb", type=int, default=SMOKE["nb"])
    ap.add_argument("--autotune-budget", type=float, default=10.0,
                    metavar="SEC",
                    help="wall-clock budget for the bounded autotune "
                         "smoke (tuning cache honors $REPRO_TUNING_CACHE)")
    args = ap.parse_args(argv)
    if args.json == "auto":
        args.json = next_snapshot_path()

    common.reset_records()
    sizes = dict(n=args.n, n_det=args.n_det, n_proj=args.n_proj, nb=args.nb)
    print("# --- variants (smoke) ---")
    bench_variants.run(**sizes)
    print("# --- tiled (smoke) ---")
    bench_tiled.run(**sizes)
    print("# --- serving layer (smoke) ---")
    bench_service.run(**sizes)
    print("# --- autotuner (bounded-budget smoke) ---")
    bench_autotune.run(**sizes, budget_s=args.autotune_budget)
    print("# --- streaming (simulated scanner) ---")
    bench_stream.run(**sizes)
    print("# --- iterative solvers (warm amortized per-iteration) ---")
    bench_solvers.run(**sizes)
    print("# --- telemetry overhead guard (<2% disabled) ---")
    bench_telemetry.run(**sizes)
    print("# --- symmetry family (realistic size) ---")
    symmetry_recheck(**BIG)
    if args.json:
        # surface the jit-program cache totals of the whole bench run:
        # the step-major executor's claim that interior tiles compile
        # once under the chunk-loop key is auditable from the snapshot
        from repro.runtime.executor import default_program_cache
        common.write_json(args.json, meta={
            "suite": "bench_smoke", **sizes,
            "program_cache": default_program_cache().stats(),
        })
    prior = auto_prior(args.json) if args.diff == "auto" else args.diff
    if args.diff and prior is None:
        print("# --diff auto: no prior BENCH_*.json found, skipping diff")
    elif prior:
        common.print_diff(common.load_json(prior),
                          warn_regress=args.warn_regress,
                          strict=args.strict)


if __name__ == "__main__":
    main()
