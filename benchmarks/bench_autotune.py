"""Autotuner benchmark: heuristic-vs-tuned wall time + search cost.

Measures the claim the autotuning subsystem (``runtime/autotune.py``)
makes — that a MEASURED per-hardware configuration beats (or at worst
matches) the planner's static heuristics — and records it into the BENCH
trajectory so the tuned/heuristic ratio is tracked per PR like every
other perf number:

  * ``autotune/heuristic``  — wall time of the heuristic config (what
    every façade runs without tuning), measured through the same
    harness the tuner uses;
  * ``autotune/tuned``      — wall time of the winning config, with the
    chosen knobs (variant/schedule/pipeline) in the derived string and
    ``speedup`` = heuristic/tuned (>= ~1.0 by construction: the
    heuristic config is always a candidate, so the tuner can only lose
    to measurement noise);
  * ``autotune/search``     — wall clock of the bounded search itself +
    how many candidates it measured (the one-time cost a deployment
    pays per hardware x request shape);
  * ``autotune/cache_resolve`` — lookup-only re-resolution against the
    persisted cache (the steady-state cost: planning stays µs).

The wide (variant="auto") space is searched so the trajectory reflects
real cross-variant portability, restricted to the pure-JAX ladder by
default so the smoke stays CI-sized (Pallas interpret timings belong to
the slow tier).

    PYTHONPATH=src python -m benchmarks.bench_autotune --budget 12
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax.numpy as jnp

from repro.core import standard_geometry
from repro.runtime.autotune import (TuningCache, autotune,
                                    default_tuning_cache, resolve_config)
from repro.runtime.executor import PlanExecutor, ProgramCache

from . import common

# smoke-sized wide search: the mp ladder's realistic contenders (the
# Pallas interpreter is orders slower on CPU CI — measuring it here
# would burn the whole budget on foregone conclusions)
SMOKE_VARIANTS = ("algorithm1_mp", "symmetry_mp", "subline_batch_mp",
                  "share_mp")


def run(n: int = 24, n_det: int = 32, n_proj: int = 16, nb: int = 4,
        budget_s: float = 12.0, cache: TuningCache | None = None,
        variants=SMOKE_VARIANTS) -> None:
    geom = standard_geometry(n=n, n_det=n_det, n_proj=n_proj)
    rng = np.random.RandomState(0)
    projs = jnp.asarray(
        rng.rand(geom.n_proj, geom.nh, geom.nw).astype(np.float32))
    opts = dict(nb=nb, tiling=(n // 2, n // 2, n),
                proj_batch=max(nb, n_proj // 2))
    tcache = cache if cache is not None else default_tuning_cache()
    pcache = ProgramCache()

    # ---- bounded wide search (force: this IS the trajectory number) ----
    t0 = time.perf_counter()
    cfg = autotune(geom, "auto", **opts, budget_s=budget_s, iters=3,
                   cache=tcache, force=True, projections=projs,
                   program_cache=pcache, variants=variants)
    search = time.perf_counter() - t0
    common.emit("autotune/heuristic", cfg.baseline_us,
                "variant=algorithm1_mp source=planner")
    common.emit("autotune/tuned", cfg.wall_us,
                f"variant={cfg.variant} schedule={cfg.schedule} "
                f"pipeline={cfg.pipeline} speedup={cfg.speedup:.2f}x")
    common.emit("autotune/search", search * 1e6,
                f"trials={cfg.trials} budget_s={budget_s}")
    print(f"# tuned {cfg.variant}/{cfg.schedule}/{cfg.pipeline} "
          f"{cfg.wall_us:.0f}us vs heuristic {cfg.baseline_us:.0f}us "
          f"({cfg.speedup:.2f}x) after {cfg.trials} trials; "
          f"cache -> {tcache.path}")

    # ---- steady state: lookup-only resolution off the persisted file ----
    t0 = time.perf_counter()
    resolved = resolve_config(geom, "auto", cache=tcache, **opts)
    resolve_us = (time.perf_counter() - t0) * 1e6
    common.emit("autotune/cache_resolve", resolve_us,
                f"source={resolved.source} variant={resolved.variant}")
    assert resolved.source == "cache", resolved.source

    # sanity: the resolved winner actually runs (warm programs from the
    # search double as the deployment warmup)
    ex = PlanExecutor.from_config(geom, resolved, cache=pcache)
    ex.reconstruct(projs)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--budget", type=float, default=12.0,
                    help="search wall-clock budget in seconds")
    ap.add_argument("--cache", default=None,
                    help="tuning-cache path (default: $REPRO_TUNING_CACHE "
                         "or ~/.cache/repro/tuning.json)")
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--n-det", type=int, default=32)
    ap.add_argument("--n-proj", type=int, default=16)
    ap.add_argument("--nb", type=int, default=4)
    args = ap.parse_args(argv)
    common.reset_records()
    run(n=args.n, n_det=args.n_det, n_proj=args.n_proj, nb=args.nb,
        budget_s=args.budget,
        cache=TuningCache(args.cache) if args.cache else None)


if __name__ == "__main__":
    main()
