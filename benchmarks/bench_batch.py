"""Paper Fig. 6 analogue: performance vs batch number nb, and the
N_mem model fit (§3.1.3/§4.3):

    N_mem ~ (4 + 1/nb) * np * nx * ny * nz
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import projection_matrices, standard_geometry, \
    transpose_projections
from repro.core.backproject import bp_subline_symmetry_batch

from .common import emit, gups, time_fn


def run(n: int = 48, n_det: int = 64, n_proj: int = 32):
    geom = standard_geometry(n=n, n_det=n_det, n_proj=n_proj)
    rng = np.random.RandomState(0)
    img = jnp.asarray(rng.rand(n_proj, geom.nh, geom.nw).astype(np.float32))
    img_t = transpose_projections(img)
    mats = projection_matrices(geom)
    shape = geom.volume_shape_xyz

    import jax

    from repro.launch import hlo_cost

    out = {}
    vol_bytes_once = None
    for nb in (1, 2, 4, 8, 16, 32):
        if n_proj % nb:
            continue
        t = time_fn(lambda nb=nb: bp_subline_symmetry_batch(
            img_t, mats, shape, nb=nb))
        compiled = jax.jit(
            lambda i, m, nb=nb: bp_subline_symmetry_batch(
                i, m, shape, nb=nb)).lower(img_t, mats).compile()
        la = hlo_cost.analyze(compiled.as_text())
        model = 4.0 + 1.0 / nb   # paper's N_mem coefficient
        emit(f"batch/nb={nb}", t * 1e6,
             f"gups={gups(geom, t):.3f} Nmem_coef={model:.3f} "
             f"hlo_bytes={la['bytes']:.3e}")
        out[nb] = (t, la["bytes"])
    return out


def main():
    run()


if __name__ == "__main__":
    main()
