"""LM-substrate micro-benchmarks (framework-side tables): per-arch smoke
train-step latency and decode-step latency on CPU (reduced configs) —
regression guards for the substrate, not roofline numbers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, ShapeConfig, get_smoke_config, \
    list_archs
from repro.launch.train import init_state, make_train_step
from repro.models import build_model

from .common import emit, time_fn

SHAPE = ShapeConfig("bench", "train", 32, 2)


def run(archs=None):
    archs = archs or ["qwen2.5-3b", "granite-moe-1b-a400m",
                      "recurrentgemma-9b", "rwkv6-3b"]
    for arch in archs:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        state = init_state(model, RunConfig(seed=0))
        batch = model.dummy_batch(SHAPE)
        step = jax.jit(make_train_step(model, RunConfig(),
                                       total_steps=100))
        t = time_fn(lambda: step(state, batch)[1]["loss"])
        tok_s = SHAPE.tokens_per_step / t
        emit(f"lm_train/{arch}", t * 1e6, f"tokens_per_s={tok_s:.0f}")

        pre = dict(batch)
        pre["tokens"] = batch["tokens"][:, :16]
        logits, cache, pos = model.prefill(state.params, pre, 64)
        dec = jax.jit(lambda p, c, t_, q: model.decode_step(p, c, t_, q))
        tok = batch["tokens"][:, :1]
        t = time_fn(lambda: dec(state.params, cache, tok,
                                jnp.int32(16))[0])
        emit(f"lm_decode/{arch}", t * 1e6,
             f"tok_per_s={SHAPE.global_batch / t:.0f}")


def main():
    run()


if __name__ == "__main__":
    main()
