"""Serving-layer benchmark: cold vs warm request latency + pipeline overlap.

Measures the two claims the serving layer (``runtime/service.py``) makes:

  1. **warm << cold** — the first request of a shape pays planning + jit
     compilation of every program the plan needs; every later same-shape
     request hits the bucket's cached executor and compiles nothing.
     Emitted as ``service/cold_request`` and ``service/warm_request``
     with the warm/cold ratio (the acceptance bar is < 0.5x; in
     practice compile dominates and the ratio is tiny).
  2. **async overlap** — the ``pipeline="async"`` flusher thread
     overlaps step N's device->host accumulator copy with step N+1's
     scan dispatch. Emitted as ``service/pipeline_sync`` vs
     ``service/pipeline_async`` with the sync/async wall ratio.

``overlap_gain`` alone is MISLEADING at smoke sizes: the per-step flush
is a few hundred KB, so the copy the thread hides is microseconds while
the thread+GIL handoff it adds is not — gains < 1 here say nothing
about clinical sizes. Both pipeline rows therefore also report
``flush_kb_per_step`` (the modeled device->host bytes each step emits —
the quantity the overlap actually hides), and :func:`run_clinical`
re-measures the pair at a clinical-scale volume where each step flushes
hundreds of KB to MBs (opt-in: ``--clinical`` here, `pytest -m slow` in
tier-1's slow lane — not smoke material).

A mixed-shape burst at the end exercises bucketing under FIFO traffic
and prints the :class:`ServiceStats` snapshot.

  3. **cross-request batching** — a same-bucket burst of k requests
     through ``max_batch=k`` forms ONE ``execute_batch`` dispatch
     stream instead of k dispatch sequences. Emitted as
     ``service/batched_burst_k{1,2,4,8}`` with the AMORTIZED us/request
     (wall / k) and realized occupancy; the k=1 row is the unbatched
     baseline on the same bucket, and the acceptance bar is k=8
     amortized strictly below it.

    PYTHONPATH=src python -m benchmarks.bench_service [--clinical]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax.numpy as jnp

from repro.core import standard_geometry
from repro.runtime.executor import PlanExecutor, ProgramCache
from repro.runtime.planner import plan_reconstruction
from repro.runtime.service import ReconService

from . import common


def _projs(geom, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(
        rng.rand(geom.n_proj, geom.nh, geom.nw).astype(np.float32))


def flush_bytes_per_step(plan) -> float:
    """Modeled device->host bytes ONE step's flush emits (float32 tile
    writes) — the traffic the async pipeline can actually hide."""
    total = 4 * sum(s.ni * s.nj * sum(w.nk for w in s.writes)
                    for s in plan.steps)
    return total / max(1, len(plan.steps))


def _pipeline_pair(geom, projs, plan, suffix: str = ""):
    """Time sync vs async on one warmed plan; emit both rows with the
    flush-bytes context that makes the ratio interpretable."""
    cache = ProgramCache()
    walls = {}
    for pipeline in ("sync", "async"):
        ex = PlanExecutor(geom, plan, cache=cache, pipeline=pipeline)
        walls[pipeline] = common.time_fn(lambda: ex.reconstruct(projs))
    gain = walls["sync"] / walls["async"]
    kb = flush_bytes_per_step(plan) / 1024
    common.emit(f"service/pipeline_sync{suffix}", walls["sync"] * 1e6,
                f"steps={len(plan.steps)} flush_kb_per_step={kb:.1f}")
    common.emit(f"service/pipeline_async{suffix}", walls["async"] * 1e6,
                f"overlap_gain={gain:.2f}x flush_kb_per_step={kb:.1f}")
    return gain, kb


def run(n: int = 24, n_det: int = 32, n_proj: int = 16, nb: int = 4):
    geom = standard_geometry(n=n, n_det=n_det, n_proj=n_proj)
    projs = _projs(geom)
    # several (i, j)-tiles + streamed chunks: the shape class a serving
    # deployment buckets on, and enough steps for the flush pipeline
    opts = dict(variant="algorithm1_mp", nb=nb,
                tiling=(n // 2, n // 2, n), proj_batch=max(nb, n_proj // 2))

    # ---- cold vs warm through the service --------------------------------
    svc = ReconService(max_inflight=1, cache=ProgramCache())
    t0 = time.perf_counter()
    svc.reconstruct(projs, geom, **opts)        # pays plan + all compiles
    cold = time.perf_counter() - t0
    warm = common.time_fn(lambda: svc.reconstruct(projs, geom, **opts))
    common.emit("service/cold_request", cold * 1e6,
                f"programs={svc.stats().cache['programs']}")
    common.emit("service/warm_request", warm * 1e6,
                f"warm_over_cold={warm / cold:.3f}x")
    ok = warm < 0.5 * cold
    print(f"# warm {warm * 1e3:.1f} ms vs cold {cold * 1e3:.1f} ms -> "
          f"{warm / cold:.3f}x ({'OK' if ok else 'FAIL'}: bar 0.5x)")
    svc.close()

    # ---- pipeline overlap: sync vs async flush on one warmed plan --------
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=nb,
                               tile_shape=(n // 2, n // 2, n),
                               proj_batch=max(nb, n_proj // 2), out="host")
    gain, kb = _pipeline_pair(geom, projs, plan)
    print(f"# overlap_gain {gain:.2f}x at {kb:.1f} KB/step flush — "
          f"smoke-size flushes are µs; see pipeline_*_clinical "
          f"(--clinical / pytest -m slow) for the number that matters")

    # ---- mixed-shape FIFO burst ------------------------------------------
    geom_b = standard_geometry(n=max(8, n // 2), n_det=max(8, n_det // 2),
                               n_proj=n_proj)
    projs_b = _projs(geom_b, seed=1)
    svc = ReconService(max_inflight=2, cache=ProgramCache())
    svc.warmup([geom, geom_b], **opts)
    t0 = time.perf_counter()
    futs = []
    for i in range(6):
        g, p = ((geom, projs) if i % 2 == 0 else (geom_b, projs_b))
        futs.append(svc.submit(p, g, **opts))
    for f in futs:
        f.result()
    burst = time.perf_counter() - t0
    stats = svc.stats()
    common.emit("service/mixed_burst6", burst * 1e6,
                f"buckets={len(stats.buckets)} "
                f"hit_rate={stats.hit_rate:.2f}")
    print(f"# {stats}")
    svc.close()

    # ---- cross-request batching: amortized us/request vs k ---------------
    batched_burst(geom, projs, opts)


def batched_burst(geom, projs, opts, ks=(1, 2, 4, 8), repeats: int = 3):
    """Amortized per-request cost of a k-deep same-bucket burst.

    One service per k (its ``max_batch`` IS k), warmed so no compile
    lands in the timed region; the burst is submitted in one go, so the
    BatchFormer coalesces it without waiting (``max_wait_ms=0`` —
    occupancy comes from queue depth alone, the serving steady state
    under load). Median of ``repeats`` bursts, amortized = wall / k.
    The k=1 service is the unbatched baseline on the same bucket.
    """
    amortized = {}
    for k in ks:
        svc = ReconService(max_inflight=1, max_batch=k,
                           cache=ProgramCache())
        svc.warmup([geom], **opts)
        svc.reconstruct(projs, geom, **opts)     # absorb first-call costs
        walls = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            futs = [svc.submit(projs, geom, **opts) for _ in range(k)]
            for f in futs:
                f.result()
            walls.append(time.perf_counter() - t0)
        walls.sort()
        wall = walls[len(walls) // 2]
        stats = svc.stats()
        occ = stats.buckets[0].mean_occupancy
        amortized[k] = wall / k * 1e6
        common.emit(f"service/batched_burst_k{k}", amortized[k],
                    f"amortized_us_per_request occupancy={occ} "
                    f"dispatches={stats.buckets[0].dispatches}")
        svc.close()
    gain = amortized[ks[0]] / amortized[ks[-1]]
    ok = amortized[ks[-1]] < amortized[ks[0]]
    print(f"# batched burst: k={ks[-1]} amortized "
          f"{amortized[ks[-1]]:.0f} us/req vs unbatched "
          f"{amortized[ks[0]]:.0f} us/req -> {gain:.2f}x "
          f"({'OK' if ok else 'FAIL'}: bar = strictly below unbatched)")
    return amortized


def run_clinical(n: int = 96, n_det: int = 128, n_proj: int = 48,
                 nb: int = 8) -> float:
    """Clinical-scale sync-vs-async overlap (the satellite the smoke
    number cannot answer): per-step flushes here are MBs, so the
    flusher thread hides real copy time instead of µs. Returns the
    overlap gain. Minutes of compile+run — slow lane only."""
    geom = standard_geometry(n=n, n_det=n_det, n_proj=n_proj)
    projs = _projs(geom)
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=nb,
                               tile_shape=(n // 2, n // 2, n),
                               proj_batch=max(nb, n_proj // 4), out="host")
    gain, kb = _pipeline_pair(geom, projs, plan, suffix="_clinical")
    print(f"# clinical overlap_gain {gain:.2f}x at {kb:.1f} KB/step")
    return gain


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clinical", action="store_true",
                    help="also run the clinical-size overlap measurement "
                         "(minutes; slow lane)")
    args = ap.parse_args(argv)
    common.reset_records()
    run()
    if args.clinical:
        print("# --- clinical size ---")
        run_clinical()


if __name__ == "__main__":
    main()
