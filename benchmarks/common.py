"""Shared benchmark utilities: timing, GUPS, CSV + JSON emission.

Every suite prints ``name,us_per_call,derived`` CSV rows through
:func:`emit`; rows are also recorded in-process so a driver can dump the
whole run as structured JSON (:func:`write_json` — the ``--json`` flag of
``benchmarks.bench_smoke`` / ``benchmarks.run``). The JSON records parse
the ``k=v`` tokens of the derived string into a dict, so downstream
tooling (the perf-trajectory files like BENCH_PR2.json) never has to
re-parse free text.
"""

from __future__ import annotations

import json
import platform
import time
from typing import Dict, List, Optional

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def gups(geom, t: float, n_proj: int | None = None) -> float:
    """Paper §2.3: nx*ny*nz*np / t / 1e9 (giga updates per second)."""
    return geom.voxel_updates(n_proj) / t / 1e9


# ---- emission -------------------------------------------------------------

_RECORDS: List[Dict] = []


def _parse_derived(derived: str) -> Dict[str, object]:
    """Parse the ``k=v`` tokens of a derived string (best effort)."""
    out: Dict[str, object] = {}
    for tok in derived.split():
        if "=" not in tok:
            continue
        k, v = tok.split("=", 1)
        try:
            out[k] = float(v.rstrip("x"))
        except ValueError:
            out[k] = v
    return out


def emit(name: str, us_per_call: float, derived: str):
    """The harness CSV contract: name,us_per_call,derived (+ JSON record)."""
    print(f"{name},{us_per_call:.1f},{derived}")
    _RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                     "derived": derived,
                     "metrics": _parse_derived(derived)})


def records() -> List[Dict]:
    """All rows emitted since the last :func:`reset_records`."""
    return list(_RECORDS)


def reset_records() -> None:
    _RECORDS.clear()


def write_json(path: str, meta: Optional[Dict] = None) -> None:
    """Dump recorded rows (+ run metadata) as a perf-trajectory JSON."""
    doc = {
        "meta": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "jax_version": jax.__version__,
            "python": platform.python_version(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            **(meta or {}),
        },
        "records": records(),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"# wrote {len(doc['records'])} records -> {path}")
