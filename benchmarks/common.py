"""Shared benchmark utilities: timing, GUPS, CSV + JSON emission.

Every suite prints ``name,us_per_call,derived`` CSV rows through
:func:`emit`; rows are also recorded in-process so a driver can dump the
whole run as structured JSON (:func:`write_json` — the ``--json`` flag of
``benchmarks.bench_smoke`` / ``benchmarks.run``). The JSON records parse
the ``k=v`` tokens of the derived string into a dict, so downstream
tooling (the perf-trajectory files like BENCH_PR2.json) never has to
re-parse free text.
"""

from __future__ import annotations

import json
import platform
import time
from typing import Dict, List, Optional

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def gups(geom, t: float, n_proj: int | None = None) -> float:
    """Paper §2.3: nx*ny*nz*np / t / 1e9 (giga updates per second)."""
    return geom.voxel_updates(n_proj) / t / 1e9


# ---- emission -------------------------------------------------------------

_RECORDS: List[Dict] = []


def _parse_derived(derived: str) -> Dict[str, object]:
    """Parse the ``k=v`` tokens of a derived string (best effort)."""
    out: Dict[str, object] = {}
    for tok in derived.split():
        if "=" not in tok:
            continue
        k, v = tok.split("=", 1)
        try:
            out[k] = float(v.rstrip("x"))
        except ValueError:
            out[k] = v
    return out


def emit(name: str, us_per_call: float, derived: str):
    """The harness CSV contract: name,us_per_call,derived (+ JSON record)."""
    print(f"{name},{us_per_call:.1f},{derived}")
    _RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                     "derived": derived,
                     "metrics": _parse_derived(derived)})


def records() -> List[Dict]:
    """All rows emitted since the last :func:`reset_records`."""
    return list(_RECORDS)


def reset_records() -> None:
    _RECORDS.clear()


def write_json(path: str, meta: Optional[Dict] = None) -> None:
    """Dump recorded rows (+ run metadata) as a perf-trajectory JSON."""
    doc = {
        "meta": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "jax_version": jax.__version__,
            "python": platform.python_version(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            **(meta or {}),
        },
        "records": records(),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"# wrote {len(doc['records'])} records -> {path}")


# ---- trajectory diff ------------------------------------------------------

def load_json(path: str) -> Dict:
    """Load a prior perf-trajectory JSON (the BENCH_*.json files)."""
    with open(path) as f:
        return json.load(f)


def diff_records(prior: Dict, current: Optional[List[Dict]] = None
                 ) -> List[Dict]:
    """Per-name wall/GUPS deltas of ``current`` rows vs a prior doc.

    Rows are matched by name; only names present in BOTH runs are
    compared (renamed/new suites simply drop out). Returns one dict per
    shared row with ``wall_ratio = now / prev`` (< 1 is faster).
    """
    cur = {r["name"]: r for r in
           (records() if current is None else current)}
    prev = {r["name"]: r for r in prior.get("records", [])}
    out = []
    for name, row in cur.items():
        if name not in prev:
            continue
        us_prev = float(prev[name]["us_per_call"])
        us_now = float(row["us_per_call"])
        out.append({
            "name": name,
            "us_prev": us_prev,
            "us_now": us_now,
            "wall_ratio": us_now / us_prev if us_prev else float("inf"),
            "gups_prev": prev[name].get("metrics", {}).get("gups"),
            "gups_now": row.get("metrics", {}).get("gups"),
        })
    return out


def print_diff(prior: Dict, current: Optional[List[Dict]] = None,
               warn_regress: Optional[float] = None,
               strict: bool = False) -> List[Dict]:
    """Print the per-variant trajectory diff; return regressed rows.

    ``warn_regress``: warn — loudly, but WITHOUT failing — about any row
    whose wall time regressed by more than that fraction (0.25 = 25%).
    Perf is a non-gating tier-1 stage: regressions must be impossible to
    miss in the log yet never turn the build red (tests/run_tier1.sh).

    ``strict``: escalate those warnings to a nonzero exit
    (``SystemExit``) — reserved for the nightly CI job, where a red
    build on a wall regression is the point; local runs and the per-PR
    gate stay non-gating.
    """
    rows = diff_records(prior, current)
    stamp = prior.get("meta", {}).get("timestamp", "?")
    print(f"# --- diff vs prior run of {stamp} ({len(rows)} shared rows) ---")
    print("# name,us_prev,us_now,wall_ratio,gups_prev,gups_now")
    for r in rows:
        print(f"{r['name']},{r['us_prev']:.1f},{r['us_now']:.1f},"
              f"{r['wall_ratio']:.2f}x,{r['gups_prev']},{r['gups_now']}")
    regressed = []
    if warn_regress is not None:
        bar = 1.0 + float(warn_regress)
        regressed = [r for r in rows if r["wall_ratio"] > bar]
        for r in regressed:
            print(f"WARNING: perf regression {r['name']}: "
                  f"{r['wall_ratio']:.2f}x wall vs prior "
                  f"(threshold {bar:.2f}x)")
        if not regressed and rows:
            print(f"# no wall regression beyond {bar:.2f}x")
        if regressed and strict:
            raise SystemExit(
                f"FAIL (--strict): {len(regressed)} row(s) regressed "
                f"beyond {bar:.2f}x wall")
    return regressed
