"""Shared benchmark utilities: timing, GUPS, CSV emission."""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def gups(geom, t: float, n_proj: int | None = None) -> float:
    """Paper §2.3: nx*ny*nz*np / t / 1e9 (giga updates per second)."""
    return geom.voxel_updates(n_proj) / t / 1e9


def emit(name: str, us_per_call: float, derived: str):
    """The harness CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")
