"""Streaming-ingestion benchmark: a simulated scanner drives online
reconstruction and we measure how much back-projection wall hides
behind acquisition.

The claim under test (ISSUE 8, the iFDK overlap argument): when
projections arrive over a scan of duration T_acq and each view-chunk
folds the moment it completes, the time from the LAST view's arrival to
the finished volume (the "tail") is a small fraction of the offline
reconstruct wall — acquisition time stops being dead time.

Rows:
  * ``stream/offline_wall`` — the same executor's offline
    ``reconstruct`` (the baseline everything is relative to; also the
    bit-parity oracle).
  * ``stream/tail`` — last-view-to-volume time of the streamed run,
    with ``tail_over_offline`` and the executor's ``hidden_fraction``
    (share of busy compute that overlapped acquisition).
  * ``stream/service_tail`` — the same scenario through
    ``ReconService.open_stream`` (the session layer adds the stream
    worker + former hop; its tail must stay in the same regime).

Acceptance (printed OK/FAIL): tail <= 0.3x the offline wall, hidden
fraction >= 0.7 — the ISSUE 8 bars. The simulated frame interval is
``pace``x the offline per-view cost (default 1.5: acquisition slightly
slower than reconstruction, the regime where full overlap is possible;
``--pace`` explores faster/slower scanners).

    PYTHONPATH=src python -m benchmarks.bench_stream [--pace 1.5]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax.numpy as jnp

from repro.core import standard_geometry
from repro.runtime.executor import PlanExecutor, ProgramCache
from repro.runtime.planner import plan_reconstruction
from repro.runtime.service import ReconService

from . import common


def _projs(geom, seed=0):
    rng = np.random.RandomState(seed)
    return rng.rand(geom.n_proj, geom.nh, geom.nw).astype(np.float32)


def _feed(push, projs, frame_dt: float) -> None:
    """Deliver one view every ``frame_dt`` seconds (the scanner)."""
    for v in range(projs.shape[0]):
        if frame_dt:
            time.sleep(frame_dt)
        push(projs[v], v)


def run(n: int = 24, n_det: int = 32, n_proj: int = 16, nb: int = 4,
        pace: float = 1.5, trials: int = 3):
    geom = standard_geometry(n=n, n_det=n_det, n_proj=n_proj)
    projs = _projs(geom)
    # the streaming grain: finer chunks than the offline default so the
    # LAST chunk's fold (which can never start before the last view
    # arrives and therefore IS the tail floor) stays a small slice of
    # the total compute — 8 chunks at the smoke size
    snb = max(2, nb // 2)
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=snb,
                               proj_batch=snb, out="host",
                               ingest="stream")
    cache = ProgramCache()
    ex = PlanExecutor(geom, plan, cache=cache, pipeline="async")

    # offline baseline on the SAME executor: warms every chunk program
    # the streamed run reuses, and is the bit-parity oracle
    jprojs = jnp.asarray(projs)
    ref = np.asarray(ex.reconstruct(jprojs))
    offline = common.time_fn(lambda: ex.reconstruct(jprojs))
    common.emit("stream/offline_wall", offline * 1e6,
                f"chunks={len(plan.chunks)} chunk_size={plan.chunk_size}")

    # simulated scanner: one view every pace * (offline/n_proj) seconds;
    # best of ``trials`` runs (single-run tails at ms scale are noisy)
    frame_dt = pace * offline / n_proj
    tail, rep = None, None
    for _ in range(max(1, trials)):
        se = ex.open_stream()
        _feed(lambda v, i: se.push(v, start=i), projs, frame_dt)
        t_last = time.perf_counter()
        vol = se.close()
        t = time.perf_counter() - t_last
        assert np.array_equal(np.asarray(vol), ref), \
            "streamed volume diverged from offline reconstruct"
        if tail is None or t < tail:
            tail, rep = t, se.report
    ratio = tail / offline
    common.emit("stream/tail", tail * 1e6,
                f"tail_over_offline={ratio:.3f}x "
                f"hidden={rep.hidden_fraction:.3f} "
                f"acquire_ms={rep.acquire_s * 1e3:.1f}")
    ok = ratio <= 0.3 and rep.hidden_fraction >= 0.7
    print(f"# stream tail {tail * 1e3:.1f} ms vs offline "
          f"{offline * 1e3:.1f} ms -> {ratio:.3f}x, hidden "
          f"{rep.hidden_fraction:.2f} "
          f"({'OK' if ok else 'FAIL'}: bars 0.3x / 0.7)")

    # the same scanner through the service session layer
    svc = ReconService(max_inflight=1, cache=cache)
    try:
        stail, srep, svol = None, None, None
        for _ in range(max(1, trials)):
            sess = svc.open_stream(geom, nb=snb, proj_batch=snb,
                                   out="host")
            _feed(lambda v, i: sess.push(v, start=i), projs, frame_dt)
            t_last = time.perf_counter()
            svol = sess.close()
            t = time.perf_counter() - t_last
            if stail is None or t < stail:
                stail, srep = t, sess.report
        sref = np.asarray(PlanExecutor(
            geom, next(b for b in svc._buckets.values()
                       if b.plan.ingest == "stream").plan,
            cache=cache).reconstruct(jprojs))
        assert np.array_equal(np.asarray(svol), sref), \
            "service-streamed volume diverged from offline reconstruct"
        common.emit("stream/service_tail", stail * 1e6,
                    f"tail_over_offline={stail / offline:.3f}x "
                    f"hidden={srep.hidden_fraction:.3f}")
        print(f"# service stream tail {stail * 1e3:.1f} ms "
              f"({stail / offline:.3f}x offline), hidden "
              f"{srep.hidden_fraction:.2f}")
    finally:
        svc.close()
    return ratio


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pace", type=float, default=1.5,
                    help="frame interval as a multiple of the offline "
                         "per-view reconstruct cost (default 1.5)")
    args = ap.parse_args(argv)
    common.reset_records()
    run(pace=args.pace)


if __name__ == "__main__":
    main()
