"""Tiled streaming engine: tile-shape sweep vs the untiled variants.

What this measures (and what the paper predicts, §3.1 + Treibig et al.'s
blocking): the tiled engine trades per-call dispatch overhead for an
O(tile) working set. On problems that FIT in memory the untiled call is
the roofline — the sweep quantifies the tiling tax as a function of tile
shape, and reports the modeled working-set bytes per tile so the
crossover (problems whose untiled temporaries exceed device memory and
simply cannot run) is visible in the same table. Full-Z tiles keep the
O3 symmetry free (mirror-paired slabs recover it otherwise); the sweep
includes both, plus the memory-budget auto-picker.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import projection_matrices, standard_geometry, \
    transpose_projections
from repro.core.tiling import tile_working_set_bytes
from repro.core.variants import get_variant
from repro.runtime.engine import TiledReconstructor

from .common import emit, gups, time_fn

VARIANT = "algorithm1_mp"


def run(n: int = 48, n_det: int = 64, n_proj: int = 32, nb: int = 8):
    geom = standard_geometry(n=n, n_det=n_det, n_proj=n_proj)
    rng = np.random.RandomState(0)
    img = jnp.asarray(rng.rand(n_proj, geom.nh,
                               geom.nw).astype(np.float32))
    img_t = transpose_projections(img)
    mats = projection_matrices(geom)
    shape = geom.volume_shape_xyz

    # untiled reference: one variant call over the full volume
    fn = get_variant(VARIANT)
    t_ref = time_fn(lambda: fn(img_t, mats, shape, nb=nb))
    ws_ref = tile_working_set_bytes(shape, (geom.nw, geom.nh), nb=nb)
    emit(f"tiled/untiled_{VARIANT}", t_ref * 1e6,
         f"gups={gups(geom, t_ref):.3f} ws_mib={ws_ref / 2**20:.1f}")

    # tile-shape sweep: full-Z (symmetry free) and slabbed (mirror pairs)
    tiles = [(n, n, n),              # degenerate: 1 tile == untiled path
             (n // 2, n // 2, n),    # 4 full-Z tiles
             (n // 4, n // 4, n),    # 16 full-Z tiles
             (n, n, n // 4),         # Z-slabs only (paired schedule)
             (n // 2, n // 2, n // 4),
             (n // 3 + 1, n // 3 + 1, n // 3)]  # non-divisible edges
    for tile in tiles:
        eng = TiledReconstructor(geom, VARIANT, tile_shape=tile, nb=nb)
        t = time_fn(lambda e=eng: e.backproject(img_t, mats))
        emit(f"tiled/{VARIANT}_t{tile[0]}x{tile[1]}x{tile[2]}", t * 1e6,
             f"gups={gups(geom, t):.3f} tax={t / t_ref:.2f}x "
             f"ws_mib={eng.working_set_bytes / 2**20:.1f} "
             f"steps={len(eng.recon_plan.steps)} "
             f"programs={len(eng.recon_plan.program_keys)}")

    # streamed filtering: chunked FDK (filter fused into the chunk
    # pipeline) vs the whole-set filter — same tiles. The step-major
    # schedule (default; device-resident scanned accumulators, one host
    # crossing per step) keeps the PR-2 row names so the trajectory diff
    # tracks it; the chunk-major rows quantify what the inversion buys
    # at the same sizes (proj_batch = nb forces n_proj/nb >= 4 chunks).
    raw = jnp.asarray(rng.rand(n_proj, geom.nh, geom.nw).astype(np.float32))
    for pb in (None, nb):
        tile = (n // 2, n // 2, n)
        eng_c = TiledReconstructor(geom, VARIANT, tile_shape=tile, nb=nb,
                                   proj_batch=pb, schedule="chunk")
        t_c = time_fn(lambda: eng_c.reconstruct(raw))
        eng_s = TiledReconstructor(geom, VARIANT, tile_shape=tile, nb=nb,
                                   proj_batch=pb)
        t_s = time_fn(lambda: eng_s.reconstruct(raw))
        n_chunks = len(eng_s.recon_plan.chunks)
        streamed = int(eng_s.recon_plan.streams_projections)
        emit(f"tiled/reconstruct_pb{pb or 'all'}_chunkmajor", t_c * 1e6,
             f"gups={gups(geom, t_c):.3f} chunks={n_chunks} "
             f"streamed={streamed}")
        emit(f"tiled/reconstruct_pb{pb or 'all'}", t_s * 1e6,
             f"gups={gups(geom, t_s):.3f} chunks={n_chunks} "
             f"streamed={streamed} step_vs_chunk={t_s / t_c:.2f}x")

    # auto-picker: half / quarter of the untiled working set
    for frac in (2, 4):
        budget = max(1, ws_ref // frac)
        eng = TiledReconstructor(geom, VARIANT, memory_budget=budget,
                                 nb=nb)
        t = time_fn(lambda e=eng: e.backproject(img_t, mats))
        ti, tj, tk = eng.tile_shape
        emit(f"tiled/{VARIANT}_budget_ws/{frac}", t * 1e6,
             f"gups={gups(geom, t):.3f} tax={t / t_ref:.2f}x "
             f"picked={ti}x{tj}x{tk} "
             f"ws_mib={eng.working_set_bytes / 2**20:.1f}")


def main():
    run()


if __name__ == "__main__":
    main()
