"""Iterative-solver benchmark: warm amortized per-iteration wall.

Measures the two claims the solver subsystem (``runtime/solvers.py``)
makes:

  1. **warm iterations compile nothing** — iteration 1 of a solve pays
     every jit compile the loop needs (scan programs, forward programs,
     normalizers); iterations 2..N dispatch cached executables. Emitted
     per method as ``solvers/<method>_iter1`` (first-iteration wall,
     compile included) vs ``solvers/<method>_warm`` (amortized
     per-iteration wall of a warm multi-iteration solve) with the
     warm/iter1 ratio and the ``SolveReport`` compile split
     (``compiles_iter1`` / ``compiles_warm`` — the latter must be 0,
     and the row asserts it).
  2. **bf16 per-iteration wall vs f32** — the ``precision="bf16"``
     planner axis re-keys every program at reduced precision; emitted
     as ``solvers/sart_bf16_warm`` with the bf16/f32 warm ratio.

    PYTHONPATH=src python -m benchmarks.bench_solvers
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax.numpy as jnp

from repro.core import standard_geometry
from repro.core.forward import forward_project
from repro.core.phantom import shepp_logan_3d
from repro.runtime.executor import ProgramCache
from repro.runtime.solvers import SOLVERS, solve

from . import common

#: iterations per timed solve — the amortization window
WARM_ITERS = 4


def _setup(n: int, n_det: int, n_proj: int):
    geom = standard_geometry(n=n, n_det=n_det, n_proj=n_proj)
    phantom = jnp.asarray(shepp_logan_3d(n))
    projs = forward_project(phantom, geom, oversample=1.0)
    return geom, projs


def _solve_kw(method: str, nb: int) -> dict:
    kw = dict(oversample=1.0, nb=nb)
    if method == "os_sart":
        kw["proj_batch"] = 4
    return kw


def run(n: int = 24, n_det: int = 32, n_proj: int = 16, nb: int = 4):
    geom, projs = _setup(n, n_det, n_proj)
    t_f32_warm = {}
    for method in SOLVERS:
        kw = _solve_kw(method, nb)
        cache = ProgramCache()
        t0 = time.perf_counter()
        _, rep1 = solve(projs, geom, method, n_iters=1, cache=cache, **kw)
        t_iter1 = time.perf_counter() - t0
        assert rep1.compiles_warm == 0, (method, rep1)

        def timed():
            return solve(projs, geom, method, n_iters=WARM_ITERS,
                         cache=cache, **kw)[0]
        t_warm = common.time_fn(timed) / WARM_ITERS
        t_f32_warm[method] = t_warm
        common.emit(f"solvers/{method}_iter1", t_iter1 * 1e6,
                    f"compiles={rep1.compiles_iter1}")
        common.emit(f"solvers/{method}_warm", t_warm * 1e6,
                    f"gups={common.gups(geom, t_warm):.3f} "
                    f"vs_iter1={t_warm / t_iter1:.2f}x compiles_warm=0")

    # bf16 axis on the cheapest loop: amortized warm wall vs f32
    cache = ProgramCache()
    kw = dict(_solve_kw("sart", nb), precision="bf16")
    solve(projs, geom, "sart", n_iters=1, cache=cache, **kw)   # compile

    def timed_bf16():
        return solve(projs, geom, "sart", n_iters=WARM_ITERS,
                     cache=cache, **kw)[0]
    t_bf16 = common.time_fn(timed_bf16) / WARM_ITERS
    common.emit("solvers/sart_bf16_warm", t_bf16 * 1e6,
                f"gups={common.gups(geom, t_bf16):.3f} "
                f"vs_f32={t_bf16 / t_f32_warm['sart']:.2f}x")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--n-det", type=int, default=32)
    ap.add_argument("--n-proj", type=int, default=16)
    ap.add_argument("--nb", type=int, default=4)
    args = ap.parse_args(argv)
    run(n=args.n, n_det=args.n_det, n_proj=args.n_proj, nb=args.nb)


if __name__ == "__main__":
    main()
