"""Fleet scaling benchmark: single device vs the sharded step schedule.

Measures what ``PlanExecutor.execute_fleet`` buys on N forced XLA host
devices (``--xla_force_host_platform_device_count``): the step-major
schedule is LPT-partitioned into per-device queues, each device runs the
shared origin-traced fleet program over its steps, and the host volume
accumulates the disjoint boxes.

The measurement runs in a SUBPROCESS because the device count must be
fixed before jax initializes — the launching process (and anything it
imported) keeps the default single device. Emitted rows:

  fleet/single_device     the plain step-major walk (the baseline)
  fleet/fleet<N>dev       the same plan through execute_fleet
  fleet/failover          fleet with one device's steps forcibly
                          failed — the price of re-running them

Forced host devices SHARE the machine's cores, so the fleet "speedup"
on a CI box is a scheduling-overhead measurement, not a scaling claim —
the number that matters is that fleet wall stays within ~2x of single
(threads + retries are cheap), while real multi-socket hardware shards
actual compute. Never a gating number (the multidevice CI lane runs it
``|| warn``).

    PYTHONPATH=src python -m benchmarks.bench_fleet [--devices 8]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from . import common

_SCRIPT = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + sys.argv[1])
sys.path.insert(0, "src")
import numpy as np
import jax, jax.numpy as jnp
import time

from repro.core import standard_geometry
from repro.core.fdk import _build_plan
from repro.runtime.executor import FleetConfig, PlanExecutor

n = int(sys.argv[2])
geom = standard_geometry(n=n, n_det=max(24, 3 * n // 2), n_proj=16)
rng = np.random.RandomState(0)
projs = jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                             geom.nw).astype(np.float32))
kw = dict(nb=8, interpret=True, tiling=(n // 4, n // 4, geom.nz),
          memory_budget=None, proj_batch=8, out="host", schedule="step")
plan = _build_plan(geom, "algorithm1_mp", **kw)

def timed(ex):
    ex.warm()
    ex.reconstruct(projs)                       # per-device compiles
    t0 = time.perf_counter()
    ex.reconstruct(projs)
    return time.perf_counter() - t0

out = {"n_devices": len(jax.local_devices()), "n_steps": len(plan.steps)}
out["single_s"] = timed(PlanExecutor(geom, plan))

ex = PlanExecutor(geom, plan, fleet=FleetConfig())
out["fleet_s"] = timed(ex)
rep = ex.last_fleet_report
out["steps_by_device"] = list(rep.steps_by_device)

def fail_last(device, step):
    if device == out["n_devices"] - 1:
        raise RuntimeError("injected fault")

ex_fo = PlanExecutor(geom, plan, fleet=FleetConfig(step_hook=fail_last))
out["failover_s"] = timed(ex_fo)
out["failover_retried"] = ex_fo.last_fleet_report.retried

print("RESULT:" + json.dumps(out))
"""


def run(devices: int = 8, n: int = 48):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, str(devices), str(n)],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError(f"fleet bench subprocess failed:\n"
                           f"{proc.stderr[-3000:]}")
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT:")][-1]
    r = json.loads(line[len("RESULT:"):])

    ratio = r["fleet_s"] / r["single_s"]
    common.emit("fleet/single_device", r["single_s"] * 1e6,
                f"steps={r['n_steps']}")
    common.emit(f"fleet/fleet{r['n_devices']}dev", r["fleet_s"] * 1e6,
                f"fleet_over_single={ratio:.2f}x")
    common.emit("fleet/failover", r["failover_s"] * 1e6,
                f"retried={r['failover_retried']} "
                f"over_fleet={r['failover_s'] / r['fleet_s']:.2f}x")
    print(f"# {r['n_steps']} steps over {r['n_devices']} forced host "
          f"devices: {r['steps_by_device']}")
    print(f"# fleet/single = {ratio:.2f}x on SHARED cores — overhead "
          f"measurement, not a scaling claim (see module docstring)")
    return r


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--n", type=int, default=48,
                    help="cubic volume edge (default 48)")
    args = ap.parse_args(argv)
    run(devices=args.devices, n=args.n)


if __name__ == "__main__":
    main()
