"""Paper Fig. 7/8 analogue: the optimization-ladder variants (Table 2).

Two views per variant:
  * wall-clock on this container's 1-core XLA-CPU backend (CAVEAT: the
    backend auto-fuses the baseline's gathers and lowers take_along_axis
    slowly — single-core wall time does NOT reproduce the paper's
    multi-core vectorization story and is reported only for
    completeness);
  * structural HLO cost (loop-aware flops / boundary bytes) — this is
    where the paper's ALGORITHMIC claims live and are checked:
    share+symmetry cut the projection dot-work ~5/6 (paper §3.1.2) and
    batching follows the (4 + 1/nb) memory model (paper §3.1.3).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import projection_matrices, standard_geometry, \
    transpose_projections
from repro.core.variants import VARIANTS, get_variant
from repro.launch import hlo_cost

from .common import emit, gups, time_fn

# variants timed on CPU (pure-JAX ladder; Pallas = interpret-only here)
TIMED = ["baseline", "transpose_mp", "share_mp", "symmetry_mp",
         "subline_mp", "algorithm1_mp"]


def run(n: int = 48, n_det: int = 64, n_proj: int = 32, nb: int = 8):
    geom = standard_geometry(n=n, n_det=n_det, n_proj=n_proj)
    rng = np.random.RandomState(0)
    img = jnp.asarray(rng.rand(n_proj, geom.nh, geom.nw).astype(np.float32))
    img_t = transpose_projections(img)
    mats = projection_matrices(geom)
    shape = geom.volume_shape_xyz

    results = {}
    base_t = None
    base_flops = None
    for name in TIMED:
        fn = get_variant(name)
        t = time_fn(lambda: fn(img_t, mats, shape, nb=nb))
        compiled = jax.jit(
            lambda i, m: fn(i, m, shape, nb=nb)).lower(
                img_t, mats).compile()
        la = hlo_cost.analyze(compiled.as_text())
        results[name] = (t, la)
        if name == "baseline":
            base_t, base_flops = t, la["flops"]
        emit(f"variants/{name}", t * 1e6,
             f"wall_speedup={base_t / t:.2f}x gups={gups(geom, t):.3f} "
             f"hlo_flops={la['flops']:.3e} "
             f"flops_vs_base={la['flops'] / base_flops:.2f} "
             f"hlo_bytes={la['bytes']:.3e}")
    return results


def main():
    run()


if __name__ == "__main__":
    main()
