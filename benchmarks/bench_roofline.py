"""Paper Fig. 10 analogue: roofline placement of the top kernel.

The paper uses Intel Advisor on dual Gold-6140; here the roofline terms
come from the dry-run's compiled artifacts (launch/roofline.py, TPU v5e
constants) plus an analytic arithmetic-intensity model of the kernels:

    AI(subline)  ~ flops / bytes
      flops/update ~ 8   (two mixes + weight + accumulate)
      bytes/update ~ (4 + 1/nb)*4 / reuse  — the paper's N_mem model

which places the kernel in the bandwidth-bound region, matching the
paper's observation that the optimized kernel sits between the L2 and L3
bandwidth ceilings on CPUs.
"""

from __future__ import annotations

import glob
import json

from .common import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def run():
    # analytic AI of the kernel family (per voxel update)
    for name, flops_per_update, bytes_per_update in [
        ("baseline", 18.0, (4 + 1.0) * 4),       # nb=1: vol rw each proj
        ("subline_nb8", 8.0, (4 + 1 / 8) * 4),
        ("subline_nb32", 8.0, (4 + 1 / 32) * 4),
        ("pallas_output_stationary", 8.0, 4.0 * 4),  # vol written once
    ]:
        ai = flops_per_update / bytes_per_update
        ridge = PEAK_FLOPS / HBM_BW
        bound = "memory" if ai < ridge else "compute"
        attainable = min(PEAK_FLOPS, ai * HBM_BW)
        emit(f"roofline/{name}", 0.0,
             f"AI={ai:.3f} bound={bound} "
             f"attainable_TFLOPs={attainable/1e12:.2f}")

    # measured placement from dry-run artifacts
    for fn in sorted(glob.glob("artifacts/dryrun/ct-backproject__*"
                               "__pod16x16.json")):
        with open(fn) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        f_dev = rec["cost"]["flops_per_device"]
        b_dev = rec["cost"]["bytes_per_device"]
        ai = f_dev / max(b_dev, 1.0)
        emit(f"roofline/dryrun_{rec['shape']}", 0.0,
             f"AI={ai:.3f} flops_dev={f_dev:.2e} bytes_dev={b_dev:.2e}")


def main():
    run()


if __name__ == "__main__":
    main()
