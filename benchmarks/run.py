# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (benchmarks/common.py emit()).
#
#   Fig. 6  -> bench_batch        (nb sweep + N_mem model)
#   Fig. 7/8-> bench_variants     (optimization-ladder speedups)
#   Fig. 9  -> bench_scaling      (work scaling + dry-run device scaling)
#   Fig. 10 -> bench_roofline     (AI placement, analytic + dry-run)
#   Fig. 11 -> bench_crossplatform(bandwidth-model comparison)
#   Table 3 -> bench_problems     (P1.. problem matrix, CPU-scaled)
#   (ours)  -> bench_tiled        (tiled engine tile-shape sweep)
#   (ours)  -> bench_lm_substrate (assigned-arch substrate latencies)

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (
        bench_batch,
        bench_crossplatform,
        bench_lm_substrate,
        bench_problems,
        bench_roofline,
        bench_scaling,
        bench_tiled,
        bench_variants,
    )

    suites = [
        ("variants(Fig7/8)", bench_variants.main),
        ("batch(Fig6)", bench_batch.main),
        ("problems(Table3)", bench_problems.main),
        ("scaling(Fig9)", bench_scaling.main),
        ("roofline(Fig10)", bench_roofline.main),
        ("crossplatform(Fig11)", bench_crossplatform.main),
        ("tiled(engine)", bench_tiled.main),
        ("lm_substrate", bench_lm_substrate.main),
    ]
    failed = 0
    for name, fn in suites:
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception:  # noqa: BLE001 — report and continue
            failed += 1
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
