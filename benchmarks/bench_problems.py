"""Paper Table 3 analogue: the P1..P10 problem-size matrix, CPU-scaled.

Full P-sizes do not fit a 1-core CPU budget; each P is scaled by 1/8 per
axis (shape RATIOS preserved: detector/volume/projection proportions are
what drive the locality behaviour the paper studies). The full-size cells
are exercised structurally by the dry-run (ct-backproject arch).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.configs.ct_paper import PROBLEMS
from repro.core import projection_matrices, standard_geometry, \
    transpose_projections
from repro.core.backproject import bp_subline_symmetry_batch

from .common import emit, gups, time_fn

SCALE = 8


def run(scale: int = SCALE, max_problems: int = 6):
    rows = {}
    for prob in PROBLEMS[:max_problems]:
        n = max(8, prob.vol // scale)
        det = max(8, prob.det // scale)
        np_ = max(4, prob.n_proj // scale)
        geom = standard_geometry(n=n, n_det=det, n_proj=np_)
        rng = np.random.RandomState(0)
        img = jnp.asarray(rng.rand(np_, geom.nh, geom.nw)
                          .astype(np.float32))
        img_t = transpose_projections(img)
        mats = projection_matrices(geom)
        nb = min(8, np_)
        t = time_fn(lambda: bp_subline_symmetry_batch(
            img_t, mats, geom.volume_shape_xyz, nb=nb))
        emit(f"problems/{prob.label}(1/{scale})", t * 1e6,
             f"gups={gups(geom, t):.3f} "
             f"updates={geom.voxel_updates():.2e}")
        rows[prob.label] = t
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
