"""Paper Fig. 9 analogue: scaling with parallel width.

The paper scales OpenMP threads 1..128 on multicore CPUs. This container
has one core, so hardware thread scaling is not measurable; instead we
measure the structural analogue the TPU mapping relies on — work-scaling
across the voxel-line grid (j-block width), which is the unit the Pallas
kernel parallelizes over — and report the dry-run-derived device-scaling
(256 -> 512 chips) from the artifacts when present.
"""

from __future__ import annotations

import glob
import json
import os

import numpy as np

import jax.numpy as jnp

from repro.core import projection_matrices, standard_geometry, \
    transpose_projections
from repro.core.backproject import bp_subline_symmetry_batch

from .common import emit, time_fn


def run():
    geom = standard_geometry(n=48, n_det=64, n_proj=16)
    rng = np.random.RandomState(0)
    img = jnp.asarray(rng.rand(16, geom.nh, geom.nw).astype(np.float32))
    img_t = transpose_projections(img)
    mats = projection_matrices(geom)

    # work scaling: time vs number of voxel lines processed
    base = None
    for frac in (1, 2, 4):
        nj = geom.ny // frac
        t = time_fn(lambda nj=nj: bp_subline_symmetry_batch(
            img_t, mats, (geom.nx, nj, geom.nz), nb=8))
        if base is None:
            base = t
        emit(f"scaling/lines_1_over_{frac}", t * 1e6,
             f"work_frac={1/frac:.2f} time_frac={t/base:.2f}")

    # device scaling from dry-run artifacts (single- vs multi-pod)
    for fn in sorted(glob.glob("artifacts/dryrun/"
                               "ct-backproject__P5__*.json")):
        with open(fn) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        emit(f"scaling/dryrun_{rec['mesh']}", 0.0,
             f"chips={rec['chips']} "
             f"flops_dev={rec['cost']['flops_per_device']:.2e} "
             f"coll_MB={rec['collectives']['total_bytes']/1e6:.1f}")


def main():
    run()


if __name__ == "__main__":
    main()
