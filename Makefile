# Convenience targets; tier1 is the CI gate (ROADMAP.md).
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: tier1 tier1-slow collect-smoke bench-tiled bench-smoke

tier1:
	tests/run_tier1.sh

tier1-slow:                    # opt-in heavyweight Pallas sweeps
	$(PY) -m pytest -q -m slow

collect-smoke:                 # collection must never silently fail
	$(PY) -m pytest -q --co -m "" >/dev/null

bench-tiled:
	$(PY) -m benchmarks.bench_tiled

bench-smoke:                   # perf-trajectory snapshot (non-gating)
	$(PY) -m benchmarks.bench_smoke --json BENCH_PR3.json \
		--diff auto --warn-regress 0.25
