# Convenience targets; tier1 is the CI gate (ROADMAP.md).
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: tier1 tier1-fast tier1-slow collect-smoke bench-tiled \
	bench-smoke bench-service bench-autotune bench-fleet bench-stream \
	bench-solvers bench-telemetry test-fleet serve trace

tier1:
	tests/run_tier1.sh

tier1-fast:                    # stages 1+2 only (per-PR CI signal);
	TIER1_FAST=1 tests/run_tier1.sh    # nightly CI runs the full gate

tier1-slow:                    # opt-in heavyweight Pallas sweeps
	$(PY) -m pytest -q -m slow

collect-smoke:                 # collection must never silently fail
	$(PY) -m pytest -q --co -m "" >/dev/null

bench-tiled:
	$(PY) -m benchmarks.bench_tiled

bench-service:                 # serving layer: cold/warm + overlap
	$(PY) -m benchmarks.bench_service

bench-autotune:                # measured per-hardware config search
	$(PY) -m benchmarks.bench_autotune

bench-fleet:                   # single vs fleet (subprocess: 8 devices)
	$(PY) -m benchmarks.bench_fleet

bench-stream:                  # online ingestion: tail + hidden fraction
	$(PY) -m benchmarks.bench_stream

bench-solvers:                 # iterative loops: warm us/iter + bf16 axis
	$(PY) -m benchmarks.bench_solvers

bench-telemetry:               # overhead guard: disabled spans < 2% wall
	$(PY) -m benchmarks.bench_telemetry

test-fleet:                    # the multidevice CI lane, locally
	$(PY) -m pytest -q tests/test_fleet.py tests/test_distributed.py \
		tests/test_fault_tolerance.py

bench-smoke:                   # perf-trajectory snapshot (non-gating);
	$(PY) -m benchmarks.bench_smoke --json auto \
		--diff auto --warn-regress 0.25    # auto = next BENCH_PR<N>.json

trace:                         # Perfetto-loadable trace of a service
	$(PY) examples/trace_recon.py  # burst (batched + streamed); writes
# recon_trace.json — open at https://ui.perfetto.dev (docs/ARCHITECTURE.md
# "Stage 10 — observe" explains the span taxonomy and thread lanes)

serve:                         # documented ReconService entrypoint:
	scripts/serve_env.sh $(PY) examples/serve_recon.py  # tcmalloc,
# quiet logs, f32, optional RECON_DEVICES=N fleet split (scripts/serve_env.sh)
