"""Encoder-decoder transformer (SeamlessM4T-medium backbone).

Per the assigned pool, the audio frontend is a STUB: the encoder consumes
precomputed frame embeddings (B, S_enc, d_model) from input_specs. The
text decoder is a standard causal stack with cross-attention; decode
serves with a self-attention KV cache plus precomputed cross K/V.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import pshint
from .layers import (
    KeyGen, apply_norm, cross_entropy, embed, embed_init, init_mlp,
    init_norm, mlp, rope_freqs, unembed,
 remat_policy,
)
from .transformer import stack_layers


def _init_enc_layer(kg: KeyGen, cfg) -> dict:
    return {
        "ln_attn": init_norm(cfg.norm, cfg.d_model, cfg.np_dtype),
        "ln_mlp": init_norm(cfg.norm, cfg.d_model, cfg.np_dtype),
        "attn": attn.init_gqa(kg, cfg),
        "mlp": init_mlp(kg, cfg.d_model, cfg.d_ff, cfg.np_dtype,
                        cfg.activation),
    }


def _init_dec_layer(kg: KeyGen, cfg) -> dict:
    p = _init_enc_layer(kg, cfg)
    p["ln_cross"] = init_norm(cfg.norm, cfg.d_model, cfg.np_dtype)
    p["cross"] = attn.init_cross(kg, cfg)
    return p


def init_encdec(kg: KeyGen, cfg) -> dict:
    return {
        "embed": embed_init(kg(), cfg.vocab_size, cfg.d_model, cfg.np_dtype),
        "enc_layers": stack_layers(
            [_init_enc_layer(kg, cfg) for _ in range(cfg.n_enc_layers)]),
        "dec_layers": stack_layers(
            [_init_dec_layer(kg, cfg) for _ in range(cfg.n_layers)]),
        "ln_enc": init_norm(cfg.norm, cfg.d_model, cfg.np_dtype),
        "ln_dec": init_norm(cfg.norm, cfg.d_model, cfg.np_dtype),
        "unembed": (jax.random.normal(kg(), (cfg.d_model, cfg.vocab_size))
                    * 0.02).astype(cfg.np_dtype),
    }


def encode(params: dict, frames: jnp.ndarray, cfg, *,
           for_train: bool = False):
    """frames: (B, S_enc, d_model) stub embeddings -> encoder output."""
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    inv_freq = rope_freqs(cfg.head_dim_, cfg.rope_theta)

    def body(h, lp):
        hn = apply_norm(cfg.norm, lp["ln_attn"], h)
        q, k, v = attn.gqa_qkv(lp["attn"], hn, cfg, positions, inv_freq)
        o = attn.flash_attention(q, k, v, causal=False,
                                 chunk=cfg.attn_chunk)
        h = h + o.reshape(B, S, -1) @ lp["attn"]["wo"]
        hn = apply_norm(cfg.norm, lp["ln_mlp"], h)
        h = h + mlp(lp["mlp"], hn, cfg.activation)
        return h, None

    fn = body
    if cfg.remat and for_train:
        fn = jax.checkpoint(body,
                            policy=remat_policy(cfg))
    h, _ = jax.lax.scan(fn, frames.astype(cfg.np_dtype),
                        params["enc_layers"])
    return apply_norm(cfg.norm, params["ln_enc"], h)


def decode_seq(params: dict, tokens: jnp.ndarray, enc_out: jnp.ndarray,
               cfg, *, for_train: bool = False, collect_cache: bool = False,
               return_hidden: bool = False):
    """Teacher-forced decoder pass. tokens (B, S_dec)."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    inv_freq = rope_freqs(cfg.head_dim_, cfg.rope_theta)

    def body(h, lp):
        hn = apply_norm(cfg.norm, lp["ln_attn"], h)
        out, cache = attn.gqa_prefill(lp["attn"], hn, cfg, positions,
                                      inv_freq)
        h = h + out
        hn = apply_norm(cfg.norm, lp["ln_cross"], h)
        ck, cv = attn.cross_kv(lp["cross"], enc_out, cfg)
        h = h + attn.cross_attention(lp["cross"], hn, ck, cv, cfg)
        hn = apply_norm(cfg.norm, lp["ln_mlp"], h)
        h = h + mlp(lp["mlp"], hn, cfg.activation)
        h = pshint.constrain(h, "residual")
        ys = (cache, (ck, cv)) if collect_cache else None
        return h, ys

    fn = body
    if cfg.remat and for_train:
        fn = jax.checkpoint(body,
                            policy=remat_policy(cfg))
    h, ys = jax.lax.scan(fn, x, params["dec_layers"])
    h = apply_norm(cfg.norm, params["ln_dec"], h)
    if return_hidden:
        return h, ys
    logits = unembed(params["unembed"], h, tied=False)
    return logits, ys


def encdec_loss(params: dict, batch: dict, cfg) -> jnp.ndarray:
    from .layers import chunked_cross_entropy
    enc_out = encode(params, batch["frames"], cfg, for_train=True)
    h, _ = decode_seq(params, batch["tokens"], enc_out, cfg,
                      for_train=True, return_hidden=True)
    return chunked_cross_entropy(h, params["unembed"], batch["labels"],
                                 tied=False)


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def encdec_prefill(params: dict, frames: jnp.ndarray, tokens: jnp.ndarray,
                   cfg, max_len: int):
    """Encode + teacher-forced decoder prefill; returns decode state."""
    enc_out = encode(params, frames, cfg)
    logits, ys = decode_seq(params, tokens, enc_out, cfg,
                            collect_cache=True)
    (k, v), (ck, cv) = ys
    S = tokens.shape[1]
    pad = max_len - S
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k, "v": v, "ck": ck, "cv": cv}
    return logits[:, -1:], cache, jnp.int32(S)


def encdec_decode_step(params: dict, cache: dict, token: jnp.ndarray,
                       pos, cfg):
    B = token.shape[0]
    x = embed(params["embed"], token)
    inv_freq = rope_freqs(cfg.head_dim_, cfg.rope_theta)

    def body(h, xs):
        lp, kc, vc, ck, cv = xs
        hn = apply_norm(cfg.norm, lp["ln_attn"], h)
        out, (k2, v2) = attn.gqa_decode(lp["attn"], hn, cfg, pos, kc, vc,
                                        inv_freq)
        h = h + out
        hn = apply_norm(cfg.norm, lp["ln_cross"], h)
        o = attn.flash_attention(
            (hn @ lp["cross"]["wq"]).reshape(B, 1, cfg.n_heads,
                                             cfg.head_dim_),
            ck, cv, causal=False, chunk=cfg.attn_chunk)
        h = h + o.reshape(B, 1, -1) @ lp["cross"]["wo"]
        hn = apply_norm(cfg.norm, lp["ln_mlp"], h)
        h = h + mlp(lp["mlp"], hn, cfg.activation)
        return h, (k2, v2)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"]))
    new_cache = dict(cache, k=k_new, v=v_new)
    x = apply_norm(cfg.norm, params["ln_dec"], x)
    logits = unembed(params["unembed"], x, tied=False)
    return logits, new_cache
