"""KV-cache structures for every attention/recurrence family.

Caches are plain pytrees with a stacked leading layer axis so the decode
layer-scan threads them as scan xs/ys. Layouts put the gathered axis
minor (O1: unit-stride minor axis — see DESIGN.md §5).

Families:
  full      (L, B, S, KVH, hd) k + v          — dense/GQA/MoE archs
  mla       (L, B, S, kv_lora) c + (L,B,S,dr) — DeepSeek-V2 latent cache
  window    (L, B, W, KVH, hd) ring buffers   — sliding-window layers
  recurrent (L, B, lru_width) h + conv tail   — RG-LRU layers
  rwkv      (L, B, H, hd, hd) S + shift state — RWKV-6
"""

from __future__ import annotations

import jax.numpy as jnp


def full_cache(n_layers, batch, max_len, n_kv, head_dim, dtype):
    shape = (n_layers, batch, max_len, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def mla_cache(n_layers, batch, max_len, kv_lora, rope_dim, dtype):
    return {
        "c": jnp.zeros((n_layers, batch, max_len, kv_lora), dtype),
        "kr": jnp.zeros((n_layers, batch, max_len, rope_dim), dtype),
    }


def window_cache(n_layers, batch, window, n_kv, head_dim, dtype):
    shape = (n_layers, batch, window, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def recurrent_state(n_layers, batch, lru_width, conv_width, dtype):
    return {
        "h": jnp.zeros((n_layers, batch, lru_width), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, conv_width - 1, lru_width),
                          dtype),
    }


def rwkv_state(n_layers, batch, n_heads, head_size, d_model, dtype):
    return {
        "S": jnp.zeros((n_layers, batch, n_heads, head_size, head_size),
                       jnp.float32),
        "x_tm": jnp.zeros((n_layers, batch, d_model), dtype),
        "x_cm": jnp.zeros((n_layers, batch, d_model), dtype),
    }
