"""Hybrid recurrent/attention assembly (RecurrentGemma-style, 1:2 pattern).

Layer pattern: repeating macro-units of (rec, rec, local-attn), each layer
being temporal-mix + MLP with pre-norm residuals. The stack is scanned
over macro-units (keeps HLO O(1) in depth despite the heterogeneous
pattern); trailing layers that do not fill a macro-unit form a second,
smaller scan over (rec,) units.

Decode state per layer: RG-LRU hidden + conv tail for "rec", a
window-sized ring-buffer KV cache for "attn" — total state is O(window),
which is what makes the long_500k cell runnable (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import pshint
from . import rglru
from .layers import (
    KeyGen, apply_norm, embed, init_mlp, init_norm, mlp, rope_freqs, unembed,
 remat_policy,
)


def _pattern(cfg):
    """Per-layer kinds for the full stack."""
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


def n_units(cfg):
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    full = cfg.n_layers // len(pat)
    trail = cfg.n_layers - full * len(pat)
    return full, trail, pat


def _init_layer(kg: KeyGen, cfg, kind: str) -> dict:
    p = {
        "ln_t": init_norm(cfg.norm, cfg.d_model, cfg.np_dtype),
        "ln_m": init_norm(cfg.norm, cfg.d_model, cfg.np_dtype),
        "mlp": init_mlp(kg, cfg.d_model, cfg.d_ff, cfg.np_dtype,
                        cfg.activation),
    }
    if kind == "rec":
        p["rec"] = rglru.init_rglru(kg, cfg)
    else:
        p["attn"] = attn.init_gqa(kg, cfg)
    return p


def init_hybrid(kg: KeyGen, cfg) -> dict:
    from .transformer import stack_layers
    full, trail, pat = n_units(cfg)
    units = []
    for _ in range(full):
        units.append({k: _init_layer(kg, cfg, kind)
                      for k, kind in zip(_unit_keys(pat), pat)})
    params = {
        "embed": (jax.random.normal(kg(), (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(cfg.np_dtype),
        "ln_f": init_norm(cfg.norm, cfg.d_model, cfg.np_dtype),
        "units": stack_layers(units),
    }
    if trail:
        tr = [{
            "layer": _init_layer(kg, cfg, pat[i % len(pat)])}
            for i in range(trail)]
        # trailing layers are all the same kind by construction (pattern
        # prefix); assert to be safe
        kinds = {pat[i % len(pat)] for i in range(trail)}
        assert len(kinds) == 1, "trailing layers must share a kind"
        params["trail"] = stack_layers(tr)
    return params


def _unit_keys(pat):
    keys = []
    counts = {}
    for kind in pat:
        counts[kind] = counts.get(kind, 0) + 1
        keys.append(f"{kind}{counts[kind]}")
    return keys


# --------------------------------------------------------------------------
# sequence mode (train / prefill)
# --------------------------------------------------------------------------

def _layer_seq(p, x, cfg, kind, positions, inv_freq, state=None,
               collect_state=False):
    h = apply_norm(cfg.norm, p["ln_t"], x)
    new_state = None
    if kind == "rec":
        out, new_state = rglru.recurrent_block_seq(
            p["rec"], h, cfg, state)
    else:
        out, (k, v) = attn.gqa_prefill(p["attn"], h, cfg, positions,
                                       inv_freq, window=cfg.window)
        if collect_state:
            # keep only the last `window` keys, layout as ring buffer
            W = cfg.window
            S = k.shape[1]
            if S >= W:
                kw, vw = k[:, S - W:], v[:, S - W:]
                # index idx holds abs pos (S-W+idx); its ring slot is
                # (S-W+idx) % W  ->  roll right by (S-W) % W.
                roll = (S - W) % W
                kw = jnp.roll(kw, roll, axis=1)
                vw = jnp.roll(vw, roll, axis=1)
            else:
                pad = W - S
                kw = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                vw = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            new_state = {"k": kw, "v": vw}
    x = x + out
    h = apply_norm(cfg.norm, p["ln_m"], x)
    x = x + mlp(p["mlp"], h, cfg.activation)
    return x, new_state


def hybrid_forward(params: dict, tokens: jnp.ndarray, cfg,
                   *, for_train: bool = False, collect_state: bool = False,
                   return_hidden: bool = False):
    B, S = tokens.shape
    full, trail, pat = n_units(cfg)
    x = embed(params["embed"], tokens) * jnp.sqrt(
        jnp.float32(cfg.d_model)).astype(cfg.np_dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    inv_freq = rope_freqs(cfg.head_dim_, cfg.rope_theta)
    keys = _unit_keys(pat)

    def unit_body(h, up):
        states = {}
        for key, kind in zip(keys, pat):
            h, st = _layer_seq(up[key], h, cfg, kind, positions, inv_freq,
                               collect_state=collect_state)
            states[key] = st
        h = pshint.constrain(h, "residual")
        return h, (states if collect_state else None)

    fn = unit_body
    if cfg.remat and for_train:
        fn = jax.checkpoint(unit_body,
                            policy=remat_policy(cfg))
    x, unit_states = jax.lax.scan(fn, x, params["units"])

    trail_states = None
    if trail:
        def trail_body(h, tp):
            h, st = _layer_seq(tp["layer"], h, cfg, pat[0], positions,
                               inv_freq, collect_state=collect_state)
            return h, (st if collect_state else None)
        x, trail_states = jax.lax.scan(trail_body, x, params["trail"])

    x = apply_norm(cfg.norm, params["ln_f"], x)
    if return_hidden:
        return x, (unit_states, trail_states)
    logits = unembed(params["embed"], x, tied=True)
    logits = 30.0 * jnp.tanh(logits / 30.0)    # gemma-style soft cap
    if collect_state:
        return logits, (unit_states, trail_states)
    return logits, jnp.float32(0.0)


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------

def init_hybrid_state(cfg, batch):
    """Decode state pytree matching the scan structure of the params."""
    full, trail, pat = n_units(cfg)
    keys = _unit_keys(pat)

    def one_layer_state(kind, n):
        if kind == "rec":
            return {
                "h": jnp.zeros((n, batch, cfg.lru_width), jnp.float32),
                "conv": jnp.zeros((n, batch, cfg.conv_width - 1,
                                   cfg.lru_width), cfg.np_dtype),
            }
        return {
            "k": jnp.zeros((n, batch, cfg.window, cfg.n_kv_heads,
                            cfg.head_dim_), cfg.np_dtype),
            "v": jnp.zeros((n, batch, cfg.window, cfg.n_kv_heads,
                            cfg.head_dim_), cfg.np_dtype),
        }

    unit_state = {k: one_layer_state(kind, full)
                  for k, kind in zip(keys, pat)}
    state = {"units": unit_state}
    if trail:
        state["trail"] = one_layer_state(pat[0], trail)
    return state


def _layer_step(p, x, cfg, kind, pos, st, inv_freq):
    h = apply_norm(cfg.norm, p["ln_t"], x)
    if kind == "rec":
        out, new_st = rglru.recurrent_block_step(p["rec"], h, cfg, st)
    else:
        out, (k2, v2) = attn.gqa_decode(p["attn"], h, cfg, pos,
                                        st["k"], st["v"], inv_freq,
                                        window=cfg.window)
        new_st = {"k": k2, "v": v2}
    x = x + out
    h = apply_norm(cfg.norm, p["ln_m"], x)
    x = x + mlp(p["mlp"], h, cfg.activation)
    return x, new_st


def hybrid_decode_step(params: dict, state: dict, token: jnp.ndarray,
                       pos, cfg):
    """token (B,1); state from init_hybrid_state. Returns (logits, state)."""
    full, trail, pat = n_units(cfg)
    keys = _unit_keys(pat)
    x = embed(params["embed"], token) * jnp.sqrt(
        jnp.float32(cfg.d_model)).astype(cfg.np_dtype)
    inv_freq = rope_freqs(cfg.head_dim_, cfg.rope_theta)

    def unit_body(h, xs):
        up, ust = xs
        new_states = {}
        for key, kind in zip(keys, pat):
            h, nst = _layer_step(up[key], h, cfg, kind, pos, ust[key],
                                 inv_freq)
            new_states[key] = nst
        return h, new_states

    x, new_unit_states = jax.lax.scan(
        unit_body, x, (params["units"], state["units"]))
    new_state = {"units": new_unit_states}

    if trail:
        def trail_body(h, xs):
            tp, tst = xs
            h, nst = _layer_step(tp["layer"], h, cfg, pat[0], pos, tst,
                                 inv_freq)
            return h, nst
        x, new_trail = jax.lax.scan(trail_body, x,
                                    (params["trail"], state["trail"]))
        new_state["trail"] = new_trail

    x = apply_norm(cfg.norm, params["ln_f"], x)
    logits = unembed(params["embed"], x, tied=True)
    return 30.0 * jnp.tanh(logits / 30.0), new_state
