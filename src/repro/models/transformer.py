"""Decoder-only transformer assembly: dense / MoE / MLA families.

Layers are stacked (leading L axis) and driven by lax.scan — compile time
and HLO size stay O(1) in depth, which is what makes the 80-95 layer
dry-run cells compile quickly. Per-layer activation checkpointing
(jax.checkpoint) is applied under cfg.remat for training.

The layer-invariant RoPE angle table is computed ONCE per step and closed
over by the scanned body (the paper's O2 hoisting discipline applied to
the LM stack — see DESIGN.md §5).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import pshint
from .layers import (
    KeyGen,
    apply_norm,
    chunked_cross_entropy,
    cross_entropy,
    dense_init,
    embed,
    embed_init,
    init_mlp,
    init_norm,
    mlp,
    rope_freqs,
    unembed,
 remat_policy,
)
from .moe import init_moe, moe_mlp


# --------------------------------------------------------------------------
# block init
# --------------------------------------------------------------------------

def init_block(kg: KeyGen, cfg, *, use_moe: bool) -> dict:
    p = {
        "ln_attn": init_norm(cfg.norm, cfg.d_model, cfg.np_dtype),
        "ln_mlp": init_norm(cfg.norm, cfg.d_model, cfg.np_dtype),
    }
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(kg, cfg)
    else:
        p["attn"] = attn.init_gqa(kg, cfg)
    if use_moe:
        p["moe"] = init_moe(kg, cfg)
    else:
        p["mlp"] = init_mlp(kg, cfg.d_model, cfg.d_ff, cfg.np_dtype,
                            cfg.activation)
    return p


def stack_layers(blocks):
    """List of per-layer param trees -> stacked tree (leading L axis)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)


def init_lm(kg: KeyGen, cfg) -> dict:
    m = cfg.moe
    n_dense_lead = m.first_dense_layers if m else 0
    n_stack = cfg.n_layers - n_dense_lead
    params = {
        "embed": embed_init(kg(), cfg.vocab_size, cfg.d_model, cfg.np_dtype),
        "ln_f": init_norm(cfg.norm, cfg.d_model, cfg.np_dtype),
        "layers": stack_layers(
            [init_block(kg, cfg, use_moe=m is not None)
             for _ in range(n_stack)]),
    }
    if n_dense_lead:
        dense_cfg_ff = m.first_dense_d_ff or cfg.d_ff
        lead = []
        for _ in range(n_dense_lead):
            p = {
                "ln_attn": init_norm(cfg.norm, cfg.d_model, cfg.np_dtype),
                "ln_mlp": init_norm(cfg.norm, cfg.d_model, cfg.np_dtype),
                "attn": (attn.init_mla(kg, cfg) if cfg.mla is not None
                         else attn.init_gqa(kg, cfg)),
                "mlp": init_mlp(kg, cfg.d_model, dense_cfg_ff,
                                cfg.np_dtype, cfg.activation),
            }
            lead.append(p)
        params["lead_layers"] = stack_layers(lead)
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(kg(), cfg.d_model, cfg.vocab_size,
                                       cfg.np_dtype, scale=0.02)
    return params


# --------------------------------------------------------------------------
# block apply (sequence mode: train / prefill)
# --------------------------------------------------------------------------

def _attn_seq(p, x, cfg, positions, inv_freq, *, collect_cache: bool):
    h = apply_norm(cfg.norm, p["ln_attn"], x)
    if cfg.mla is not None:
        out, cache = attn.mla_prefill(p["attn"], h, cfg, positions, inv_freq)
    else:
        out, cache = attn.gqa_prefill(p["attn"], h, cfg, positions, inv_freq)
    x = x + out
    return x, (cache if collect_cache else None)


def _mlp_block(p, x, cfg, *, use_moe: bool):
    h = apply_norm(cfg.norm, p["ln_mlp"], x)
    if use_moe:
        out, aux = moe_mlp(p["moe"], h, cfg)
    else:
        out, aux = mlp(p["mlp"], h, cfg.activation), jnp.float32(0.0)
    return x + out, aux


def block_seq(p, x, cfg, positions, inv_freq, *, use_moe: bool,
              collect_cache: bool):
    x, cache = _attn_seq(p, x, cfg, positions, inv_freq,
                         collect_cache=collect_cache)
    x, aux = _mlp_block(p, x, cfg, use_moe=use_moe)
    return x, aux, cache


# --------------------------------------------------------------------------
# forward over the whole stack (sequence mode)
# --------------------------------------------------------------------------

def forward_embeds(params: dict, x: jnp.ndarray, cfg, positions,
                   *, collect_cache: bool = False, for_train: bool = False):
    """Run the layer stack on embedded inputs x (B, S, d).

    Returns (hidden, aux_loss, caches|None). caches, when collected, have
    a stacked leading layer axis matching kvcache layouts.
    """
    use_moe = cfg.moe is not None
    inv_freq = rope_freqs(
        cfg.mla.qk_rope_dim if cfg.mla is not None else cfg.head_dim_,
        cfg.rope_theta)

    def layer_body(carry, lp):
        h, aux = carry
        h, aux2, cache = block_seq(lp, h, cfg, positions, inv_freq,
                                   use_moe=use_moe,
                                   collect_cache=collect_cache)
        # Sequence-parallel residual constraint (no-op without a policy).
        h = pshint.constrain(h, "residual")
        return (h, aux + aux2), cache

    fn = layer_body
    if cfg.remat and for_train:
        fn = jax.checkpoint(layer_body,
                            policy=remat_policy(cfg))

    aux0 = jnp.float32(0.0)
    # Leading dense layers (DeepSeek-V2 pattern) — plain MLP, no MoE.
    lead_caches = None
    if "lead_layers" in params:
        def lead_body(carry, lp):
            h, aux = carry
            h, c = _attn_seq(lp, h, cfg, positions, inv_freq,
                             collect_cache=collect_cache)
            h, a2 = _mlp_block(lp, h, cfg, use_moe=False)
            return (h, aux + a2), c
        lfn = lead_body
        if cfg.remat and for_train:
            lfn = jax.checkpoint(
                lead_body, policy=remat_policy(cfg))
        (x, aux0), lead_caches = jax.lax.scan(lfn, (x, aux0),
                                              params["lead_layers"])

    (x, aux), caches = jax.lax.scan(fn, (x, aux0), params["layers"])
    x = apply_norm(cfg.norm, params["ln_f"], x)
    if collect_cache:
        return x, aux, (lead_caches, caches)
    return x, aux, None


def logits_from_hidden(params: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return unembed(params["embed"], x, tied=True)
    return unembed(params["unembed"], x, tied=False)


def lm_forward(params: dict, tokens: jnp.ndarray, cfg,
               *, for_train: bool = False):
    """tokens (B, S) -> (logits (B,S,V) fp32, aux_loss)."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, aux, _ = forward_embeds(params, x, cfg, positions,
                               for_train=for_train)
    return logits_from_hidden(params, x, cfg), aux


def lm_hidden(params: dict, tokens: jnp.ndarray, cfg,
              *, for_train: bool = False):
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, aux, _ = forward_embeds(params, x, cfg, positions,
                               for_train=for_train)
    return x, aux


def lm_loss(params: dict, batch: dict, cfg) -> jnp.ndarray:
    h, aux = lm_hidden(params, batch["tokens"], cfg, for_train=True)
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    loss = chunked_cross_entropy(h, w, batch["labels"],
                                 tied=cfg.tie_embeddings)
    return loss + 0.01 * aux


# --------------------------------------------------------------------------
# prefill / decode
# --------------------------------------------------------------------------

def lm_prefill(params: dict, tokens: jnp.ndarray, cfg, max_len: int):
    """Prefill: returns (last-position logits, cache dict, pos)."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x, _, caches = forward_embeds(params, x, cfg, positions,
                                  collect_cache=True)
    lead_caches, stack_caches = caches
    cache = _caches_to_struct(cfg, stack_caches, lead_caches, B, S, max_len)
    logits = logits_from_hidden(params, x[:, -1:], cfg)
    return logits, cache, jnp.int32(S)


def _caches_to_struct(cfg, stack_caches, lead_caches, B, S, max_len):
    """Pad collected per-layer (k,v) or (c,kr) to max_len along time."""
    def pad_time(a):
        pad = max_len - a.shape[2]
        cfgd = [(0, 0)] * a.ndim
        cfgd[2] = (0, pad)
        return jnp.pad(a, cfgd)

    def cat(lead, stk):
        if lead is None:
            return stk
        return jnp.concatenate([lead, stk], axis=0)

    if cfg.mla is not None:
        c = cat(lead_caches[0] if lead_caches else None, stack_caches[0])
        kr = cat(lead_caches[1] if lead_caches else None, stack_caches[1])
        return {"c": pad_time(c), "kr": pad_time(kr)}
    k = cat(lead_caches[0] if lead_caches else None, stack_caches[0])
    v = cat(lead_caches[1] if lead_caches else None, stack_caches[1])
    return {"k": pad_time(k), "v": pad_time(v)}


def lm_decode_step(params: dict, cache: dict, token: jnp.ndarray, pos,
                   cfg):
    """token (B, 1) int32; pos () int32. Returns (logits, new_cache)."""
    B = token.shape[0]
    x = embed(params["embed"], token)
    use_moe = cfg.moe is not None
    inv_freq = rope_freqs(
        cfg.mla.qk_rope_dim if cfg.mla is not None else cfg.head_dim_,
        cfg.rope_theta)
    n_lead = cfg.moe.first_dense_layers if cfg.moe else 0

    def dec_block(p, h, cache_l, *, moe_layer: bool):
        # Anchor the per-layer cache slice inside the scan body so value
        # hoisting cannot move cache-wide converts out of the loop.
        # (The XLA *CPU* backend still lowers the bf16 cache DUS as an
        # upcast-update-downcast over the whole stack — a +10.7 GB/dev
        # measurement artifact of this container, absent on TPU where
        # bf16 DUS is native; quantified in EXPERIMENTS.md §Perf.)
        cache_l = jax.lax.optimization_barrier(cache_l)
        hn = apply_norm(cfg.norm, p["ln_attn"], h)
        if cfg.mla is not None:
            out, (c2, kr2) = attn.mla_decode(
                p["attn"], hn, cfg, pos, cache_l["c"], cache_l["kr"],
                inv_freq)
            new_cache = {"c": c2, "kr": kr2}
        else:
            out, (k2, v2) = attn.gqa_decode(
                p["attn"], hn, cfg, pos, cache_l["k"], cache_l["v"],
                inv_freq)
            new_cache = {"k": k2, "v": v2}
        h = h + out
        h, _ = _mlp_block(p, h, cfg, use_moe=moe_layer)
        return h, new_cache

    # Lead (dense) layers then the homogeneous stack, both scanned.
    if n_lead:
        lead_cache = jax.tree_util.tree_map(lambda a: a[:n_lead], cache)
        stack_cache = jax.tree_util.tree_map(lambda a: a[n_lead:], cache)

        def lead_body(h, xs):
            lp, cl = xs
            h, nc = dec_block(lp, h, cl, moe_layer=False)
            return h, nc

        x, new_lead = jax.lax.scan(lead_body, x,
                                   (params["lead_layers"], lead_cache))
    else:
        stack_cache = cache
        new_lead = None

    def body(h, xs):
        lp, cl = xs
        h, nc = dec_block(lp, h, cl, moe_layer=use_moe)
        return h, nc

    x, new_stack = jax.lax.scan(body, x, (params["layers"], stack_cache))
    if new_lead is not None:
        new_cache = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], axis=0), new_lead,
            new_stack)
    else:
        new_cache = new_stack
    x = apply_norm(cfg.norm, params["ln_f"], x)
    logits = logits_from_hidden(params, x, cfg)
    return logits, new_cache
