"""Attention: GQA (full + sliding window) and MLA, prefill + decode paths.

Prefill uses a flash-style chunked attention (lax.scan over KV chunks with
an online softmax) so the S^2 score matrix is never materialized — at the
32k prefill shapes of the assigned pool a materialized score tensor would
dominate HBM. Decode is a single fused read over the cache (full) or over
a ring buffer (sliding window). MLA decode uses DeepSeek's weight
absorption: attention runs entirely in the kv_lora latent space and the
cache stores only (c_kv, k_rope).
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import KeyGen, apply_rope, dense_init, rope_freqs

NEG_INF = -1e30


# --------------------------------------------------------------------------
# reference (S^2) attention — oracle for tests
# --------------------------------------------------------------------------

def attention_ref(q, k, v, *, causal=True, window=None, q_offset=0):
    """q: (B,Sq,H,D); k,v: (B,Skv,KVH,D). Returns (B,Sq,H,Dv)."""
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qf = q.astype(jnp.float32).reshape(B, Sq, KVH, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) / math.sqrt(D)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(B, Sq, H, -1).astype(q.dtype)


# --------------------------------------------------------------------------
# flash-style chunked attention (prefill) with a CUSTOM VJP
# --------------------------------------------------------------------------
# The naive differentiated scan would checkpoint the (o, m, l) carry at
# every KV chunk (O(n_chunks * B*Sq*H*D) temp — measured 100s of GB/device
# at the 32k cells). The custom VJP implements the FlashAttention-2
# backward: save only (q, k, v, out, LSE) and recompute P chunk-by-chunk.

def _chunk_kv(k, v, chunk):
    B, Skv, KVH, D = k.shape
    Dv = v.shape[3]
    n_chunks = (Skv + chunk - 1) // chunk
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KVH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KVH, Dv).transpose(1, 0, 2, 3, 4)
    return kc, vc, n_chunks


def _chunk_mask(kpos, qpos, Skv, causal, window):
    mask = (kpos < Skv)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    return mask


def _flash_fwd_impl(q, k, v, causal, window, chunk, q_offset):
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    G = H // KVH
    chunk = min(chunk, Skv)
    kc, vc, n_chunks = _chunk_kv(k, v, chunk)
    scale = 1.0 / math.sqrt(D)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KVH, G, D)
    qpos = (q_offset + jnp.arange(Sq))[:, None]          # (Sq, 1)

    def body(carry, xs):
        o, m, l = carry
        kb, vb, c_idx = xs
        kpos = c_idx * chunk + jnp.arange(chunk)[None, :]
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kb.astype(jnp.float32))
        mask = _chunk_mask(kpos, qpos, Skv, causal, window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vb.astype(jnp.float32))
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((B, Sq, KVH, G, Dv), jnp.float32)
    m0 = jnp.full((B, Sq, KVH, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KVH, G), jnp.float32)
    (o, m, l), _ = jax.lax.scan(
        body, (o0, m0, l0), (kc, vc, jnp.arange(n_chunks)))
    l_safe = jnp.maximum(l, 1e-30)
    out = o / l_safe[..., None]
    lse = m + jnp.log(l_safe)                            # (B,Sq,KVH,G)
    return out.reshape(B, Sq, H, Dv).astype(q.dtype), lse


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, chunk, q_offset):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, chunk, q_offset)
    return out


def _flash_fwd(q, k, v, causal, window, chunk, q_offset):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, chunk, q_offset)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, chunk, q_offset, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    G = H // KVH
    chunk = min(chunk, Skv)
    kc, vc, n_chunks = _chunk_kv(k, v, chunk)
    scale = 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Sq, KVH, G, D)
    dof = dout.astype(jnp.float32).reshape(B, Sq, KVH, G, Dv)
    of = out.astype(jnp.float32).reshape(B, Sq, KVH, G, Dv)
    delta = jnp.sum(dof * of, axis=-1)                   # (B,Sq,KVH,G)
    qpos = (q_offset + jnp.arange(Sq))[:, None]

    def body(dq_acc, xs):
        kb, vb, c_idx = xs
        kbf = kb.astype(jnp.float32)
        vbf = vb.astype(jnp.float32)
        kpos = c_idx * chunk + jnp.arange(chunk)[None, :]
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kbf) * scale
        mask = _chunk_mask(kpos, qpos, Skv, causal, window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                  # normalized probs
        dv_b = jnp.einsum("bqhgk,bqhgd->bkhd", p, dof)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", dof, vbf)
        ds = p * (dp - delta[..., None])
        dq_acc = dq_acc + jnp.einsum("bqhgk,bkhd->bqhgd", ds, kbf) * scale
        dk_b = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qf) * scale
        return dq_acc, (dk_b, dv_b)

    dq0 = jnp.zeros((B, Sq, KVH, G, D), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(
        body, dq0, (kc, vc, jnp.arange(n_chunks)))
    dk = dk_c.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, KVH,
                                               D)[:, :Skv]
    dv = dv_c.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, KVH,
                                               Dv)[:, :Skv]
    return (dq.reshape(B, Sq, H, D).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, window=None, chunk=1024,
                    q_offset=0):
    """Online-softmax attention, scanning KV in chunks, O(chunk) memory in
    both forward and backward (custom VJP; FlashAttention-2 schedule).

    q: (B,Sq,H,D); k,v: (B,Skv,KVH,Dk/Dv). Returns (B,Sq,H,Dv) in q.dtype.
    """
    return _flash(q, k, v, causal, window, chunk, q_offset)


# --------------------------------------------------------------------------
# decode attention over caches
# --------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, pos):
    """One-token attention over a full cache.

    q: (B,1,H,D); k_cache/v_cache: (B,S,KVH,D); pos: () int32 current index
    (the cache holds valid entries at [0, pos]).

    NOTE: the cache is contracted in ITS OWN dtype with fp32 accumulation
    (preferred_element_type) — an explicit .astype(f32) here gets hoisted
    out of the decode layer-scan by XLA, materializing a full fp32 copy
    of the stacked multi-GB cache (measured +10.7 GB/dev, §Perf).
    """
    B, _, H, D = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    qf = ((q.astype(jnp.float32) / math.sqrt(D))
          .astype(k_cache.dtype).reshape(B, KVH, G, D))
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache,
                   preferred_element_type=jnp.float32)
    valid = jnp.arange(S)[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, -1).astype(q.dtype)


def decode_attention_window(q, k_ring, v_ring, pos, window):
    """One-token attention over a ring-buffer cache (sliding window).

    k_ring/v_ring: (B,W,KVH,D); slot w holds absolute position
    p_w = pos - ((pos - w) mod W); valid iff p_w >= 0 (rope already applied
    at write time at the absolute position).
    """
    B, W, KVH, D = k_ring.shape
    H = q.shape[2]
    G = H // KVH
    qf = ((q.astype(jnp.float32) / math.sqrt(D))
          .astype(k_ring.dtype).reshape(B, KVH, G, D))
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_ring,
                   preferred_element_type=jnp.float32)
    w_idx = jnp.arange(W)
    slot_pos = pos - jnp.mod(pos - w_idx, W)
    valid = (slot_pos >= 0) & (slot_pos > pos - window)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_ring.dtype), v_ring,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, -1).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention block (params + apply)
# --------------------------------------------------------------------------

def init_gqa(kg: KeyGen, cfg) -> dict:
    d, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    p = {
        "wq": dense_init(kg(), d, H * hd, cfg.np_dtype),
        "wk": dense_init(kg(), d, KVH * hd, cfg.np_dtype),
        "wv": dense_init(kg(), d, KVH * hd, cfg.np_dtype),
        "wo": dense_init(kg(), H * hd, d, cfg.np_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), cfg.np_dtype)
        p["bk"] = jnp.zeros((KVH * hd,), cfg.np_dtype)
        p["bv"] = jnp.zeros((KVH * hd,), cfg.np_dtype)
    return p


def gqa_qkv(p: dict, x: jnp.ndarray, cfg, positions, inv_freq):
    """Project + rope. x: (B,S,d). Returns q (B,S,H,hd), k/v (B,S,KVH,hd)."""
    B, S, _ = x.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    from . import pshint
    q = pshint.constrain(q.reshape(B, S, H, hd), "heads")
    k = pshint.constrain(k.reshape(B, S, KVH, hd), "heads")
    v = pshint.constrain(v.reshape(B, S, KVH, hd), "heads")
    q = apply_rope(q, positions, inv_freq)
    k = apply_rope(k, positions, inv_freq)
    return q, k, v


def gqa_prefill(p: dict, x, cfg, positions, inv_freq, *, window=None):
    q, k, v = gqa_qkv(p, x, cfg, positions, inv_freq)
    o = flash_attention(q, k, v, causal=True, window=window,
                        chunk=cfg.attn_chunk)
    B, S = x.shape[:2]
    out = o.reshape(B, S, -1) @ p["wo"]
    return out, (k, v)


def gqa_decode(p: dict, x, cfg, pos, k_cache, v_cache, inv_freq,
               *, window=None):
    """x: (B,1,d). Updates the cache at ``pos`` and attends.

    Full cache: (B,S,KVH,hd) updated at index pos.
    Window cache: ring (B,W,KVH,hd) updated at slot pos % W.
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = gqa_qkv(p, x, cfg, positions, inv_freq)
    if window is None:
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
        o = decode_attention(q, k_cache, v_cache, pos)
    else:
        W = k_cache.shape[1]
        slot = jnp.mod(pos, W)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, slot, 0, 0))
        o = decode_attention_window(q, k_cache, v_cache, pos, window)
    out = o.reshape(B, 1, -1) @ p["wo"]
    return out, (k_cache, v_cache)


# --------------------------------------------------------------------------
# Cross-attention (encoder-decoder)
# --------------------------------------------------------------------------

def cross_attention(p: dict, x, enc_k, enc_v, cfg, enc_mask=None):
    """x: (B,Sd,d); enc_k/enc_v: (B,Se,KVH,hd) precomputed from encoder."""
    B, Sd, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim_
    q = (x @ p["wq"]).reshape(B, Sd, H, hd)
    o = flash_attention(q, enc_k, enc_v, causal=False,
                        chunk=cfg.attn_chunk)
    return o.reshape(B, Sd, -1) @ p["wo"]


def init_cross(kg: KeyGen, cfg) -> dict:
    d, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    return {
        "wq": dense_init(kg(), d, H * hd, cfg.np_dtype),
        "wk": dense_init(kg(), d, KVH * hd, cfg.np_dtype),
        "wv": dense_init(kg(), d, KVH * hd, cfg.np_dtype),
        "wo": dense_init(kg(), H * hd, d, cfg.np_dtype),
    }


def cross_kv(p: dict, enc_out, cfg):
    B, Se, _ = enc_out.shape
    KVH, hd = cfg.n_kv_heads, cfg.head_dim_
    k = (enc_out @ p["wk"]).reshape(B, Se, KVH, hd)
    v = (enc_out @ p["wv"]).reshape(B, Se, KVH, hd)
    return k, v


# --------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2)
# --------------------------------------------------------------------------

def init_mla(kg: KeyGen, cfg) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv, L = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim, m.kv_lora_rank
    return {
        "wq": dense_init(kg(), d, H * (dn + dr), cfg.np_dtype),
        "w_dkv": dense_init(kg(), d, L, cfg.np_dtype),
        "kv_norm": {"scale": jnp.ones((L,), cfg.np_dtype)},
        "w_uk": dense_init(kg(), L, H * dn, cfg.np_dtype),
        "w_uv": dense_init(kg(), L, H * dv, cfg.np_dtype),
        "w_kr": dense_init(kg(), d, dr, cfg.np_dtype),
        "wo": dense_init(kg(), H * dv, d, cfg.np_dtype),
    }


def _mla_q(p, x, cfg, positions, inv_freq_r):
    from .layers import rms_norm
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr = m.qk_nope_dim, m.qk_rope_dim
    q = (x @ p["wq"]).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, inv_freq_r)
    return q_nope, q_rope


def mla_prefill(p: dict, x, cfg, positions, inv_freq_r):
    """Returns (out, cache=(c_kv, k_rope)) — the latent cache."""
    from .layers import rms_norm
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv, L = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim, m.kv_lora_rank
    q_nope, q_rope = _mla_q(p, x, cfg, positions, inv_freq_r)
    c = rms_norm(p["kv_norm"], x @ p["w_dkv"])              # (B,S,L)
    k_nope = (c @ p["w_uk"]).reshape(B, S, H, dn)
    vv = (c @ p["w_uv"]).reshape(B, S, H, dv)
    k_r = apply_rope((x @ p["w_kr"]).reshape(B, S, 1, dr), positions,
                     inv_freq_r)
    k_r_b = jnp.broadcast_to(k_r, (B, S, H, dr))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_r_b], axis=-1)
    o = flash_attention(q, k, vv, causal=True, chunk=cfg.attn_chunk)
    out = o.reshape(B, S, -1) @ p["wo"]
    return out, (c, k_r[:, :, 0, :])


def mla_decode(p: dict, x, cfg, pos, c_cache, kr_cache, inv_freq_r):
    """Weight-absorbed MLA decode: attention in latent space.

    c_cache: (B,S,L); kr_cache: (B,S,dr). Score_t = q_abs . c_t + q_r . kr_t
    where q_abs = q_nope absorbed through w_uk; output re-expanded through
    w_uv. FLOPs per token scale with L + dr, not H*(dn+dv).
    """
    from .layers import rms_norm
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    dn, dr, dv, L = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim, m.kv_lora_rank
    positions = jnp.full((B, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg, positions, inv_freq_r)  # (B,1,H,*)
    # Update latent cache at pos.
    c_new = rms_norm(p["kv_norm"], x @ p["w_dkv"])             # (B,1,L)
    kr_new = apply_rope((x @ p["w_kr"]).reshape(B, 1, 1, dr), positions,
                        inv_freq_r)[:, :, 0, :]
    c_cache = jax.lax.dynamic_update_slice(
        c_cache, c_new.astype(c_cache.dtype), (0, pos, 0))
    kr_cache = jax.lax.dynamic_update_slice(
        kr_cache, kr_new.astype(kr_cache.dtype), (0, pos, 0))
    # Absorb: q_abs[b,h,l] = sum_dn q_nope * w_uk[l, h*dn+dn_idx].
    # Cache einsums stay in cache dtype with fp32 accumulation — an
    # .astype(f32) on the cache would get hoisted out of the decode
    # layer-scan into a full fp32 copy of the stacked latent cache.
    w_uk = p["w_uk"].reshape(L, H, dn)
    qn = q_nope[:, 0]                                          # (B,H,dn)
    q_abs = jnp.einsum("bhd,lhd->bhl", qn, w_uk,
                       preferred_element_type=jnp.float32)
    scale = 1.0 / math.sqrt(dn + dr)
    s_lat = jnp.einsum("bhl,bsl->bhs", q_abs.astype(c_cache.dtype),
                       c_cache, preferred_element_type=jnp.float32)
    qr = q_rope[:, 0]                                          # (B,H,dr)
    s_rope = jnp.einsum("bhd,bsd->bhs", qr.astype(kr_cache.dtype),
                        kr_cache, preferred_element_type=jnp.float32)
    s = (s_lat + s_rope) * scale
    S = c_cache.shape[1]
    valid = jnp.arange(S)[None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    att = jax.nn.softmax(s, axis=-1)
    z = jnp.einsum("bhs,bsl->bhl", att.astype(c_cache.dtype), c_cache,
                   preferred_element_type=jnp.float32)
    w_uv = p["w_uv"].reshape(L, H, dv).astype(jnp.float32)
    o = jnp.einsum("bhl,lhd->bhd", z, w_uv)                    # (B,H,dv)
    out = o.reshape(B, 1, H * dv).astype(x.dtype) @ p["wo"]
    return out, (c_cache, kr_cache)
