"""Shared neural-net building blocks (functional, pytree params).

Conventions:
  * params are nested dicts of jnp arrays; stacked layers add a leading
    n_layers axis to every leaf (lax.scan consumes them directly);
  * activations flow in ``cfg.dtype`` (bf16 on TPU); normalization
    statistics, softmax and RoPE run in fp32;
  * weight layout is (d_in, d_out) so ``x @ w`` contracts the minor axis
    of x — the O1 lesson (unit-stride minor) applied to the LM stack.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# rng plumbing
# --------------------------------------------------------------------------

class KeyGen:
    """Hands out fresh PRNG keys: kg = KeyGen(seed); w = init(kg(), ...)."""

    def __init__(self, key_or_seed):
        if isinstance(key_or_seed, int):
            key_or_seed = jax.random.PRNGKey(key_or_seed)
        self._key = key_or_seed

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32)
            * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32)
    return y.astype(x.dtype)


def apply_norm(kind: str, params: dict, x: jnp.ndarray) -> jnp.ndarray:
    return rms_norm(params, x) if kind == "rmsnorm" else layer_norm(params, x)


def init_norm(kind: str, d: int, dtype) -> dict:
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" else init_layernorm(
        d, dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim//2,), fp32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               inv_freq: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs (x[..., :half], x[..., half:]) — NEOX style.

    x: (..., S, n_heads, head_dim); positions: (..., S) int32.
    The angle table is hoisted by callers where possible (O2: k-invariant
    hoisting — here, layer-invariant: computed once per step, reused by
    every layer of the scan).
    """
    half = inv_freq.shape[0]
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]   # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def init_mlp(kg: KeyGen, d_model: int, d_ff: int, dtype,
             activation: str = "swiglu") -> dict:
    if activation in ("swiglu", "geglu"):
        return {
            "wi_gate": dense_init(kg(), d_model, d_ff, dtype),
            "wi_up": dense_init(kg(), d_model, d_ff, dtype),
            "wo": dense_init(kg(), d_ff, d_model, dtype),
        }
    return {
        "wi": dense_init(kg(), d_model, d_ff, dtype),
        "wo": dense_init(kg(), d_ff, d_model, dtype),
    }


def mlp(params: dict, x: jnp.ndarray, activation: str = "swiglu"):
    from . import pshint
    if activation in ("swiglu", "geglu"):
        act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
        g = act(pshint.constrain(x @ params["wi_gate"], "ffn"))
        u = pshint.constrain(x @ params["wi_up"], "ffn")
        return (g * u) @ params["wo"]
    h = jax.nn.gelu(pshint.constrain(x @ params["wi"], "ffn"))
    return h @ params["wo"]


# --------------------------------------------------------------------------
# embeddings / unembedding
# --------------------------------------------------------------------------

def embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed(table_or_head: jnp.ndarray, x: jnp.ndarray,
            *, tied: bool) -> jnp.ndarray:
    """Logits in fp32 (loss stability)."""
    w = table_or_head.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if tied:
        return jnp.einsum("...d,vd->...v", xf, w)
    return xf @ w


def chunked_cross_entropy(hidden: jnp.ndarray, table_or_head: jnp.ndarray,
                          labels: jnp.ndarray, *, tied: bool,
                          chunk: int = 512,
                          softcap: float = 0.0) -> jnp.ndarray:
    """Cross-entropy without materializing full (B, S, V) logits.

    Scans the sequence in chunks; each chunk's logits are produced,
    consumed and (via jax.checkpoint) recomputed in backward — peak temp
    drops from O(B*S*V) to O(B*chunk*V). The paper's O5 batching argument
    applied to the loss layer: accumulate in registers, write once.
    """
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    n = (S + chunk - 1) // chunk
    pad = n * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=-1)
    h_c = jnp.moveaxis(hidden.reshape(B, n, chunk, d), 1, 0)
    l_c = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

    @jax.checkpoint
    def body(carry, xs):
        nll_sum, cnt = carry
        hb, lb = xs
        logits = unembed(table_or_head, hb, tied=tied)   # (B, chunk, V)
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        valid = lb >= 0
        safe = jnp.maximum(lb, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll = jnp.where(valid, logz - ll, 0.0)
        return (nll_sum + nll.sum(),
                cnt + valid.sum(dtype=jnp.float32)), None

    (nll_sum, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (h_c, l_c))
    return nll_sum / jnp.maximum(cnt, 1.0)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy in fp32. labels: int32, -1 = ignore."""
    logits = logits.astype(jnp.float32)
    valid = (labels >= 0)
    if mask is not None:
        valid = valid & (mask > 0)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - ll) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)


def remat_policy(cfg):
    """jax.checkpoint policy from cfg.remat_policy.

    "nothing": minimum memory, maximum recompute (and, under FSDP+SP,
    maximum re-gather traffic in backward).
    "dots": save matmul outputs — removes the recompute pass's weight and
    activation all-gathers at the cost of per-layer dot-output residency
    (measured trade in EXPERIMENTS.md §Perf).
    """
    import jax as _jax
    if getattr(cfg, "remat_policy", "nothing") == "dots":
        return _jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return _jax.checkpoint_policies.nothing_saveable
