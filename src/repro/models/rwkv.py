"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time-mix with
data-dependent decay, plus squared-ReLU channel-mix.

Time-mix core (per head, head_size hd):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state: hd x hd, fp32)
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

with w_t = exp(-exp(decay_t)) data-dependent per channel, u the "bonus"
for the current token, and the v6 ddlerp token-shift (a LoRA on the
interpolation between x_t and x_{t-1}) producing the five mix inputs.

Sequence mode runs a lax.scan over time carrying S (the O(1)-state
property that makes the 512k-decode cell feasible); decode is the same
body on a single step.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import KeyGen, dense_init


def init_time_mix(kg: KeyGen, cfg) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_size
    H = d // hd
    dd = cfg.rwkv_ddlora
    wd = cfg.rwkv_decay_lora
    dt = cfg.np_dtype
    u01 = lambda: (jax.random.uniform(kg(), (d,)) * 0.5 + 0.25).astype(dt)
    return {
        "maa_x": u01(), "maa_w": u01(), "maa_k": u01(), "maa_v": u01(),
        "maa_r": u01(), "maa_g": u01(),
        "maa_w1": dense_init(kg(), d, 5 * dd, dt, scale=0.01),
        "maa_w2": (jax.random.normal(kg(), (5, dd, d)) * 0.01).astype(dt),
        "decay": (jax.random.normal(kg(), (d,)) * 0.5 - 4.0).astype(dt),
        "decay_w1": dense_init(kg(), d, wd, dt, scale=0.01),
        "decay_w2": dense_init(kg(), wd, d, dt, scale=0.01),
        "bonus": (jax.random.normal(kg(), (H, hd)) * 0.1).astype(dt),
        "w_r": dense_init(kg(), d, d, dt),
        "w_k": dense_init(kg(), d, d, dt),
        "w_v": dense_init(kg(), d, d, dt),
        "w_g": dense_init(kg(), d, d, dt),
        "w_o": dense_init(kg(), d, d, dt),
        "ln_x_scale": jnp.ones((d,), dt),
        "ln_x_bias": jnp.zeros((d,), dt),
    }


def init_channel_mix(kg: KeyGen, cfg) -> dict:
    d, ff, dt = cfg.d_model, cfg.d_ff, cfg.np_dtype
    u01 = lambda: (jax.random.uniform(kg(), (d,)) * 0.5 + 0.25).astype(dt)
    return {
        "maa_k": u01(), "maa_r": u01(),
        "w_k": dense_init(kg(), d, ff, dt),
        "w_v": dense_init(kg(), ff, d, dt),
        "w_r": dense_init(kg(), d, d, dt),
    }


def _ddlerp(p, x, sx):
    """v6 data-dependent token-shift: five mixed variants of x.

    x, sx: (B, T, d) with sx = x_{t-1} - x_t. Returns (xw,xk,xv,xr,xg).
    """
    xxx = x + sx * p["maa_x"]
    a = jnp.tanh(xxx @ p["maa_w1"])                     # (B,T,5*dd)
    B_, T_, _ = a.shape
    dd = p["maa_w2"].shape[1]
    a = a.reshape(B_, T_, 5, dd)
    m = jnp.einsum("btfd,fdo->btfo", a, p["maa_w2"])    # (B,T,5,d)
    mw, mk, mv, mr, mg = [m[:, :, i] for i in range(5)]
    xw = x + sx * (p["maa_w"] + mw)
    xk = x + sx * (p["maa_k"] + mk)
    xv = x + sx * (p["maa_v"] + mv)
    xr = x + sx * (p["maa_r"] + mr)
    xg = x + sx * (p["maa_g"] + mg)
    return xw, xk, xv, xr, xg


def _group_norm(p, y, H, hd):
    """Per-head LayerNorm of the wkv output. y: (B,T,H,hd)."""
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + 64e-5)
    yn = yn.reshape(*y.shape[:-2], H * hd)
    return yn * p["ln_x_scale"].astype(jnp.float32) + \
        p["ln_x_bias"].astype(jnp.float32)


def wkv6_scan(r, k, v, w, u, S0=None, *, chunk: int = 128):
    """The WKV-6 recurrence over a sequence, chunk-rematerialized.

    r,k,v,w: (B,T,H,hd); u: (H,hd); S0: (B,H,hd,hd) fp32 or None.
    Returns (y (B,T,H,hd) fp32, S_last).

    A flat differentiated scan checkpoints the (B,H,hd,hd) state at every
    timestep (T x state = GBs at train_4k). Instead the outer scan runs
    over chunks with a jax.checkpoint'd inner scan: only chunk-boundary
    states are saved, in-chunk states recompute in backward — the same
    trade the layer stack makes (and the paper's O5 batching shape:
    bounded live-set, amortized writes).
    """
    B, T, H, hd = r.shape
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    if S0 is None:
        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs                       # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S_new = wt[..., :, None] * S + kv
        return S_new, y

    chunk = max(1, min(chunk, T))
    if T % chunk != 0:      # uneven tail: fall back to the flat scan
        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
        S_last, ys = jax.lax.scan(step, S0, xs)
        return jnp.moveaxis(ys, 0, 1), S_last

    n_chunks = T // chunk
    # (n_chunks, chunk, B, H, hd)
    xs = tuple(
        jnp.moveaxis(t, 1, 0).reshape(n_chunks, chunk, B, H, hd)
        for t in (rf, kf, vf, wf))

    @jax.checkpoint
    def chunk_body(S, xs_c):
        return jax.lax.scan(step, S, xs_c)

    S_last, ys = jax.lax.scan(chunk_body, S0, xs)
    ys = ys.reshape(T, B, H, hd)
    return jnp.moveaxis(ys, 0, 1), S_last


def time_mix_seq(p: dict, x: jnp.ndarray, cfg, state=None):
    """x: (B,T,d). state: None or {"S": (B,H,hd,hd), "x_tm": (B,d)}.

    Returns (out (B,T,d), new_state pieces (S_last, last_x)).
    """
    B, T, d = x.shape
    hd = cfg.rwkv_head_size
    H = d // hd
    prev = state["x_tm"][:, None] if state else jnp.zeros((B, 1, d), x.dtype)
    x_prev = jnp.concatenate([prev, x[:, :-1]], axis=1)
    sx = x_prev - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)
    r = (xr @ p["w_r"]).reshape(B, T, H, hd)
    k = (xk @ p["w_k"]).reshape(B, T, H, hd)
    v = (xv @ p["w_v"]).reshape(B, T, H, hd)
    g = jax.nn.silu(xg @ p["w_g"])
    decay = p["decay"].astype(jnp.float32) + \
        (jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]).astype(jnp.float32)
    wt = jnp.exp(-jnp.exp(decay)).reshape(B, T, H, hd)
    u = p["bonus"].astype(jnp.float32)
    y, S_last = wkv6_scan(r, k, v, wt, u,
                          state["S"] if state else None)
    y = _group_norm(p, y, H, hd).astype(x.dtype)
    out = (y * g) @ p["w_o"]
    return out, {"S": S_last, "x_tm": x[:, -1]}


def channel_mix_seq(p: dict, x: jnp.ndarray, state=None):
    """Squared-ReLU channel mix. state: {"x_cm": (B,d)} or None."""
    B, T, d = x.shape
    prev = state["x_cm"][:, None] if state else jnp.zeros((B, 1, d), x.dtype)
    x_prev = jnp.concatenate([prev, x[:, :-1]], axis=1)
    sx = x_prev - x
    xk = x + sx * p["maa_k"]
    xr = x + sx * p["maa_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (kk @ p["w_v"])
    return out, {"x_cm": x[:, -1]}
