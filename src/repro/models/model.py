"""Model dispatcher: one uniform API over every architecture family.

    model = build_model(cfg)
    params = model.init(seed)                 # or abstract_params(cfg)
    loss = model.loss(params, batch)          # train objective
    logits, cache, pos = model.prefill(params, batch, max_len)
    logits, cache = model.decode_step(params, cache, token, pos)
    batch = model.dummy_batch(shape)          # concrete (smoke tests)
    specs = model.input_specs(shape)          # ShapeDtypeStructs (dry-run)

Families: dense | moe (incl. MLA) | encdec | hybrid | ssm | vlm.
Frontend stubs ([audio]/[vlm] per the pool): input_specs provide
precomputed frame/patch embeddings; the backbone is fully real.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import encdec, hybrid, ssm, transformer
from .layers import KeyGen, cross_entropy, dense_init


# --------------------------------------------------------------------------
# analytic parameter counts (roofline's 6*N*D)
# --------------------------------------------------------------------------

def count_params_analytic(cfg, active_only: bool = False) -> int:
    d, ff, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_

    def attn_params():
        if cfg.mla is not None:
            m = cfg.mla
            return (d * H * (m.qk_nope_dim + m.qk_rope_dim)
                    + d * m.kv_lora_rank + m.kv_lora_rank
                    + m.kv_lora_rank * H * m.qk_nope_dim
                    + m.kv_lora_rank * H * m.v_head_dim
                    + d * m.qk_rope_dim + H * m.v_head_dim * d)
        n = d * H * hd + 2 * d * KVH * hd + H * hd * d
        if cfg.qkv_bias:
            n += H * hd + 2 * KVH * hd
        return n

    def mlp_params(dff):
        mult = 3 if cfg.activation == "swiglu" else 2
        return mult * d * dff

    if cfg.family == "ssm":
        hd_r = cfg.rwkv_head_size
        Hn = d // hd_r
        tm = (6 * d + d * 5 * cfg.rwkv_ddlora + 5 * cfg.rwkv_ddlora * d
              + d + d * cfg.rwkv_decay_lora + cfg.rwkv_decay_lora * d
              + Hn * hd_r + 5 * d * d + 2 * d)
        cm = 2 * d + d * ff + ff * d + d * d
        return V * d + L * (tm + cm + 4 * d) + d * V + 4 * d

    if cfg.family == "hybrid":
        w = cfg.lru_width
        bw = w // H
        rec = (2 * d * w + cfg.conv_width * w + w
               + 2 * (H * bw * bw + w) + w + w * d)
        att = attn_params()
        per_mlp = mlp_params(ff)
        full, trail, pat = hybrid.n_units(cfg)
        n_rec = sum(1 for k in pat if k == "rec") * full + trail
        n_att = sum(1 for k in pat if k == "attn") * full
        return (V * d + n_rec * (rec + per_mlp + 2 * d)
                + n_att * (att + per_mlp + 2 * d) + d)

    if cfg.family == "encdec":
        enc = cfg.n_enc_layers * (attn_params() + mlp_params(ff) + 2 * d)
        cross = L * (attn_params())
        dec = L * (attn_params() + mlp_params(ff) + 3 * d)
        return V * d + enc + dec + cross + 2 * d + d * V

    # dense / moe / vlm backbones
    n = V * d + 2 * d  # embed + final norm
    if not cfg.tie_embeddings:
        n += d * V
    m = cfg.moe
    n_lead = m.first_dense_layers if m else 0
    if m is not None:
        expert = mlp_params(m.d_ff_expert)
        router = d * m.num_experts
        shared = m.num_shared * mlp_params(m.d_ff_shared or m.d_ff_expert)
        active = (m.top_k * expert + router + shared + attn_params() + 2 * d)
        total = (m.num_experts * expert + router + shared + attn_params()
                 + 2 * d)
        per_layer = active if active_only else total
        n += (L - n_lead) * per_layer
        n += n_lead * (attn_params()
                       + mlp_params(m.first_dense_d_ff or ff) + 2 * d)
    else:
        n += L * (attn_params() + mlp_params(ff) + 2 * d)
    if cfg.family == "vlm":
        n += cfg.frontend_dim * d + d * d + 2 * d  # patch projector MLP
    return n


# --------------------------------------------------------------------------
# VLM / audio frontend stubs
# --------------------------------------------------------------------------

def _init_vlm_extras(kg: KeyGen, cfg) -> dict:
    return {
        "proj1": dense_init(kg(), cfg.frontend_dim, cfg.d_model,
                            cfg.np_dtype),
        "proj2": dense_init(kg(), cfg.d_model, cfg.d_model, cfg.np_dtype),
    }


def _vlm_embed(params, batch, cfg):
    """Concatenate projected patch embeddings with token embeddings."""
    from .layers import embed
    patches = batch["patches"]                        # (B, P, frontend_dim)
    h = jax.nn.gelu(patches.astype(cfg.np_dtype) @ params["vlm"]["proj1"])
    h = h @ params["vlm"]["proj2"]                    # (B, P, d)
    tok = embed(params["embed"], batch["tokens"])     # (B, S, d)
    return jnp.concatenate([h, tok], axis=1)


# --------------------------------------------------------------------------
# the Model facade
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable
    loss: Callable
    forward: Callable
    prefill: Optional[Callable]
    decode_step: Optional[Callable]
    init_decode_state: Optional[Callable]
    dummy_batch: Callable
    input_specs: Callable


def init_params(cfg, seed: int = 0):
    return build_model(cfg).init(seed)


def abstract_params(cfg, seed: int = 0):
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(seed))


def build_model(cfg) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return _build_lm(cfg)
    if fam == "encdec":
        return _build_encdec(cfg)
    if fam == "hybrid":
        return _build_hybrid(cfg)
    if fam == "ssm":
        return _build_ssm(cfg)
    raise ValueError(f"unknown family {fam}")


# ---- dense / moe / vlm ----------------------------------------------------

def _build_lm(cfg) -> Model:
    is_vlm = cfg.family == "vlm"

    def init(seed=0):
        kg = KeyGen(seed)
        p = transformer.init_lm(kg, cfg)
        if is_vlm:
            p["vlm"] = _init_vlm_extras(kg, cfg)
        return p

    def forward(params, batch):
        if is_vlm:
            x = _vlm_embed(params, batch, cfg)
            B, S, _ = x.shape
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (B, S))
            h, aux, _ = transformer.forward_embeds(params, x, cfg,
                                                   positions)
            return transformer.logits_from_hidden(params, h, cfg), aux
        return transformer.lm_forward(params, batch["tokens"], cfg)

    def loss(params, batch):
        if is_vlm:
            from .layers import chunked_cross_entropy
            x = _vlm_embed(params, batch, cfg)
            B, S, _ = x.shape
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (B, S))
            h, aux, _ = transformer.forward_embeds(params, x, cfg,
                                                   positions,
                                                   for_train=True)
            P = batch["patches"].shape[1]
            w = (params["embed"] if cfg.tie_embeddings
                 else params["unembed"])
            ce = chunked_cross_entropy(h[:, P:], w, batch["labels"],
                                       tied=cfg.tie_embeddings)
            return ce + 0.01 * aux
        return transformer.lm_loss(params, batch, cfg)

    def prefill(params, batch, max_len):
        tokens = batch["tokens"]
        if is_vlm:
            x = _vlm_embed(params, batch, cfg)
            B, S, _ = x.shape
            # the cache must hold patch tokens + text (+ decode room)
            max_len = max(max_len, S)
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32), (B, S))
            h, _, caches = transformer.forward_embeds(
                params, x, cfg, positions, collect_cache=True)
            lead, stack = caches
            cache = transformer._caches_to_struct(cfg, stack, lead, B, S,
                                                  max_len)
            logits = transformer.logits_from_hidden(params, h[:, -1:], cfg)
            return logits, cache, jnp.int32(S)
        return transformer.lm_prefill(params, tokens, cfg, max_len)

    def decode_step(params, cache, token, pos):
        return transformer.lm_decode_step(params, cache, token, pos, cfg)

    def init_decode_state(batch_size, max_len):
        from .kvcache import full_cache, mla_cache
        if cfg.mla is not None:
            return mla_cache(cfg.n_layers, batch_size, max_len,
                             cfg.mla.kv_lora_rank, cfg.mla.qk_rope_dim,
                             cfg.np_dtype)
        return full_cache(cfg.n_layers, batch_size, max_len,
                          cfg.n_kv_heads, cfg.head_dim_, cfg.np_dtype)

    def dummy_batch(shape, seed=0):
        rng = jax.random.PRNGKey(seed)
        B, S = shape.global_batch, shape.seq_len
        b = {
            "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size,
                                         jnp.int32),
            "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size,
                                         jnp.int32),
        }
        if is_vlm:
            b["patches"] = jax.random.normal(
                rng, (B, cfg.frontend_tokens, cfg.frontend_dim),
                jnp.float32)
        return b

    def input_specs(shape):
        B, S = shape.global_batch, shape.seq_len
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if is_vlm:
            specs["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
        return specs

    return Model(cfg, init, loss, forward, prefill, decode_step,
                 init_decode_state, dummy_batch, input_specs)


# ---- encoder-decoder -------------------------------------------------------

def _build_encdec(cfg) -> Model:
    def init(seed=0):
        return encdec.init_encdec(KeyGen(seed), cfg)

    def forward(params, batch):
        enc_out = encdec.encode(params, batch["frames"], cfg)
        logits, _ = encdec.decode_seq(params, batch["tokens"], enc_out, cfg)
        return logits, jnp.float32(0.0)

    def loss(params, batch):
        return encdec.encdec_loss(params, batch, cfg)

    def prefill(params, batch, max_len):
        return encdec.encdec_prefill(params, batch["frames"],
                                     batch["tokens"], cfg, max_len)

    def decode_step(params, cache, token, pos):
        return encdec.encdec_decode_step(params, cache, token, pos, cfg)

    def init_decode_state(batch_size, max_len):
        # decoder self-attention cache + precomputed cross K/V over an
        # encoder sequence of the same length (the decode shape's
        # seq_len bounds both sides for the dry-run).
        L, KVH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim_
        shp = (L, batch_size, max_len, KVH, hd)
        return {"k": jnp.zeros(shp, cfg.np_dtype),
                "v": jnp.zeros(shp, cfg.np_dtype),
                "ck": jnp.zeros(shp, cfg.np_dtype),
                "cv": jnp.zeros(shp, cfg.np_dtype)}

    def dummy_batch(shape, seed=0):
        rng = jax.random.PRNGKey(seed)
        B, S = shape.global_batch, shape.seq_len
        return {
            "frames": jax.random.normal(rng, (B, S, cfg.d_model),
                                        jnp.float32),
            "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size,
                                         jnp.int32),
            "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size,
                                         jnp.int32),
        }

    def input_specs(shape):
        B, S = shape.global_batch, shape.seq_len
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                           jnp.float32),
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }

    return Model(cfg, init, loss, forward, prefill, decode_step,
                 init_decode_state, dummy_batch, input_specs)


# ---- hybrid ----------------------------------------------------------------

def _build_hybrid(cfg) -> Model:
    def init(seed=0):
        return hybrid.init_hybrid(KeyGen(seed), cfg)

    def forward(params, batch):
        return hybrid.hybrid_forward(params, batch["tokens"], cfg)

    def loss(params, batch):
        from .layers import chunked_cross_entropy
        h, _ = hybrid.hybrid_forward(params, batch["tokens"], cfg,
                                     for_train=True, return_hidden=True)
        return chunked_cross_entropy(h, params["embed"],
                                     batch["labels"], tied=True,
                                     softcap=30.0)

    def prefill(params, batch, max_len):
        del max_len  # state is O(window), not O(seq)
        logits, (unit_states, trail_states) = hybrid.hybrid_forward(
            params, batch["tokens"], cfg, collect_state=True)
        state = {"units": unit_states}
        if trail_states is not None:
            state["trail"] = trail_states
        return logits[:, -1:], state, jnp.int32(batch["tokens"].shape[1])

    def decode_step(params, state, token, pos):
        return hybrid.hybrid_decode_step(params, state, token, pos, cfg)

    def init_decode_state(batch_size, max_len):
        del max_len
        return hybrid.init_hybrid_state(cfg, batch_size)

    def dummy_batch(shape, seed=0):
        rng = jax.random.PRNGKey(seed)
        B, S = shape.global_batch, shape.seq_len
        return {
            "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size,
                                         jnp.int32),
            "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size,
                                         jnp.int32),
        }

    def input_specs(shape):
        B, S = shape.global_batch, shape.seq_len
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }

    return Model(cfg, init, loss, forward, prefill, decode_step,
                 init_decode_state, dummy_batch, input_specs)


# ---- ssm (rwkv) ------------------------------------------------------------

def _build_ssm(cfg) -> Model:
    def init(seed=0):
        return ssm.init_rwkv_lm(KeyGen(seed), cfg)

    def forward(params, batch):
        return ssm.rwkv_forward(params, batch["tokens"], cfg)

    def loss(params, batch):
        from .layers import chunked_cross_entropy
        h, _ = ssm.rwkv_forward(params, batch["tokens"], cfg,
                                for_train=True, return_hidden=True)
        return chunked_cross_entropy(h, params["unembed"],
                                     batch["labels"], tied=False)

    def prefill(params, batch, max_len):
        del max_len  # O(1) state
        return ssm.rwkv_prefill(params, batch["tokens"], cfg)

    def decode_step(params, state, token, pos):
        return ssm.rwkv_decode_step(params, state, token, pos, cfg)

    def init_decode_state(batch_size, max_len):
        del max_len
        return ssm.init_rwkv_state(cfg, batch_size)

    def dummy_batch(shape, seed=0):
        rng = jax.random.PRNGKey(seed)
        B, S = shape.global_batch, shape.seq_len
        return {
            "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size,
                                         jnp.int32),
            "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size,
                                         jnp.int32),
        }

    def input_specs(shape):
        B, S = shape.global_batch, shape.seq_len
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }

    return Model(cfg, init, loss, forward, prefill, decode_step,
                 init_decode_state, dummy_batch, input_specs)
