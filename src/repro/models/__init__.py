"""LM model substrate for the assigned architecture pool.

Functional JAX (no framework): parameters are pytrees of arrays, layer
stacks are lax.scan-compatible (stacked leading dim), every architecture
family exposes init / forward / prefill / decode through models.model.
"""

from .model import (  # noqa: F401
    abstract_params,
    build_model,
    init_params,
)
