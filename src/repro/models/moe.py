"""Mixture-of-Experts: top-k routing with capacity-based dispatch.

GShard/Switch-style formulation: dispatch and combine are dense einsums
over (tokens, experts, capacity), which (a) lowers to all-to-alls when the
expert axis is sharded (expert parallelism over the mesh "model" axis) and
(b) keeps compiled FLOPs proportional to *active* experts — the quantity
the roofline's 6·N_active·D model expects.

Shared experts (DeepSeek-V2 style) are always-on MLPs added to the routed
output. A Switch-style load-balance auxiliary loss is returned to the
trainer.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import KeyGen, dense_init


def init_moe(kg: KeyGen, cfg) -> dict:
    m = cfg.moe
    d, ff, E = cfg.d_model, m.d_ff_expert, m.num_experts
    p = {
        "router": dense_init(kg(), d, E, cfg.np_dtype, scale=0.02),
        "wi_gate": jnp.stack([dense_init(kg(), d, ff, cfg.np_dtype)
                              for _ in range(E)]),
        "wi_up": jnp.stack([dense_init(kg(), d, ff, cfg.np_dtype)
                            for _ in range(E)]),
        "wo": jnp.stack([dense_init(kg(), ff, d, cfg.np_dtype)
                         for _ in range(E)]),
    }
    if m.num_shared:
        sff = m.d_ff_shared or ff
        p["shared"] = {
            "wi_gate": jnp.stack([dense_init(kg(), d, sff, cfg.np_dtype)
                                  for _ in range(m.num_shared)]),
            "wi_up": jnp.stack([dense_init(kg(), d, sff, cfg.np_dtype)
                                for _ in range(m.num_shared)]),
            "wo": jnp.stack([dense_init(kg(), sff, d, cfg.np_dtype)
                             for _ in range(m.num_shared)]),
        }
    return p


def _routing(logits: jnp.ndarray, top_k: int, capacity: int):
    """Build (combine, dispatch) tensors, plus aux load-balance loss.

    logits: (T, E). combine: (T, E, C) fp32 routing weights; dispatch:
    same-shape boolean. Tokens overflowing an expert's capacity are
    dropped for that expert (standard capacity-factor semantics).
    """
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)          # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Position of each (token, k) assignment inside its expert queue.
    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)  # (T, k, E)
    flat = onehot.reshape(T * top_k, E)
    pos = jnp.cumsum(flat, axis=0) - 1.0                  # (T*k, E)
    pos_in_e = (pos * flat).sum(-1).reshape(T, top_k)     # (T, k)
    keep = pos_in_e < capacity

    # Scatter into (T, E, C).
    pos_c = jnp.clip(pos_in_e, 0, capacity - 1).astype(jnp.int32)
    cap_oh = jax.nn.one_hot(pos_c, capacity, dtype=jnp.float32)  # (T,k,C)
    w = (top_p * keep)[..., None, None] * onehot[..., None] * \
        cap_oh[:, :, None, :]                             # (T,k,E,C)
    combine = w.sum(axis=1)                               # (T, E, C)
    dispatch = combine > 0

    # Switch aux loss: E * sum_e fraction_tokens_e * mean_prob_e.
    me = probs.mean(axis=0)                               # (E,)
    ce = onehot.sum(axis=1).mean(axis=0)                  # (E,)
    aux = E * jnp.sum(me * ce) / top_k
    return combine, dispatch, aux


def _expert_mlp(wi_gate, wi_up, wo, xin):
    """xin: (E, C, d) -> (E, C, d), per-expert SwiGLU."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, wi_gate))
    u = jnp.einsum("ecd,edf->ecf", xin, wi_up)
    return jnp.einsum("ecf,efd->ecd", g * u, wo)


def moe_mlp(p: dict, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar).

    Tokens are routed in fixed-size GROUPS (GShard's group dimension):
    capacity is per-group, so the (G, Sg, E, C) dispatch/combine tensors
    scale LINEARLY in total tokens (C ~ Sg*k/E, fixed) instead of the
    quadratic T*E*(T*k/E) of ungrouped routing — measured 27.7 -> fits
    on the granite train_4k cell (§Perf). The group axis also gives SPMD
    a clean data-parallel dim for the dispatch einsums (EP all-to-alls).
    """
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    group = min(getattr(m, "group_size", 4096) or 4096, T)
    pad = (-T) % group
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    G = xt.shape[0] // group
    xg = xt.reshape(G, group, d)
    capacity = max(1, int(m.capacity_factor * group * m.top_k
                          / m.num_experts))
    logits = xg @ p["router"]                            # (G, Sg, E)
    combine, dispatch, aux = jax.vmap(
        lambda lg: _routing(lg, m.top_k, capacity))(logits)
    aux = aux.mean()
    xin = jnp.einsum("gsec,gsd->gecd", dispatch.astype(xg.dtype), xg)
    out_e = jax.vmap(
        lambda xe: _expert_mlp(p["wi_gate"], p["wi_up"], p["wo"], xe))(xin)
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(xg.dtype), out_e)
    out = out.reshape(-1, d)
    if m.num_shared:
        sh = p["shared"]
        g = jax.nn.silu(jnp.einsum("td,ndf->ntf", xt, sh["wi_gate"]))
        u = jnp.einsum("td,ndf->ntf", xt, sh["wi_up"])
        out = out + jnp.einsum("ntf,nfd->td", g * u, sh["wo"])
    if pad:
        out = out[:T]
    return out.reshape(B, S, d), aux
