"""Activation-sharding hints (sequence parallelism), context-scoped.

Model code stays mesh-agnostic: it calls ``constrain(x, "residual")`` at
layer boundaries; drivers opt in by installing a policy (a dict kind ->
PartitionSpec) under an active mesh. Without a policy the call is a
no-op, so tests and single-device runs are untouched.

Why it exists (measured in EXPERIMENTS.md §Perf): with per-layer remat,
the live set is one residual activation per layer. Unconstrained, those
replicate across the model axis — 80 x (B_loc, S, d) at qwen-110b scale
is ~80 GB/device. Constraining the sequence axis onto "model" (Megatron-
style sequence parallelism; XLA inserts the all-gather/reduce-scatter
pair around attention/MLP) divides that by the TP width.
"""

from __future__ import annotations

import contextlib
from typing import Dict, Optional

import jax

_POLICY: Dict[str, object] = {}


@contextlib.contextmanager
def activation_policy(policy: Dict[str, object]):
    """policy: {"residual": PartitionSpec(batch, seq, feature), ...}"""
    global _POLICY
    old = _POLICY
    _POLICY = dict(policy)
    try:
        yield
    finally:
        _POLICY = old


def constrain(x, kind: str = "residual"):
    sharding = _POLICY.get(kind)
    if sharding is None:
        return x
    # accept NamedSharding (preferred — carries its mesh) or PartitionSpec
    spec = getattr(sharding, "spec", sharding)
    if x.ndim != len(spec):
        return x
    mesh = getattr(sharding, "mesh", None)
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for dim, ax in zip(x.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            prod = 1
            for a in axes:
                prod *= sizes.get(a, 1)
            if dim % prod != 0:
                return x   # not divisible: leave layout to the compiler
    return jax.lax.with_sharding_constraint(x, sharding)
