"""RWKV-6 LM assembly (attention-free stack, scanned over layers)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import pshint, rwkv
from .layers import (
    KeyGen, apply_norm, embed, embed_init, init_norm, unembed,
 remat_policy,
)
from .transformer import stack_layers


def _init_layer(kg: KeyGen, cfg) -> dict:
    return {
        "ln_t": init_norm("layernorm", cfg.d_model, cfg.np_dtype),
        "ln_c": init_norm("layernorm", cfg.d_model, cfg.np_dtype),
        "tm": rwkv.init_time_mix(kg, cfg),
        "cm": rwkv.init_channel_mix(kg, cfg),
    }


def init_rwkv_lm(kg: KeyGen, cfg) -> dict:
    return {
        "embed": embed_init(kg(), cfg.vocab_size, cfg.d_model, cfg.np_dtype),
        "ln_in": init_norm("layernorm", cfg.d_model, cfg.np_dtype),
        "ln_f": init_norm("layernorm", cfg.d_model, cfg.np_dtype),
        "layers": stack_layers([_init_layer(kg, cfg)
                                for _ in range(cfg.n_layers)]),
        "unembed": (jax.random.normal(kg(), (cfg.d_model, cfg.vocab_size))
                    * 0.02).astype(cfg.np_dtype),
    }


def rwkv_forward(params: dict, tokens: jnp.ndarray, cfg,
                 *, for_train: bool = False, return_hidden: bool = False):
    x = embed(params["embed"], tokens)
    x = apply_norm("layernorm", params["ln_in"], x)

    def body(h, lp):
        hn = apply_norm("layernorm", lp["ln_t"], h)
        out, _ = rwkv.time_mix_seq(lp["tm"], hn, cfg)
        h = h + out
        hn = apply_norm("layernorm", lp["ln_c"], h)
        out, _ = rwkv.channel_mix_seq(lp["cm"], hn)
        h = h + out
        h = pshint.constrain(h, "residual")
        return h, None

    fn = body
    if cfg.remat and for_train:
        fn = jax.checkpoint(body,
                            policy=remat_policy(cfg))
    x, _ = jax.lax.scan(fn, x, params["layers"])
    x = apply_norm("layernorm", params["ln_f"], x)
    if return_hidden:
        return x, jnp.float32(0.0)
    return unembed(params["unembed"], x, tied=False), jnp.float32(0.0)


def init_rwkv_state(cfg, batch):
    from .kvcache import rwkv_state
    H = cfg.d_model // cfg.rwkv_head_size
    return rwkv_state(cfg.n_layers, batch, H, cfg.rwkv_head_size,
                      cfg.d_model, cfg.np_dtype)


def rwkv_prefill(params: dict, tokens: jnp.ndarray, cfg):
    """Run the sequence and return (last logits, state, pos)."""
    x = embed(params["embed"], tokens)
    x = apply_norm("layernorm", params["ln_in"], x)

    def body(h, lp):
        hn = apply_norm("layernorm", lp["ln_t"], h)
        out, tm_state = rwkv.time_mix_seq(lp["tm"], hn, cfg)
        h = h + out
        hn = apply_norm("layernorm", lp["ln_c"], h)
        out, cm_state = rwkv.channel_mix_seq(lp["cm"], hn)
        h = h + out
        return h, {"S": tm_state["S"], "x_tm": tm_state["x_tm"],
                   "x_cm": cm_state["x_cm"]}

    x, state = jax.lax.scan(body, x, params["layers"])
    x = apply_norm("layernorm", params["ln_f"], x)
    logits = unembed(params["unembed"], x[:, -1:], tied=False)
    return logits, state, jnp.int32(tokens.shape[1])


def rwkv_decode_step(params: dict, state: dict, token: jnp.ndarray, pos,
                     cfg):
    """One token through the stack; state threaded by the layer scan.

    The per-step cost is O(1) in sequence length — the property that makes
    the long_500k cell runnable for this family.
    """
    del pos  # RWKV state carries all positional information
    x = embed(params["embed"], token)
    x = apply_norm("layernorm", params["ln_in"], x)

    def body(h, xs):
        lp, st = xs
        hn = apply_norm("layernorm", lp["ln_t"], h)
        out, tm_state = rwkv.time_mix_seq(
            lp["tm"], hn, cfg, state={"S": st["S"], "x_tm": st["x_tm"]})
        h = h + out
        hn = apply_norm("layernorm", lp["ln_c"], h)
        out, cm_state = rwkv.channel_mix_seq(
            lp["cm"], hn, state={"x_cm": st["x_cm"]})
        h = h + out
        new_st = {"S": tm_state["S"], "x_tm": tm_state["x_tm"],
                  "x_cm": cm_state["x_cm"]}
        return h, new_st

    x, new_state = jax.lax.scan(body, x, (params["layers"], state))
    x = apply_norm("layernorm", params["ln_f"], x)
    logits = unembed(params["unembed"], x, tied=False)
    return logits, new_state
