"""RG-LRU recurrent blocks (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence:  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * r_t), c = 8, and per-channel gates
r_t, i_t produced by block-diagonal projections (num_heads blocks).

Training/prefill evaluates the linear recurrence with
jax.lax.associative_scan (log-depth — the TPU-native choice); decode is a
single fused step carrying (h, conv tail). A 1:2 attn:recurrent pattern
and a short causal depthwise conv (width 4) complete the temporal-mixing
block, per the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import KeyGen, dense_init

_C = 8.0


def init_rglru(kg: KeyGen, cfg) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    nh = cfg.n_heads
    bw = w // nh
    return {
        "w_in_x": dense_init(kg(), d, w, cfg.np_dtype),
        "w_in_g": dense_init(kg(), d, w, cfg.np_dtype),
        "conv_w": (jax.random.normal(kg(), (cfg.conv_width, w)) * 0.1
                   ).astype(cfg.np_dtype),
        "conv_b": jnp.zeros((w,), cfg.np_dtype),
        # block-diagonal gate projections: (heads, bw, bw)
        "w_a": jnp.stack([dense_init(kg(), bw, bw, cfg.np_dtype)
                          for _ in range(nh)]),
        "b_a": jnp.zeros((w,), cfg.np_dtype),
        "w_x": jnp.stack([dense_init(kg(), bw, bw, cfg.np_dtype)
                          for _ in range(nh)]),
        "b_x": jnp.zeros((w,), cfg.np_dtype),
        # Lambda parametrized so a ~ U(0.9, 0.999) at init (paper App.)
        "lam": jnp.asarray(
            jnp.linspace(2.0, 6.0, w), cfg.np_dtype),
        "w_out": dense_init(kg(), w, d, cfg.np_dtype),
    }


def _block_diag(x, w, nh):
    """x (..., W) @ blockdiag(w): w (nh, bw, bw)."""
    shp = x.shape
    xb = x.reshape(*shp[:-1], nh, shp[-1] // nh)
    yb = jnp.einsum("...nb,nbc->...nc", xb, w)
    return yb.reshape(shp)


def _gates(p, x, nh):
    r = jax.nn.sigmoid(_block_diag(x, p["w_a"], nh) + p["b_a"])
    i = jax.nn.sigmoid(_block_diag(x, p["w_x"], nh) + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * \
        r.astype(jnp.float32)
    a = jnp.exp(log_a)
    # multiplier on the input branch; a^2 from log-space for stability
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta, i.astype(jnp.float32)


def rglru_scan(p: dict, x: jnp.ndarray, cfg, h0=None):
    """x: (B, S, W). Linear recurrence via associative_scan over S.

    Returns (y (B,S,W) in x.dtype, h_last (B,W) fp32).
    """
    B, S, W = x.shape
    a, beta, i = _gates(p, x, cfg.n_heads)
    b = beta * i * x.astype(jnp.float32)
    if h0 is not None:
        # Fold the carried state into the first step: h_1 = a_1 h_0 + b_1.
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, bl * ar + br

    A, Bv = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = Bv                                     # h_t for every t
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p: dict, x_t: jnp.ndarray, h: jnp.ndarray, cfg):
    """Single decode step. x_t: (B, W); h: (B, W) fp32."""
    a, beta, i = _gates(p, x_t[:, None], cfg.n_heads)
    a, beta, i = a[:, 0], beta[:, 0], i[:, 0]
    h_new = a * h + beta * i * x_t.astype(jnp.float32)
    return h_new.astype(x_t.dtype), h_new


def causal_conv(p: dict, x: jnp.ndarray, tail=None):
    """Depthwise causal conv, width cw. x: (B,S,W); tail: (B,cw-1,W).

    Returns (y (B,S,W), new_tail (B,cw-1,W)).
    """
    cw = p["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)            # (B, S+cw-1, W)
    y = sum(xp[:, k:k + x.shape[1]] * p["conv_w"][k]
            for k in range(cw))
    y = y + p["conv_b"]
    new_tail = xp[:, -(cw - 1):]
    return y.astype(x.dtype), new_tail


def recurrent_block_seq(p: dict, x: jnp.ndarray, cfg, state=None):
    """Full Griffin recurrent temporal block, sequence mode.

    x: (B, S, d_model). state: None or {"h": (B,W), "conv": (B,cw-1,W)}.
    Returns (out (B,S,d_model), new_state).
    """
    gate = jax.nn.gelu(x @ p["w_in_g"])
    xb = x @ p["w_in_x"]
    xb, tail = causal_conv(p, xb, state["conv"] if state else None)
    h, h_last = rglru_scan(p, xb, cfg, h0=state["h"] if state else None)
    out = (h * gate) @ p["w_out"]
    return out, {"h": h_last, "conv": tail}


def recurrent_block_step(p: dict, x_t: jnp.ndarray, cfg, state):
    """Decode step. x_t: (B, 1, d_model)."""
    xt = x_t[:, 0]
    gate = jax.nn.gelu(xt @ p["w_in_g"])
    xb = xt @ p["w_in_x"]
    # conv with cached tail
    tail = state["conv"]                                # (B, cw-1, W)
    cw = p["conv_w"].shape[0]
    xcat = jnp.concatenate([tail, xb[:, None]], axis=1)  # (B, cw, W)
    y = sum(xcat[:, k] * p["conv_w"][k] for k in range(cw)) + p["conv_b"]
    new_tail = xcat[:, 1:]
    h_out, h_new = rglru_step(p, y.astype(xb.dtype), state["h"], cfg)
    out = (h_out * gate) @ p["w_out"]
    return out[:, None], {"h": h_new, "conv": new_tail}
