"""Sharded, atomic, async checkpointing.

Layout:  <dir>/step_<N>/
             manifest.json      {step, leaf paths, shapes, dtypes, hash}
             <leaf-escaped>.npy one file per pytree leaf

Guarantees used by the fault-tolerance layer:
  * **atomic**: written to step_<N>.tmp-<pid> then os.rename'd — a crash
    mid-save never corrupts the latest checkpoint;
  * **async**: save() snapshots to host memory synchronously (cheap) and
    writes in a background thread (training continues);
  * **self-describing**: restore() rebuilds the pytree from the manifest
    and verifies shapes/dtypes, so an elastic restart on a different mesh
    can reshard (runtime/elastic.py) without pickled treedefs;
  * **integrity**: manifest carries a content hash per leaf (crc32) —
    partial/bit-rotted restores fail loudly.
"""

from __future__ import annotations

import json
import os
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


def _escape(path_str: str) -> str:
    return path_str.replace("/", "__")


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        name = "/".join(_key_str(k) for k in kp)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- save --------------------------------------------------------------
    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        # Synchronous device->host snapshot (consistent view), async write.
        host = [(name, np.asarray(leaf)) for name, leaf in _leaf_paths(tree)]
        self.wait()
        if blocking:
            self._write(step, host)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + f".tmp-{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for name, arr in host:
            fn = _escape(name) + ".npy"
            logical_dtype = str(arr.dtype)
            # numpy serializes ml_dtypes (bf16/f8) as raw void — store a
            # uint view and record the logical dtype in the manifest
            if arr.dtype.kind not in "biufc":
                arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append({
                "name": name, "file": fn, "shape": list(arr.shape),
                "dtype": logical_dtype,
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            import shutil
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            import shutil
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---- restore -----------------------------------------------------------
    def all_steps(self):
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(
                    tuple(f".tmp-{i}" for i in range(0))) and \
                    ".tmp-" not in d:
                try:
                    steps.append(int(d.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any) -> Any:
        """Restore into the structure of `like` (shape/dtype verified)."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {l["name"]: l for l in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for kp, leaf in flat:
            name = "/".join(_key_str(k) for k in kp)
            meta = by_name[name]
            arr = np.load(os.path.join(d, meta["file"]))
            if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != \
                    meta["crc32"]:
                raise IOError(f"checkpoint leaf {name} failed crc check")
            if str(arr.dtype) != meta["dtype"]:
                # restore ml_dtypes stored as uint views
                import ml_dtypes  # noqa: F401 — registers the dtypes
                arr = arr.view(np.dtype(meta["dtype"]))
            want_shape = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != {want_shape}")
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, like: Any):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like)
