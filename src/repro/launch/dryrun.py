import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512" + (
    " " + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first initialization, and the production mesh
needs 512 placeholder host devices.

Per cell this produces (and prints):
  * compiled.memory_analysis()  — proves the per-device footprint fits;
  * compiled.cost_analysis()    — per-device HLO FLOPs / bytes for the
                                  roofline (§Roofline reads these);
  * collective byte totals parsed from the compiled HLO text, per
    collective kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --arch ct-backproject \
      --shape P5 [--multi-pod]
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


# --------------------------------------------------------------------------
# HLO parsing: collective bytes
# --------------------------------------------------------------------------

_ARRAY_RE = re.compile(r"([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+([^=]+?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Result-bytes per collective kind from compiled HLO (per device).

    Convention: we sum RESULT sizes (for all-gather this is the gathered
    size, an upper bound on wire bytes per device; for reduce-scatter the
    scattered size, a lower bound; all-reduce wire bytes ~= 2x result in
    ring terms — reported raw here, the roofline applies the ring factor).
    `-done` ops alias their `-start` and are not counted.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        out[kind] += _type_bytes(type_str)
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


# --------------------------------------------------------------------------
# cell construction
# --------------------------------------------------------------------------

def _lower_lm_cell(arch: str, shape_name: str, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import RunConfig, get_config, get_shape
    from repro.models import build_model
    from repro.models.pshint import activation_policy
    from repro.launch import sharding as shd
    from repro.launch.train import TrainState, make_train_step
    from repro.optim import adamw_init

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    model = build_model(cfg)

    if shape.kind == "decode" and shape.seq_len >= 100_000 and \
            not cfg.sub_quadratic:
        return None, {"status": "skipped",
                      "reason": "full attention at 512k decode "
                                "(DESIGN.md §5)"}

    aparams = jax.eval_shape(lambda: model.init(0))
    pspecs = shd.param_specs(aparams, mesh)

    def nshard(tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P))

    # sequence-parallel activation policy (train/prefill only)
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    batch_axes = shd._batch_axes(mesh, shape.global_batch)
    def make_policy(batch_dim: int):
        """Megatron-style layout policy: SP residuals + TP ffn hidden.

        No "heads" constraint: measured on qwen1.5-110b it forces
        involuntary resharding copies inside the flash-attention scan
        (+1.7 GB/dev) — see EXPERIMENTS.md §Perf iteration log.
        """
        bx = shd._batch_axes(mesh, batch_dim)
        pol = {
            # MLP hidden: ff sharded over model (column-parallel)
            "ffn": NamedSharding(mesh, P(bx, None, "model")),
        }
        if shape.kind != "decode" and shape.seq_len % msize == 0:
            pol["residual"] = NamedSharding(mesh, P(bx, "model", None))
        return pol

    batch_axes = shd._batch_axes(mesh, shape.global_batch)
    policy = make_policy(shape.global_batch)

    if shape.kind == "train":
        # Microbatch gradient accumulation (O5 at the gradient buffer):
        # 8 microbatches divide the per-step activation live-set 8x and
        # keep the cross-replica reduction at once-per-step (measured:
        # 23.8 -> 12.9 GB/dev on qwen1.5-110b, §Perf).
        n_micro = 8 if shape.global_batch % (8 * 8) == 0 else 1
        micro = shape.global_batch // n_micro
        specs = model.input_specs(shape)
        batch_like = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(
                (n_micro, micro) + s.shape[1:], s.dtype), specs)
        astate = TrainState(params=aparams,
                            opt=jax.eval_shape(adamw_init, aparams))
        ospecs = shd.optimizer_specs(pspecs)
        mb_axes = shd._batch_axes(mesh, micro)
        bspecs = jax.tree_util.tree_map(
            lambda s: P(None, mb_axes, *([None] * (len(s.shape) - 2))),
            batch_like)
        state_sh = TrainState(params=nshard(pspecs), opt=nshard(ospecs))
        step = make_train_step(model, RunConfig(microbatch=n_micro),
                               total_steps=1000)
        # residual/hidden activations are (micro, S, d) under accumulation
        policy = make_policy(micro)
        with activation_policy(policy):
            # donate the train state: params/opt buffers alias in->out
            jf = jax.jit(step, in_shardings=(state_sh, nshard(bspecs)),
                         donate_argnums=(0,))
            lowered = jf.lower(astate, batch_like)
        return lowered, {"kind": "train", "n_micro": n_micro}

    if shape.kind == "prefill":
        specs = model.input_specs(shape)
        bspecs = shd.batch_specs(specs, mesh)

        def prefill_step(params, batch):
            return model.prefill(params, batch, shape.seq_len)

        with activation_policy(policy):
            jf = jax.jit(prefill_step,
                         in_shardings=(nshard(pspecs), nshard(bspecs)))
            lowered = jf.lower(aparams, specs)
        return lowered, {"kind": "prefill"}

    # decode: one new token against a seq_len-deep cache
    B = shape.global_batch
    cache_like = jax.eval_shape(
        lambda: model.init_decode_state(B, shape.seq_len))
    cspecs = shd.cache_specs(cache_like, mesh, cfg)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, P(shd._batch_axes(mesh, B), None))
    pos_sh = NamedSharding(mesh, P())

    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    # donate the cache: the multi-GB KV buffers alias in->out (§Perf)
    jf = jax.jit(decode_step,
                 in_shardings=(nshard(pspecs), nshard(cspecs), tok_sh,
                               pos_sh), donate_argnums=(1,))
    lowered = jf.lower(aparams, cache_like, tok,
                       jax.ShapeDtypeStruct((), jnp.int32))
    return lowered, {"kind": "decode"}


def _lower_ct_cell(problem_label: str, mesh):
    """Distributed back-projection (iFDK-style, DESIGN.md §4)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.ct_paper import get_problem
    from repro.core.distributed import make_distributed_bp

    prob = get_problem(problem_label)
    geom = prob.geometry()
    nb = 32
    fn, specs = make_distributed_bp(geom, mesh, nb=nb)
    img_spec, mat_spec, origin_spec, out_spec = specs
    img_like = jax.ShapeDtypeStruct((nb, geom.nw, geom.nh), jnp.float32)
    mat_like = jax.ShapeDtypeStruct((nb, 3, 4), jnp.float32)
    origin_like = jax.ShapeDtypeStruct((2,), jnp.float32)
    jf = jax.jit(fn, in_shardings=(NamedSharding(mesh, img_spec),
                                   NamedSharding(mesh, mat_spec),
                                   NamedSharding(mesh, origin_spec)),
                 out_shardings=NamedSharding(mesh, out_spec))
    lowered = jf.lower(img_like, mat_like, origin_like)
    return lowered, {"kind": "ct-backproject", "nb": nb}


# --------------------------------------------------------------------------
# cell runner
# --------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True) -> dict:
    from repro.launch.mesh import make_production_mesh

    t0 = time.time()
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_chips = 512 if multi_pod else 256
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": n_chips}
    hlo_text = None
    try:
        if arch == "ct-backproject":
            lowered, info = _lower_ct_cell(shape_name, mesh)
        else:
            lowered, info = _lower_lm_cell(arch, shape_name, mesh)
        rec.update(info)
        if lowered is None:           # skipped cell
            rec["status"] = rec.get("status", "skipped")
        else:
            compiled = lowered.compile()
            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):   # jax <= 0.4.x: per-device
                ca = ca[0] if ca else {}        # list of dicts
            hlo = compiled.as_text()
            hlo_text = hlo
            coll = collective_bytes(hlo)
            # Loop-aware walk: XLA cost_analysis counts while bodies ONCE
            # (a scanned layer stack under-reports ~n_layers x); this
            # multiplies through scan trip counts. See hlo_cost.py.
            from repro.launch import hlo_cost
            la = hlo_cost.analyze(hlo)
            rec.update({
                "status": "ok",
                "compile_s": round(time.time() - t0, 1),
                "memory": {
                    "argument_bytes": ma.argument_size_in_bytes,
                    "output_bytes": ma.output_size_in_bytes,
                    "temp_bytes": ma.temp_size_in_bytes,
                    "alias_bytes": ma.alias_size_in_bytes,
                    "peak_est_bytes": ma.argument_size_in_bytes
                    + ma.temp_size_in_bytes + ma.output_size_in_bytes
                    - ma.alias_size_in_bytes,
                },
                "cost": {
                    "flops_per_device": la["flops"],
                    "bytes_per_device": la["bytes"],
                    "transcendentals": la["trans"],
                    "xla_flops_loop_body_once": ca.get("flops", 0.0),
                    "xla_bytes_loop_body_once": ca.get("bytes accessed",
                                                       0.0),
                },
                "collectives": {
                    "bytes": la["coll"],
                    "counts": la["coll_counts"],
                    "total_bytes": sum(la["coll"].values()),
                    "body_once_bytes": coll["bytes"],
                },
            })
            if verbose:
                print(f"[{arch} x {shape_name} x {mesh_name}] "
                      f"compile {rec['compile_s']}s")
                print("  memory_analysis:", ma)
                print(f"  cost(loop-aware): flops/dev={la['flops']:.3e} "
                      f"bytes/dev={la['bytes']:.3e}")
                print(f"  collectives: "
                      f"{ {k: int(v) for k, v in la['coll_counts'].items() if v} } "
                      f"total {sum(la['coll'].values())/1e6:.1f} MB/dev")
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] FAILED: "
                  f"{rec['error']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = os.path.join(out_dir,
                          f"{arch}__{shape_name}__{mesh_name}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] == "ok" and hlo_text is not None:
            import gzip
            with gzip.open(fn.replace(".json", ".hlo.gz"), "wt") as f:
                f.write(hlo_text)
    return rec


def reanalyze(out_dir: str) -> int:
    """Recompute cost/collective fields from saved .hlo.gz artifacts
    (no recompilation) after hlo_cost model changes."""
    import glob
    import gzip

    from repro.launch import hlo_cost
    n = 0
    for fn in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        gz = fn.replace(".json", ".hlo.gz")
        if not os.path.exists(gz):
            continue
        with open(fn) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        with gzip.open(gz, "rt") as f:
            hlo = f.read()
        la = hlo_cost.analyze(hlo)
        rec["cost"]["flops_per_device"] = la["flops"]
        rec["cost"]["bytes_per_device"] = la["bytes"]
        rec["cost"]["transcendentals"] = la["trans"]
        rec["collectives"]["bytes"] = la["coll"]
        rec["collectives"]["counts"] = la["coll_counts"]
        rec["collectives"]["total_bytes"] = sum(la["coll"].values())
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    return n


LM_SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
CT_SHAPE_NAMES = ("P1", "P5", "P9", "P10")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute cost fields from saved .hlo.gz")
    args = ap.parse_args()

    if args.reanalyze:
        n = reanalyze(args.out)
        print(f"reanalyzed {n} cells")
        sys.exit(0)

    from repro.configs import list_archs

    cells = []
    if args.all:
        for arch in list_archs():
            for shape in LM_SHAPE_NAMES:
                cells.append((arch, shape))
        for shape in CT_SHAPE_NAMES:
            cells.append(("ct-backproject", shape))
    else:
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for multi_pod in meshes:
        for arch, shape in cells:
            mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
            out_fn = os.path.join(
                args.out, f"{arch}__{shape}__{mesh_name}.json")
            if args.skip_existing and os.path.exists(out_fn):
                with open(out_fn) as f:
                    prev = json.load(f)
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[{arch} x {shape} x {mesh_name}] cached "
                          f"({prev['status']})")
                    continue
            rec = run_cell(arch, shape, multi_pod=multi_pod,
                           out_dir=args.out)
            if rec["status"] == "error":
                failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
