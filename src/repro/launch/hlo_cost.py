"""Loop-aware cost analysis of compiled HLO text.

Why this exists: ``compiled.cost_analysis()`` counts every while-loop body
ONCE — a framework whose layer stack is a lax.scan (and whose gradient
accumulation is another scan) under-reports FLOPs/bytes/collectives by
the loop trip counts (~100x for a 95-layer model with 4 microbatches).
This module walks the HLO call graph, extracts scan trip counts from
while-loop conditions, and multiplies through, so the roofline terms in
EXPERIMENTS.md reflect the whole step.

Model (deliberately simple, documented in EXPERIMENTS.md §Roofline):
  * flops: exact for dot (2*prod(result)*prod(contracting)), 1/elem for
    elementwise arithmetic, counted through fusions;
  * bytes: boundary traffic of top-level (unfused) ops — operands +
    result of fusions/dots/copies/DUS/collectives — i.e. what actually
    crosses HBM on a fused backend;
  * collectives: RESULT bytes per kind, multiplied by loop trips.
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(
    r"([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "select", "and", "or", "xor", "negate", "abs", "floor", "ceil",
    "round-nearest-afz", "clamp", "compare", "sign",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "cosine", "sine", "logistic", "expm1", "log1p",
                   "atan2", "erf", "cbrt"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems = 0
    nbytes = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES.get(dt, 4)
    return elems, nbytes


_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s*\(.*\)\s*->")
_OP_LINE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([^=]+?)\s+([a-z][\w\-]*)\(")
_CALL_ATTR = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_BODY_ATTR = re.compile(r"body=%?([\w\.\-]+)")
_COND_ATTR = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_ATTR = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((-?[0-9]+)\)")


class _Comp:
    def __init__(self, name):
        self.name = name
        self.ops: List[dict] = []
        self.symbols: Dict[str, str] = {}   # %name -> type string
        self.trip_const: Optional[int] = None  # if this is a while cond


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_computations(hlo: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in hlo.splitlines():
        # strip /*index=N*/ tuple-position comments — they contain '='
        # and break the op-line regex
        if "/*" in line:
            line = _COMMENT_RE.sub("", line)
        if not line.startswith(" "):
            m = _COMP_HDR.match(line.strip())
            if m and "{" in line:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.group(1), m.group(2).strip(), m.group(3)
        cur.symbols[name] = type_str
        # operands: names inside the first (...) after the opcode
        paren = line[m.end() - 1:]
        depth = 0
        arg_str = ""
        for ch in paren:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            if ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                arg_str += ch
        operands = _OPERAND_RE.findall(arg_str)
        op = {"name": name, "type": type_str, "opcode": opcode,
              "operands": operands, "line": line}
        cur.ops.append(op)
        if opcode == "constant":
            cm = _CONST_RE.search(line)
            if cm:
                cur.symbols["__const_" + name] = cm.group(1)
    return comps


def _while_trip_count(cond: _Comp) -> int:
    """Extract N from the canonical scan condition compare(iv, N), LT."""
    consts = {}
    for op in cond.ops:
        if op["opcode"] == "constant":
            cm = _CONST_RE.search(op["line"])
            if cm:
                consts[op["name"]] = int(cm.group(1))
    for op in cond.ops:
        if op["opcode"] == "compare" and "direction=LT" in op["line"]:
            for o in op["operands"]:
                if o in consts:
                    return max(consts[o], 1)
    # fallback: any constant in the condition
    if consts:
        return max(max(consts.values()), 1)
    return 1


def _op_flops(op, comp: _Comp) -> Tuple[float, float]:
    """(flops, transcendentals) of one op line (fusion internals are
    handled by recursion into the called computation)."""
    opcode = op["opcode"]
    elems, _ = _shape_elems_bytes(op["type"])
    if opcode == "dot":
        cm = _CONTRACT_RE.search(op["line"])
        contract = 1
        if cm and op["operands"]:
            lhs_t = comp.symbols.get(op["operands"][0], "")
            m2 = _ARRAY_RE.search(lhs_t)
            if m2:
                dims = [int(d) for d in m2.group(2).split(",") if d]
                for idx in (int(i) for i in cm.group(1).split(",") if i):
                    if idx < len(dims):
                        contract *= dims[idx]
        return 2.0 * elems * contract, 0.0
    if opcode in _ELEMENTWISE:
        return float(elems), 0.0
    if opcode in _TRANSCENDENTAL:
        return float(elems), float(elems)
    if opcode == "reduce" and op["operands"]:
        src_t = comp.symbols.get(op["operands"][0], op["type"])
        src_elems, _ = _shape_elems_bytes(src_t)
        return float(src_elems), 0.0
    if opcode == "convolution":
        # not used by this framework; crude: 2 * result elems
        return 2.0 * elems, 0.0
    return 0.0, 0.0


_MEM_OPS = {"fusion", "dot", "copy", "dynamic-update-slice",
            "dynamic-slice", "convert", "transpose", "broadcast",
            "reduce", "concatenate", "pad", "slice", "reverse", "gather",
            "scatter", "iota", "convolution", "sort", "rng-bit-generator"}


def _op_bytes(op, comp: _Comp) -> float:
    """Boundary HBM traffic of a top-level op.

    Slicing ops move only the slice, not their (possibly huge) operand:
      dynamic-slice / slice / gather  -> 2 * result bytes
      dynamic-update-slice            -> 2 * update-operand bytes
    (in-place on the aliased buffer). Everything else: operands + result.
    """
    opcode = op["opcode"]
    if opcode not in _MEM_OPS and not opcode.startswith(
            tuple(_COLLECTIVES)):
        return 0.0
    _, out_b = _shape_elems_bytes(op["type"])
    if opcode in ("dynamic-slice", "slice", "gather"):
        return 2.0 * out_b
    if opcode == "dynamic-update-slice":
        upd = op["operands"][1] if len(op["operands"]) > 1 else None
        t = comp.symbols.get(upd) if upd else None
        if t:
            return 2.0 * _shape_elems_bytes(t)[1]
        return float(out_b)
    if opcode in ("broadcast", "iota"):
        return float(out_b)
    total = float(out_b)
    for o in op["operands"]:
        t = comp.symbols.get(o)
        if t:
            total += _shape_elems_bytes(t)[1]
    return total


def analyze(hlo: str) -> dict:
    """Loop-aware totals: flops, transcendentals, bytes, collectives."""
    comps = parse_computations(hlo)
    entry_name = None
    m = re.search(r"^ENTRY %?([\w\.\-]+)", hlo, re.M)
    if m:
        entry_name = m.group(1)
    memo: Dict[str, dict] = {}

    def comp_cost(name: str, *, in_fusion: bool) -> dict:
        key = f"{name}|{in_fusion}"
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        zero = {"flops": 0.0, "trans": 0.0, "bytes": 0.0,
                "coll": {k: 0.0 for k in _COLLECTIVES},
                "coll_counts": {k: 0.0 for k in _COLLECTIVES}}
        if comp is None:
            return zero
        tot = {"flops": 0.0, "trans": 0.0, "bytes": 0.0,
               "coll": {k: 0.0 for k in _COLLECTIVES},
               "coll_counts": {k: 0.0 for k in _COLLECTIVES}}
        memo[key] = tot  # break cycles defensively
        for op in comp.ops:
            opcode = op["opcode"]
            f, tr = _op_flops(op, comp)
            tot["flops"] += f
            tot["trans"] += tr
            if not in_fusion:
                tot["bytes"] += _op_bytes(op, comp)
            base = opcode[:-6] if opcode.endswith("-start") else opcode
            if base in _COLLECTIVES and not opcode.endswith("-done"):
                _, b = _shape_elems_bytes(op["type"])
                tot["coll"][base] += b
                tot["coll_counts"][base] += 1
            if opcode == "fusion":
                cm = _CALLS_ATTR.search(op["line"])
                if cm:
                    sub = comp_cost(cm.group(1), in_fusion=True)
                    tot["flops"] += sub["flops"]
                    tot["trans"] += sub["trans"]
                    for k in _COLLECTIVES:
                        tot["coll"][k] += sub["coll"][k]
                        tot["coll_counts"][k] += sub["coll_counts"][k]
            elif opcode == "while":
                bm = _BODY_ATTR.search(op["line"])
                cm = _COND_ATTR.search(op["line"])
                trips = 1
                if cm and cm.group(1) in comps:
                    trips = _while_trip_count(comps[cm.group(1)])
                if bm:
                    sub = comp_cost(bm.group(1), in_fusion=False)
                    for k in ("flops", "trans", "bytes"):
                        tot[k] += trips * sub[k]
                    for k in _COLLECTIVES:
                        tot["coll"][k] += trips * sub["coll"][k]
                        tot["coll_counts"][k] += trips * \
                            sub["coll_counts"][k]
            elif opcode in ("call", "conditional", "custom-call"):
                cm = _CALLS_ATTR.search(op["line"])
                if cm:
                    sub = comp_cost(cm.group(1), in_fusion=in_fusion)
                    for k in ("flops", "trans", "bytes"):
                        tot[k] += sub[k]
                    for k in _COLLECTIVES:
                        tot["coll"][k] += sub["coll"][k]
                        tot["coll_counts"][k] += sub["coll_counts"][k]
        return tot

    if entry_name is None:
        return comp_cost("", in_fusion=False)
    out = comp_cost(entry_name, in_fusion=False)
    out["total_collective_bytes"] = sum(out["coll"].values())
    return out
