"""Sharding rules: param-path -> PartitionSpec, divisibility-guarded.

Strategy (DESIGN.md §4): 2-D weight sharding — tensor-parallel over
"model" on the contraction-exposed axis, FSDP over "data" on the other —
so per-chip parameter bytes scale with the FULL mesh (256x), not just TP.
Experts are expert-parallel over "model". The "pod" axis never appears in
a weight spec: weights replicate across pods and only gradient reductions
cross the pod boundary (DCN-friendly).

Every candidate axis is divisibility-checked against the actual dim and
dropped (replicated) if it does not divide — vocab sizes like 49155 or
head counts like 14 simply fall back, keeping every (arch x mesh) cell
compilable by construction.
"""

from __future__ import annotations

import re
from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# (regex on the FULL path, spec template applied to the TRAILING dims).
# Templates name mesh axes; leading (stacked-layer / expert) dims are
# handled structurally below.
_MATRIX_RULES = [
    # --- embeddings / unembedding ----------------------------------------
    (r"(^|/)embed$",               ("model", "data")),    # (V, d)
    (r"(^|/)unembed$",             ("data", "model")),    # (d, V)
    # --- MoE (leading E dim handled structurally) -------------------------
    (r"/moe/router$",              ("data", None)),       # (d, E)
    (r"/moe/wi_(gate|up)$",        ("expert", "data", None)),  # (E,d,ff)
    (r"/moe/wo$",                  ("expert", None, "data")),  # (E,ff,d)
    (r"/moe/shared/wi_(gate|up)$", (None, "data", "model")),
    (r"/moe/shared/wo$",           (None, "model", "data")),
    # --- MLA ---------------------------------------------------------------
    (r"/attn/w_dkv$",              ("data", None)),
    (r"/attn/w_uk$",               (None, "model")),
    (r"/attn/w_uv$",               (None, "model")),
    (r"/attn/w_kr$",               ("data", None)),
    # --- attention (GQA + cross) -------------------------------------------
    (r"/(attn|cross)/wq$",         ("data", "model")),
    # wk/wv (+ biases): NEVER model-shard the kv output dim. It is
    # (KVH*hd) and the guard below can only check divisibility, not
    # whole-head alignment — a split inside head_dim lands a sharded-axis
    # slice in apply_rope (RoPE halves) for k, and for v it measurably
    # perturbs the flash-attention train step (GQA smoke config on a
    # (2, 2)+ mesh: loss drifts 3e-3, gnorm 30% — far beyond reduction-
    # order noise). The GQA kv projections are the small ones (8-16x
    # smaller than wq); FSDP over "data" keeps their memory scaled.
    (r"/(attn|cross)/w[kv]$",      ("data", None)),
    (r"/(attn|cross)/wo$",         ("model", "data")),
    (r"/(attn|cross)/bq$",         ("model",)),
    (r"/(attn|cross)/b[kv]$",      (None,)),
    # --- MLPs ----------------------------------------------------------------
    (r"/mlp/wi(_gate|_up)?$",      ("data", "model")),
    (r"/mlp/wo$",                  ("model", "data")),
    # --- RG-LRU --------------------------------------------------------------
    (r"/rec/w_in_[xg]$",           ("data", "model")),
    (r"/rec/w_out$",               ("model", "data")),
    (r"/rec/w_[ax]$",              ("model", None, None)),  # (nh, bw, bw)
    (r"/rec/b_[ax]$",              ("model",)),
    (r"/rec/conv_w$",              (None, "model")),
    (r"/rec/conv_b$",              ("model",)),
    (r"/rec/lam$",                 ("model",)),
    # --- RWKV ------------------------------------------------------------------
    (r"/tm/w_[rkvg]$",             ("data", "model")),
    (r"/tm/w_o$",                  ("model", "data")),
    (r"/tm/maa_w1$",               ("data", None)),
    (r"/tm/maa_w2$",               (None, None, "data")),
    (r"/tm/decay_w1$",             ("data", None)),
    (r"/tm/decay_w2$",             (None, "data")),
    (r"/tm/bonus$",                (None, None)),
    (r"/cm/w_k$",                  ("data", "model")),
    (r"/cm/w_v$",                  ("model", "data")),
    (r"/cm/w_r$",                  ("data", "model")),
    # --- VLM projector -----------------------------------------------------------
    (r"/vlm/proj1$",               (None, "data")),
    (r"/vlm/proj2$",               ("data", "model")),
]

# Path components that indicate one stacked leading axis each.
_STACK_KEYS = ("layers", "lead_layers", "enc_layers", "dec_layers",
               "units", "trail")


def _axis_size(mesh, name) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def spec_for_param(path: str, shape, mesh, *, attn_fsdp: bool = True) -> P:
    """PartitionSpec for one parameter leaf.

    attn_fsdp=False: attention projections are TP-only (no "data" factor)
    — trades per-use FSDP all-gathers (x24/layer/step under microbatch
    accumulation + remat) for +bf16-params/TP memory; pair with ZeRO-1
    optimizer sharding (optimizer_specs(zero1=True)) so m/v stay fully
    sharded. Measured on qwen1.5-110b train_4k in EXPERIMENTS.md §Perf.
    """
    n_stack = sum(1 for part in path.split("/") if part in _STACK_KEYS)
    template = None
    for pat, tmpl in _MATRIX_RULES:
        if re.search(pat, path):
            template = list(tmpl)
            break
    if not attn_fsdp and re.search(r"/(attn|cross)/w[qo]$", path):
        # TP-only for the SQUARE projections (wq/wo) — ~88% of attention
        # FSDP gather bytes for half the replication cost; wk/wv (GQA,
        # d x kv*hd) stay FSDP (their gathers are 8x smaller).
        template = [None if t == "data" else t for t in template]
    trailing = len(shape) - n_stack
    if template is None:
        template = [None] * trailing
    # "expert" pseudo-axis = expert parallelism on the mesh model axis.
    template = ["model" if t == "expert" else t for t in template]
    if len(template) != trailing:
        # structural mismatch (e.g. vector where rule expected matrix):
        template = (template + [None] * trailing)[:trailing]
    spec = [None] * n_stack
    for dim, ax in zip(shape[n_stack:], template):
        if ax is None:
            spec.append(None)
        elif ax in mesh.axis_names and dim % _axis_size(mesh, ax) == 0:
            spec.append(ax)
        else:
            spec.append(None)   # divisibility fallback: replicate
    return P(*spec)


def param_specs(params_or_abstract, mesh, *, attn_fsdp: bool = True):
    """Tree of PartitionSpecs matching a (possibly abstract) param tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_or_abstract)

    def key_str(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return "/".join(parts)

    specs = [spec_for_param(key_str(kp), leaf.shape, mesh,
                            attn_fsdp=attn_fsdp)
             for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params_or_abstract, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params_or_abstract, mesh),
        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# batch / cache / optimizer specs
# --------------------------------------------------------------------------

def _batch_axes(mesh, dim: int):
    """Largest prefix of ('pod','data') whose product divides dim."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen = []
    prod = 1
    for a in axes:
        if dim % (prod * _axis_size(mesh, a)) == 0:
            chosen.append(a)
            prod *= _axis_size(mesh, a)
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


def batch_specs(batch_tree, mesh):
    """Shard the leading (global batch) dim of every batch leaf."""
    def spec(leaf):
        b = leaf.shape[0]
        ax = _batch_axes(mesh, b)
        return P(ax, *([None] * (len(leaf.shape) - 1)))
    return jax.tree_util.tree_map(spec, batch_tree)


def cache_specs(cache_tree, mesh, cfg, *, strategy: str = "heads"):
    """Decode-state specs: batch on ('pod','data'), one trailing axis on
    'model'. Leaf layouts are (L, B, ...).

    strategy="seq":     prefer the time axis (dim 2) — context-parallel
                        KV sharding. Measured (EXPERIMENTS.md §Perf): the
                        per-step dynamic-update-slice at a dynamic
                        position straddles shards and XLA re-materializes
                        the cache (+~18 GB/dev temp on the 32k decode
                        cells of the dense archs).
    strategy="feature": prefer the LAST dim (head_dim / latent).
                        Measured: 14x MORE collective bytes than "seq"
                        (score psums over the contracted dim) — refuted
                        as a default, kept for A/B.
    strategy="heads":   prefer the KV-heads dim (dim 3 of full caches) —
                        the per-step DUS is then fully shard-local (no
                        involuntary rematerialization) AND attention
                        needs no cross-shard reduction. Only possible
                        when n_kv_heads divides the model axis (e.g.
                        stablelm kv=32); falls back to "seq" order.
                        Default.
    """
    msize = _axis_size(mesh, "model")

    def spec(leaf):
        shape = leaf.shape
        out = [None, _batch_axes(mesh, shape[1])] + \
            [None] * (len(shape) - 2)
        if strategy == "seq":
            candidates = [2] + list(range(len(shape) - 1, 2, -1))
        elif strategy == "heads":
            candidates = ([3] if len(shape) == 5 else []) + \
                [2] + list(range(len(shape) - 1, 2, -1))
        else:
            candidates = list(range(len(shape) - 1, 1, -1))
        for i in candidates:
            if i < len(shape) and shape[i] % msize == 0 and \
                    shape[i] >= msize:
                out[i] = "model"
                break
        return P(*out)

    return jax.tree_util.tree_map(spec, cache_tree)


def optimizer_specs(pspecs, params_or_abstract=None, mesh=None,
                    *, zero1: bool = False):
    """AdamW state specs. Default: mirror the param specs.

    zero1=True (requires the abstract params + mesh): additionally shard
    m/v over "data" on the first divisible replicated dim even where the
    PARAM is TP-only — ZeRO-1. The fp32 optimizer state is the largest
    per-device tensor class; this keeps it fully distributed while
    letting hot weights skip FSDP gathers.
    """
    from repro.optim.optimizer import AdamWState

    if not zero1:
        mirror = jax.tree_util.tree_map(
            lambda s: s, pspecs, is_leaf=lambda x: isinstance(x, P))
        return AdamWState(step=P(), m=mirror, v=mirror)

    dsize = _axis_size(mesh, "data")
    flat_s, treedef = jax.tree_util.tree_flatten(
        pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_p = treedef.flatten_up_to(params_or_abstract)

    def z1(spec, leaf):
        used = {a for a in jax.tree_util.tree_leaves(tuple(spec))}
        if "data" in used:
            return spec
        out = list(spec)
        for i, (ax, dim) in enumerate(zip(spec, leaf.shape)):
            if ax is None and dim % dsize == 0 and dim >= dsize:
                out[i] = "data"
                return P(*out)
        return spec

    zspecs = treedef.unflatten([z1(s, p)
                                for s, p in zip(flat_s, flat_p)])
    return AdamWState(step=P(), m=zspecs, v=zspecs)
