# Launch layer: production meshes, sharding rules, drivers, dry-run,
# roofline. Import modules directly (repro.launch.mesh etc.); this
# package intentionally avoids importing jax at package-import time so
# dryrun.py can set XLA_FLAGS before any jax initialization.
