"""Three-term roofline analysis over dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOPs
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(cost_analysis of the SPMD-partitioned executable is per device, so
dividing by per-chip peaks is identical to the global form
HLO_FLOPs / (chips * peak).)

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Also derives MODEL_FLOPS (6*N*D train / 2*N*D inference, N = active
params for MoE) and the usefulness ratio MODEL_FLOPS / HLO_FLOPs_global,
which catches remat recompute and padding waste.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline --dir artifacts/dryrun
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / link (ICI)


def model_flops(arch: str, shape_name: str) -> Optional[float]:
    """6*N*D (train) or 2*N*D (prefill/decode), N active params."""
    if arch == "ct-backproject":
        from repro.configs.ct_paper import get_problem
        prob = get_problem(shape_name)
        # per dry-run step: one nb=32 batch; ~8 useful flops per voxel
        # update (2-mix subline interpolation + weighting + accumulate).
        nb = 32
        return 8.0 * prob.vol ** 3 * nb
    from repro.configs import get_config, get_shape
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n = cfg.active_param_count()
    d = shape.tokens_per_step
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * d


def terms_for(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    flops_dev = rec["cost"]["flops_per_device"] or 0.0
    bytes_dev = rec["cost"]["bytes_per_device"] or 0.0
    coll_dev = rec["collectives"]["total_bytes"]
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"),
                   (t_coll, "collective"))[1]
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = flops_dev * chips
    useful = (mf / hlo_global) if (mf and hlo_global) else None
    bound = max(t_comp, t_mem, t_coll)
    roofline_frac = (t_comp / bound) if bound else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": useful,
        "roofline_fraction": roofline_frac,
        "peak_mem_gb": rec["memory"]["peak_est_bytes"] / 1e9,
    }


def load_dir(d: str):
    recs = []
    for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(rows, *, mesh_filter: Optional[str] = None) -> str:
    hdr = ("| arch | shape | t_compute | t_memory | t_collective | "
           "dominant | useful | roofline-frac | peak GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r is None or (mesh_filter and r["mesh"] != mesh_filter):
            continue
        useful = (f"{r['useful_ratio']:.2f}"
                  if r["useful_ratio"] is not None else "-")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} "
            f"| {r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
            f"| {r['dominant']} | {useful} "
            f"| {r['roofline_fraction']:.2f} | {r['peak_mem_gb']:.1f} |\n")
    return "".join(out)


def pick_hillclimb_cells(rows):
    """The three §Perf targets: worst roofline fraction, most
    collective-bound, and the paper's own kernel cell."""
    ok = [r for r in rows if r and r["mesh"] == "pod16x16"
          and r["arch"] != "ct-backproject"]
    worst = min(ok, key=lambda r: r["roofline_fraction"], default=None)
    coll = max(ok, key=lambda r: r["t_collective_s"]
               / max(r["t_compute_s"], 1e-12), default=None)
    ct = [r for r in rows if r and r["arch"] == "ct-backproject"
          and r["mesh"] == "pod16x16"]
    ct_cell = max(ct, key=lambda r: r["t_compute_s"], default=None)
    return worst, coll, ct_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    rows = [terms_for(r) for r in load_dir(args.dir)]
    print(markdown_table(rows, mesh_filter=args.mesh))
    worst, coll, ct = pick_hillclimb_cells(rows)
    print("\nhillclimb candidates:")
    for label, r in (("worst-fraction", worst),
                     ("most-collective-bound", coll),
                     ("paper-kernel", ct)):
        if r:
            print(f"  {label}: {r['arch']} x {r['shape']} "
                  f"(dominant={r['dominant']})")


if __name__ == "__main__":
    main()
