"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required for the dry-run's
host-platform device-count override to act first.
"""

from __future__ import annotations

import jax


def make_mesh(shape, names):
    """Version-portable ``jax.make_mesh``.

    Newer jax wants explicit ``axis_types`` (we always mean Auto);
    mid-0.4.x has ``jax.make_mesh`` without the kwarg; older 0.4.x has
    neither and needs ``Mesh(create_device_mesh(...))`` directly.
    """
    if hasattr(jax, "make_mesh"):
        axis_type = getattr(jax.sharding, "AxisType", None)
        if axis_type is not None:
            try:
                return jax.make_mesh(
                    shape, names,
                    axis_types=(axis_type.Auto,) * len(names))
            except TypeError:
                pass
        return jax.make_mesh(shape, names)
    from jax.experimental import mesh_utils
    return jax.sharding.Mesh(mesh_utils.create_device_mesh(shape), names)


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh.

    Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the "pod" axis
    maps to the DCN/ICI-sparse dimension — only gradient/volume
    all-reduces cross it (see launch/sharding.py and DESIGN.md §4).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist right now (tests / elastic restarts)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that shard the batch: ('pod','data') when pod exists."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
