"""Serving driver: batched prefill + decode with continuous batching.

``make_serve_steps`` builds the two jitted SPMD entry points the dry-run
lowers (prefill_step / decode_step with explicit cache shardings);
``BatchedServer`` is a runnable host-scale server with slot-based
continuous batching (examples/serve_lm.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from . import sharding as shd


def make_decode_fn(model):
    cfg = model.cfg

    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return decode_step


def decode_state_like(model, batch: int, max_len: int):
    """Abstract decode state (ShapeDtypeStructs) for lowering."""
    return jax.eval_shape(
        lambda: model.init_decode_state(batch, max_len))


def shard_decode_step(model, mesh, abstract_params, batch: int,
                      max_len: int):
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = model.cfg
    pspecs = shd.param_specs(abstract_params, mesh)
    cache_like = decode_state_like(model, batch, max_len)
    cspecs = shd.cache_specs(cache_like, mesh, cfg)

    def nshard(tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P))

    tok_spec = NamedSharding(mesh, P(shd._batch_axes(mesh, batch), None))
    pos_spec = NamedSharding(mesh, P())
    fn = jax.jit(
        make_decode_fn(model),
        in_shardings=(nshard(pspecs), nshard(cspecs), tok_spec, pos_spec),
    )
    return fn, cache_like, cspecs


# --------------------------------------------------------------------------
# host-scale continuous-batching server
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    """Slot-based continuous batching over a fixed decode batch.

    Admission: waiting requests claim free slots; their prompts are
    prefilled one slot at a time (per-slot prefill keeps the example
    simple; a production server would batch prefills too). Every decode
    step advances ALL active slots by one token.
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256):
        self.model = build_model(cfg)
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.requests: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int64)
        self._cache = None
        self._decode = jax.jit(make_decode_fn(self.model))

    # -- single-slot prefill (model API is batch-first, so B=1) ----------
    def _prefill_slot(self, slot: int, req: Request):
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1, pos = self.model.prefill(
            self.params, {"tokens": tokens}, self.max_len)
        if self._cache is None:
            self._cache = jax.tree_util.tree_map(
                lambda a: jnp.concatenate([a] * self.slots, axis=1),
                cache1)
        else:
            self._cache = jax.tree_util.tree_map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=1),
                self._cache, cache1)
        self.pos[slot] = int(pos)
        tok = int(jnp.argmax(logits[0, -1]))
        req.out.append(tok)

    def submit(self, req: Request) -> bool:
        for s in range(self.slots):
            if self.requests[s] is None:
                self.requests[s] = req
                self._prefill_slot(s, req)
                return True
        return False

    def step(self):
        """One decode step for all active slots (greedy)."""
        active = [s for s, r in enumerate(self.requests)
                  if r is not None and not r.done]
        if not active or self._cache is None:
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.requests[s].out[-1]
        # NOTE: slots share a position scalar per decode call; the server
        # decodes at the max active position and masks per-slot validity
        # through the cache contents (positions beyond a slot's pos hold
        # zeros written at prefill padding).
        pos = int(max(self.pos[s] for s in active))
        logits, self._cache = self._decode(
            self.params, self._cache, jnp.asarray(toks), jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s in active:
            r = self.requests[s]
            r.out.append(int(nxt[s]))
            self.pos[s] += 1
            if len(r.out) >= r.max_new_tokens:
                r.done = True
                self.requests[s] = None   # free the slot

    def run_until_drained(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if all(r is None for r in self.requests):
                break
            self.step()
