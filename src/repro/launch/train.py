"""Training driver: sharded train_step factory + fault-tolerant loop.

``make_train_step`` builds the jitted SPMD step with explicit in/out
shardings (params FSDPxTP, optimizer state mirroring params, batch over
the data axes). ``main`` wires pipeline + checkpointer + FT loop into a
runnable trainer (examples/train_lm.py uses it at toy scale on CPU).
"""

from __future__ import annotations

import argparse
import functools
import logging
import time
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_config, get_smoke_config
from repro.models import build_model
from repro.optim import (
    accumulate_gradients, adamw_init, adamw_update, clip_by_global_norm,
    cosine_warmup,
)
from . import sharding as shd

log = logging.getLogger("repro.train")


class TrainState(NamedTuple):
    params: Any
    opt: Any


def make_train_step(model, run: RunConfig, total_steps: int,
                    grad_shardings=None):
    """(state, batch) -> (state, metrics); pure, jit-able, SPMD-ready."""

    def step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        lr = cosine_warmup(state.opt.step, base_lr=run.lr,
                           warmup_steps=run.warmup_steps,
                           total_steps=total_steps)
        if run.microbatch:
            # batch leaves are (n_micro, micro, ...): accumulate (O5 —
            # one gradient buffer + one reduction per step).
            loss, grads = accumulate_gradients(
                model.loss, state.params, batch,
                grad_shardings=grad_shardings)
        else:
            loss, grads = jax.value_and_grad(model.loss)(state.params,
                                                         batch)
        grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
        params, opt = adamw_update(state.params, grads, state.opt, lr=lr,
                                   weight_decay=run.weight_decay)
        return TrainState(params, opt), {"loss": loss, "gnorm": gnorm,
                                         "lr": lr}

    return step


def shard_train_step(step_fn, model, mesh, abstract_params, batch_like):
    """jit the step with explicit shardings under `mesh`."""
    from jax.sharding import NamedSharding

    pspecs = shd.param_specs(abstract_params, mesh)
    ospecs = shd.optimizer_specs(pspecs)
    bspecs = shd.batch_specs(batch_like, mesh)

    def nshard(tree):
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), tree,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    state_shardings = TrainState(params=nshard(pspecs), opt=nshard(ospecs))
    metric_shardings = {"loss": NamedSharding(mesh, jax.sharding.PartitionSpec()),
                        "gnorm": NamedSharding(mesh, jax.sharding.PartitionSpec()),
                        "lr": NamedSharding(mesh, jax.sharding.PartitionSpec())}
    return jax.jit(
        step_fn,
        in_shardings=(state_shardings, nshard(bspecs)),
        out_shardings=(state_shardings, metric_shardings),
    ), state_shardings


def init_state(model, run: RunConfig) -> TrainState:
    params = model.init(run.seed)
    return TrainState(params=params, opt=adamw_init(params))


# --------------------------------------------------------------------------
# runnable trainer (host-scale; the same code drives the pod-scale mesh)
# --------------------------------------------------------------------------

def train(cfg, run: RunConfig, *, shape=None, use_mesh=None,
          pipeline=None, quiet: bool = False):
    from repro.checkpoint import Checkpointer
    from repro.data import TokenPipeline
    from repro.runtime import FaultTolerantLoop, StragglerMonitor

    model = build_model(cfg)
    if shape is None:
        from repro.configs import ShapeConfig
        shape = ShapeConfig("toy", "train", 64, 4)
    if pipeline is None:
        pipeline = TokenPipeline(vocab_size=cfg.vocab_size,
                                 seq_len=shape.seq_len,
                                 global_batch=shape.global_batch,
                                 seed=run.seed)
    ckpt = Checkpointer(run.checkpoint_dir)
    loop = FaultTolerantLoop(checkpointer=ckpt, pipeline=pipeline,
                             save_every=run.checkpoint_every)
    monitor = StragglerMonitor()
    step_fn = make_train_step(
        model, run, total_steps=run.schedule_horizon or run.steps)
    jit_step = jax.jit(step_fn)

    start, state = loop.resume_or_init(lambda: init_state(model, run))
    losses = []

    def on_metrics(step, metrics):
        t = time.time()
        losses.append(float(metrics["loss"]))
        if not quiet and step % run.log_every == 0:
            log.info("step %d loss %.4f gnorm %.3f", step,
                     float(metrics["loss"]), float(metrics["gnorm"]))

    def timed_step(state, batch):
        t0 = time.time()
        out = jit_step(state, batch)
        jax.block_until_ready(out[1]["loss"])
        monitor.record(pipeline.step, time.time() - t0)
        return out

    end_step, state = loop.run(state, timed_step, start_step=start,
                               num_steps=run.steps, on_metrics=on_metrics)
    return state, {"losses": losses, "end_step": end_step,
                   "recoveries": loop.recoveries,
                   "median_step_s": monitor.median}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    from repro.configs import ShapeConfig
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    run = RunConfig(steps=args.steps, checkpoint_dir=args.ckpt_dir)
    _, info = train(cfg, run, shape=shape)
    print(f"final loss: {info['losses'][-1]:.4f} "
          f"(first {info['losses'][0]:.4f}), steps={info['end_step']}")


if __name__ == "__main__":
    main()
