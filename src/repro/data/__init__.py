from .pipeline import CTProjectionSource, TokenPipeline  # noqa: F401
from .tokenizer import ByteTokenizer  # noqa: F401
