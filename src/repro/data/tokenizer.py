"""Byte-level tokenizer stub (vocab-mapped) for the runnable examples.

Real deployments plug a sentencepiece model in here; the interface is the
only contract the pipeline depends on.
"""

from __future__ import annotations

import numpy as np


class ByteTokenizer:
    """Bytes + specials, folded into an arbitrary model vocab size."""

    BOS = 256
    EOS = 257
    PAD = 258

    def __init__(self, vocab_size: int):
        assert vocab_size >= 259, "need room for bytes + specials"
        self.vocab_size = vocab_size

    def encode(self, text: str, *, bos: bool = True) -> np.ndarray:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.BOS] + ids
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        bs = bytes(int(i) for i in ids if int(i) < 256)
        return bs.decode("utf-8", errors="replace")
