"""Deterministic, seekable, shardable data pipeline.

Fault-tolerance contract (runtime/ relies on all three properties):

  * **deterministic**: batch(step, shard) is a pure function of
    (seed, step, shard) — any host can regenerate any batch;
  * **seekable**: ``seek(step)`` is O(1) — restart and straggler
    skip-ahead never replay the stream;
  * **shardable**: hosts own disjoint shards of the global batch; the
    global batch for a step is the concatenation over shards, independent
    of the number of hosts (elastic re-sharding safe).

The token source is a synthetic, seeded LCG-hash stream with a Zipf-ish
marginal (stands in for a tokenized corpus; swap ``_tokens_for`` with a
real reader to deploy). A background prefetch thread overlaps host-side
batch synthesis with device compute (the paper's O6 at the input layer).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


def _hash_u32(x: np.ndarray) -> np.ndarray:
    """Vectorized xxhash-flavoured integer mix (deterministic, fast)."""
    x = x.astype(np.uint64)
    x = (x ^ (x >> 33)) * np.uint64(0xFF51AFD7ED558CCD)
    x = (x ^ (x >> 33)) * np.uint64(0xC4CEB9FE1A85EC53)
    return (x ^ (x >> 33)).astype(np.uint64)


class TokenPipeline:
    """Synthetic LM token pipeline with prefetch."""

    def __init__(self, *, vocab_size: int, seq_len: int, global_batch: int,
                 shard_index: int = 0, num_shards: int = 1, seed: int = 0,
                 prefetch: int = 2):
        assert global_batch % num_shards == 0, (global_batch, num_shards)
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.shard_batch = global_batch // num_shards
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.seed = seed
        self._step = 0
        self._prefetch_n = prefetch
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ---- deterministic batch synthesis ------------------------------------
    def _tokens_for(self, step: int) -> np.ndarray:
        """(shard_batch, seq_len+1) tokens for (seed, step, shard)."""
        b = self.shard_batch
        rows = (np.arange(b, dtype=np.uint64)
                + np.uint64(self.shard_index * b))
        cols = np.arange(self.seq_len + 1, dtype=np.uint64)
        with np.errstate(over="ignore"):   # modular u64 arithmetic
            base = (np.uint64(self.seed) * np.uint64(0x9E3779B97F4A7C15)
                    + np.uint64(step) * np.uint64(0x2545F4914F6CDD1D))
            grid = (base + rows[:, None] * np.uint64(1 << 20)
                    + cols[None, :])
            h = _hash_u32(grid)
        # Zipf-ish marginal: square a uniform to skew towards small ids.
        u = (h % np.uint64(1 << 30)).astype(np.float64) / float(1 << 30)
        toks = np.floor((u ** 2.0) * self.vocab_size).astype(np.int32)
        return np.clip(toks, 0, self.vocab_size - 1)

    def batch_at(self, step: int) -> dict:
        t = self._tokens_for(step)
        return {"tokens": t[:, :-1], "labels": t[:, 1:]}

    # ---- iteration / seek --------------------------------------------------
    def seek(self, step: int) -> None:
        """O(1) repositioning — restart/straggler skip-ahead."""
        self._step = step
        if self._q is not None:
            self._drain()

    @property
    def step(self) -> int:
        return self._step

    def __next__(self) -> dict:
        if self._q is None:
            out = self.batch_at(self._step)
            self._step += 1
            return out
        item = self._q.get()
        self._step = item["_step"] + 1
        return {k: v for k, v in item.items() if not k.startswith("_")}

    def __iter__(self) -> Iterator[dict]:
        return self

    # ---- prefetch thread ---------------------------------------------------
    def start_prefetch(self) -> None:
        if self._thread is not None:
            return
        self._q = queue.Queue(maxsize=self._prefetch_n)
        self._stop.clear()

        def worker():
            s = self._step
            while not self._stop.is_set():
                item = self.batch_at(s)
                item["_step"] = s
                try:
                    self._q.put(item, timeout=0.2)
                    s += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def stop_prefetch(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._drain()
        self._thread.join(timeout=2.0)
        self._thread = None
        self._q = None

    def _drain(self):
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


class CTProjectionSource:
    """Streams CT projection batches (the paper's input pipeline).

    Projections are synthesized once by forward-projecting a phantom and
    then served in angle-contiguous batches of ``nb`` (the paper's batch
    number) — the unit the back-projection kernels consume.
    """

    def __init__(self, geom, *, nb: int = 8, phantom: str = "shepp"):
        import jax.numpy as jnp

        from repro.core.forward import forward_project
        from repro.core.phantom import ball_phantom, shepp_logan_3d

        self.geom = geom
        self.nb = nb
        vol = (shepp_logan_3d(geom.nx, geom.ny, geom.nz)
               if phantom == "shepp" else ball_phantom(geom.nx))
        self.volume = vol
        self.projections = np.asarray(
            forward_project(jnp.asarray(vol), geom))

    def __iter__(self):
        n = self.geom.n_proj
        for s0 in range(0, n, self.nb):
            yield self.projections[s0:s0 + self.nb], np.arange(
                s0, min(s0 + self.nb, n))
