"""Pallas TPU kernel: back-projection with MXU one-hot interpolation.

Beyond-paper variant (DESIGN.md §2, assumption change #2). The paper's
sub-line stage 2 is a per-point gather in the cache-resident sMem buffer —
cheap on CPUs, but on TPU a dynamic gather along lanes serializes on the
VPU. This kernel replaces the gather with a *sparse interpolation matrix
contracted on the MXU*:

    val[j, k] = sum_n A[j, k, n] * sMem[j, n]
    A[j, k, n] = (1-dy) * [n == floor(y)] + dy * [n == floor(y)+1]

A is built from broadcasted iotas (pure VPU compares, no gathers) and the
contraction is a batched GEMV on the MXU. The trade: 2*kh*nh FLOPs per
line instead of ~6*kh gather-ops — profitable when gather throughput,
not FLOPs, is the bottleneck (roofline arithmetic in EXPERIMENTS.md §Perf
compares both kernels on the same problem).

Schedule, blocking, hoisting, symmetry and the sub-line stage 1 are
identical to backproject_subline.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backproject_subline import _line_scalars


def _make_kernel(BI: int, BJ: int, nz: int, nw: int, nh: int, k_chunk: int):
    kh = nz // 2          # mirrored half
    khp = nz - kh         # direct half (includes middle plane for odd nz)
    GJ = BJ // 8

    def kernel(mat_ref, img_ref, out_ref, smem_ref):
        s = pl.program_id(2)
        ti = pl.program_id(0)
        tj = pl.program_id(1)

        @pl.when(s == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        n_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nh), 2)

        for ii in range(BI):
            i_g = ti * BI + ii
            for jg in range(GJ):
                f_list, w_list = [], []
                for jj in range(8):
                    j_g = tj * BJ + jg * 8 + jj
                    f, w_eff, ixc, dx = _line_scalars(mat_ref, i_g, j_g, nw)
                    cols = img_ref[pl.ds(ixc, 2), :]
                    smem_ref[jj, :] = cols[0] * (1.0 - dx) + cols[1] * dx
                    f_list.append(f)
                    w_list.append(w_eff)
                f_vec = jnp.stack(f_list).reshape(8, 1)
                w_vec = jnp.stack(w_list).reshape(8, 1)
                i_f = i_g.astype(jnp.float32)
                j_base = (tj * BJ + jg * 8).astype(jnp.float32)
                j_off = jax.lax.broadcasted_iota(jnp.float32, (8, 1), 0)
                j_vec = j_base + j_off
                a = (mat_ref[1, 0] * i_f + mat_ref[1, 1] * j_vec
                     + mat_ref[1, 3]) * f_vec
                b = mat_ref[1, 2] * f_vec
                sm = smem_ref[...]                              # (8, nh)

                def interp_onehot(yy):
                    """(8, kc) coords -> (8, kc) values via MXU contraction."""
                    y0 = jnp.floor(yy)
                    iy = y0.astype(jnp.int32)
                    dy = yy - y0
                    ok = (iy >= 0) & (iy <= nh - 2)
                    iyc = jnp.clip(iy, 0, nh - 2)
                    lo = (n_iota == iyc[..., None]).astype(jnp.float32)
                    hi = (n_iota == (iyc + 1)[..., None]).astype(jnp.float32)
                    A = lo * (1.0 - dy)[..., None] + hi * dy[..., None]
                    A = A * ok[..., None].astype(jnp.float32)
                    # batched GEMV on the MXU: (8, kc, nh) x (8, nh) -> (8, kc)
                    return jax.lax.dot_general(
                        A, sm,
                        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
                        preferred_element_type=jnp.float32)

                jlo = jg * 8
                for kc0 in range(0, khp, k_chunk):
                    kc = min(k_chunk, khp - kc0)
                    k = kc0 + jax.lax.broadcasted_iota(
                        jnp.float32, (8, kc), 1)
                    y = a + b * k
                    lo_v = interp_onehot(y) * w_vec
                    out_ref[ii, jlo:jlo + 8, kc0:kc0 + kc] += lo_v
                    # Mirrored half only covers k < kh (skips the odd-nz
                    # self-mirrored middle plane).
                    kch = max(0, min(kc0 + kc, kh) - kc0)
                    if kch > 0:
                        hi_v = interp_onehot(
                            (nh - 1.0) - y[:, :kch]) * w_vec
                        out_ref[ii, jlo:jlo + 8,
                                nz - kc0 - kch:nz - kc0] += hi_v[:, ::-1]

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("vol_shape_xyz", "block", "k_chunk", "interpret"),
)
def backproject_onehot_pallas(img_t: jnp.ndarray, mat: jnp.ndarray,
                              vol_shape_xyz, *, block=(4, 8),
                              k_chunk: int = 128,
                              interpret: bool = True) -> jnp.ndarray:
    n_proj, nw, nh = img_t.shape
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    assert ni % BI == 0 and nj % BJ == 0 and BJ % 8 == 0
    k_chunk = min(k_chunk, nz - nz // 2)

    kernel = _make_kernel(BI, BJ, nz, nw, nh, k_chunk)
    grid = (ni // BI, nj // BJ, n_proj)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, 3, 4), lambda ti, tj, s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, nw, nh), lambda ti, tj, s: (s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((BI, BJ, nz), lambda ti, tj, s: (ti, tj, 0)),
        out_shape=jax.ShapeDtypeStruct((ni, nj, nz), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, nh), jnp.float32)],
        interpret=interpret,
    )(mat.astype(jnp.float32), img_t.astype(jnp.float32))
