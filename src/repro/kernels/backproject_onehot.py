"""Pallas TPU kernel: back-projection with MXU one-hot interpolation.

Beyond-paper variant (DESIGN.md §2, assumption change #2). The paper's
sub-line stage 2 is a per-point gather in the cache-resident sMem buffer —
cheap on CPUs, but on TPU a dynamic gather along lanes serializes on the
VPU. This kernel replaces the gather with a *sparse interpolation matrix
contracted on the MXU*:

    val[j, k] = sum_n A[j, k, n] * sMem[j, n]
    A[j, k, n] = (1-dy) * [n == floor(y)] + dy * [n == floor(y)+1]

A is built from broadcasted iotas (pure VPU compares, no gathers) and the
contraction is a batched GEMV on the MXU. The trade: 2*kh*nh FLOPs per
line instead of ~6*kh gather-ops — profitable when gather throughput,
not FLOPs, is the bottleneck (roofline arithmetic in EXPERIMENTS.md §Perf
compares both kernels on the same problem).

Schedule, blocking, hoisting, symmetry and the sub-line stage 1 are
identical to backproject_subline.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backproject_subline import _stage1_lines, _y_affine


def _accumulate_projection_onehot(m, img_cols, out_ref, smem_ref, i0, j0,
                                  BI: int, GJ: int, nz: int, nw: int,
                                  nh: int, k_chunk: int, n_iota):
    """Accumulate ONE projection via the MXU one-hot contraction.

    Shared between the per-projection grid kernel and the fused
    multi-batch (``proj_loop``) kernel; stage 1 and the y-coefficient
    hoist are the sub-line kernel's (``_stage1_lines``/``_y_affine``) —
    only stage 2 (gather -> MXU contraction) differs."""
    kh = nz // 2          # mirrored half
    khp = nz - kh         # direct half (includes middle plane for odd nz)
    for ii in range(BI):
        i_g = i0 + ii
        for jg in range(GJ):
            f_vec, w_vec = _stage1_lines(m, img_cols, smem_ref, i_g, j0,
                                         jg, nw)
            a, b = _y_affine(m, i_g, j0, jg, f_vec)
            sm = smem_ref[...]                              # (8, nh)

            def interp_onehot(yy):
                """(8, kc) coords -> (8, kc) values via MXU contraction."""
                y0 = jnp.floor(yy)
                iy = y0.astype(jnp.int32)
                dy = yy - y0
                ok = (iy >= 0) & (iy <= nh - 2)
                iyc = jnp.clip(iy, 0, nh - 2)
                lo = (n_iota == iyc[..., None]).astype(jnp.float32)
                hi = (n_iota == (iyc + 1)[..., None]).astype(jnp.float32)
                A = lo * (1.0 - dy)[..., None] + hi * dy[..., None]
                A = A * ok[..., None].astype(jnp.float32)
                # batched GEMV on the MXU: (8, kc, nh) x (8, nh) -> (8, kc)
                return jax.lax.dot_general(
                    A, sm,
                    dimension_numbers=(((2,), (1,)), ((0,), (0,))),
                    preferred_element_type=jnp.float32)

            jlo = jg * 8
            for kc0 in range(0, khp, k_chunk):
                kc = min(k_chunk, khp - kc0)
                k = kc0 + jax.lax.broadcasted_iota(
                    jnp.float32, (8, kc), 1)
                y = a + b * k
                lo_v = interp_onehot(y) * w_vec
                out_ref[ii, jlo:jlo + 8, kc0:kc0 + kc] += lo_v
                # Mirrored half only covers k < kh (skips the odd-nz
                # self-mirrored middle plane).
                kch = max(0, min(kc0 + kc, kh) - kc0)
                if kch > 0:
                    hi_v = interp_onehot(
                        (nh - 1.0) - y[:, :kch]) * w_vec
                    out_ref[ii, jlo:jlo + 8,
                            nz - kc0 - kch:nz - kc0] += hi_v[:, ::-1]


def _make_kernel(BI: int, BJ: int, nz: int, nw: int, nh: int, k_chunk: int):
    GJ = BJ // 8

    def kernel(mat_ref, img_ref, out_ref, smem_ref):
        s = pl.program_id(2)
        ti = pl.program_id(0)
        tj = pl.program_id(1)

        @pl.when(s == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        n_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nh), 2)
        _accumulate_projection_onehot(
            mat_ref, lambda ixc: img_ref[pl.ds(ixc, 2), :],
            out_ref, smem_ref, ti * BI, tj * BJ, BI, GJ, nz, nw, nh,
            k_chunk, n_iota)

    return kernel


def _make_fused_kernel(BI: int, BJ: int, nz: int, nw: int, nh: int,
                       k_chunk: int, nb: int):
    """Fused multi-batch mode (``proj_loop``): in-kernel ``fori_loop``
    over the nb projections of one batch block — the Z-slab accumulator
    is read-modified-written once per batch instead of once per
    projection (see backproject_subline._make_fused_kernel)."""
    GJ = BJ // 8

    def kernel(mat_ref, img_ref, out_ref, smem_ref):
        ti = pl.program_id(0)
        tj = pl.program_id(1)
        sb = pl.program_id(2)

        @pl.when(sb == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        n_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, nh), 2)

        def body(b, carry):
            _accumulate_projection_onehot(
                mat_ref[b], lambda ixc: img_ref[b, pl.ds(ixc, 2), :],
                out_ref, smem_ref, ti * BI, tj * BJ, BI, GJ, nz, nw, nh,
                k_chunk, n_iota)
            return carry

        jax.lax.fori_loop(0, nb, body, 0)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("vol_shape_xyz", "block", "k_chunk", "interpret"),
)
def backproject_onehot_pallas(img_t: jnp.ndarray, mat: jnp.ndarray,
                              vol_shape_xyz, *, block=(4, 8),
                              k_chunk: int = 128,
                              interpret: bool = True) -> jnp.ndarray:
    n_proj, nw, nh = img_t.shape
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    assert ni % BI == 0 and nj % BJ == 0 and BJ % 8 == 0
    k_chunk = min(k_chunk, nz - nz // 2)

    kernel = _make_kernel(BI, BJ, nz, nw, nh, k_chunk)
    grid = (ni // BI, nj // BJ, n_proj)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, 3, 4), lambda ti, tj, s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, nw, nh), lambda ti, tj, s: (s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((BI, BJ, nz), lambda ti, tj, s: (ti, tj, 0)),
        out_shape=jax.ShapeDtypeStruct((ni, nj, nz), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, nh), jnp.float32)],
        interpret=interpret,
    )(mat.astype(jnp.float32), img_t.astype(jnp.float32))


@functools.partial(
    jax.jit,
    static_argnames=("vol_shape_xyz", "block", "k_chunk", "nb", "interpret"),
)
def backproject_onehot_fused(img_t: jnp.ndarray, mat: jnp.ndarray,
                             vol_shape_xyz, *, block=(4, 8),
                             k_chunk: int = 128, nb: int = 8,
                             interpret: bool = True) -> jnp.ndarray:
    """Fused multi-batch (``proj_loop``) form of the one-hot kernel;
    requires ``n_proj % nb == 0`` (ops.py falls back otherwise)."""
    n_proj, nw, nh = img_t.shape
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    assert ni % BI == 0 and nj % BJ == 0 and BJ % 8 == 0
    assert n_proj % nb == 0 and nb >= 1, (n_proj, nb)
    k_chunk = min(k_chunk, nz - nz // 2)

    kernel = _make_fused_kernel(BI, BJ, nz, nw, nh, k_chunk, nb)
    grid = (ni // BI, nj // BJ, n_proj // nb)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nb, 3, 4), lambda ti, tj, s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((nb, nw, nh), lambda ti, tj, s: (s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((BI, BJ, nz), lambda ti, tj, s: (ti, tj, 0)),
        out_shape=jax.ShapeDtypeStruct((ni, nj, nz), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, nh), jnp.float32)],
        interpret=interpret,
    )(mat.astype(jnp.float32), img_t.astype(jnp.float32))
