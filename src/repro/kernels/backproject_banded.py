"""Pallas TPU kernel: banded, geometry-prefetched sub-line back-projection.

Beyond-paper optimization C3 (EXPERIMENTS.md §Perf CT campaign). The
output-stationary schedule of backproject_subline re-streams every full
projection for every volume tile — at P10 scale that is PBs of HBM
traffic. But a (BI, BJ) voxel tile only touches a NARROW BAND of detector
columns per projection: x(i,j) = (m00 i + m01 j + m03)/(m20 i + m21 j +
m23) is a ratio of linear functions, so its extrema over the tile
rectangle sit at the 4 corners — the needed band is known on the host
from the matrices alone.

Realization:
  * the projections are re-laid-out ONCE into 2x-overlapping bands
    img_b[s, b] = img_t[s, b*BW : b*BW + 2*BW, :]  (2x img memory, read
    O(T) times — amortized immediately);
  * a scalar-prefetch array band[s, ti, tj] = floor(xmin/BW) drives the
    BlockSpec index_map, so the pipeline DMAs exactly one (2*BW, nh) band
    per (tile, projection) — the paper's locality insight promoted into
    the prefetch engine (O6 with geometry awareness);
  * coverage is guaranteed when max tile x-span + 2 <= BW (checked by the
    wrapper, which picks BW from the geometry).

HBM projection traffic drops from T * np * nw * nh to
T * np * 2*BW * nh  (nw/2BW fold; ~14x for P10 at BW=64).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backproject_subline import _line_scalars


def band_layout(img_t: jnp.ndarray, bw: int):
    """(np, nw, nh) -> overlapping bands (np, n_bands, 2*bw, nh)."""
    n_proj, nw, nh = img_t.shape
    n_bands = max(1, -(-nw // bw))
    pad = n_bands * bw + bw - nw      # so band b slice [b*bw, b*bw+2bw) fits
    imgp = jnp.pad(img_t, ((0, 0), (0, pad), (0, 0)))
    idx = (jnp.arange(n_bands)[:, None] * bw
           + jnp.arange(2 * bw)[None, :])            # (n_bands, 2bw)
    return imgp[:, idx, :], n_bands                  # (np, nb, 2bw, nh)


def tile_bands(mat: np.ndarray, ni: int, nj: int, BI: int, BJ: int,
               bw: int, n_bands: int, nw: int):
    """band[s, ti, tj] block index + the max span (for the BW check).

    Corner evaluation is exact for z>0 (linear-fractional x over the
    tile rectangle attains extrema at corners).
    """
    mat = np.asarray(mat, np.float64)
    ti = np.arange(ni // BI)
    tj = np.arange(nj // BJ)
    i_lo, i_hi = ti * BI, ti * BI + (BI - 1)
    j_lo, j_hi = tj * BJ, tj * BJ + (BJ - 1)
    xs = []
    for ic in (i_lo, i_hi):
        for jc in (j_lo, j_hi):
            i = ic[:, None, None]                    # (Ti,1,1)
            j = jc[None, :, None]                    # (1,Tj,1)
            m = mat[None, None]                      # (1,1,ns,3,4)
            z = m[..., 2, 0] * i + m[..., 2, 1] * j + m[..., 2, 3]
            x = (m[..., 0, 0] * i + m[..., 0, 1] * j
                 + m[..., 0, 3]) / np.maximum(z, 1e-6)
            xs.append(x)                             # (Ti,Tj,ns)
    xs = np.stack(xs)                                # (4,Ti,Tj,ns)
    xmin = np.clip(xs.min(0), 0, nw - 1)
    xmax = np.clip(xs.max(0), 0, nw - 1)
    span = float((xmax - xmin).max()) + 2.0
    band = np.clip((xmin // bw).astype(np.int32), 0, n_bands - 1)
    # (ns, Ti, Tj) layout for the prefetch array
    return np.ascontiguousarray(np.transpose(band, (2, 0, 1))), span


def _make_kernel(BI: int, BJ: int, nz: int, bw: int, nw: int, nh: int):
    kh = nz // 2
    khp = nz - kh
    GJ = BJ // 8

    def kernel(band_ref, mat_ref, img_ref, out_ref, smem_ref):
        ti = pl.program_id(0)
        tj = pl.program_id(1)
        s = pl.program_id(2)

        @pl.when(s == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        col0 = band_ref[s, ti, tj] * bw           # global col of block[0]

        for ii in range(BI):
            i_g = ti * BI + ii
            for jg in range(GJ):
                f_list, w_list = [], []
                for jj in range(8):
                    j_g = tj * BJ + jg * 8 + jj
                    f, w_eff, ixc, dx = _line_scalars(mat_ref, i_g, j_g,
                                                      nw)
                    loc = jnp.clip(ixc - col0, 0, 2 * bw - 2)
                    # zero the line if the band misses (never happens
                    # when the wrapper's span check passed; belt+braces)
                    in_band = (ixc - col0 >= 0) & (ixc - col0 <= 2*bw - 2)
                    w_eff = jnp.where(in_band, w_eff, 0.0)
                    cols = img_ref[pl.ds(loc, 2), :]      # (2, nh)
                    smem_ref[jj, :] = cols[0] * (1.0 - dx) + cols[1] * dx
                    f_list.append(f)
                    w_list.append(w_eff)
                f_vec = jnp.stack(f_list).reshape(8, 1)
                w_vec = jnp.stack(w_list).reshape(8, 1)
                i_f = i_g.astype(jnp.float32)
                j_base = (tj * BJ + jg * 8).astype(jnp.float32)
                j_off = jax.lax.broadcasted_iota(jnp.float32, (8, 1), 0)
                j_vec = j_base + j_off
                k = jax.lax.broadcasted_iota(jnp.float32, (8, khp), 1)
                a = (mat_ref[1, 0] * i_f + mat_ref[1, 1] * j_vec
                     + mat_ref[1, 3]) * f_vec
                b = mat_ref[1, 2] * f_vec
                y = a + b * k
                sm = smem_ref[...]

                def interp(yy):
                    y0 = jnp.floor(yy)
                    iy = y0.astype(jnp.int32)
                    dy = yy - y0
                    ok = (iy >= 0) & (iy <= nh - 2)
                    iyc = jnp.clip(iy, 0, nh - 2)
                    s0 = jnp.take_along_axis(sm, iyc, axis=1)
                    s1 = jnp.take_along_axis(sm, iyc + 1, axis=1)
                    v = s0 * (1.0 - dy) + s1 * dy
                    return jnp.where(ok, v, 0.0)

                lo = interp(y) * w_vec
                y_m = (nh - 1.0) - y[:, :kh]
                hi = interp(y_m) * w_vec
                jlo = jg * 8
                out_ref[ii, jlo:jlo + 8, :khp] += lo
                out_ref[ii, jlo:jlo + 8, khp:] += hi[:, ::-1]

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("vol_shape_xyz", "block", "bw", "nw", "interpret"),
)
def _banded_call(img_b, mat, band, vol_shape_xyz, *, block, bw, nw,
                 interpret):
    n_proj = img_b.shape[0]
    nh = img_b.shape[3]
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    # nw = TRUE detector width: the validity mask must not admit the
    # zero-padded band tail (cols nw-1..) or edge columns leak into the
    # interpolation.
    kernel = _make_kernel(BI, BJ, nz, bw, nw, nh)
    grid = (ni // BI, nj // BJ, n_proj)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, 3, 4), lambda ti, tj, s, band: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, None, 2 * bw, nh),
                         lambda ti, tj, s, band: (s, band[s, ti, tj],
                                                  0, 0)),
        ],
        out_specs=pl.BlockSpec((BI, BJ, nz),
                               lambda ti, tj, s, band: (ti, tj, 0)),
        scratch_shapes=[pltpu.VMEM((8, nh), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ni, nj, nz), jnp.float32),
        interpret=interpret,
    )(band, mat.astype(jnp.float32), img_b.astype(jnp.float32))


def backproject_banded(img_t: jnp.ndarray, mat: jnp.ndarray,
                       vol_shape_xyz, *, block=(4, 8), bw: int = 32,
                       interpret: bool = True) -> jnp.ndarray:
    """Banded back-projection. img_t (np, nw, nh); returns (ni, nj, nz).

    Picks/validates the band width: requires max tile x-span + 2 <= bw
    (doubling bw until it holds), then runs the scalar-prefetched kernel.
    """
    n_proj, nw, nh = img_t.shape
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    assert ni % BI == 0 and nj % BJ == 0 and BJ % 8 == 0
    mat_np = np.asarray(mat)
    while True:
        n_bands = max(1, -(-nw // bw))
        band, span = tile_bands(mat_np, ni, nj, BI, BJ, bw, n_bands, nw)
        if span <= bw or bw >= nw:
            break
        bw *= 2
    img_b, n_bands = band_layout(img_t, bw)
    return _banded_call(img_b, mat, jnp.asarray(band), tuple(vol_shape_xyz),
                        block=block, bw=bw, nw=nw, interpret=interpret)
