"""Pallas TPU kernel: banded, geometry-prefetched sub-line back-projection.

Beyond-paper optimization C3 (EXPERIMENTS.md §Perf CT campaign). The
output-stationary schedule of backproject_subline re-streams every full
projection for every volume tile — at P10 scale that is PBs of HBM
traffic. But a (BI, BJ) voxel tile only touches a NARROW BAND of detector
columns per projection: x(i,j) = (m00 i + m01 j + m03)/(m20 i + m21 j +
m23) is a ratio of linear functions, so its extrema over the tile
rectangle sit at the 4 corners — the needed band is known on the host
from the matrices alone.

Realization:
  * the projections are re-laid-out ONCE into 2x-overlapping bands
    img_b[s, b] = img_t[s, b*BW : b*BW + 2*BW, :]  (2x img memory, read
    O(T) times — amortized immediately);
  * a scalar-prefetch array band[s, ti, tj] = floor(xmin/BW) drives the
    BlockSpec index_map, so the pipeline DMAs exactly one (2*BW, nh) band
    per (tile, projection) — the paper's locality insight promoted into
    the prefetch engine (O6 with geometry awareness);
  * coverage is guaranteed when max tile x-span + 2 <= BW (checked by the
    wrapper, which picks BW from the geometry).

HBM projection traffic drops from T * np * nw * nh to
T * np * 2*BW * nh  (nw/2BW fold; ~14x for P10 at BW=64).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backproject_subline import _accumulate_projection, fused_batch_ok


def band_layout(img_t: jnp.ndarray, bw: int):
    """(np, nw, nh) -> overlapping bands (np, n_bands, 2*bw, nh)."""
    n_proj, nw, nh = img_t.shape
    n_bands = max(1, -(-nw // bw))
    pad = n_bands * bw + bw - nw      # so band b slice [b*bw, b*bw+2bw) fits
    imgp = jnp.pad(img_t, ((0, 0), (0, pad), (0, 0)))
    idx = (jnp.arange(n_bands)[:, None] * bw
           + jnp.arange(2 * bw)[None, :])            # (n_bands, 2bw)
    return imgp[:, idx, :], n_bands                  # (np, nb, 2bw, nh)


def tile_bands(mat: np.ndarray, ni: int, nj: int, BI: int, BJ: int,
               bw: int, n_bands: int, nw: int, group: int = 1):
    """band[s, ti, tj] block index + the max span (for the BW check).

    Corner evaluation is exact for z>0 (linear-fractional x over the
    tile rectangle attains extrema at corners). ``group > 1`` reduces
    over groups of that many consecutive projections — the fused
    multi-batch (``proj_loop``) kernel shares ONE band per in-kernel
    batch, so the span check must cover the batch's x-range union and
    the returned array has one row per batch.
    """
    mat = np.asarray(mat, np.float64)
    ti = np.arange(ni // BI)
    tj = np.arange(nj // BJ)
    i_lo, i_hi = ti * BI, ti * BI + (BI - 1)
    j_lo, j_hi = tj * BJ, tj * BJ + (BJ - 1)
    xs = []
    for ic in (i_lo, i_hi):
        for jc in (j_lo, j_hi):
            i = ic[:, None, None]                    # (Ti,1,1)
            j = jc[None, :, None]                    # (1,Tj,1)
            m = mat[None, None]                      # (1,1,ns,3,4)
            z = m[..., 2, 0] * i + m[..., 2, 1] * j + m[..., 2, 3]
            x = (m[..., 0, 0] * i + m[..., 0, 1] * j
                 + m[..., 0, 3]) / np.maximum(z, 1e-6)
            xs.append(x)                             # (Ti,Tj,ns)
    xs = np.stack(xs)                                # (4,Ti,Tj,ns)
    xmin = np.clip(xs.min(0), 0, nw - 1)
    xmax = np.clip(xs.max(0), 0, nw - 1)
    if group > 1:
        t_i, t_j, ns = xmin.shape
        assert ns % group == 0, (ns, group)
        xmin = xmin.reshape(t_i, t_j, ns // group, group).min(-1)
        xmax = xmax.reshape(t_i, t_j, ns // group, group).max(-1)
    span = float((xmax - xmin).max()) + 2.0
    band = np.clip((xmin // bw).astype(np.int32), 0, n_bands - 1)
    # (ns, Ti, Tj) layout for the prefetch array
    return np.ascontiguousarray(np.transpose(band, (2, 0, 1))), span


def _make_kernel(BI: int, BJ: int, nz: int, bw: int, nw: int, nh: int):
    GJ = BJ // 8

    def kernel(band_ref, mat_ref, img_ref, out_ref, smem_ref):
        ti = pl.program_id(0)
        tj = pl.program_id(1)
        s = pl.program_id(2)

        @pl.when(s == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        col0 = band_ref[s, ti, tj] * bw           # global col of block[0]
        _accumulate_projection(
            mat_ref, lambda loc: img_ref[pl.ds(loc, 2), :],
            out_ref, smem_ref, ti * BI, tj * BJ, BI, GJ, nz, nw, nh,
            band=(col0, 2 * bw))

    return kernel


def _make_fused_kernel(BI: int, BJ: int, nz: int, bw: int, nw: int,
                       nh: int, nb: int):
    """Fused multi-batch mode (``proj_loop``): one band block + one
    (nb, 3, 4) matrix block per grid step, in-kernel ``fori_loop`` over
    the batch. The band is SHARED by the batch (tile_bands group=nb
    guarantees the batch's x-range union fits the 2*bw window), so the
    prefetch engine DMAs one band per nb projections."""
    GJ = BJ // 8

    def kernel(band_ref, mat_ref, img_ref, out_ref, smem_ref):
        ti = pl.program_id(0)
        tj = pl.program_id(1)
        sb = pl.program_id(2)

        @pl.when(sb == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        col0 = band_ref[sb, ti, tj] * bw          # batch-shared band

        def body(b, carry):
            _accumulate_projection(
                mat_ref[b], lambda loc: img_ref[b, pl.ds(loc, 2), :],
                out_ref, smem_ref, ti * BI, tj * BJ, BI, GJ, nz, nw, nh,
                band=(col0, 2 * bw))
            return carry

        jax.lax.fori_loop(0, nb, body, 0)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("vol_shape_xyz", "block", "bw", "nw", "interpret"),
)
def _banded_call(img_b, mat, band, vol_shape_xyz, *, block, bw, nw,
                 interpret):
    n_proj = img_b.shape[0]
    nh = img_b.shape[3]
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    # nw = TRUE detector width: the validity mask must not admit the
    # zero-padded band tail (cols nw-1..) or edge columns leak into the
    # interpolation.
    kernel = _make_kernel(BI, BJ, nz, bw, nw, nh)
    grid = (ni // BI, nj // BJ, n_proj)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, 3, 4), lambda ti, tj, s, band: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, None, 2 * bw, nh),
                         lambda ti, tj, s, band: (s, band[s, ti, tj],
                                                  0, 0)),
        ],
        out_specs=pl.BlockSpec((BI, BJ, nz),
                               lambda ti, tj, s, band: (ti, tj, 0)),
        scratch_shapes=[pltpu.VMEM((8, nh), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ni, nj, nz), jnp.float32),
        interpret=interpret,
    )(band, mat.astype(jnp.float32), img_b.astype(jnp.float32))


@functools.partial(
    jax.jit,
    static_argnames=("vol_shape_xyz", "block", "bw", "nw", "nb",
                     "interpret"),
)
def _banded_call_fused(img_b, mat, band, vol_shape_xyz, *, block, bw, nw,
                       nb, interpret):
    n_proj = img_b.shape[0]
    nh = img_b.shape[3]
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    kernel = _make_fused_kernel(BI, BJ, nz, bw, nw, nh, nb)
    grid = (ni // BI, nj // BJ, n_proj // nb)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nb, 3, 4), lambda ti, tj, s, band: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((nb, None, 2 * bw, nh),
                         lambda ti, tj, s, band: (s, band[s, ti, tj],
                                                  0, 0)),
        ],
        out_specs=pl.BlockSpec((BI, BJ, nz),
                               lambda ti, tj, s, band: (ti, tj, 0)),
        scratch_shapes=[pltpu.VMEM((8, nh), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ni, nj, nz), jnp.float32),
        interpret=interpret,
    )(band, mat.astype(jnp.float32), img_b.astype(jnp.float32))


def backproject_banded(img_t: jnp.ndarray, mat: jnp.ndarray,
                       vol_shape_xyz, *, block=(4, 8), bw: int = 32,
                       nb: int = 0, proj_loop: bool = False,
                       interpret: bool = True) -> jnp.ndarray:
    """Banded back-projection. img_t (np, nw, nh); returns (ni, nj, nz).

    Picks/validates the band width: requires max tile x-span + 2 <= bw
    (doubling bw until it holds), then runs the scalar-prefetched
    kernel. With ``proj_loop`` (and ``n_proj`` divisible by ``nb``) the
    fused multi-batch kernel runs instead: one band per nb-projection
    batch (the span check covers the batch union — wider motion per
    batch may force a larger bw), 1/nb output read-modify-write traffic.
    """
    n_proj, nw, nh = img_t.shape
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    assert ni % BI == 0 and nj % BJ == 0 and BJ % 8 == 0
    fused = fused_batch_ok(n_proj, nb, proj_loop)
    group = nb if fused else 1
    mat_np = np.asarray(mat)
    while True:
        n_bands = max(1, -(-nw // bw))
        band, span = tile_bands(mat_np, ni, nj, BI, BJ, bw, n_bands, nw,
                                group=group)
        if span <= bw or bw >= nw:
            break
        bw *= 2
    img_b, n_bands = band_layout(img_t, bw)
    if fused:
        return _banded_call_fused(
            img_b, mat, jnp.asarray(band), tuple(vol_shape_xyz),
            block=block, bw=bw, nw=nw, nb=nb, interpret=interpret)
    return _banded_call(img_b, mat, jnp.asarray(band), tuple(vol_shape_xyz),
                        block=block, bw=bw, nw=nw, interpret=interpret)
