"""Pallas TPU kernel: sub-line back-projection (paper Algorithm 1 + O6).

TPU-native schedule (see DESIGN.md §2 for the CPU->TPU mapping):

  grid = (ni/BI, nj/BJ, np)          # s innermost
  img block   (nw, nh)   <- indexed by s: streamed through VMEM, Pallas
                            double-buffers it across grid steps = the
                            paper's Algorithm 2 prefetch, for free.
  mat block   (3, 4)     <- SMEM scalars (the 48-byte matrix of §3.2.1-I).
  out block   (BI,BJ,nz) <- indexed by (ti,tj) only: VMEM-resident across
                            the whole s sweep (output-stationary), zeroed
                            at s==0, written back to HBM exactly once.
                            This is the nb->np limit of the paper's
                            batching: volume HBM traffic = one write.
  scratch     (8, nh)    <- the sMem sub-line buffer (Fig. 3a) in VMEM.

Inside each grid cell the voxel lines of the (BI, BJ) tile are processed
in groups of 8 (TPU sublanes). Per line the k-invariant scalars
F = 1/z, W = F*F, X (paper lines 4..7) are computed on the scalar core
from SMEM matrix entries — the hoisting of O2 — and X drives a 2-column
dynamic slice of the image block whose blend is the sub-line (O4).
The vertical coordinate y is affine in k, evaluated vectorized over the
(8, nz/2) half-tile; the mirrored half reuses it via y' = nh-1-y (O3).

Alignment notes (TPU target): nh and nz should be multiples of 128 and
BJ a multiple of 8 for native tiling; the wrapper in ops.py pads. CPU
validation runs the same kernel with interpret=True.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def fused_batch_ok(n_proj: int, nb: int, proj_loop: bool) -> bool:
    """Whether the fused multi-batch (``proj_loop``) kernel may run: an
    in-kernel batch needs nb >= 2 and an nb-divisible projection count
    (the executor pads globally; raw callers fall back silently). The
    ONE eligibility rule, shared by all three kernel wrappers."""
    return bool(proj_loop) and nb > 1 and n_proj % nb == 0


def _line_scalars(mat_ref, i_g, j_g, nw):
    """Scalar-core computation of z, F, W, X, x-column and blend weight
    for one voxel line (i_g, j_g). Everything here is k-invariant (O2)."""
    i_f = i_g.astype(jnp.float32)
    j_f = j_g.astype(jnp.float32)
    z = mat_ref[2, 0] * i_f + mat_ref[2, 1] * j_f + mat_ref[2, 3]
    f = 1.0 / z
    x = (mat_ref[0, 0] * i_f + mat_ref[0, 1] * j_f + mat_ref[0, 3]) * f
    x0 = jnp.floor(x)
    ix = x0.astype(jnp.int32)
    dx = x - x0
    ok = (ix >= 0) & (ix <= nw - 2) & (z > 0)
    ixc = jnp.clip(ix, 0, nw - 2)
    w = f * f
    # Fold the line validity into the weight: invalid lines contribute 0.
    w_eff = jnp.where(ok, w, 0.0)
    return f, w_eff, ixc, dx


def _stage1_lines(m, img_cols, smem_ref, i_g, j0, jg, nw, band=None):
    """Stage 1 for one 8-line group (O4, Fig. 3a): blend the two
    detector columns of each line into the sMem scratch; returns the
    (8, 1) ``f`` and effective-weight vectors.

    ``m`` is the 3x4 matrix (SMEM ref or loaded array — both
    scalar-indexable); ``img_cols(ixc)`` returns the (2, nh) detector
    columns at column ``ixc``; ``band=(col0, two_bw)`` remaps detector
    columns into a 2*bw band block starting at global column ``col0``
    (lines whose columns miss the band are zeroed).
    """
    f_list, w_list = [], []
    for jj in range(8):
        j_g = j0 + jg * 8 + jj
        f, w_eff, ixc, dx = _line_scalars(m, i_g, j_g, nw)
        if band is not None:
            col0, two_bw = band
            rel = ixc - col0
            # zero the line if the band misses (never happens when
            # the wrapper's span check passed; belt+braces)
            w_eff = jnp.where((rel >= 0) & (rel <= two_bw - 2),
                              w_eff, 0.0)
            ixc = jnp.clip(rel, 0, two_bw - 2)
        cols = img_cols(ixc)                      # (2, nh)
        smem_ref[jj, :] = cols[0] * (1.0 - dx) + cols[1] * dx
        f_list.append(f)
        w_list.append(w_eff)
    return (jnp.stack(f_list).reshape(8, 1),
            jnp.stack(w_list).reshape(8, 1))


def _y_affine(m, i_g, j0, jg, f_vec):
    """The (8, 1) y-coefficients a, b with y(k) = a + b*k (O2 hoist)."""
    i_f = i_g.astype(jnp.float32)
    j_base = (j0 + jg * 8).astype(jnp.float32)
    j_off = jax.lax.broadcasted_iota(jnp.float32, (8, 1), 0)
    j_vec = j_base + j_off                         # (8, 1)
    a = (m[1, 0] * i_f + m[1, 1] * j_vec + m[1, 3]) * f_vec
    b = m[1, 2] * f_vec
    return a, b


def _accumulate_projection(m, img_cols, out_ref, smem_ref, i0, j0,
                           BI: int, GJ: int, nz: int, nw: int, nh: int,
                           band=None):
    """Accumulate ONE projection into the (BI, BJ, nz) output block.

    Shared between the per-projection grid kernel and the fused
    multi-batch (``proj_loop``) kernel — and, via ``band``, by the
    banded kernel family (see :func:`_stage1_lines` for the ``m`` /
    ``img_cols`` / ``band`` calling convention).
    """
    kh = nz // 2          # mirrored half
    khp = nz - kh         # direct half (== kh, or kh+1 when nz odd)
    for ii in range(BI):
        i_g = i0 + ii
        for jg in range(GJ):
            f_vec, w_vec = _stage1_lines(m, img_cols, smem_ref, i_g, j0,
                                         jg, nw, band=band)
            # --- stage 2: vectorized y interpolation (Fig. 3b) -------
            a, b = _y_affine(m, i_g, j0, jg, f_vec)
            k = jax.lax.broadcasted_iota(jnp.float32, (8, khp), 1)
            y = a + b * k                                  # (8, khp)
            sm = smem_ref[...]                             # (8, nh)

            def interp(yy):
                y0 = jnp.floor(yy)
                iy = y0.astype(jnp.int32)
                dy = yy - y0
                ok = (iy >= 0) & (iy <= nh - 2)
                iyc = jnp.clip(iy, 0, nh - 2)
                s0 = jnp.take_along_axis(sm, iyc, axis=1)
                s1 = jnp.take_along_axis(sm, iyc + 1, axis=1)
                v = s0 * (1.0 - dy) + s1 * dy
                return jnp.where(ok, v, 0.0)

            lo = interp(y) * w_vec                         # k in [0, khp)
            y_m = (nh - 1.0) - y[:, :kh]                   # O3 mirror
            hi = interp(y_m) * w_vec                       # k in [khp, nz)
            jlo = jg * 8
            out_ref[ii, jlo:jlo + 8, :khp] += lo
            out_ref[ii, jlo:jlo + 8, khp:] += hi[:, ::-1]


def _make_kernel(BI: int, BJ: int, nz: int, nw: int, nh: int):
    GJ = BJ // 8  # groups of 8 lines (sublanes)

    def kernel(mat_ref, img_ref, out_ref, smem_ref):
        s = pl.program_id(2)
        ti = pl.program_id(0)
        tj = pl.program_id(1)

        @pl.when(s == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        _accumulate_projection(
            mat_ref, lambda ixc: img_ref[pl.ds(ixc, 2), :],
            out_ref, smem_ref, ti * BI, tj * BJ, BI, GJ, nz, nw, nh)

    return kernel


def _make_fused_kernel(BI: int, BJ: int, nz: int, nw: int, nh: int,
                       nb: int):
    """Fused multi-batch mode (``proj_loop``): the grid's projection
    axis runs over nb-sized BATCHES and a ``fori_loop`` walks the batch
    inside the kernel, so the (BI, BJ, nz) Z-slab accumulator is
    read-modified-written once per nb projections instead of once per
    projection — the paper's O1 loop order + O3 locality carried into
    the kernel (1/nb output traffic, §3.1.3)."""
    GJ = BJ // 8

    def kernel(mat_ref, img_ref, out_ref, smem_ref):
        ti = pl.program_id(0)
        tj = pl.program_id(1)
        sb = pl.program_id(2)

        @pl.when(sb == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        def body(b, carry):
            _accumulate_projection(
                mat_ref[b], lambda ixc: img_ref[b, pl.ds(ixc, 2), :],
                out_ref, smem_ref, ti * BI, tj * BJ, BI, GJ, nz, nw, nh)
            return carry

        jax.lax.fori_loop(0, nb, body, 0)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("vol_shape_xyz", "block", "interpret"),
)
def backproject_subline_pallas(img_t: jnp.ndarray, mat: jnp.ndarray,
                               vol_shape_xyz, *, block=(4, 8),
                               interpret: bool = True) -> jnp.ndarray:
    """Back-project transposed projections with the sub-line Pallas kernel.

    img_t (np, nw, nh) f32; mat (np, 3, 4) f32.
    Returns vol_t (nx, ny, nz) f32. Requires ni % BI == nj % BJ == 0
    (ops.py pads arbitrary i/j); any nz (odd handled by uneven halves).
    """
    n_proj, nw, nh = img_t.shape
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    assert ni % BI == 0 and nj % BJ == 0 and BJ % 8 == 0, (ni, nj, block)

    kernel = _make_kernel(BI, BJ, nz, nw, nh)
    grid = (ni // BI, nj // BJ, n_proj)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, 3, 4), lambda ti, tj, s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, nw, nh), lambda ti, tj, s: (s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((BI, BJ, nz), lambda ti, tj, s: (ti, tj, 0)),
        out_shape=jax.ShapeDtypeStruct((ni, nj, nz), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, nh), jnp.float32)],
        interpret=interpret,
    )(mat.astype(jnp.float32), img_t.astype(jnp.float32))


@functools.partial(
    jax.jit,
    static_argnames=("vol_shape_xyz", "block", "nb", "interpret"),
)
def backproject_subline_fused(img_t: jnp.ndarray, mat: jnp.ndarray,
                              vol_shape_xyz, *, block=(4, 8), nb: int = 8,
                              interpret: bool = True) -> jnp.ndarray:
    """Fused multi-batch (``proj_loop``) form of the sub-line kernel.

    Identical math to :func:`backproject_subline_pallas`; the grid's
    projection axis runs over ``n_proj // nb`` batches, each kernel call
    receives an (nb, nw, nh) image block + (nb, 3, 4) matrix block and
    loops the batch in-kernel. Requires ``n_proj % nb == 0`` (the
    executor pads globally; ops.py falls back to the per-projection
    grid otherwise).
    """
    n_proj, nw, nh = img_t.shape
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    assert ni % BI == 0 and nj % BJ == 0 and BJ % 8 == 0, (ni, nj, block)
    assert n_proj % nb == 0 and nb >= 1, (n_proj, nb)

    kernel = _make_fused_kernel(BI, BJ, nz, nw, nh, nb)
    grid = (ni // BI, nj // BJ, n_proj // nb)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nb, 3, 4), lambda ti, tj, s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((nb, nw, nh), lambda ti, tj, s: (s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((BI, BJ, nz), lambda ti, tj, s: (ti, tj, 0)),
        out_shape=jax.ShapeDtypeStruct((ni, nj, nz), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, nh), jnp.float32)],
        interpret=interpret,
    )(mat.astype(jnp.float32), img_t.astype(jnp.float32))
