"""Pallas TPU kernel: sub-line back-projection (paper Algorithm 1 + O6).

TPU-native schedule (see DESIGN.md §2 for the CPU->TPU mapping):

  grid = (ni/BI, nj/BJ, np)          # s innermost
  img block   (nw, nh)   <- indexed by s: streamed through VMEM, Pallas
                            double-buffers it across grid steps = the
                            paper's Algorithm 2 prefetch, for free.
  mat block   (3, 4)     <- SMEM scalars (the 48-byte matrix of §3.2.1-I).
  out block   (BI,BJ,nz) <- indexed by (ti,tj) only: VMEM-resident across
                            the whole s sweep (output-stationary), zeroed
                            at s==0, written back to HBM exactly once.
                            This is the nb->np limit of the paper's
                            batching: volume HBM traffic = one write.
  scratch     (8, nh)    <- the sMem sub-line buffer (Fig. 3a) in VMEM.

Inside each grid cell the voxel lines of the (BI, BJ) tile are processed
in groups of 8 (TPU sublanes). Per line the k-invariant scalars
F = 1/z, W = F*F, X (paper lines 4..7) are computed on the scalar core
from SMEM matrix entries — the hoisting of O2 — and X drives a 2-column
dynamic slice of the image block whose blend is the sub-line (O4).
The vertical coordinate y is affine in k, evaluated vectorized over the
(8, nz/2) half-tile; the mirrored half reuses it via y' = nh-1-y (O3).

Alignment notes (TPU target): nh and nz should be multiples of 128 and
BJ a multiple of 8 for native tiling; the wrapper in ops.py pads. CPU
validation runs the same kernel with interpret=True.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _line_scalars(mat_ref, i_g, j_g, nw):
    """Scalar-core computation of z, F, W, X, x-column and blend weight
    for one voxel line (i_g, j_g). Everything here is k-invariant (O2)."""
    i_f = i_g.astype(jnp.float32)
    j_f = j_g.astype(jnp.float32)
    z = mat_ref[2, 0] * i_f + mat_ref[2, 1] * j_f + mat_ref[2, 3]
    f = 1.0 / z
    x = (mat_ref[0, 0] * i_f + mat_ref[0, 1] * j_f + mat_ref[0, 3]) * f
    x0 = jnp.floor(x)
    ix = x0.astype(jnp.int32)
    dx = x - x0
    ok = (ix >= 0) & (ix <= nw - 2) & (z > 0)
    ixc = jnp.clip(ix, 0, nw - 2)
    w = f * f
    # Fold the line validity into the weight: invalid lines contribute 0.
    w_eff = jnp.where(ok, w, 0.0)
    return f, w_eff, ixc, dx


def _make_kernel(BI: int, BJ: int, nz: int, nw: int, nh: int):
    # Symmetry split: k in [0, khp) computed directly (includes the
    # self-mirrored middle plane when nz is odd), k in [khp, nz) mirrored.
    kh = nz // 2          # mirrored half
    khp = nz - kh         # direct half (== kh, or kh+1 when nz odd)
    GJ = BJ // 8  # groups of 8 lines (sublanes)

    def kernel(mat_ref, img_ref, out_ref, smem_ref):
        s = pl.program_id(2)
        ti = pl.program_id(0)
        tj = pl.program_id(1)

        @pl.when(s == 0)
        def _init():
            out_ref[...] = jnp.zeros_like(out_ref)

        for ii in range(BI):
            i_g = ti * BI + ii
            for jg in range(GJ):
                f_list, w_list = [], []
                # --- stage 1: sub-line blends for 8 lines (O4, Fig. 3a) --
                for jj in range(8):
                    j_g = tj * BJ + jg * 8 + jj
                    f, w_eff, ixc, dx = _line_scalars(mat_ref, i_g, j_g, nw)
                    cols = img_ref[pl.ds(ixc, 2), :]          # (2, nh)
                    smem_ref[jj, :] = cols[0] * (1.0 - dx) + cols[1] * dx
                    f_list.append(f)
                    w_list.append(w_eff)
                f_vec = jnp.stack(f_list).reshape(8, 1)
                w_vec = jnp.stack(w_list).reshape(8, 1)
                # --- stage 2: vectorized y interpolation (Fig. 3b) -------
                i_f = i_g.astype(jnp.float32)
                j_base = (tj * BJ + jg * 8).astype(jnp.float32)
                j_off = jax.lax.broadcasted_iota(jnp.float32, (8, 1), 0)
                j_vec = j_base + j_off                         # (8, 1)
                k = jax.lax.broadcasted_iota(jnp.float32, (8, khp), 1)
                a = (mat_ref[1, 0] * i_f + mat_ref[1, 1] * j_vec
                     + mat_ref[1, 3]) * f_vec                  # (8, 1)
                b = mat_ref[1, 2] * f_vec                      # (8, 1)
                y = a + b * k                                  # (8, khp)
                sm = smem_ref[...]                             # (8, nh)

                def interp(yy):
                    y0 = jnp.floor(yy)
                    iy = y0.astype(jnp.int32)
                    dy = yy - y0
                    ok = (iy >= 0) & (iy <= nh - 2)
                    iyc = jnp.clip(iy, 0, nh - 2)
                    s0 = jnp.take_along_axis(sm, iyc, axis=1)
                    s1 = jnp.take_along_axis(sm, iyc + 1, axis=1)
                    v = s0 * (1.0 - dy) + s1 * dy
                    return jnp.where(ok, v, 0.0)

                lo = interp(y) * w_vec                         # k in [0, khp)
                y_m = (nh - 1.0) - y[:, :kh]                   # O3 mirror
                hi = interp(y_m) * w_vec                       # k in [khp, nz)
                jlo = jg * 8
                out_ref[ii, jlo:jlo + 8, :khp] += lo
                out_ref[ii, jlo:jlo + 8, khp:] += hi[:, ::-1]

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("vol_shape_xyz", "block", "interpret"),
)
def backproject_subline_pallas(img_t: jnp.ndarray, mat: jnp.ndarray,
                               vol_shape_xyz, *, block=(4, 8),
                               interpret: bool = True) -> jnp.ndarray:
    """Back-project transposed projections with the sub-line Pallas kernel.

    img_t (np, nw, nh) f32; mat (np, 3, 4) f32.
    Returns vol_t (nx, ny, nz) f32. Requires ni % BI == nj % BJ == 0
    (ops.py pads arbitrary i/j); any nz (odd handled by uneven halves).
    """
    n_proj, nw, nh = img_t.shape
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    assert ni % BI == 0 and nj % BJ == 0 and BJ % 8 == 0, (ni, nj, block)

    kernel = _make_kernel(BI, BJ, nz, nw, nh)
    grid = (ni // BI, nj // BJ, n_proj)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, 3, 4), lambda ti, tj, s: (s, 0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((None, nw, nh), lambda ti, tj, s: (s, 0, 0)),
        ],
        out_specs=pl.BlockSpec((BI, BJ, nz), lambda ti, tj, s: (ti, tj, 0)),
        out_shape=jax.ShapeDtypeStruct((ni, nj, nz), jnp.float32),
        scratch_shapes=[pltpu.VMEM((8, nh), jnp.float32)],
        interpret=interpret,
    )(mat.astype(jnp.float32), img_t.astype(jnp.float32))
