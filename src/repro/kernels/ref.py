"""Pure-jnp oracles for the Pallas back-projection kernels.

The oracle implements the exact math of the paper's Algorithm 1
(transpose + hoist + symmetry + subline) with full-precision jnp ops and a
simple sum over projections. Every Pallas kernel in this package must
match it to fp32 interpolation tolerance across the shape/dtype sweeps in
tests/test_kernels.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def backproject_ref(img_t: jnp.ndarray, mat: jnp.ndarray,
                    vol_shape_xyz) -> jnp.ndarray:
    """Oracle: subline+symmetry back-projection, summed over projections.

    img_t: (np, nw, nh) transposed projections (float32)
    mat:   (np, 3, 4) projection matrices
    returns vol_t: (nx, ny, nz) float32
    """
    from repro.core.backproject import _bp_subline_single

    def one(im, mm):
        # Subline math without the symmetry split: valid for any nz and
        # identical values (symmetry is exact for centered geometries).
        return _bp_subline_single(im, mm, tuple(vol_shape_xyz))

    per = jax.vmap(one)(img_t.astype(jnp.float32), mat.astype(jnp.float32))
    return per.sum(axis=0)


def subline_blend_ref(img_ts: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Oracle for just the sub-line blend stage (Fig. 3a).

    img_ts: (nw, nh); x: (n_lines,) fractional columns.
    Returns (n_lines, nh) blended sub-lines (columns clamped like the
    kernel; validity handled by the caller's mask).
    """
    nw = img_ts.shape[0]
    ix = jnp.clip(jnp.floor(x).astype(jnp.int32), 0, nw - 2)
    dx = x - jnp.floor(x)
    c0 = img_ts[ix]         # (n_lines, nh)
    c1 = img_ts[ix + 1]
    return c0 * (1.0 - dx)[:, None] + c1 * dx[:, None]
