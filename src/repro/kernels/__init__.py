# Pallas TPU kernels for the paper's compute hot-spot: back-projection.
# <name>.py = pl.pallas_call + BlockSpec; ops.py = jit'd wrappers;
# ref.py = pure-jnp oracle used by tests/test_kernels.py.

from .ops import (  # noqa: F401
    backproject_banded,
    backproject_onehot,
    backproject_subline,
)
from .ref import backproject_ref  # noqa: F401
