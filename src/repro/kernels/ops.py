"""Jitted public wrappers for the Pallas back-projection kernels.

Handles arbitrary problem shapes by padding the volume tile grid (voxel
lines outside the true volume compute garbage that is sliced away; their
projections may be off-detector, which the in-kernel masks already
zero — padding only costs compute, never correctness).

On real TPUs set interpret=False; the CPU CI in this repo always runs
interpret=True (kernel body executed in Python by the Pallas interpreter).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from .backproject_banded import backproject_banded as _backproject_banded
from .backproject_onehot import (backproject_onehot_fused,
                                 backproject_onehot_pallas)
from .backproject_subline import (backproject_subline_fused,
                                  backproject_subline_pallas,
                                  fused_batch_ok)

# KernelSpec contract (core.variants.REGISTRY): the call-time options each
# public wrapper consumes. The registry's Pallas KernelSpecs must declare
# exactly these sets — tests/test_planner.py cross-checks the two layers
# so a new kernel knob cannot be added here without the planner (which
# filters options through KernelSpec.options) learning about it.
ACCEPTED_OPTIONS = {
    "backproject_subline": frozenset({"nb", "block", "proj_loop",
                                      "interpret"}),
    "backproject_onehot": frozenset({"nb", "block", "k_chunk", "proj_loop",
                                     "interpret"}),
    "backproject_banded": frozenset({"nb", "block", "bw", "proj_loop",
                                     "interpret"}),
}


def _pad_to(n: int, b: int) -> int:
    return ((n + b - 1) // b) * b


def _fused_ok(img_t, nb: int, proj_loop: bool) -> bool:
    """Fused-mode eligibility (see kernels.backproject_subline
    ``fused_batch_ok`` — the one definition, shared with the banded
    wrapper's internal routing)."""
    return fused_batch_ok(img_t.shape[0], nb, proj_loop)


def _run_padded(fn, img_t, mat, vol_shape_xyz, block, **kw):
    # Only i/j may be padded: extra voxel LINES are masked by the kernel's
    # bounds checks. nz must never be padded — the symmetry pairing
    # k <-> nz-1-k is defined by the true volume center (the kernels
    # handle odd nz natively via an uneven half-split).
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    nip = _pad_to(ni, BI)
    njp = _pad_to(nj, BJ)
    vol = fn(img_t, mat, (nip, njp, nz), block=block, **kw)
    if (nip, njp) != (ni, nj):
        vol = vol[:ni, :nj]
    return vol


def backproject_subline(img_t: jnp.ndarray, mat: jnp.ndarray,
                        vol_shape_xyz, *, nb: int = 0,
                        block=(4, 8), proj_loop: bool = False,
                        interpret: bool = True) -> jnp.ndarray:
    """Paper Algorithm 1 as a Pallas kernel (symmetry_pf analogue).

    The output-stationary Pallas schedule holds the volume tile in VMEM
    across ALL projections — the nb -> np ideal of the paper's batching.
    With ``proj_loop`` the projection grid additionally runs over
    nb-sized batches with an in-kernel ``fori_loop``, cutting the
    per-grid-step output read-modify-write by the batch factor (paper
    O5 inside the kernel); without it ``nb`` is accepted for registry-
    signature uniformity but ignored. See DESIGN.md §2.
    """
    if _fused_ok(img_t, nb, proj_loop):
        return _run_padded(backproject_subline_fused, img_t, mat,
                           tuple(vol_shape_xyz), block, nb=nb,
                           interpret=interpret)
    return _run_padded(backproject_subline_pallas, img_t, mat,
                       tuple(vol_shape_xyz), block, interpret=interpret)


def backproject_onehot(img_t: jnp.ndarray, mat: jnp.ndarray,
                       vol_shape_xyz, *, nb: int = 0, block=(4, 8),
                       k_chunk: int = 128, proj_loop: bool = False,
                       interpret: bool = True) -> jnp.ndarray:
    """Beyond-paper MXU one-hot interpolation kernel (``proj_loop``:
    fused multi-batch mode, see :func:`backproject_subline`)."""
    if _fused_ok(img_t, nb, proj_loop):
        return _run_padded(backproject_onehot_fused, img_t, mat,
                           tuple(vol_shape_xyz), block, k_chunk=k_chunk,
                           nb=nb, interpret=interpret)
    return _run_padded(backproject_onehot_pallas, img_t, mat,
                       tuple(vol_shape_xyz), block, k_chunk=k_chunk,
                       interpret=interpret)


def backproject_banded(img_t: jnp.ndarray, mat: jnp.ndarray,
                       vol_shape_xyz, *, nb: int = 0, block=(4, 8),
                       bw: int = 32, proj_loop: bool = False,
                       interpret: bool = True) -> jnp.ndarray:
    """Beyond-paper geometry-prefetched banded kernel (C3): streams only
    the ~2*bw detector columns each (tile, projection) pair touches.
    ``proj_loop`` shares one band per nb-projection batch (the kernel
    wrapper widens bw until the batch union fits)."""
    ni, nj, nz = vol_shape_xyz
    BI, BJ = block
    nip, njp = _pad_to(ni, BI), _pad_to(nj, BJ)
    vol = _backproject_banded(img_t, mat, (nip, njp, nz), block=block,
                              bw=bw, nb=nb, proj_loop=proj_loop,
                              interpret=interpret)
    if (nip, njp) != (ni, nj):
        vol = vol[:ni, :nj]
    return vol
