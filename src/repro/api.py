"""The unified reconstruction API: one options object, one entry point.

Historically every façade re-declared the same ~12 keyword arguments
(``nb``, ``interpret``, ``tiling``, ``memory_budget``, ``proj_batch``,
``out``, ``schedule``, ``pipeline``, ``tuning``, ``devices``, plus
free-form kernel options) — three copies that drifted independently.
This module consolidates them:

* :class:`ReconOptions` — one frozen, hashable record of every knob a
  reconstruction can take, analytic (FDK) and iterative alike.
* :func:`reconstruct` — the top-level entry point:
  ``repro.reconstruct(projections, geom, method="fdk"|"sart"|
  "os_sart"|"cgls"|"fista_tv", options=ReconOptions(...))``.

Legacy keyword spellings keep working: ``reconstruct(..., nb=4)`` is
accepted and folded into the options record by :func:`_coerce_options`
— the ONE place the translation lives. Passing a legacy kwarg that
CONFLICTS with an explicitly-set options field raises a
``DeprecationWarning`` (the kwarg wins, matching the historical call
sites), so tier-1's ``error::DeprecationWarning`` filter turns any
drifting double-spelling in-repo into a test failure.
"""

from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Any, Optional, Sequence, Tuple, Union

import jax.numpy as jnp

from repro.core.geometry import CTGeometry

#: iterative methods (``method="fdk"`` is the analytic path)
ITERATIVE_METHODS = ("sart", "os_sart", "cgls", "fista_tv")


@dataclass(frozen=True)
class ReconOptions:
    """Every reconstruction knob, in one frozen record.

    Planner-owned fields (``variant`` .. ``precision``) mirror
    ``plan_reconstruction``; executor-owned fields (``pipeline``,
    ``devices``, ``service``, ``tuning``) mirror the façade extras;
    solver-owned fields (``n_iters`` .. ``oversample``) only apply to
    iterative methods and are ignored by ``method="fdk"``.
    ``kernel_options`` holds variant-specific extras and normalizes to
    a sorted tuple of pairs so the record stays hashable.
    """

    # -- planner-owned -----------------------------------------------------
    variant: str = "algorithm1_mp"
    nb: int = 8
    interpret: bool = True
    tiling: Union[None, str, Sequence[int]] = None
    memory_budget: Optional[int] = None
    proj_batch: Optional[int] = None
    out: Optional[str] = None
    schedule: Optional[str] = None
    precision: str = "f32"
    # -- executor / serving-owned -----------------------------------------
    pipeline: Optional[str] = None
    tuning: Any = None
    service: Any = None
    devices: Any = None
    # -- solver-owned (iterative methods only) ----------------------------
    n_iters: int = 10
    relax: float = 0.9
    tv_weight: float = 0.005
    tv_inner: Optional[int] = None
    oversample: float = 1.0
    x0: Any = None
    # -- variant-specific extras ------------------------------------------
    kernel_options: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self):
        ko = self.kernel_options
        if isinstance(ko, dict):
            object.__setattr__(self, "kernel_options",
                               tuple(sorted(ko.items())))
        elif not isinstance(ko, tuple):
            object.__setattr__(self, "kernel_options",
                               tuple(tuple(p) for p in ko))

    def kernel_options_dict(self) -> dict:
        return dict(self.kernel_options)


_FIELDS = {f.name: f.default for f in dataclasses.fields(ReconOptions)
           if f.name != "kernel_options"}


def _coerce_options(options: Optional[ReconOptions],
                    overrides: dict, caller: str) -> ReconOptions:
    """Fold legacy keyword spellings into one :class:`ReconOptions`.

    ``overrides`` (the legacy kwargs) win — that preserves historical
    call-site behavior — but an override that disagrees with a field
    the caller ALSO set explicitly on ``options`` is a conflicting
    double spelling and raises ``DeprecationWarning``. Unknown keys are
    variant kernel options and merge into ``kernel_options``.
    """
    opts = options if options is not None else ReconOptions()
    if not isinstance(opts, ReconOptions):
        raise TypeError(
            f"{caller}: options must be a ReconOptions, got "
            f"{type(opts).__name__}")
    if not overrides:
        return opts
    updates: dict = {}
    extra_ko: dict = {}
    for name, value in overrides.items():
        if name not in _FIELDS:
            extra_ko[name] = value
            continue
        current = getattr(opts, name)
        if current != _FIELDS[name] and current != value:
            warnings.warn(
                f"{caller}: legacy kwarg {name}={value!r} conflicts with "
                f"options.{name}={current!r}; the kwarg wins. Set the "
                f"field on ReconOptions instead of spelling it twice.",
                DeprecationWarning, stacklevel=3)
        updates[name] = value
    if extra_ko:
        merged = dict(opts.kernel_options)
        merged.update(extra_ko)
        updates["kernel_options"] = tuple(sorted(merged.items()))
    return dataclasses.replace(opts, **updates)


def reconstruct(projections: jnp.ndarray, geom: CTGeometry,
                method: str = "fdk",
                options: Optional[ReconOptions] = None,
                **overrides) -> jnp.ndarray:
    """Reconstruct a (nz, ny, nx) volume from (np, nh, nw) projections.

    ``method`` selects the algorithm: ``"fdk"`` (analytic filter +
    back-project) or one of the iterative solvers ``"sart"`` /
    ``"os_sart"`` / ``"cgls"`` / ``"fista_tv"`` (plan-level loops over
    the persistent :class:`~repro.runtime.solvers.IterativeExecutor`).
    All knobs ride ``options``; legacy keyword spellings are still
    accepted and folded in by the deprecation shim.
    """
    o = _coerce_options(options, overrides, f"reconstruct(method={method!r})")
    if method == "fdk":
        from repro.core.fdk import fdk_reconstruct
        return fdk_reconstruct(
            projections, geom, o.variant, nb=o.nb, interpret=o.interpret,
            tiling=o.tiling, memory_budget=o.memory_budget,
            proj_batch=o.proj_batch, out=o.out, schedule=o.schedule,
            pipeline=o.pipeline, tuning=o.tuning, service=o.service,
            devices=o.devices, precision=o.precision,
            **o.kernel_options_dict())
    if method not in ITERATIVE_METHODS:
        raise ValueError(
            f"method must be 'fdk' or one of {ITERATIVE_METHODS}, "
            f"got {method!r}")
    if o.devices is not None:
        raise ValueError(
            "iterative methods run single-device (the solver loop owns "
            "the volume); devices= applies to method='fdk' only")
    if o.service is not None:
        return o.service.reconstruct(
            projections, geom, variant=o.variant, nb=o.nb,
            interpret=o.interpret, tiling=o.tiling,
            memory_budget=o.memory_budget, proj_batch=o.proj_batch,
            out=o.out, schedule=o.schedule, precision=o.precision,
            solver=method, n_iters=o.n_iters, relax=o.relax,
            tv_weight=o.tv_weight, tv_inner=o.tv_inner, x0=o.x0,
            oversample=o.oversample, **o.kernel_options_dict())
    from repro.runtime.solvers import solve
    vol, _report = solve(
        projections, geom, method, n_iters=o.n_iters, relax=o.relax,
        x0=o.x0, tv_weight=o.tv_weight, tv_inner=o.tv_inner,
        oversample=o.oversample, variant=o.variant, nb=o.nb,
        interpret=o.interpret, proj_batch=o.proj_batch,
        schedule=o.schedule, precision=o.precision,
        **o.kernel_options_dict())
    return vol
