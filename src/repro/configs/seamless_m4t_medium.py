"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (GQA kv=16)
d_ff=4096 vocab=256206 — enc-dec, multimodal.  [arXiv:2308.11596; hf]

The speech frontend (conformer feature extractor) is a STUB per the pool:
input_specs provide precomputed frame embeddings (B, S, d_model). The
transformer backbone (12L encoder + 12L decoder with cross-attention) is
fully real.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,            # decoder layers
    n_enc_layers=12,        # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    norm="layernorm",
    activation="gelu",
    rope_theta=10000.0,
    frontend="audio_frames",
    frontend_dim=1024,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium-smoke", family="encdec",
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, norm="layernorm", activation="gelu",
        dtype="float32", attn_chunk=64, remat=False,
        frontend="audio_frames", frontend_dim=64,
    )
