"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + InternLM2(Qwen2-0.5B) backbone.
[arXiv:2404.16821; hf]

The InternViT-300M vision tower is a STUB per the pool: input_specs
provide precomputed patch embeddings (B, 256, 1024); the mlp1 projector
(1024 -> d_model) and the full LM backbone are real.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    tie_embeddings=True,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=1000000.0,
    frontend="vision_patches",
    frontend_dim=1024,       # InternViT-300M hidden size
    frontend_tokens=256,     # patch tokens per image tile
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke", family="vlm",
        n_layers=2, d_model=56, n_heads=4, n_kv_heads=2, d_ff=112,
        vocab_size=512, qkv_bias=True, tie_embeddings=True,
        norm="rmsnorm", activation="swiglu", dtype="float32",
        attn_chunk=64, remat=False,
        frontend="vision_patches", frontend_dim=32, frontend_tokens=8,
    )
