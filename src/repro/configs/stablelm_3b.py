"""stablelm-3b [dense] — 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,          # kv == heads -> plain MHA expressed as GQA
    d_ff=6912,
    vocab_size=50304,
    norm="layernorm",       # StableLM family uses LayerNorm
    activation="swiglu",
    rope_theta=10000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, norm="layernorm", activation="swiglu",
        dtype="float32", attn_chunk=64, remat=False,
    )
