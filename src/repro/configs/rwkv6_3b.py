"""rwkv6-3b [ssm] — 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536
— Finch, data-dependent decay.  [arXiv:2404.05892; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,             # d_model / head_size
    n_kv_heads=40,
    d_ff=8960,              # channel-mix width
    vocab_size=65536,
    norm="layernorm",
    activation="relu2",     # squared ReLU channel mix
    rwkv_head_size=64,
    rwkv_ddlora=32,
    rwkv_decay_lora=64,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, norm="layernorm", activation="relu2",
        dtype="float32", remat=False,
        rwkv_head_size=16, rwkv_ddlora=8, rwkv_decay_lora=8,
    )
