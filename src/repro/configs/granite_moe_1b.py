"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from .base import ModelConfig, MoESettings

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,               # per-expert FFN width
    vocab_size=49155,
    tie_embeddings=True,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=10000.0,
    moe=MoESettings(num_experts=32, top_k=8, d_ff_expert=512),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab_size=512, tie_embeddings=True, norm="rmsnorm",
        activation="swiglu", dtype="float32", attn_chunk=64, remat=False,
        # capacity_factor high enough that smoke tests never drop tokens
        # (keeps prefill and per-token decode bit-consistent).
        moe=MoESettings(num_experts=4, top_k=2, d_ff_expert=64,
                        capacity_factor=8.0),
    )
