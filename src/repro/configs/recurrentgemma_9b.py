"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1)
d_ff=12288 vocab=256000 — RG-LRU + local attn, 1:2.  [arXiv:2402.19427;
unverified]

Layer pattern (rec, rec, attn) — one local-attention layer per two
RG-LRU layers; 38 = 12 full macro-units + 2 trailing recurrent layers.
Local attention window 2048, MQA (kv=1). Sub-quadratic: runs long_500k.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,           # MQA
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    norm="rmsnorm",
    activation="geglu",     # gemma-style GeGLU
    rope_theta=10000.0,
    block_pattern=("rec", "rec", "attn"),
    window=2048,
    lru_width=4096,
    conv_width=4,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke", family="hybrid",
        n_layers=5,          # 1 macro-unit + 2 trailing rec layers
        d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128,
        vocab_size=512, norm="rmsnorm", activation="geglu",
        dtype="float32", attn_chunk=64, remat=False,
        block_pattern=("rec", "rec", "attn"), window=16, lru_width=64,
        conv_width=4,
    )
