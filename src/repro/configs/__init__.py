"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

All ten assigned pool architectures plus the paper's own CT workload.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from .base import (  # noqa: F401
    LM_SHAPES,
    MeshConfig,
    MLASettings,
    ModelConfig,
    MoESettings,
    RunConfig,
    ShapeConfig,
    get_shape,
)

_ARCH_MODULES: Dict[str, str] = {
    "stablelm-3b": "stablelm_3b",
    "qwen1.5-110b": "qwen1_5_110b",
    "deepseek-67b": "deepseek_67b",
    "qwen2.5-3b": "qwen2_5_3b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-1b": "internvl2_1b",
    "rwkv6-3b": "rwkv6_3b",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def _module(name: str):
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; have {list_archs()} "
                       f"(+ 'ct-backproject' via configs.ct_paper)")
    return importlib.import_module(
        f"repro.configs.{_ARCH_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke()
