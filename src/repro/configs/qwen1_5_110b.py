"""qwen1.5-110b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064 — QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=1000000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=160,
        vocab_size=512, qkv_bias=True, norm="rmsnorm",
        activation="swiglu", dtype="float32", attn_chunk=64, remat=False,
    )
