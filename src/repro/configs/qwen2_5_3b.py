"""qwen2.5-3b [dense] — 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,    # qwen2.5-3b ties input/output embeddings
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=1000000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab_size=512, qkv_bias=True, tie_embeddings=True,
        norm="rmsnorm", activation="swiglu", dtype="float32",
        attn_chunk=64, remat=False,
    )
