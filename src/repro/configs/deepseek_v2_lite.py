"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408 (expert)
vocab=102400, MoE 64e top-6, MLA kv_lora=512, 2 shared experts.
[arXiv:2405.04434; hf]

Pool-note reconciliation: the header says "MoE 64e top-6"; the free-text
note says "160 routed" which describes DeepSeek-V3 — we follow the header
(64 routed experts, top-6, 2 shared), matching the actual V2-Lite HF
config. V2-Lite additionally runs its FIRST layer as a dense MLP
(intermediate 10944) — modeled via first_dense_layers below.
"""

from .base import MLASettings, ModelConfig, MoESettings

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,              # per-expert FFN width (pool header)
    vocab_size=102400,
    norm="rmsnorm",
    activation="swiglu",
    rope_theta=10000.0,
    moe=MoESettings(num_experts=64, top_k=6, d_ff_expert=1408,
                    num_shared=2, d_ff_shared=1408,
                    first_dense_layers=1, first_dense_d_ff=10944),
    mla=MLASettings(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                    v_head_dim=128),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab_size=512, norm="rmsnorm", activation="swiglu",
        dtype="float32", attn_chunk=64, remat=False,
        moe=MoESettings(num_experts=4, top_k=2, d_ff_expert=64,
                        num_shared=1, d_ff_shared=64,
                        first_dense_layers=1, first_dense_d_ff=128,
                        capacity_factor=8.0),
        mla=MLASettings(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                        v_head_dim=16),
    )
