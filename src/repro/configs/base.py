"""Config dataclasses: model architecture, shapes, meshes, runs.

One ``ModelConfig`` per assigned architecture lives in its own module in
this package (exact dims from the public pool) together with a reduced
``smoke()`` variant for CPU tests. Shape configs implement the pool's
four workload cells (train_4k / prefill_32k / decode_32k / long_500k).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class MoESettings:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    d_ff_shared: Optional[int] = None
    capacity_factor: float = 1.25
    # Routing group size (GShard group dim): capacity is per group, so
    # dispatch tensors scale linearly in tokens. 0 -> all tokens one group.
    group_size: int = 4096
    # DeepSeek-V2: leading dense layers before the MoE stack begins.
    first_dense_layers: int = 0
    first_dense_d_ff: int = 0


@dataclasses.dataclass(frozen=True)
class MLASettings:
    kv_lora_rank: int
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | encdec | hybrid | ssm | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"
    activation: str = "swiglu"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    attn_chunk: int = 1024         # flash-attention KV chunk
    remat: bool = True             # activation checkpointing per layer
    remat_policy: str = "nothing"  # "nothing" | "dots" — what remat saves
    # MoE / MLA
    moe: Optional[MoESettings] = None
    mla: Optional[MLASettings] = None
    # hybrid (RecurrentGemma / Griffin)
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    window: int = 0                # sliding-window size for "attn" blocks
    lru_width: int = 0
    conv_width: int = 4
    # rwkv
    rwkv_head_size: int = 64
    rwkv_ddlora: int = 32
    rwkv_decay_lora: int = 64
    # encoder-decoder
    n_enc_layers: int = 0
    # frontend stubs ([audio]/[vlm]: precomputed embeddings per the pool)
    frontend: Optional[str] = None        # "audio_frames" | "vision_patches"
    frontend_dim: int = 0                 # raw stub embedding dim
    frontend_tokens: int = 0              # tokens contributed by frontend

    # ---- derived ---------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def np_dtype(self):
        return _DTYPES[self.dtype]

    @property
    def sub_quadratic(self) -> bool:
        """True iff a 512k-token decode state is O(1) or O(window)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # no encoder-only arch in the assigned pool

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                      # train_4k / prefill_32k / ...
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch          # one new token per sequence
        return self.seq_len * self.global_batch


LM_SHAPES = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self):
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self):
        return ("pod", "data", "model") if self.multi_pod else (
            "data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training-run hyperparameters (launch/train.py)."""
    steps: int = 100
    schedule_horizon: int = 0      # 0 = use `steps`; set explicitly when
    # a run is split across restarts so the LR schedule stays consistent
    microbatch: int = 0            # 0 = no gradient accumulation
    lr: float = 3e-4
    warmup_steps: int = 10
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    grad_compression: bool = False  # int8 error-feedback all-reduce
    log_every: int = 10
