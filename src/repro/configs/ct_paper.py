"""The paper's own workload: cone-beam back-projection problems P1..P10
(paper Table 3), expressed as a config the launcher/dry-run treats as an
eleventh architecture (``--arch ct-backproject``)."""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.geometry import CTGeometry


@dataclasses.dataclass(frozen=True)
class CTProblem:
    label: str
    det: int          # detector is det x det
    n_proj: int
    vol: int          # volume is vol^3

    def geometry(self) -> CTGeometry:
        from repro.core.geometry import standard_geometry
        return standard_geometry(n=self.vol, n_det=self.det,
                                 n_proj=self.n_proj)

    @property
    def updates(self) -> int:
        """GUPS numerator: nx*ny*nz*np."""
        return self.vol ** 3 * self.n_proj


# Paper Table 3. (P10's 1300^3 volume is ~8.2 GB — the case that does not
# fit P100/V100 GPUs, Fig. 11.)
PROBLEMS: Tuple[CTProblem, ...] = (
    CTProblem("P1", 256, 512, 256),
    CTProblem("P2", 256, 512, 512),
    CTProblem("P3", 256, 512, 1024),
    CTProblem("P4", 512, 512, 256),
    CTProblem("P5", 512, 512, 512),
    CTProblem("P6", 512, 512, 1024),
    CTProblem("P7", 1024, 512, 256),
    CTProblem("P8", 1024, 512, 512),
    CTProblem("P9", 1024, 512, 1024),
    CTProblem("P10", 1024, 512, 1300),
)


def get_problem(label: str) -> CTProblem:
    for p in PROBLEMS:
        if p.label == label:
            return p
    raise KeyError(label)


def smoke_problem() -> CTProblem:
    """Reduced problem for CPU tests (same structure as P5)."""
    return CTProblem("P5-smoke", 24, 8, 16)
