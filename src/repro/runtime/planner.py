"""Reconstruction planning: a pure, declarative schedule for any entry point.

This is stage 1 of the repo's plan/compile/execute architecture
(docs/ARCHITECTURE.md). A :class:`ReconPlan` is built once from geometry +
request parameters by :func:`plan_reconstruction` — with **no** array data
and **no** jax in the loop — and then consumed by ``runtime.executor``:

    plan     runtime.planner.plan_reconstruction  (this module, pure)
    compile  runtime.executor.ProgramCache        (keyed jit programs)
    execute  runtime.executor.PlanExecutor        (streaming loops)

The plan owns every scheduling decision the paper ties performance to:

  * the (i, j)-tile x Z-slab decomposition, with the O3 mirror-pair
    schedule for symmetry-carrying variants (``core.tiling.plan_z_units``)
    and depth-bounded plain slabs for symmetry-free ones;
  * per-step variant resolution: a Z-slab that is neither volume-centered
    nor mirror-paired runs the variant's declarative
    ``KernelSpec.slab_safe_fallback`` instead (``core.variants.REGISTRY``);
  * per-step matrix translation offsets (``core.tiling.translate_matrices``
    folds the sub-box origin into the constant column, so the kernels run
    unchanged);
  * the projection-chunk schedule: chunk bounds over the *padded*
    projection count (tail batches padded to a multiple of ``nb`` with
    zero images + repeated matrices — exactly zero contribution), which
    is what lets the executor stream pre-weighting + ramp filtering
    through the chunk loop instead of filtering the whole set up front;
  * the loop ORDER: ``schedule="step"`` (default) inverts execution to
    step-major — :class:`StepMajorSchedule` gives every step the full
    chunk work list, the executor carries each step's tile accumulator
    across all chunks on device (one ``lax.scan`` megaprogram per
    program key) and emits it to host exactly once, so device->host
    volume traffic is O(vol) instead of the chunk-major O(n_chunks x
    vol); ``schedule="chunk"`` keeps the PR-2 chunk-major loop;
  * option validation, in ONE place, for every façade
    (``fdk_reconstruct``, ``sart_step``, ``TiledReconstructor``,
    ``backproject_distributed``).

Because planning is pure, every scheduling invariant is unit-testable
without touching arrays (tests/test_planner.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Sequence, Tuple

from repro.core.geometry import CTGeometry
from repro.core.tiling import (
    TileSpec, make_tiles, pick_tile_shape, plan_proj_chunks, plan_z_slabs,
    plan_z_units, tile_working_set_bytes,
)
from repro.core.variants import KernelSpec, get_spec
from repro.runtime import telemetry


@dataclasses.dataclass(frozen=True)
class TileWrite:
    """How one contiguous Z-range of a kernel call's output lands in the
    volume: ``out[..., lo:hi]`` is written at global Z origin ``k0``."""

    k0: int
    lo: int
    hi: int

    @property
    def nk(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass(frozen=True)
class PlanStep:
    """One kernel invocation: a sub-box call plus its volume writes.

    A mirror-paired step calls the (symmetry-carrying) kernel once with
    virtual depth ``2*nk`` and scatters the two halves to the slab and
    its O3 mirror — two :class:`TileWrite` entries. Plain steps have one.
    ``variant`` is already resolved (slab-safe fallback applied), so the
    executor never consults the registry for scheduling decisions.
    """

    i0: int
    j0: int
    ni: int
    nj: int
    k_off: int                      # Z translation folded into the matrices
    call_nk: int                    # Z extent of the kernel call
    variant: str                    # resolved kernel name
    writes: Tuple[TileWrite, ...]

    @property
    def call_shape(self) -> Tuple[int, int, int]:
        return (self.ni, self.nj, self.call_nk)

    @property
    def paired(self) -> bool:
        return len(self.writes) > 1


@dataclasses.dataclass(frozen=True)
class ChunkWork:
    """One projection chunk as seen by a step-major schedule: chunk
    number ``index`` covering padded projection rows ``[s0, s1)``. The
    tail chunk may be smaller than the uniform scan slot (``size <
    chunk_size``); the difference is zero-image scan padding."""

    index: int
    s0: int
    s1: int

    @property
    def size(self) -> int:
        return self.s1 - self.s0


@dataclasses.dataclass(frozen=True)
class StepWork:
    """One step-major unit of work: a kernel step plus the full chunk
    list its device-resident accumulator is scanned over."""

    step: PlanStep
    chunks: Tuple[ChunkWork, ...]


@dataclasses.dataclass(frozen=True)
class ChunkFold:
    """One online-arrival unit of work: a completed projection chunk
    plus every tile step it must be folded into. The executor runs the
    steps in schedule order, adding each kernel output into that step's
    device-resident accumulator — the arrival-ordered dual of
    :class:`StepWork`."""

    chunk: ChunkWork
    steps: Tuple[PlanStep, ...]


@dataclasses.dataclass(frozen=True)
class StreamSchedule:
    """Arrival-ordered (chunk-major) view of a plan for online ingest.

    ``folds[c]`` becomes runnable the moment every raw view of chunk
    ``c`` has arrived; folds MUST be consumed in index order (the
    chunk-index fold order is what makes the online reduction
    bit-identical to the offline chunk-major loop — see
    docs/ARCHITECTURE.md Stage 8). ``n_views`` is the raw view count a
    stream must deliver before it can close; rows past it inside the
    tail chunk are the usual zero-image nb padding and are never
    pushed.
    """

    n_chunks: int
    chunk_size: int
    n_views: int
    folds: Tuple[ChunkFold, ...]


@dataclasses.dataclass(frozen=True)
class StepMajorSchedule:
    """Step-major view of a plan: per-step chunk work lists + the scan
    grid shape.

    The executor's scan megaprogram consumes a uniform
    ``(n_chunks, chunk_size, ...)`` chunk stack; ``n_scan = n_chunks *
    chunk_size`` is the stacked projection extent (rows past the padded
    projection count are zero images + repeated matrices — exactly zero
    contribution, same trick as the nb tail pad). Every step scans the
    SAME chunk list, which is what lets the filtered-chunk producer run
    once and feed all steps.
    """

    n_chunks: int
    chunk_size: int
    n_scan: int
    steps: Tuple[StepWork, ...]

    def fleet(self, n_shards: int) -> "FleetSchedule":
        """Partition this schedule's steps into ``n_shards`` balanced
        per-device work queues (see :func:`partition_steps`)."""
        return partition_steps(tuple(w.step for w in self.steps),
                               n_shards)


@dataclasses.dataclass(frozen=True)
class FleetSchedule:
    """Per-device work queues over a step schedule — the multi-device
    fleet's partition of a :class:`StepMajorSchedule`.

    ``queues[d]`` holds the step INDICES (into the partitioned step
    sequence, in schedule order) device ``d`` owns at launch; ``loads``
    is the modeled voxel-work per device the LPT packing balanced.
    Because every step writes a DISJOINT box of the volume and is
    re-entrant (pure function of the filtered chunk stack + its origin),
    ownership is only the STARTING assignment: work stealing may migrate
    a queued step to any idle device, and failover may re-run a failed
    device's steps elsewhere, without changing the result.
    """

    n_shards: int
    queues: Tuple[Tuple[int, ...], ...]
    loads: Tuple[int, ...]

    @property
    def n_steps(self) -> int:
        return sum(len(q) for q in self.queues)


def step_cost(step: PlanStep) -> int:
    """Modeled per-chunk work of one step: the kernel call's voxel
    count. All steps of one schedule scan the same chunk list, so the
    chunk factor is constant and drops out of the balance."""
    return step.ni * step.nj * step.call_nk


def partition_steps(steps: Sequence[PlanStep],
                    n_shards: int) -> FleetSchedule:
    """Partition a step list into ``n_shards`` balanced work queues.

    Greedy LPT (longest-processing-time first): steps are assigned in
    decreasing :func:`step_cost` order to the least-loaded shard —
    within 4/3 of the optimal makespan, deterministic (ties break on
    the lower step index, then the lower shard index), and pure, so the
    partition is unit-testable without devices (tests/test_planner.py).
    Every index in ``range(len(steps))`` appears in exactly one queue;
    queues keep schedule order (interior tiles stay adjacent — the
    shared scan-program key stays warm within a queue).
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    order = sorted(range(len(steps)),
                   key=lambda i: (-step_cost(steps[i]), i))
    loads = [0] * n_shards
    queues: Tuple[list, ...] = tuple([] for _ in range(n_shards))
    for i in order:
        d = min(range(n_shards), key=lambda s: (loads[s], s))
        queues[d].append(i)
        loads[d] += step_cost(steps[i])
    return FleetSchedule(
        n_shards=n_shards,
        queues=tuple(tuple(sorted(q)) for q in queues),
        loads=tuple(loads))


def build_step_major(steps: Sequence[PlanStep],
                     chunks: Sequence[Tuple[int, int]],
                     chunk_size: int) -> StepMajorSchedule:
    """Invert a (steps x chunks) schedule to step-major work lists.

    Shared by :attr:`ReconPlan.step_major` (the planned projection
    count) and the executor's data-dependent path (``backproject``
    accepts any view count, so its chunk list follows the input)."""
    work = tuple(ChunkWork(c, s0, s1) for c, (s0, s1) in enumerate(chunks))
    n_chunks = len(work)
    return StepMajorSchedule(
        n_chunks=n_chunks, chunk_size=int(chunk_size),
        n_scan=n_chunks * int(chunk_size),
        steps=tuple(StepWork(s, work) for s in steps))


@dataclasses.dataclass(frozen=True)
class ReconPlan:
    """Complete, immutable schedule for one reconstruction.

    ``steps`` covers the volume disjointly via their writes; ``chunks``
    covers ``[0, n_proj_padded)`` disjointly. ``schedule`` selects the
    executor's loop order: ``"step"`` (step-major — the tile accumulator
    is carried across all projection chunks on device by one scan
    program and crosses to the host once per step) or ``"chunk"`` (the
    PR-2 chunk-major loop — one host crossing per step per chunk, kept
    for bounded-device-memory streaming and as the parity oracle).
    ``options`` holds the validated extra kernel options (already
    filtered to what the requested variant's KernelSpec accepts).

    The plan is hashable (a frozen dataclass of hashable fields), so it
    can key caches directly; :attr:`bucket_key` is the compact identity
    the serving layer buckets on.
    """

    vol_shape_xyz: Tuple[int, int, int]
    det_shape_wh: Tuple[int, int]
    variant: str
    tile_shape: Tuple[int, int, int]
    nb: int
    n_proj: int
    n_proj_padded: int
    chunk_size: int                       # projections per chunk (nb-multiple)
    out: str                              # "host" | "device"
    interpret: bool
    steps: Tuple[PlanStep, ...]
    options: Tuple[Tuple[str, object], ...] = ()
    schedule: str = "step"                # "step" | "chunk"
    # rb: how many same-bucket REQUESTS one execution carries as a
    # leading batch axis (cross-request batching — the service-level
    # second tier of the paper's nb in-batch trick). Deliberately NOT
    # part of bucket_key: same-bucket requests of any arrival order are
    # batchable, and the bucket identity must not fragment on how many
    # of them happened to coalesce. It DOES scale the working-set model
    # (every projection stack and accumulator is rb-deep).
    request_batch: int = 1
    # ingest: "offline" (all projections available up front — every
    # pre-PR-8 path) | "stream" (projections arrive while the plan
    # runs; the executor folds each view chunk the moment it
    # completes). Stream plans are always chunk-major — the arriving
    # unit IS the chunk — and ARE part of bucket_key: a stream session
    # holds per-step accumulators alive across pushes, so it must not
    # share an executor bucket with offline one-shot requests.
    ingest: str = "offline"
    # precision: "f32" (exact float32 everywhere) | "bf16" (reduced-
    # precision data path: projection samples are rounded to bfloat16
    # before entering a kernel — halving the streamed projection bytes,
    # the Treibig/Hofmann locality lever — while interpolation weights
    # and every accumulator stay float32). A numeric knob with the same
    # exactness-tolerance contract as variant="auto": parity with f32
    # holds at tolerance, never bit level. Part of bucket_key — bf16
    # and f32 traffic compile distinct program families and must not
    # share a bucket.
    precision: str = "f32"
    # solver: "none" (a single back-projection / FDK pass — every
    # pre-PR-9 plan) | "sart" | "os_sart" | "cgls" | "fista_tv" (the
    # plan drives runtime.solvers.IterativeExecutor's plan-level
    # iteration loop). Part of bucket_key: solver buckets hold forward-
    # projection programs and normalizer volumes alive across requests,
    # so they must not share an executor bucket with one-shot FDK
    # traffic. For "os_sart" the projection-chunk schedule doubles as
    # the ordered-subset partition (chunk c == subset c).
    solver: str = "none"

    # ---- derived schedules / introspection --------------------------------

    @property
    def chunks(self) -> Tuple[Tuple[int, int], ...]:
        """[s0, s1) projection-chunk bounds over the padded count."""
        _, _, chunks = plan_proj_chunks(self.n_proj_padded, self.nb,
                                        self.chunk_size)
        return tuple(chunks)

    @property
    def streams_projections(self) -> bool:
        """Whether more than one chunk flows through the executor."""
        return self.chunk_size < self.n_proj_padded

    @property
    def step_major(self) -> StepMajorSchedule:
        """First-class step-major schedule over the planned projections."""
        return build_step_major(self.steps, self.chunks, self.chunk_size)

    @property
    def stream(self) -> StreamSchedule:
        """Arrival-ordered online schedule: one :class:`ChunkFold` per
        projection chunk, runnable as soon as that chunk's views have
        all arrived. Defined for any plan (the fold list is just the
        chunk-major loop transposed), but executed only by stream
        executors on ``ingest="stream"`` plans."""
        work = tuple(ChunkWork(c, s0, s1)
                     for c, (s0, s1) in enumerate(self.chunks))
        return StreamSchedule(
            n_chunks=len(work), chunk_size=self.chunk_size,
            n_views=self.n_proj,
            folds=tuple(ChunkFold(w, self.steps) for w in work))

    @property
    def subsets(self) -> Tuple[Tuple[int, int], ...]:
        """Ordered-subset view ranges: the projection-chunk schedule
        clipped to the REAL view count (the chunk grid's zero-image nb
        padding carries no data and is never a subset member). This is
        the partition OS-SART sweeps — one subset per chunk, so the
        tuner's existing ``proj_batch`` axis IS the subset-count axis.
        """
        out = []
        for s0, s1 in self.chunks:
            if s0 >= self.n_proj:
                break
            out.append((s0, min(s1, self.n_proj)))
        return tuple(out)

    @property
    def program_keys(self) -> Tuple[Tuple[str, Tuple[int, int, int]], ...]:
        """Distinct (variant, call_shape) pairs — the compile workload.

        Interior tiles share shapes, so this is typically much smaller
        than ``len(steps)``: the program cache compiles each key once.
        """
        seen: Dict[Tuple[str, Tuple[int, int, int]], None] = {}
        for s in self.steps:
            seen.setdefault((s.variant, s.call_shape))
        return tuple(seen)

    @property
    def bucket_key(self) -> Tuple:
        """Hashable request-shape identity for the serving layer.

        Two requests with equal bucket keys plan identical schedules
        and hit the same compiled programs, so ``runtime/service.py``
        buckets on ``(geometry, plan.bucket_key)``. The derived
        ``steps``/``chunks`` are deterministic functions of these
        fields, so they are deliberately excluded — the key stays a
        flat tuple of scalars/short tuples. ``request_batch`` is also
        excluded ON PURPOSE: rb is an execution multiplicity over the
        same compiled shape family, and batching only works if k
        same-bucket requests land in ONE bucket.
        """
        return (self.vol_shape_xyz, self.det_shape_wh, self.variant,
                self.tile_shape, self.nb, self.n_proj, self.n_proj_padded,
                self.chunk_size, self.out, self.interpret, self.options,
                self.schedule, self.ingest, self.precision, self.solver)

    @property
    def working_set_bytes(self) -> int:
        """Peak modeled working set over all planned kernel calls,
        scaled by ``request_batch``: an rb-batched execution carries rb
        projection stacks and rb accumulators through every call, so
        the memory-budget contract must bill all of them."""
        return self.request_batch * max(tile_working_set_bytes(
            s.call_shape, self.det_shape_wh, nb=self.nb)
            for s in self.steps)

    def batched(self, request_batch: int) -> "ReconPlan":
        """This plan with a ``request_batch`` leading axis of ``rb``
        requests (same ``bucket_key`` — see above). The schedule is
        unchanged: the executor's rb-batched programs vmap/stack the
        SAME step-major scan over the request axis."""
        rb = int(request_batch)
        if rb < 1:
            raise ValueError(f"request_batch must be >= 1, got {rb}")
        if rb == self.request_batch:
            return self
        return dataclasses.replace(self, request_batch=rb)

    def kernel_options(self) -> Dict:
        return dict(self.options)


# --------------------------------------------------------------------------
# Per-tile variant resolution (shared with the single-tile façade)
# --------------------------------------------------------------------------

def resolve_tile_variant(variant: str, tile: TileSpec, nz: int) -> str:
    """Kernel to run on one arbitrary sub-box: the requested variant when
    the box is Z-centered on the volume midplane (symmetry exact), its
    declarative slab-safe fallback otherwise."""
    spec = get_spec(variant)
    if not spec.uses_symmetry or 2 * tile.k0 + tile.nk == nz:
        return variant
    return spec.slab_safe_fallback


# --------------------------------------------------------------------------
# The planner
# --------------------------------------------------------------------------

def _plan_steps(vol_shape_xyz: Tuple[int, int, int],
                tile_shape: Tuple[int, int, int],
                spec: KernelSpec) -> Tuple[PlanStep, ...]:
    """Tile/slab schedule with per-step variant resolution.

    Symmetry variants get the mirror-paired Z schedule (one call of
    virtual depth 2*nk fills both slabs — the O3 flop saving survives
    tiling; the centered middle slab may be up to 2*tk-1 deep). Symmetry-
    free variants get plain slabs bounded at tk, since pairing buys them
    nothing.
    """
    nx, ny, nz = vol_shape_xyz
    ti, tj, tk = tile_shape
    z_units = (plan_z_units(nz, tk) if spec.uses_symmetry
               else plan_z_slabs(nz, tk))
    steps = []
    for t in make_tiles((nx, ny, 1), (ti, tj, 1)):
        for u in z_units:
            if u.paired and spec.uses_symmetry:
                steps.append(PlanStep(
                    t.i0, t.j0, t.ni, t.nj, k_off=u.k0, call_nk=2 * u.nk,
                    variant=spec.name,
                    writes=(TileWrite(u.k0, 0, u.nk),
                            TileWrite(u.mirror_k0, u.nk, 2 * u.nk))))
            else:
                sub = TileSpec(t.i0, t.j0, u.k0, t.ni, t.nj, u.nk)
                steps.append(PlanStep(
                    t.i0, t.j0, t.ni, t.nj, k_off=u.k0, call_nk=u.nk,
                    variant=resolve_tile_variant(spec.name, sub, nz),
                    writes=(TileWrite(u.k0, 0, u.nk),)))
    return tuple(steps)


def _plan_reconstruction_impl(geom: CTGeometry,
                        variant: str = "algorithm1_mp", *,
                        tile_shape: Optional[Sequence[int]] = None,
                        memory_budget: Optional[int] = None,
                        nb: int = 8,
                        proj_batch: Optional[int] = None,
                        out: str = "host",
                        interpret: bool = True,
                        schedule: Optional[str] = None,
                        request_batch: int = 1,
                        ingest: str = "offline",
                        precision: str = "f32",
                        solver: str = "none",
                        tuning=None,
                        **kernel_options) -> ReconPlan:
    """Build the :class:`ReconPlan` every entry point executes.

    Parameters mirror the façades; validation for ALL of them lives here:

    tile_shape : (ti, tj, tk) max tile size; ``None`` picks it from
        ``memory_budget``, or uses the full volume if neither is given
        (the untiled plan: one step, one chunk — exactly the seed path).
    memory_budget : byte budget for one call's working set. Combined with
        an explicit ``tile_shape`` it validates instead of picking.
    nb : in-batch projection count (paper O5); must be >= 1.
    proj_batch : projections streamed per kernel call, rounded UP to a
        multiple of ``nb``; ``None`` = all at once (a single chunk).
    out : "host" (numpy accumulator, device holds one tile) | "device".
    interpret : forwarded to Pallas variants (CPU CI runs interpret=True).
    schedule : "step" (device-resident scanned accumulators, one host
        crossing per step) | "chunk" (the PR-2 chunk-major loop;
        per-chunk host crossings, but also per-chunk — not whole-set —
        device residency of the filtered projections) | None (default:
        resolve it). Step-major stacks the whole filtered projection
        set on device as the scan input, so an explicit
        ``memory_budget`` — the caller's byte-bound contract — resolves
        to "chunk" (whose residency the per-call working-set model
        soundly describes); everything else resolves to "step".
    ingest : "offline" (default — the whole projection set is handed to
        the executor at once) | "stream" (projections are PUSHED as the
        scanner produces them; ``StreamingExecutor`` folds each view
        chunk the moment it completes). Stream plans are forced
        chunk-major — the completed chunk is the unit of arrival — so
        ``ingest="stream"`` with an explicit ``schedule="step"`` is an
        error, and ``schedule=None`` resolves to "chunk". Because a
        ``TunedConfig`` does not carry an ingest axis, stream plans
        always resolve heuristically: ``variant="auto"`` falls back to
        the default kernel and ``tuning`` is ignored.
    request_batch : rb, the cross-request batch width this plan is
        sized for (>= 1; default 1 = the single-request plan). rb is
        NOT part of the bucket identity, but it scales the working-set
        math: the tile auto-picker sees ``memory_budget // rb`` (rb
        accumulators + projection stacks must fit together) and the
        explicit-tile validation bills the rb-scaled working set, so
        the byte contract stays honest under batching.
    precision : "f32" (default — exact float32) | "bf16" (reduced-
        precision data path: bf16-rounded projection samples, f32
        interpolation weights + accumulators — see
        :attr:`ReconPlan.precision`). A numeric knob: output parity
        with f32 is at tolerance, like ``variant="auto"``.
    solver : "none" (default — one back-projection pass) | "sart" |
        "os_sart" | "cgls" | "fista_tv": marks the plan as the engine
        of an iterative loop (``runtime.solvers.IterativeExecutor``).
        Solver plans accumulate on device (the volume feeds the next
        forward projection), so ``out`` must stay "device"; for
        "os_sart" the chunk schedule is also the ordered-subset
        partition (:attr:`ReconPlan.subsets`).
    tuning : opt-in to the measured autotuner's persisted winners
        (``runtime.autotune``): a ``TuningCache``, a cache-file path,
        or None. With ``variant="auto"`` (or any non-None ``tuning``)
        the plan is resolved by LOOKUP against the tuning cache — a
        persisted winner for this hardware fingerprint x request shape
        replaces the heuristic knobs; a miss (or a missing/corrupt
        cache file) falls back to exactly the heuristic plan this
        function builds today. Planning never measures.
    kernel_options : extra per-variant knobs (e.g. ``block=``, ``bw=``),
        validated against the variant's ``KernelSpec.options``. The
        ``proj_loop`` fused in-kernel projection loop is resolved here
        per variant: defaulted ON for kernels whose KernelSpec
        advertises the capability, absent otherwise.
    """
    if ingest not in ("offline", "stream"):
        raise ValueError(
            f"ingest must be 'offline' or 'stream', got {ingest!r}")
    if ingest == "stream":
        # TunedConfig has no ingest axis; stream plans stay heuristic
        tuning = None
        if variant == "auto":
            variant = "algorithm1_mp"
    if variant == "auto" or tuning is not None:
        # lookup-only: the autotuner owns fingerprinting + the cache;
        # imported lazily so the heuristic path stays jax-free
        from repro.runtime.autotune import resolve_plan
        return resolve_plan(
            geom, variant=variant, tuning=tuning, tile_shape=tile_shape,
            memory_budget=memory_budget, nb=nb, proj_batch=proj_batch,
            out=out, interpret=interpret, schedule=schedule,
            request_batch=request_batch, precision=precision,
            solver=solver, **kernel_options)
    spec = get_spec(variant)
    if precision not in ("f32", "bf16"):
        raise ValueError(
            f"precision must be 'f32' or 'bf16', got {precision!r}")
    if solver not in ("none", "sart", "os_sart", "cgls", "fista_tv"):
        raise ValueError(
            f"solver must be 'none', 'sart', 'os_sart', 'cgls' or "
            f"'fista_tv', got {solver!r}")
    if solver != "none":
        if out not in (None, "device"):
            raise ValueError(
                "solver plans accumulate on device (the volume feeds "
                "the next forward projection every iteration; host "
                "staging would add two full-volume round-trips per "
                f"sweep) — out must be 'device', got {out!r}")
        out = "device"
        if ingest == "stream":
            raise ValueError(
                "solver plans iterate over the COMPLETE projection set "
                "(every sweep revisits all views); ingest='stream' "
                "cannot compose with them — reconstruct online with "
                "solver='none' or wait for the scan to finish")
    request_batch = int(request_batch)
    if request_batch < 1:
        raise ValueError(
            f"request_batch must be >= 1, got {request_batch}")
    if out not in ("host", "device"):
        raise ValueError(f"out must be 'host' or 'device', got {out!r}")
    if schedule not in (None, "step", "chunk"):
        raise ValueError(
            f"schedule must be 'step', 'chunk' or None, got {schedule!r}")
    if ingest == "stream" and schedule == "step":
        raise ValueError(
            "ingest='stream' folds view chunks as they arrive, which is "
            "chunk-major by construction; schedule='step' scans a "
            "complete chunk stack and cannot start before the last view "
            "— use schedule='chunk' or leave it unset")
    if schedule is None:
        schedule = ("chunk" if (ingest == "stream"
                                or memory_budget is not None) else "step")
    nb = int(nb)
    if nb < 1:
        raise ValueError(f"nb must be >= 1, got {nb}")

    unknown = set(kernel_options) - set(spec.options) - {"nb", "interpret"}
    if unknown:
        raise ValueError(
            f"variant {variant!r} does not accept option(s) "
            f"{sorted(unknown)}; its KernelSpec allows "
            f"{sorted(spec.options)}")

    # proj_loop capability resolution (paper O1 loop order + O3 locality
    # carried INTO the kernel): on by default where the KernelSpec
    # advertises it; a registry-validated no-op everywhere else.
    if spec.proj_loop and "proj_loop" not in kernel_options:
        kernel_options["proj_loop"] = True

    nx, ny, nz = geom.volume_shape_xyz
    tile_given = tile_shape is not None
    if tile_shape is None:
        if memory_budget is not None:
            # rb batched executions carry rb working sets at once: the
            # auto-picker must size ONE against budget/rb so all rb
            # together honor the caller's byte contract
            tile_shape = pick_tile_shape(
                (nx, ny, nz), (geom.nw, geom.nh),
                max(1, int(memory_budget) // request_batch),
                nb=nb, pair_z=spec.uses_symmetry)
        else:
            tile_shape = (nx, ny, nz)
    ti, tj, tk = (int(v) for v in tile_shape)
    tile = (max(1, min(ti, nx)), max(1, min(tj, ny)), max(1, min(tk, nz)))

    steps = _plan_steps((nx, ny, nz), tile, spec)

    n_proj = int(geom.n_proj)
    n_pad, chunk, _ = plan_proj_chunks(n_proj, nb, proj_batch)

    plan = ReconPlan(
        vol_shape_xyz=(nx, ny, nz), det_shape_wh=(geom.nw, geom.nh),
        variant=variant, tile_shape=tile, nb=nb,
        n_proj=n_proj, n_proj_padded=n_pad, chunk_size=chunk,
        out=out, interpret=interpret, steps=steps,
        options=tuple(sorted(spec.resolve_options(kernel_options).items())),
        schedule=schedule, request_batch=request_batch, ingest=ingest,
        precision=precision, solver=solver)

    if tile_given and memory_budget is not None and \
            plan.working_set_bytes > int(memory_budget):
        raise ValueError(
            f"explicit tile_shape {tile} needs "
            f"{plan.working_set_bytes} B, over the memory_budget of "
            f"{int(memory_budget)} B — drop one of the two or enlarge "
            f"the budget")
    return plan


@functools.wraps(_plan_reconstruction_impl)
def plan_reconstruction(geom: CTGeometry, variant: str = "algorithm1_mp",
                        **kwargs) -> ReconPlan:
    # Telemetry seam: every plan build (heuristic or tuning-lookup —
    # the lookup path re-enters here for its heuristic fallback, which
    # nests a second span) is one "plan.build" span. All knobs beyond
    # ``variant`` are keyword-only in the impl, so the pass-through
    # signature is lossless; @wraps keeps the docstring + introspection.
    with telemetry.span("plan.build", variant=str(variant)):
        return _plan_reconstruction_impl(geom, variant, **kwargs)


plan_reconstruction.__name__ = "plan_reconstruction"
plan_reconstruction.__qualname__ = "plan_reconstruction"
