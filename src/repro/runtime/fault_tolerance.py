"""Fault-tolerant training loop wrapper.

Posture for 1000+ nodes (what runs here is the single-process realization
of the same contract; on a real cluster the heartbeat transport is the
coordinator's key-value store):

  * every step is re-entrant: state = (params, opt_state, data_step), all
    derivable from (checkpoint, pipeline.seek);
  * failures surface as exceptions from the jitted step (device loss,
    NaN-guard, preemption signal) -> the loop restores the last
    checkpoint, reseeks the pipeline and continues;
  * repeated failure at the SAME step (poison batch / systematic fault)
    triggers skip-ahead of one step after `max_retries_per_step`;
  * heartbeats timestamp progress so an external supervisor can detect a
    hung host (see Heartbeat.stale).
"""

from __future__ import annotations

import collections
import logging
import time
from typing import Any, Callable, Dict, Optional

log = logging.getLogger("repro.runtime")


class Heartbeat:
    """Progress timestamps for an external supervisor.

    ``stale`` is gated on the first completed step (``step >= 0``): the
    gap between construction and the first beat includes jit warmup of
    the first step, which can legitimately exceed ``timeout_s`` — a
    supervisor must not shoot a host that is still compiling. Once any
    step has beaten, a silent gap longer than ``timeout_s`` means hung.
    """

    def __init__(self, timeout_s: float = 300.0):
        self.timeout_s = timeout_s
        self.last_beat = time.monotonic()
        self.step = -1

    def beat(self, step: int) -> None:
        self.step = step
        self.last_beat = time.monotonic()

    @property
    def stale(self) -> bool:
        if self.step < 0:        # warmup: no step has completed yet
            return False
        return (time.monotonic() - self.last_beat) > self.timeout_s


class FaultTolerantLoop:
    """Drives `step_fn(state, batch) -> (state, metrics)` with recovery."""

    def __init__(self, *, checkpointer, pipeline, save_every: int = 50,
                 max_retries_per_step: int = 2, heartbeat: Heartbeat = None,
                 nan_guard: bool = True):
        self.ckpt = checkpointer
        self.pipeline = pipeline
        self.save_every = save_every
        self.max_retries = max_retries_per_step
        self.heartbeat = heartbeat or Heartbeat()
        self.nan_guard = nan_guard
        self.failures = 0
        self.recoveries = 0

    def resume_or_init(self, init_state_fn: Callable[[], Any]):
        """Restore the latest checkpoint or build fresh state."""
        like = init_state_fn()
        step, state = self.ckpt.restore_latest(like)
        if step is None:
            return 0, like
        self.pipeline.seek(step)
        log.info("resumed from checkpoint step %d", step)
        return step, state

    def run(self, state, step_fn: Callable, *, start_step: int,
            num_steps: int, on_metrics: Optional[Callable] = None):
        step = start_step
        # Failures are counted PER STEP INDEX, never reset by successes:
        # when a checkpoint precedes a deterministic poison step, the
        # restore rewinds to ck_step and the replayed steps all succeed —
        # a consecutive-attempt counter (the old `retries_here`) would
        # reset on each of them and the loop would recover forever. The
        # per-index count survives the replay, so the poison step's
        # budget is exceeded after max_retries+1 failures no matter how
        # many checkpoint rewinds happen in between.
        fail_counts: Dict[int, int] = collections.Counter()
        while step < start_step + num_steps:
            if fail_counts[step] > self.max_retries:
                log.warning("skipping poisoned step %d", step)
                step += 1          # poison skip-ahead (re-entrant steps)
                continue
            batch = self.pipeline.batch_at(step)
            try:
                state, metrics = step_fn(state, batch)
                if self.nan_guard and _has_nan(metrics):
                    raise FloatingPointError(
                        f"non-finite loss at step {step}: {metrics}")
            except Exception as e:  # noqa: BLE001 — any step fault recovers
                self.failures += 1
                fail_counts[step] += 1
                log.warning("step %d failed (%s); recovering", step, e)
                ck_step, restored = self.ckpt.restore_latest(state)
                if restored is not None:
                    state = restored
                    step = ck_step
                self.recoveries += 1
                continue
            self.heartbeat.beat(step)
            if on_metrics:
                on_metrics(step, metrics)
            step += 1
            if step % self.save_every == 0:
                self.ckpt.save(step, state)
        self.ckpt.save(step, state, blocking=True)
        return step, state


def _has_nan(metrics) -> bool:
    import math
    loss = metrics.get("loss") if isinstance(metrics, dict) else None
    if loss is None:
        return False
    try:
        v = float(loss)
    except TypeError:
        return False
    return math.isnan(v) or math.isinf(v)
