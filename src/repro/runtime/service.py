"""Reconstruction serving layer: shape-bucketed requests over the
plan/compile/execute core.

iFDK (arXiv:1909.02724) frames the end-game for CPU back-projection as
instant reconstruction as a *service*; the repo's last two PRs built
exactly the substrate that makes that cheap — a pure, hashable
:class:`~repro.runtime.planner.ReconPlan` and a process-shared
:class:`~repro.runtime.executor.ProgramCache` keyed so repeated
same-shape work never retraces. :class:`ReconService` is the layer that
exploits it:

  * **shape bucketing** — every request (geometry + projections +
    façade options) is planned (pure, microseconds) and bucketed on
    ``(geometry, plan.bucket_key)``. The first request into a bucket
    builds its :class:`~repro.runtime.executor.PlanExecutor` and
    pre-compiles every program the plan needs (``PlanExecutor.warm``);
    every later same-shape request reuses them — zero new compiles, by
    construction and by test (tests/test_service.py).
  * **warmup** — ``warmup(geometries, **options)`` drives the same
    bucket-creation path without data, so a deployment can pay all
    compilation before the first real request arrives.
  * **async step pipeline** — bucket executors default to
    ``pipeline="async"``: a depth-bounded flusher thread overlaps each
    step's device->host accumulator copy with the next step's scan
    dispatch (``runtime.executor._AsyncFlushQueue``), with output
    bit-identical to the sequential flush.
  * **bounded, fair execution** — requests enter ONE FIFO queue and are
    drained by ``max_inflight`` worker threads: admission order is
    completion-start order (no shape starves another), and at most
    ``max_inflight`` reconstructions hold device memory at once.
  * **cross-request batching** — a :class:`_BatchFormer` sits between
    the FIFO queue and the workers: up to ``max_batch`` SAME-bucket
    requests (any interleaving — mixed buckets never cross-batch)
    coalesce into one ``PlanExecutor.execute_batch`` dispatch stream,
    amortizing per-dispatch overhead exactly like the paper's O5
    in-batch ``nb`` axis, one tier up. Forming is deadline/priority
    aware: a partial batch waits at most ``max_wait_ms`` for peers,
    never past any member's deadline headroom, and a ``priority > 0``
    (latency-critical) request dispatches immediately. Per-lane output
    is bit-identical to the unbatched request. The autotuner searches
    ``max_batch`` (``TunedConfig.max_batch``) so tuned buckets cap
    batches at the measured per-hardware sweet spot.
  * **streaming sessions** — ``open_stream(geom, ...)`` returns a
    :class:`StreamSession`: projections are PUSHED as the scanner
    produces them and each view-chunk back-projects the moment it
    completes (``runtime.executor.StreamingExecutor``), hiding
    reconstruction wall behind acquisition. Sessions bucket on
    ``bucket_key`` like requests; a dedicated stream worker folds
    same-phase chunks of concurrent same-bucket sessions through ONE
    batched dispatch (the ``_BatchFormer`` machinery, keyed per view
    chunk). ``close() -> volume`` is bit-identical to the offline
    chunk-major reconstruction; per-session overlap metrics
    (hidden-fraction, last-view-to-volume tail) stream into
    :class:`ServiceStats`.
  * **measured tuning** — ``warmup(..., tune=True)`` runs the
    per-hardware autotuner (``runtime.autotune``) for each bucket
    before traffic: persisted winners resolve with zero re-measurement,
    fresh hardware pays a bounded search once, and every bucket's
    ``ServiceStats`` row reports whether its configuration was tuned or
    heuristic (``source``). ``variant="auto"`` requests resolve through
    the same cache at plan time (lookup only).
  * **introspection** — ``stats()`` returns a :class:`ServiceStats`
    snapshot: per-bucket request/hit/miss/compile counts plus the
    shared ProgramCache totals (the same numbers bench_smoke surfaces
    in the BENCH_*.json meta block), and STREAMED latency accounting —
    each completed request lands in its bucket's
    :class:`LatencyHistogram` as it finishes, so per-bucket (and
    merged) p50/p99/mean are live numbers, not poll-time samples.

Usage
-----
    from repro.runtime.service import ReconService

    svc = ReconService(max_inflight=2)
    svc.warmup([geom_a, geom_b], variant="algorithm1_mp",
               tiling=(32, 32, 64), proj_batch=32)     # pay compiles now

    h = svc.submit(projections, geom_a, variant="algorithm1_mp",
                   tiling=(32, 32, 64), proj_batch=32)  # non-blocking
    vol = h.result()                                    # (nz, ny, nx)

    vol = svc.reconstruct(projections, geom_b)          # synchronous
    print(svc.stats())                                  # buckets + cache
    svc.close()

``fdk_reconstruct(..., service=svc)`` routes the façade through the
same buckets, so existing call sites join the serving path unchanged.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Dict, Iterable, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.fdk import _build_plan
from repro.core.geometry import CTGeometry
from repro.runtime import telemetry
from repro.runtime.executor import FleetConfig, PlanExecutor, \
    ProgramCache, as_fleet_config, default_program_cache
from repro.runtime.planner import ReconPlan


# --------------------------------------------------------------------------
# Streamed latency accounting
# --------------------------------------------------------------------------

# The streamed log-2 latency histogram was absorbed into the telemetry
# metrics registry (runtime/telemetry.py — one histogram type for the
# whole runtime); the serving-layer name survives as an alias.
LatencyHistogram = telemetry.Histogram


# --------------------------------------------------------------------------
# Stats snapshots (immutable — safe to hand out across threads)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketStats(telemetry.EmitMixin):
    """One shape bucket's counters at snapshot time.

    ``misses`` is 1 for every live bucket (its creation); ``hits`` are
    the requests that reused it; ``programs_built`` is how many jit
    programs its warm-up compiled (0 when another bucket already
    populated the shared cache with the same program keys). ``source``
    records how the bucket's configuration was chosen — "heuristic"
    (the planner's static rules), "tuned-measured" (this process ran
    the autotuner search), or "tuned-cache" (a persisted per-hardware
    winner) — and ``pipeline`` the flush discipline that choice
    resolved. ``completed``/``p50_ms``/``p99_ms``/``mean_ms`` stream
    from the bucket's :class:`LatencyHistogram`.
    """

    variant: str
    vol_shape_xyz: Tuple[int, int, int]
    n_proj: int
    schedule: str
    requests: int
    hits: int
    misses: int
    programs_built: int
    source: str = "heuristic"
    pipeline: str = "async"
    completed: int = 0
    p50_ms: Optional[float] = None
    p99_ms: Optional[float] = None
    mean_ms: Optional[float] = None
    # cross-request batching: ``dispatches`` counts executor calls
    # (a formed batch of k requests is ONE dispatch), so
    # ``mean_occupancy`` = completed requests / dispatches is the
    # realized batch fill; ``batch_p50_ms`` streams the formed-batch
    # wall times and ``amortized_us_per_request`` divides total
    # execution wall over all completed requests — the number that
    # must drop as occupancy rises. ``max_batch`` is this bucket's
    # effective cap (the tuned ``TunedConfig.max_batch`` when the
    # bucket is tuned, the service default otherwise).
    dispatches: int = 0
    mean_occupancy: Optional[float] = None
    batch_p50_ms: Optional[float] = None
    amortized_us_per_request: Optional[float] = None
    max_batch: int = 1
    # fleet placement (all zero on a single-device service): device
    # count of the last fleet run, plus lifetime steal / failover-rerun
    # / retired-device totals from the bucket executor's fleet_totals
    devices: int = 0
    steals: int = 0
    failovers: int = 0
    dead_devices: int = 0
    # streaming sessions: ``streams`` opened / ``streams_closed``
    # finished; one stream "dispatch" per folded chunk batch with
    # ``stream_mean_lanes`` its realized cross-session fill;
    # ``stream_tail_ms`` is the mean time from last view arrival to
    # finished volume and ``stream_hidden_fraction`` the mean fraction
    # of back-projection wall hidden behind acquisition (both over
    # closed sessions)
    streams: int = 0
    streams_closed: int = 0
    stream_dispatches: int = 0
    stream_mean_lanes: Optional[float] = None
    stream_tail_ms: Optional[float] = None
    stream_hidden_fraction: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class ServiceStats(telemetry.EmitMixin):
    """Whole-service snapshot: totals + per-bucket rows + cache stats.

    ``p50_ms``/``p99_ms`` aggregate the per-bucket streamed histograms
    (merged bin counts, not an average of quantiles). ``as_dict()`` /
    ``emit()`` follow the shared telemetry report contract;
    :meth:`export_prometheus` renders the snapshot as Prometheus text
    exposition for a scrape endpoint."""

    requests: int
    bucket_hits: int
    bucket_misses: int
    buckets: Tuple[BucketStats, ...]
    cache: Dict[str, int]
    max_inflight: int
    queued: int
    p50_ms: Optional[float] = None
    p99_ms: Optional[float] = None
    # batching totals across buckets: executor dispatches and the
    # realized completed-requests / dispatches fill (None pre-traffic)
    max_batch: int = 1
    dispatches: int = 0
    mean_occupancy: Optional[float] = None
    # streaming totals across buckets: sessions opened, plus the mean
    # tail (last view -> volume) and hidden-fraction over all CLOSED
    # sessions (None before any stream finishes)
    streams: int = 0
    stream_tail_ms: Optional[float] = None
    stream_hidden_fraction: Optional[float] = None

    @property
    def hit_rate(self) -> float:
        total = self.bucket_hits + self.bucket_misses
        return self.bucket_hits / total if total else 0.0

    def export_prometheus(self) -> str:
        """This snapshot as Prometheus text exposition (version 0.0.4).

        Service totals are unlabeled samples; per-bucket rows carry
        ``{variant, schedule, source, vol, n_proj}`` labels (together
        unique per bucket). Empty quantiles render as NaN — present but
        unobserved, the exposition-format convention.
        """
        rows = [
            ("repro_requests_total", "counter",
             "requests admitted via submit()", [({}, self.requests)]),
            ("repro_bucket_hits_total", "counter",
             "requests that reused a live bucket",
             [({}, self.bucket_hits)]),
            ("repro_bucket_misses_total", "counter",
             "buckets created", [({}, self.bucket_misses)]),
            ("repro_hit_rate", "gauge", "bucket hit rate",
             [({}, self.hit_rate)]),
            ("repro_queued", "gauge", "requests waiting in the former",
             [({}, self.queued)]),
            ("repro_dispatches_total", "counter",
             "executor dispatches (a formed batch is one)",
             [({}, self.dispatches)]),
            ("repro_mean_occupancy", "gauge",
             "completed requests per dispatch",
             [({}, self.mean_occupancy)]),
            ("repro_latency_p50_ms", "gauge",
             "request latency p50 (merged streamed histograms)",
             [({}, self.p50_ms)]),
            ("repro_latency_p99_ms", "gauge",
             "request latency p99 (merged streamed histograms)",
             [({}, self.p99_ms)]),
            ("repro_streams_total", "counter",
             "streaming sessions opened", [({}, self.streams)]),
            ("repro_stream_tail_ms", "gauge",
             "mean last-view-to-volume tail over closed sessions",
             [({}, self.stream_tail_ms)]),
            ("repro_stream_hidden_fraction", "gauge",
             "mean fold wall hidden behind acquisition",
             [({}, self.stream_hidden_fraction)]),
            ("repro_program_cache_hits_total", "counter",
             "jit-program cache hits", [({}, self.cache.get("hits", 0))]),
            ("repro_program_cache_misses_total", "counter",
             "jit-program cache misses (== programs built)",
             [({}, self.cache.get("misses", 0))]),
        ]

        def lab(b: "BucketStats") -> Dict[str, object]:
            return {"variant": b.variant, "schedule": b.schedule,
                    "source": b.source,
                    "vol": "x".join(str(v) for v in b.vol_shape_xyz),
                    "n_proj": b.n_proj}

        bs = self.buckets
        rows += [
            ("repro_bucket_requests", "counter",
             "per-bucket requests", [(lab(b), b.requests) for b in bs]),
            ("repro_bucket_completed", "counter",
             "per-bucket completed requests",
             [(lab(b), b.completed) for b in bs]),
            ("repro_bucket_dispatches", "counter",
             "per-bucket executor dispatches",
             [(lab(b), b.dispatches) for b in bs]),
            ("repro_bucket_p50_ms", "gauge", "per-bucket latency p50",
             [(lab(b), b.p50_ms) for b in bs]),
            ("repro_bucket_p99_ms", "gauge", "per-bucket latency p99",
             [(lab(b), b.p99_ms) for b in bs]),
            ("repro_bucket_programs_built", "counter",
             "programs compiled by this bucket's warm-up",
             [(lab(b), b.programs_built) for b in bs]),
        ]
        return telemetry.prom_render(rows)


def _ms(seconds: Optional[float]) -> Optional[float]:
    return None if seconds is None else round(seconds * 1e3, 3)


@dataclasses.dataclass
class _Request:
    """One queued reconstruction plus its batching identity/constraints.

    ``key`` is ``(geometry, plan.bucket_key)`` — the batchability
    identity (``request_batch`` is deliberately not in ``bucket_key``,
    so any k same-bucket requests share a key). ``deadline_s`` is the
    ABSOLUTE ``time.perf_counter`` deadline (None = none); ``priority
    > 0`` marks a latency-critical request that never waits to fill a
    batch (and releases any batch it joins immediately)."""

    fut: Future
    projections: object
    geom: CTGeometry
    plan: ReconPlan
    config: object
    key: tuple
    deadline_s: Optional[float] = None
    priority: int = 0
    # iterative-request knobs (n_iters/relax/...), forwarded to the
    # bucket's IterativeExecutor; None for plain FDK requests
    solver_kw: Optional[Dict] = None
    # per-request telemetry identity (telemetry.new_trace_id): carried
    # into the worker's dispatch span so a k-wide batched dispatch
    # links back to all k request traces
    trace_id: str = ""


@dataclasses.dataclass
class _StreamWork:
    """One READY view-chunk of one open stream session.

    Duck-types the :class:`_BatchFormer` item contract (``key`` /
    ``priority`` / ``deadline_s``): ``key`` is the session's bucket key
    PLUS the chunk index, so the former coalesces the same rotation
    phase across concurrent same-bucket sessions into one batched fold
    and never mixes phases (different chunk indices -> different keys).
    """

    session: "StreamSession"
    chunk: int
    key: tuple
    deadline_s: Optional[float] = None
    priority: int = 0


class _BatchFormer:
    """The coalescing stage between ``submit``'s FIFO queue and the
    worker threads.

    ``take`` pops the FIFO head — the head's bucket DEFINES the batch;
    requests of other buckets are never pulled in (their relative order
    is preserved) — then gathers every queued same-bucket request up to
    the head's cap (``cap_fn``). A still-partial batch may wait for
    late peers, bounded by the TIGHTEST of: the service ``max_wait_s``,
    and each member's deadline headroom minus the bucket's running
    latency estimate (``est_fn`` — a deadline that cannot absorb the
    wait dispatches the batch immediately). Members with ``priority >
    0`` never wait: the batch ships as soon as one is aboard. With
    ``cap == 1`` or ``max_wait_s == 0`` and no queued peers this
    degenerates to exactly the old FIFO queue — one request per take,
    admission order preserved.

    ``put`` / ``close`` are atomic w.r.t. each other, so a request
    either raises (closed) or is guaranteed a consumer: workers drain
    the queue to empty before honoring the close. ``cap_fn``/``est_fn``
    are called while holding the former's condition — they must never
    take a lock that a ``put``/``close`` caller holds (the service
    passes lock-free readers).
    """

    def __init__(self, *, max_wait_s: float, cap_fn, est_fn=None):
        self._dq: "collections.deque[_Request]" = collections.deque()
        self._cond = threading.Condition()
        self._closed = False
        self._cap_fn = cap_fn
        # est_fn returns the bucket's expected run seconds, or None
        # while NO estimate exists (cold start) — the default knows
        # nothing, so it must say so rather than claim "instant"
        self._est_fn = est_fn if est_fn is not None else (lambda r: None)
        self.max_wait_s = float(max_wait_s)

    def put(self, req: _Request) -> None:
        with self._cond:
            if self._closed:
                raise RuntimeError("ReconService is closed")
            self._dq.append(req)
            self._cond.notify_all()

    def qsize(self) -> int:
        with self._cond:
            return len(self._dq)

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def _gather(self, batch: List[_Request], cap: int) -> None:
        """Pull queued same-bucket requests into ``batch`` (FIFO order,
        call under the condition); other buckets keep their positions."""
        key = batch[0].key
        if len(batch) >= cap:
            return
        keep: "collections.deque[_Request]" = collections.deque()
        while self._dq and len(batch) < cap:
            r = self._dq.popleft()
            if r.key == key:
                batch.append(r)
            else:
                keep.append(r)
        keep.extend(self._dq)
        self._dq = keep

    def _wait_limit(self, batch: List[_Request], t0: float) -> float:
        """Absolute time until which this batch may keep waiting."""
        limit = t0 + self.max_wait_s
        est = self._est_fn(batch[0])
        for r in batch:
            if r.priority > 0:
                return t0            # latency-critical: ship now
            if r.deadline_s is not None:
                if est is None:
                    # cold start: no latency estimate exists yet, so
                    # deadline headroom cannot be computed — a 0
                    # estimate would let the batch wait out the whole
                    # deadline against a fictitious instant run. A
                    # deadline-carrying member therefore never waits
                    # until the bucket has completed traffic.
                    return t0
                # the wait must fit inside the member's deadline with
                # the (estimated) reconstruction still to run
                limit = min(limit, r.deadline_s - est)
        return limit

    def take(self) -> Optional[List[_Request]]:
        """The next formed batch, or None when closed AND drained."""
        with self._cond:
            while not self._dq:
                if self._closed:
                    return None
                self._cond.wait(0.05)
            # the forming window is a span (not the idle head wait):
            # its duration is the wait-for-peers cost and its args the
            # realized occupancy — the coalescing trade made visible
            with telemetry.span("batch.form") as sp:
                batch = [self._dq.popleft()]
                cap = max(1, int(self._cap_fn(batch[0])))
                self._gather(batch, cap)
                if len(batch) >= cap or self.max_wait_s <= 0.0:
                    sp.set(k=len(batch), cap=cap, waited=False)
                    return batch
                t0 = time.perf_counter()
                while len(batch) < cap and not self._closed:
                    now = time.perf_counter()
                    limit = self._wait_limit(batch, t0)
                    if now >= limit:
                        break
                    self._cond.wait(min(0.01, limit - now))
                    self._gather(batch, cap)
                sp.set(k=len(batch), cap=cap, waited=True)
                return batch


class _Bucket:
    """A cached (geometry, plan) pair: executor + per-bucket counters."""

    def __init__(self, geom: CTGeometry, plan: ReconPlan,
                 executor: PlanExecutor, programs_built: int,
                 config=None, source: str = "heuristic"):
        self.geom = geom
        self.plan = plan
        self.executor = executor
        self.programs_built = programs_built
        self.config = config          # TunedConfig provenance (or None)
        self.source = source
        self.latency = LatencyHistogram()
        self.requests = 0
        self.hits = 0
        # batching counters (mutated under the service lock): one
        # "dispatch" per executor call, however many requests it served
        self.cap = 1                   # effective max_batch
        self.dispatches = 0
        self.batched_requests = 0      # completed requests, all batches
        self.exec_total_s = 0.0        # wall summed once per dispatch
        self.batch_latency = LatencyHistogram()
        # streaming counters (mutated under the service lock): one
        # stream "dispatch" per folded chunk batch, ``stream_lanes``
        # its summed lane count; tail/hidden accumulate each closed
        # session's StreamReport for the overlap means in stats()
        self.stream_sessions = 0
        self.stream_closed = 0
        self.stream_dispatches = 0
        self.stream_lanes = 0
        self.stream_tail_s = 0.0
        self.stream_hidden = 0.0

    def snapshot(self) -> BucketStats:
        with self.executor._fleet_lock:
            fleet = dict(self.executor.fleet_totals)
        return BucketStats(
            devices=fleet["devices"],
            steals=fleet["stolen"],
            failovers=fleet["retried"],
            dead_devices=fleet["dead_devices"],
            variant=self.plan.variant,
            vol_shape_xyz=self.plan.vol_shape_xyz,
            n_proj=self.plan.n_proj,
            schedule=self.plan.schedule,
            requests=self.requests,
            hits=self.hits,
            misses=1,
            programs_built=self.programs_built,
            source=self.source,
            pipeline=self.executor.pipeline,
            completed=self.latency.count,
            p50_ms=_ms(self.latency.quantile(0.50)),
            p99_ms=_ms(self.latency.quantile(0.99)),
            mean_ms=_ms(self.latency.mean()),
            dispatches=self.dispatches,
            mean_occupancy=(round(self.batched_requests / self.dispatches,
                                  3) if self.dispatches else None),
            batch_p50_ms=_ms(self.batch_latency.quantile(0.50)),
            amortized_us_per_request=(
                round(self.exec_total_s / self.batched_requests * 1e6, 1)
                if self.batched_requests else None),
            max_batch=self.cap,
            streams=self.stream_sessions,
            streams_closed=self.stream_closed,
            stream_dispatches=self.stream_dispatches,
            stream_mean_lanes=(round(self.stream_lanes /
                                     self.stream_dispatches, 3)
                               if self.stream_dispatches else None),
            stream_tail_ms=(_ms(self.stream_tail_s / self.stream_closed)
                            if self.stream_closed else None),
            stream_hidden_fraction=(round(self.stream_hidden /
                                          self.stream_closed, 3)
                                    if self.stream_closed else None))


# --------------------------------------------------------------------------
# The service
# --------------------------------------------------------------------------

class ReconService:
    """Shape-bucketed reconstruction server over the shared ProgramCache.

    Parameters
    ----------
    max_inflight : worker-thread count == the bound on concurrently
        executing reconstructions (each holds at most one tile
        accumulator + the pipelined flush buffers on device). Requests
        beyond it wait in the FIFO queue — admission order is start
        order, so mixed-shape traffic shares the service fairly.
    pipeline : step-major flush discipline for bucket executors
        ("async" by default — the serving layer is exactly the caller
        that benefits from overlap; "sync" restores the in-thread
        double buffer).
    cache : optional private :class:`ProgramCache`; default is the
        process-shared one, so the service inherits programs compiled
        by any earlier façade call (and vice versa).
    tuning : the autotuner's persisted-winner store consulted by
        ``warmup(tune=True)`` and by ``variant="auto"`` requests — a
        ``runtime.autotune.TuningCache``, a cache-file path, or None
        (the default cache: ``$REPRO_TUNING_CACHE`` or
        ``~/.cache/repro/tuning.json``).
    devices : multi-device placement for every bucket. ``None`` (the
        default) keeps single-device execution; ``"all"`` spreads each
        reconstruction's step schedule over every local device; an int
        N uses the first N local devices; a device sequence or a
        :class:`~repro.runtime.executor.FleetConfig` is used as-is.
        Fleet buckets plan ``out="host"`` / ``schedule="step"`` by
        default (the fleet's required placement) and run with
        straggler-aware work stealing + per-step failover
        (``PlanExecutor.execute_fleet``); per-bucket steal/failover
        totals surface in :class:`ServiceStats`.
    fleet_max_retries : per-STEP failover budget of fleet buckets
        (``FleetConfig.max_retries_per_step``); ignored without
        ``devices``.
    max_batch : cross-request batching cap — how many SAME-bucket
        queued requests one executor dispatch may serve
        (``PlanExecutor.execute_batch``). 1 (the default) disables
        batching and preserves the exact pre-batching FIFO behavior.
        Tuned buckets whose measured ``TunedConfig.max_batch`` is
        smaller cap there instead (the operator's value stays the hard
        upper bound). Per-lane output is bit-identical to an unbatched
        request; only latency shaping changes.
    max_wait_ms : how long a PARTIAL batch may hold the queue head
        waiting for same-bucket peers. 0 (the default) never waits —
        batching then only coalesces requests that are ALREADY queued
        together (a burst). Deadline-aware: the wait never exceeds any
        member's ``deadline_ms`` headroom (minus the bucket's running
        latency estimate), and ``priority > 0`` members ship at once.
    """

    def __init__(self, *, max_inflight: int = 2, pipeline: str = "async",
                 cache: Optional[ProgramCache] = None, tuning=None,
                 devices=None, fleet_max_retries: int = 2,
                 max_batch: int = 1, max_wait_ms: float = 0.0):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        self.cache = cache if cache is not None else default_program_cache()
        self.pipeline = pipeline
        self.tuning = tuning
        self.fleet: Optional[FleetConfig] = as_fleet_config(
            devices, max_retries_per_step=fleet_max_retries)
        self.max_inflight = int(max_inflight)
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self._buckets: Dict[tuple, _Bucket] = {}
        self._lock = threading.Lock()          # buckets + counters
        # cap_fn/est_fn run under the former's condition: lock-free
        # bucket reads only (append-only dict + GIL), never the
        # service lock — put()/close() callers may hold it
        self._former = _BatchFormer(
            max_wait_s=self.max_wait_ms / 1e3,
            cap_fn=self._cap_for, est_fn=self._run_estimate)
        # streaming: a dedicated former + ONE worker thread, created
        # lazily by the first open_stream (most services never stream)
        self._stream_former: Optional[_BatchFormer] = None
        self._stream_thread: Optional[threading.Thread] = None
        self._closed = False
        self._workers = [
            threading.Thread(target=self._worker, name=f"recon-serve-{i}",
                             daemon=True)
            for i in range(self.max_inflight)]
        for t in self._workers:
            t.start()

    # ---- batching policy -------------------------------------------------

    def _effective_cap(self, config) -> int:
        """Batch cap for a bucket with tuned provenance ``config``: the
        service ``max_batch`` bounded by a MEASURED winner's
        ``max_batch`` (the tuner searched rb amortized — a measured 1
        means batching lost on this hardware and disables it here;
        heuristic configs carry no measurement and keep the default)."""
        cap = self.max_batch
        if cap > 1 and config is not None \
                and getattr(config, "source", "heuristic") != "heuristic":
            cap = min(cap, max(1, int(getattr(config, "max_batch", 1))))
        return cap

    def _cap_for(self, req: _Request) -> int:
        bucket = self._buckets.get(req.key)   # lock-free: see __init__
        if bucket is not None:
            return bucket.cap
        return self._effective_cap(req.config)

    def _run_estimate(self, req: _Request) -> Optional[float]:
        """Expected reconstruction seconds for deadline headroom math,
        or ``None`` while the bucket has NO completed traffic — the
        explicit cold-start contract: with no estimate, a deadline-
        carrying batch ships immediately instead of waiting out its
        deadline against an estimate of 0 (see ``_wait_limit``)."""
        bucket = self._buckets.get(req.key)   # lock-free: see __init__
        if bucket is None:
            return None
        return bucket.latency.mean()          # None while empty

    # ---- bucketing -------------------------------------------------------

    def _tuning_cache(self, tuning=None):
        from repro.runtime.autotune import as_tuning_cache
        return as_tuning_cache(tuning if tuning is not None
                               else self.tuning)

    def _plan(self, geom: CTGeometry, options: Dict):
        """Façade options -> (plan, TunedConfig-or-None) (pure;
        validation errors raise here, in the submitting thread, not in
        a worker). ``variant="auto"`` / ``tuning=`` resolve through the
        tuning cache (lookup only — a miss is the heuristic config)."""
        opts = dict(options)
        variant = opts.pop("variant", None)
        tuning = opts.pop("tuning", None)
        solver = opts.pop("solver", "none")
        precision = opts.pop("precision", "f32")
        # per-request loop knobs ride the request, not the bucket
        solver_kw = {k: opts.pop(k) for k in
                     ("n_iters", "relax", "x0", "tv_weight", "tv_inner",
                      "oversample") if k in opts}
        if tuning is None:
            # ONE read (under the lock warmup(tune=True) writes under):
            # both decisions below must see the same store, or a
            # request racing a tuned warmup could resolve half-tuned
            with self._lock:
                tuning = self.tuning
        if variant is None:
            # a tuning-enabled service (constructed with tuning=, or
            # warmed with tune=True) defaults requests to the tuned
            # resolution so they land in the tuned buckets; otherwise
            # keep the façade's heuristic default
            variant = "auto" if tuning is not None else "algorithm1_mp"
        kw = dict(
            nb=opts.pop("nb", 8), interpret=opts.pop("interpret", True),
            tiling=opts.pop("tiling", None),
            memory_budget=opts.pop("memory_budget", None),
            proj_batch=opts.pop("proj_batch", None),
            out=opts.pop("out", None), schedule=opts.pop("schedule", None),
            precision=precision)
        if solver != "none":
            # solver buckets: the loop owns a device-resident volume
            # and pairs FP with BP — no fleet sharding, and tuned
            # resolution is method-aware (autotune(method=...)), not
            # the FDK lookup, so requests resolve heuristically here
            if self.fleet is not None:
                raise ValueError(
                    "iterative solver requests run single-device (the "
                    "solve loop owns the volume); they cannot ride a "
                    "fleet service (ReconService(devices=...))")
            if variant == "auto":
                variant = "algorithm1_mp"
            tuning = None
            kw["solver"] = solver
            kw["out"] = "device"
        ingest = opts.pop("ingest", "offline")
        if ingest != "offline":
            # stream plans resolve heuristically (TunedConfig carries no
            # ingest axis) and are chunk-major by construction; offline
            # requests never carry the key, so the tuning-cache request
            # key is unchanged by its existence (the planner validates
            # the value)
            if variant == "auto":
                variant = "algorithm1_mp"
            tuning = None
            kw["ingest"] = ingest
        if self.fleet is not None:
            # fleet execution requires host accumulation over the step
            # schedule; default unset knobs to that placement (explicit
            # contrary choices fail fast in PlanExecutor's validation)
            kw["out"] = kw["out"] or "host"
            kw["schedule"] = kw["schedule"] or "step"
        if solver == "none" and solver_kw:
            raise ValueError(
                f"solver knobs {sorted(solver_kw)} need an iterative "
                f"request (pass solver='sart'|'os_sart'|'cgls'|"
                f"'fista_tv')")
        if variant == "auto" or tuning is not None:
            from repro.runtime.autotune import resolve_config
            cfg = resolve_config(geom, variant,
                                 cache=self._tuning_cache(tuning),
                                 **kw, **opts)
            return cfg.build_plan(geom), cfg, None
        return (_build_plan(geom, variant, **kw, **opts), None,
                solver_kw or None)

    @staticmethod
    def _source_of(config) -> str:
        if config is None or config.source == "heuristic":
            return "heuristic"
        return "tuned-" + config.source      # "measured" | "cache"

    def _bucket(self, geom: CTGeometry, plan: ReconPlan,
                config=None) -> _Bucket:
        """Find-or-create the bucket for ``(geom, plan.bucket_key)``.

        Creation happens under the service lock so the warm-up compile
        count is attributable to THIS bucket even with concurrent
        workers: the cache-miss delta across ``PlanExecutor.warm`` is
        the bucket's ``programs_built``. ``config`` (a resolved
        ``TunedConfig``) carries the tuned pipeline choice and the
        choice provenance surfaced per bucket in :class:`ServiceStats`.
        """
        key = (geom, plan.bucket_key)
        with self._lock:
            bucket = self._buckets.get(key)
            if bucket is not None:
                bucket.hits += 1
                if config is not None and config.source != "heuristic" \
                        and bucket.source == "heuristic":
                    # a measured winner that differs only in executor-
                    # level knobs (pipeline/depth — not part of the
                    # bucket_key) lands on an existing heuristic
                    # bucket: upgrade it in place rather than dropping
                    # the tuned choice. In-flight requests finish on
                    # the old executor (bit-identical output either
                    # way); new requests get the tuned one.
                    ex = PlanExecutor(
                        geom, plan, cache=self.cache,
                        pipeline=config.pipeline,
                        pipeline_depth=config.pipeline_depth,
                        tuned=config, fleet=self.fleet)
                    ex.warm()
                    cap = self._effective_cap(config)
                    if cap > 1 and ex.supports_request_batching:
                        ex.warm_batch(cap)
                    bucket.executor = ex
                    bucket.config = config
                    bucket.source = self._source_of(config)
                    bucket.cap = cap
                return bucket
            misses_before = self.cache.stats()["misses"]
            tuned = config is not None and config.source != "heuristic"
            if plan.solver != "none":
                # iterative bucket: the persistent FP+BP pairing, warm
                # like any other bucket (normalizers + every program a
                # solve needs compile HERE, attributed to this bucket;
                # warm requests then iterate without compiling)
                from .solvers import IterativeExecutor
                ex = IterativeExecutor(geom, plan, self.cache,
                                       pipeline=self.pipeline)
            else:
                ex = PlanExecutor(
                    geom, plan, cache=self.cache,
                    pipeline=config.pipeline if tuned else self.pipeline,
                    pipeline_depth=(config.pipeline_depth if tuned else 2),
                    tuned=config if tuned else None, fleet=self.fleet)
            ex.warm()
            cap = self._effective_cap(config)
            if cap > 1 and ex.supports_request_batching:
                # the first FORMED batch must compile nothing either
                ex.warm_batch(cap)
            built = self.cache.stats()["misses"] - misses_before
            bucket = _Bucket(geom, plan, ex, programs_built=built,
                             config=config, source=self._source_of(config))
            bucket.cap = cap
            self._buckets[key] = bucket
            return bucket

    def warmup(self, geometries: Iterable[CTGeometry], *,
               tune: bool = False, tune_budget_s: float = 20.0,
               **options) -> ServiceStats:
        """Pre-compile (and optionally pre-TUNE) the buckets a
        deployment will serve.

        One bucket per geometry, same options for all (call repeatedly
        for mixed option sets). After warmup, the first real request of
        each warmed shape is a bucket hit with zero new compiles.

        ``tune=True`` runs the measured autotuner
        (``runtime.autotune.autotune``) per bucket before any traffic:
        a persisted winner for this hardware resolves with ZERO
        re-measurement (bucket ``source == "tuned-cache"``), otherwise
        the search runs under ``tune_budget_s`` wall seconds per bucket
        and the winner is persisted (``source == "tuned-measured"``).
        Tuning shares this service's ProgramCache, so every program the
        winning config needs is already compiled when the bucket opens.
        """
        for geom in geometries:
            if tune:
                from repro.runtime.autotune import autotune
                opts = dict(options)
                cache = self._tuning_cache(opts.pop("tuning", None))
                with self._lock:
                    if self.tuning is None:
                        # later requests must resolve through the SAME
                        # cache to land in the tuned buckets
                        self.tuning = cache
                cfg = autotune(geom, opts.pop("variant", "auto"),
                               budget_s=tune_budget_s, cache=cache,
                               program_cache=self.cache, **opts)
                self._bucket(geom, cfg.build_plan(geom), config=cfg)
            else:
                plan, cfg, _skw = self._plan(geom, options)
                self._bucket(geom, plan, config=cfg)
        return self.stats()

    # ---- request path ----------------------------------------------------

    def submit(self, projections: jnp.ndarray, geom: CTGeometry, *,
               deadline_ms: Optional[float] = None, priority: int = 0,
               **options) -> "Future":
        """Enqueue one reconstruction; returns a ``Future`` whose
        ``result()`` is the volume (same contract as the façade the
        options mirror — ``fdk_reconstruct``). FIFO across callers.

        ``deadline_ms`` (relative to now) and ``priority`` shape BATCH
        FORMING only — they never reorder the FIFO queue: a deadline
        caps how long a partial batch this request joins may wait for
        peers, and ``priority > 0`` marks it latency-critical (any
        batch it joins dispatches immediately). Both are no-ops when
        batching is off (``max_batch == 1``)."""
        plan, config, solver_kw = self._plan(geom, options)
        # (validation above happens in the submitting thread)
        if deadline_ms is not None and deadline_ms < 0:
            raise ValueError(
                f"deadline_ms must be >= 0, got {deadline_ms}")
        with telemetry.span("plan.bucket_key"):
            key = (geom, plan.bucket_key)
        trace_id = telemetry.new_trace_id()
        telemetry.instant("request.submit", trace_id=trace_id,
                          variant=plan.variant, priority=int(priority))
        fut: Future = Future()
        fut.trace_id = trace_id      # exposed to the caller for linkage
        req = _Request(
            fut=fut, projections=projections, geom=geom, plan=plan,
            config=config, key=key,
            deadline_s=(None if deadline_ms is None
                        else time.perf_counter() + deadline_ms / 1e3),
            priority=int(priority), solver_kw=solver_kw,
            trace_id=trace_id)
        # put() checks closed under the former's condition, so a
        # request either raises here or is guaranteed a consumer
        # (workers drain the queue to empty before honoring close)
        self._former.put(req)
        return fut

    def reconstruct(self, projections: jnp.ndarray, geom: CTGeometry,
                    **options):
        """Synchronous request: ``submit(...).result()``."""
        return self.submit(projections, geom, **options).result()

    def _worker(self) -> None:
        while True:
            batch = self._former.take()
            if batch is None:
                return
            live = [r for r in batch
                    if r.fut.set_running_or_notify_cancel()]
            if not live:
                continue
            try:
                head = live[0]
                bucket = self._bucket(head.geom, head.plan,
                                      config=head.config)
                k = len(live)
                with self._lock:
                    bucket.requests += k
                t0 = time.perf_counter()
                # the dispatch span carries EVERY member's trace id —
                # the k-wide batched dispatch links back to all k
                # request traces (request.submit instants)
                with telemetry.span(
                        "service.dispatch", k=k,
                        variant=bucket.plan.variant,
                        trace_ids=[r.trace_id for r in live]):
                    if k == 1:
                        results = [bucket.executor.reconstruct(
                            head.projections, **(head.solver_kw or {}))]
                    elif bucket.executor.supports_request_batching:
                        # ONE dispatch stream serves all k lanes —
                        # bit-identical per lane to the k==1 path
                        results = bucket.executor.execute_batch(
                            [r.projections for r in live])
                    else:
                        # chunk-major and solver buckets can't batch: the
                        # formed group still runs back-to-back on one
                        # worker (each solve keeps its own request knobs)
                        results = [bucket.executor.reconstruct(
                            r.projections, **(r.solver_kw or {}))
                                   for r in live]
                wall = time.perf_counter() - t0
                # streamed accounting: every member's service time IS
                # the batch wall (they complete together); the batch
                # itself lands once in the occupancy/amortized counters
                for _ in live:
                    bucket.latency.record(wall)
                bucket.batch_latency.record(wall)
                with self._lock:
                    bucket.dispatches += 1
                    bucket.batched_requests += k
                    bucket.exec_total_s += wall
                for r, vol in zip(live, results):
                    r.fut.set_result(vol)
            except BaseException as exc:
                for r in live:
                    if not r.fut.done():
                        r.fut.set_exception(exc)

    # ---- streaming sessions ----------------------------------------------

    def open_stream(self, geom: CTGeometry, *, priority: int = 0,
                    max_pending_chunks: int = 2,
                    **options) -> "StreamSession":
        """Open an online reconstruction session (the service-level twin
        of ``PlanExecutor.open_stream``): push projections as the
        scanner produces them, ``close()`` returns the volume —
        bit-identical to the offline chunk-major reconstruction of the
        same views.

        Sessions bucket on ``(geometry, plan.bucket_key)`` exactly like
        requests (``ingest="stream"`` is part of the key, so stream and
        offline traffic never share a bucket) and reuse the bucket's
        warmed programs. Concurrent same-bucket sessions at the same
        rotation phase coalesce: the stream worker folds up to
        ``max_batch`` ready chunk-``c`` arrivals through ONE batched
        dispatch (``ProgramCache.batch_program``), per-lane
        bit-identical to an unbatched session. ``max_pending_chunks``
        bounds the per-session arrival queue (``push`` blocks beyond
        it); ``priority > 0`` ships this session's chunks without
        waiting for peers. Options mirror ``submit`` (``proj_batch``
        defaults to ~n_proj/8 views per chunk, the streaming grain).
        """
        if self.fleet is not None:
            raise ValueError(
                "streaming sessions do not compose with fleet "
                "execution; construct the service without devices=")
        opts = dict(options)
        opts["ingest"] = "stream"
        if opts.get("proj_batch") is None:
            # a stream needs a real chunk grain: ~8 chunks per rotation
            # (bounded below by nb so the planner's rounding is a no-op)
            opts["proj_batch"] = max(int(opts.get("nb", 8)),
                                     geom.n_proj // 8)
        plan, config, _skw = self._plan(geom, opts)
        bucket = self._bucket(geom, plan, config=config)
        self._ensure_stream_worker()
        with self._lock:
            bucket.stream_sessions += 1
        return StreamSession(self, bucket, priority=int(priority),
                             max_pending_chunks=max_pending_chunks)

    def _ensure_stream_worker(self) -> None:
        with self._lock:
            if self._closed:
                raise RuntimeError("ReconService is closed")
            if self._stream_former is not None:
                return
            self._stream_former = _BatchFormer(
                max_wait_s=self.max_wait_ms / 1e3,
                cap_fn=lambda w: max(1, self.max_batch),
                est_fn=lambda w: None)   # chunk folds carry no deadlines
            self._stream_thread = threading.Thread(
                target=self._stream_worker, name="recon-stream",
                daemon=True)
            self._stream_thread.start()

    def _stream_worker(self) -> None:
        former = self._stream_former
        while True:
            batch = former.take()
            if batch is None:
                return
            # the fold-order contract: chunk c of a session may only
            # fold when it IS that session's next_fold. Out-of-order
            # pushes can complete chunk c+1 first — its work item
            # requeues until chunk c lands (whose own completion event
            # wakes this worker again).
            runnable: List[_StreamWork] = []
            for w in batch:
                if w.session._core.next_fold == w.chunk:
                    runnable.append(w)
                else:
                    try:
                        former.put(w)
                    except RuntimeError as exc:
                        w.session._core.fail(exc)
            if not runnable:
                time.sleep(0.002)      # only deferred items are queued
                continue
            try:
                self._fold_stream_chunk(runnable)
            except BaseException as exc:
                for w in runnable:
                    w.session._core.fail(exc)

    def _fold_stream_chunk(self, works: List[_StreamWork]) -> None:
        """Fold one ready view-chunk for k same-bucket sessions.

        k == 1 delegates to the session core's own ``fold`` (which
        overlaps the next chunk's filtering and self-times). k > 1
        stacks the k filtered chunks on a leading lane axis and runs ONE
        rb-lane program per plan step — vmap adds a batch axis and never
        reassociates a lane's reduction, so each lane's accumulator
        receives exactly the unbatched partial (the
        ``PlanExecutor.execute_batch`` argument, per chunk)."""
        c = works[0].chunk
        bucket = works[0].session._bucket
        cores = [w.session._core for w in works]
        with telemetry.span("service.stream_dispatch", chunk=c,
                            k=len(cores),
                            trace_ids=[w.session.trace_id
                                       for w in works]):
            self._fold_stream_chunk_inner(c, bucket, cores)
        with self._lock:
            bucket.stream_dispatches += 1
            bucket.stream_lanes += len(cores)

    def _fold_stream_chunk_inner(self, c, bucket, cores) -> None:
        if len(cores) == 1:
            cores[0].fold(c)
        else:
            ex = bucket.executor
            plan = bucket.plan
            t0 = time.perf_counter()
            pairs = [core.filtered(c) for core in cores]
            for core in cores:
                core.prefilter(c + 1)  # overlap next chunk's filtering
            img_b = jnp.stack([img for img, _ in pairs])
            mat_c = pairs[0][1]        # same geometry -> same matrices
            for i, step in enumerate(plan.steps):
                prog = self.cache.batch_program(
                    step.variant, step.call_shape, plan.nb,
                    ex._dtype, plan.interpret, plan.options,
                    rb=len(cores))
                out_b = prog(img_b, ex._translated(mat_c, step))
                for r, core in enumerate(cores):
                    core.accept_part(i, out_b[r])
            wall = time.perf_counter() - t0
            for core in cores:
                core.chunk_done(c)
                core.add_busy(wall)

    # ---- lifecycle / introspection ---------------------------------------

    def stats(self) -> ServiceStats:
        with self._lock:
            live = list(self._buckets.values())
            buckets = tuple(b.snapshot() for b in live)
            s_open = sum(b.stream_sessions for b in live)
            s_closed = sum(b.stream_closed for b in live)
            s_tail = sum(b.stream_tail_s for b in live)
            s_hidden = sum(b.stream_hidden for b in live)
        overall = LatencyHistogram.merged(b.latency for b in live)
        dispatches = sum(b.dispatches for b in buckets)
        completed = sum(b.completed for b in buckets)
        return ServiceStats(
            requests=sum(b.requests for b in buckets),
            bucket_hits=sum(b.hits for b in buckets),
            bucket_misses=len(buckets),
            buckets=buckets,
            cache=self.cache.stats(),
            max_inflight=self.max_inflight,
            queued=self._former.qsize(),
            p50_ms=_ms(overall.quantile(0.50)),
            p99_ms=_ms(overall.quantile(0.99)),
            max_batch=self.max_batch,
            dispatches=dispatches,
            mean_occupancy=(round(completed / dispatches, 3)
                            if dispatches else None),
            streams=s_open,
            stream_tail_ms=(_ms(s_tail / s_closed) if s_closed else None),
            stream_hidden_fraction=(round(s_hidden / s_closed, 3)
                                    if s_closed else None))

    def close(self, wait: bool = True) -> None:
        """Stop accepting requests; drain workers (idempotent).
        Already-queued requests complete — workers exit only once the
        queue is empty."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        # outside the service lock: the former's condition is also
        # taken by forming workers that read buckets (lock ordering)
        self._former.close()
        if self._stream_former is not None:
            self._stream_former.close()
        if wait:
            for t in self._workers:
                t.join()
            if self._stream_thread is not None:
                self._stream_thread.join()

    def __enter__(self) -> "ReconService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class StreamSession:
    """One open projection stream bound to a service bucket.

    ``push(views)`` hands view rows to the session's
    :class:`~repro.runtime.executor.StreamingExecutor` core; each
    completed view-chunk queues a :class:`_StreamWork` to the service's
    stream worker, which folds same-phase chunks of concurrent
    same-bucket sessions through one batched dispatch. ``close()``
    blocks for the tail folds and returns the volume; the session's
    :class:`~repro.runtime.executor.StreamReport` then lands in the
    bucket's overlap counters (``ServiceStats.stream_tail_ms`` /
    ``stream_hidden_fraction``)."""

    def __init__(self, service: ReconService, bucket: _Bucket, *,
                 priority: int = 0, max_pending_chunks: int = 2):
        self._service = service
        self._bucket = bucket
        self._priority = int(priority)
        self._key_base = (bucket.geom, bucket.plan.bucket_key)
        # per-session trace identity: carried by every batched chunk
        # dispatch this session participates in (service.stream_dispatch
        # spans), the stream twin of _Request.trace_id
        self.trace_id = telemetry.new_trace_id("stream")
        telemetry.instant("stream.open", trace_id=self.trace_id,
                          variant=bucket.plan.variant)
        self._core = bucket.executor.open_stream(
            max_pending_chunks=max_pending_chunks, on_ready=self._ready)

    def _ready(self, chunk: int) -> None:
        """StreamingExecutor callback: chunk complete -> queue its fold.
        Runs on the pushing thread with the core's condition RELEASED
        (the core guarantees it), so the former's put is safe here."""
        work = _StreamWork(session=self, chunk=chunk,
                           key=self._key_base + (chunk,),
                           priority=self._priority)
        try:
            self._service._stream_former.put(work)
        except RuntimeError as exc:      # service closed mid-stream
            self._core.fail(exc)

    def push(self, views, start: Optional[int] = None) -> None:
        """Deliver view rows (blocks only on arrival-queue backpressure)."""
        self._core.push(views, start=start)

    @property
    def report(self):
        """The core's :class:`StreamReport` (None until closed)."""
        return self._core.report

    def close(self):
        """Finish the stream and return the volume (nz, ny, nx)."""
        vol = self._core.close()
        rep = self._core.report
        with self._service._lock:
            self._bucket.stream_closed += 1
            if rep is not None:
                self._bucket.stream_tail_s += rep.tail_s
                self._bucket.stream_hidden += rep.hidden_fraction
        return vol

    def __enter__(self) -> "StreamSession":
        return self

    def __exit__(self, *exc) -> None:
        if exc and exc[0] is not None:
            self._core.fail(exc[1])
        elif not self._core._ingest_closed:   # tolerate explicit close()
            self.close()
