"""Reconstruction serving layer: shape-bucketed requests over the
plan/compile/execute core.

iFDK (arXiv:1909.02724) frames the end-game for CPU back-projection as
instant reconstruction as a *service*; the repo's last two PRs built
exactly the substrate that makes that cheap — a pure, hashable
:class:`~repro.runtime.planner.ReconPlan` and a process-shared
:class:`~repro.runtime.executor.ProgramCache` keyed so repeated
same-shape work never retraces. :class:`ReconService` is the layer that
exploits it:

  * **shape bucketing** — every request (geometry + projections +
    façade options) is planned (pure, microseconds) and bucketed on
    ``(geometry, plan.bucket_key)``. The first request into a bucket
    builds its :class:`~repro.runtime.executor.PlanExecutor` and
    pre-compiles every program the plan needs (``PlanExecutor.warm``);
    every later same-shape request reuses them — zero new compiles, by
    construction and by test (tests/test_service.py).
  * **warmup** — ``warmup(geometries, **options)`` drives the same
    bucket-creation path without data, so a deployment can pay all
    compilation before the first real request arrives.
  * **async step pipeline** — bucket executors default to
    ``pipeline="async"``: a depth-bounded flusher thread overlaps each
    step's device->host accumulator copy with the next step's scan
    dispatch (``runtime.executor._AsyncFlushQueue``), with output
    bit-identical to the sequential flush.
  * **bounded, fair execution** — requests enter ONE FIFO queue and are
    drained by ``max_inflight`` worker threads: admission order is
    completion-start order (no shape starves another), and at most
    ``max_inflight`` reconstructions hold device memory at once.
  * **introspection** — ``stats()`` returns a :class:`ServiceStats`
    snapshot: per-bucket request/hit/miss/compile counts plus the
    shared ProgramCache totals (the same numbers bench_smoke surfaces
    in the BENCH_*.json meta block).

Usage
-----
    from repro.runtime.service import ReconService

    svc = ReconService(max_inflight=2)
    svc.warmup([geom_a, geom_b], variant="algorithm1_mp",
               tiling=(32, 32, 64), proj_batch=32)     # pay compiles now

    h = svc.submit(projections, geom_a, variant="algorithm1_mp",
                   tiling=(32, 32, 64), proj_batch=32)  # non-blocking
    vol = h.result()                                    # (nz, ny, nx)

    vol = svc.reconstruct(projections, geom_b)          # synchronous
    print(svc.stats())                                  # buckets + cache
    svc.close()

``fdk_reconstruct(..., service=svc)`` routes the façade through the
same buckets, so existing call sites join the serving path unchanged.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from concurrent.futures import Future
from typing import Dict, Iterable, Optional, Tuple

import jax.numpy as jnp

from repro.core.fdk import _build_plan
from repro.core.geometry import CTGeometry
from repro.runtime.executor import PlanExecutor, ProgramCache, \
    default_program_cache
from repro.runtime.planner import ReconPlan


# --------------------------------------------------------------------------
# Stats snapshots (immutable — safe to hand out across threads)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketStats:
    """One shape bucket's counters at snapshot time.

    ``misses`` is 1 for every live bucket (its creation); ``hits`` are
    the requests that reused it; ``programs_built`` is how many jit
    programs its warm-up compiled (0 when another bucket already
    populated the shared cache with the same program keys).
    """

    variant: str
    vol_shape_xyz: Tuple[int, int, int]
    n_proj: int
    schedule: str
    requests: int
    hits: int
    misses: int
    programs_built: int


@dataclasses.dataclass(frozen=True)
class ServiceStats:
    """Whole-service snapshot: totals + per-bucket rows + cache stats."""

    requests: int
    bucket_hits: int
    bucket_misses: int
    buckets: Tuple[BucketStats, ...]
    cache: Dict[str, int]
    max_inflight: int
    queued: int

    @property
    def hit_rate(self) -> float:
        total = self.bucket_hits + self.bucket_misses
        return self.bucket_hits / total if total else 0.0


class _Bucket:
    """A cached (geometry, plan) pair: executor + per-bucket counters."""

    def __init__(self, geom: CTGeometry, plan: ReconPlan,
                 executor: PlanExecutor, programs_built: int):
        self.geom = geom
        self.plan = plan
        self.executor = executor
        self.programs_built = programs_built
        self.requests = 0
        self.hits = 0

    def snapshot(self) -> BucketStats:
        return BucketStats(
            variant=self.plan.variant,
            vol_shape_xyz=self.plan.vol_shape_xyz,
            n_proj=self.plan.n_proj,
            schedule=self.plan.schedule,
            requests=self.requests,
            hits=self.hits,
            misses=1,
            programs_built=self.programs_built)


# --------------------------------------------------------------------------
# The service
# --------------------------------------------------------------------------

class ReconService:
    """Shape-bucketed reconstruction server over the shared ProgramCache.

    Parameters
    ----------
    max_inflight : worker-thread count == the bound on concurrently
        executing reconstructions (each holds at most one tile
        accumulator + the pipelined flush buffers on device). Requests
        beyond it wait in the FIFO queue — admission order is start
        order, so mixed-shape traffic shares the service fairly.
    pipeline : step-major flush discipline for bucket executors
        ("async" by default — the serving layer is exactly the caller
        that benefits from overlap; "sync" restores the in-thread
        double buffer).
    cache : optional private :class:`ProgramCache`; default is the
        process-shared one, so the service inherits programs compiled
        by any earlier façade call (and vice versa).
    """

    def __init__(self, *, max_inflight: int = 2, pipeline: str = "async",
                 cache: Optional[ProgramCache] = None):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.cache = cache if cache is not None else default_program_cache()
        self.pipeline = pipeline
        self.max_inflight = int(max_inflight)
        self._buckets: Dict[tuple, _Bucket] = {}
        self._lock = threading.Lock()          # buckets + counters
        self._queue: "queue.Queue" = queue.Queue()
        self._closed = False
        self._workers = [
            threading.Thread(target=self._worker, name=f"recon-serve-{i}",
                             daemon=True)
            for i in range(self.max_inflight)]
        for t in self._workers:
            t.start()

    # ---- bucketing -------------------------------------------------------

    def _plan(self, geom: CTGeometry, options: Dict) -> ReconPlan:
        """Façade options -> plan (pure; validation errors raise here,
        in the submitting thread, not in a worker)."""
        opts = dict(options)
        return _build_plan(
            geom, opts.pop("variant", "algorithm1_mp"),
            nb=opts.pop("nb", 8), interpret=opts.pop("interpret", True),
            tiling=opts.pop("tiling", None),
            memory_budget=opts.pop("memory_budget", None),
            proj_batch=opts.pop("proj_batch", None),
            out=opts.pop("out", None), schedule=opts.pop("schedule", None),
            **opts)

    def _bucket(self, geom: CTGeometry, plan: ReconPlan) -> _Bucket:
        """Find-or-create the bucket for ``(geom, plan.bucket_key)``.

        Creation happens under the service lock so the warm-up compile
        count is attributable to THIS bucket even with concurrent
        workers: the cache-miss delta across ``PlanExecutor.warm`` is
        the bucket's ``programs_built``.
        """
        key = (geom, plan.bucket_key)
        with self._lock:
            bucket = self._buckets.get(key)
            if bucket is not None:
                bucket.hits += 1
                return bucket
            misses_before = self.cache.stats()["misses"]
            ex = PlanExecutor(geom, plan, cache=self.cache,
                              pipeline=self.pipeline)
            ex.warm()
            built = self.cache.stats()["misses"] - misses_before
            bucket = _Bucket(geom, plan, ex, programs_built=built)
            self._buckets[key] = bucket
            return bucket

    def warmup(self, geometries: Iterable[CTGeometry],
               **options) -> ServiceStats:
        """Pre-compile the buckets a deployment will serve.

        One bucket per geometry, same options for all (call repeatedly
        for mixed option sets). After warmup, the first real request of
        each warmed shape is a bucket hit with zero new compiles.
        """
        for geom in geometries:
            self._bucket(geom, self._plan(geom, options))
        return self.stats()

    # ---- request path ----------------------------------------------------

    def submit(self, projections: jnp.ndarray, geom: CTGeometry,
               **options) -> "Future":
        """Enqueue one reconstruction; returns a ``Future`` whose
        ``result()`` is the volume (same contract as the façade the
        options mirror — ``fdk_reconstruct``). FIFO across callers."""
        plan = self._plan(geom, options)   # validate in the caller
        fut: Future = Future()
        # the closed check and the enqueue are atomic under the lock so
        # a request can never land behind close()'s worker sentinels
        # (its future would hang with no consumer left)
        with self._lock:
            if self._closed:
                raise RuntimeError("ReconService is closed")
            self._queue.put((fut, projections, geom, plan))
        return fut

    def reconstruct(self, projections: jnp.ndarray, geom: CTGeometry,
                    **options):
        """Synchronous request: ``submit(...).result()``."""
        return self.submit(projections, geom, **options).result()

    def _worker(self) -> None:
        while True:
            item = self._queue.get()
            try:
                if item is None:
                    return
                fut, projections, geom, plan = item
                if not fut.set_running_or_notify_cancel():
                    continue
                try:
                    bucket = self._bucket(geom, plan)
                    with self._lock:
                        bucket.requests += 1
                    fut.set_result(bucket.executor.reconstruct(projections))
                except BaseException as exc:
                    fut.set_exception(exc)
            finally:
                self._queue.task_done()

    # ---- lifecycle / introspection ---------------------------------------

    def stats(self) -> ServiceStats:
        with self._lock:
            buckets = tuple(b.snapshot() for b in self._buckets.values())
        return ServiceStats(
            requests=sum(b.requests for b in buckets),
            bucket_hits=sum(b.hits for b in buckets),
            bucket_misses=len(buckets),
            buckets=buckets,
            cache=self.cache.stats(),
            max_inflight=self.max_inflight,
            queued=self._queue.qsize())

    def close(self, wait: bool = True) -> None:
        """Stop accepting requests; drain workers (idempotent)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            for _ in self._workers:
                self._queue.put(None)
        if wait:
            for t in self._workers:
                t.join()

    def __enter__(self) -> "ReconService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
