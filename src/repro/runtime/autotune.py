"""Performance-portability autotuner: measured per-hardware config search.

The paper's central claim is that its back-projection kernels are
*performance portable over a wide range of CPUs* — and its own Table 4
shows the winning (variant, loop order, blocking) choice differs per
machine, as Treibig et al. (arXiv:1104.5243) demonstrated for RabbitCT
and iFDK (arXiv:1909.02724) at cluster scale. Everywhere else in this
repo the planner resolves its knobs (variant fallback, ``schedule``,
``proj_loop``, ``pipeline``, tile/chunk sizes) from static heuristics.
This module is the subsystem that *measures* instead of guesses:

  * :func:`autotune` — given a request (the same façade options every
    entry point takes), enumerate the candidate configuration space and
    time each candidate on the LIVE device with warm
    :class:`~repro.runtime.executor.ProgramCache` programs (compile is
    paid outside the timed region; warmup + median-of-k inside), under
    a wall-clock search budget. The search is a greedy per-axis sweep —
    variant ladder, ``KernelSpec.tuning_space`` options (e.g.
    ``proj_loop`` on/off), tile-spec and projection-chunk candidates
    pruned by the existing ``core.tiling.tile_working_set_bytes``
    model, ``schedule`` "step"/"chunk", ``pipeline`` "sync"/"async"
    with depths — so ~15 measurements cover a space whose cross product
    has hundreds of points. The heuristic config is ALWAYS measured
    first, so any budget leaves a valid winner.
  * :class:`TunedConfig` — the resolved winner: every knob an executor
    needs, self-contained and JSON-serializable
    (``PlanExecutor.from_config`` turns it back into a running
    executor; ``build_plan`` into a :class:`ReconPlan`).
  * :class:`TuningCache` — winners persist on disk (JSON under
    ``~/.cache/repro/tuning.json``, or ``$REPRO_TUNING_CACHE``, or any
    user path), keyed by a hardware fingerprint ``(backend, device
    kind, cpu count, jax version)`` x the request's
    ``ReconPlan.bucket_key``. A second process on the same machine
    resolves the same winner with ZERO re-measurement; a different
    machine (fingerprint mismatch) re-tunes. Missing or corrupt cache
    files degrade to the heuristics — never to an error. Entries are
    SELF-MAINTAINING: an :func:`autotune` resolve of an entry older
    than ``revalidate_s`` re-measures the heuristic baseline once
    (cheap) and invalidates + re-tunes when it drifted beyond
    :data:`DRIFT_RATIO` from the recorded baseline — a stale winner
    from a changed machine heals itself instead of pinning a bad
    configuration forever.
  * :func:`resolve_config` / :func:`resolve_plan` — the LOOKUP-ONLY
    path consulted by ``plan_reconstruction(variant="auto")``, the
    ``fdk_reconstruct`` façade, and ``ReconService``: cache hit returns
    the tuned config, miss falls back to today's heuristics. Planning
    stays microseconds either way; measurement only ever happens inside
    :func:`autotune` (e.g. ``ReconService.warmup(tune=True)``).

Exactness contract
------------------
The searched knobs split into two classes, and the default respects the
split:

  * **order-only knobs** — ``schedule`` ("step"/"chunk" walk the same
    chunk grid in the same per-voxel addition order) and ``pipeline`` /
    ``pipeline_depth`` (the async flusher only moves WHEN host adds
    happen, never their order). Tuning these is bit-identical to the
    heuristic config by construction (asserted in
    tests/test_autotune.py and tests/test_service.py).
  * **numeric knobs** — ``variant``, ``proj_loop``, tile shape, chunk
    size. These change float-op order; parity is at tolerance, not bit
    level.

``autotune(..., exact=True)`` — the default whenever the caller names
a variant, including through ``ReconService.warmup(tune=True,
variant=...)`` — searches only order-only knobs, so the tuned output is
bit-identical to the heuristic config. ``variant="auto"`` (or
``exact=False``) widens to the full space. Winners are keyed per
request KIND as well as shape: an "auto" winner (which may carry a
different variant) is never resolved by an explicitly-named-variant
request (:func:`request_key`).
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.tiling import tile_working_set_bytes
from repro.core.variants import get_spec
from repro.runtime import telemetry

_DEFAULT_VARIANT = "algorithm1_mp"

# measurement priority for variant="auto": the pure-JAX ladder first
# (strongest heuristics up front so early budget exhaustion still
# leaves a good winner), Pallas kernels last (interpret-mode timing on
# CPU CI is real but slow).
_LADDER = ("algorithm1_mp", "symmetry_mp", "subline_batch_mp",
           "subline_mp", "share_mp", "transpose_mp",
           "subline_pl", "onehot_pl", "banded_pl")

# cache self-maintenance: a resolved entry older than ``revalidate_s``
# gets ONE cheap heuristic-baseline probe; a probe/recorded-baseline
# ratio beyond DRIFT_RATIO (either direction) invalidates the entry and
# re-runs the search — the machine the entry was measured on is no
# longer the machine we are running on, performance-wise.
DRIFT_RATIO = 2.0


# --------------------------------------------------------------------------
# Hardware fingerprint
# --------------------------------------------------------------------------

def hardware_fingerprint() -> Tuple[str, str, int, str]:
    """(backend, device kind, cpu count, jax version) of THIS process.

    The tuple every cached winner is scoped to: a measured choice is
    only trusted on hardware indistinguishable under this key — any
    mismatch re-tunes rather than importing another machine's winner.
    """
    import jax
    devs = jax.devices()
    kind = devs[0].device_kind if devs else "unknown"
    return (str(jax.default_backend()), str(kind),
            int(os.cpu_count() or 1), str(jax.__version__))


def fingerprint_key(fp: Optional[Tuple] = None) -> str:
    """Flat string form of the fingerprint (the JSON cache's outer key)."""
    return "|".join(str(p) for p in (hardware_fingerprint()
                                     if fp is None else fp))


def _scope(variant) -> str:
    """Key namespace of a request: "auto" when the tuner may switch
    variants, "explicit" when the caller named one."""
    return "auto" if variant in (None, "auto") else "explicit"


def request_key(base_plan, scope: str = "explicit") -> str:
    """Stable identity of one request SHAPE: the heuristic base plan's
    ``bucket_key`` (the exact tuple the serving layer buckets on),
    rendered with ``repr`` — scalars/short tuples only, so the string
    is deterministic across processes. ``scope`` ("auto" | "explicit",
    see :func:`_scope`) keeps the two request kinds in separate
    namespaces: a ``variant="auto"`` winner may carry a DIFFERENT
    variant than the default the base plan was built with, and an
    explicitly-named-variant request must never resolve it (the
    exactness contract promises explicit requests stay on their
    variant)."""
    return f"{scope}|{base_plan.bucket_key!r}"


# --------------------------------------------------------------------------
# TunedConfig: one fully resolved configuration
# --------------------------------------------------------------------------

def _tupleize(v):
    """JSON round-trip repair: lists back to tuples (plan options and
    tile shapes must stay hashable — they sit inside bucket keys)."""
    if isinstance(v, list):
        return tuple(_tupleize(x) for x in v)
    return v


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """Every knob one reconstruction execution needs, fully resolved.

    Self-contained: ``build_plan(geom)`` re-plans it and
    ``PlanExecutor.from_config`` runs it, with no reference back to the
    search that produced it. ``wall_us``/``baseline_us`` record the
    measured winner and heuristic medians; ``source`` says where the
    config came from ("measured" — this process timed it, "cache" — a
    persisted winner, "heuristic" — no tuning information) and
    ``trials`` how many candidates were measured (0 on a cache hit —
    the acceptance assertion).
    """

    variant: str
    schedule: str                       # "step" | "chunk"
    pipeline: str                       # "sync" | "async"
    pipeline_depth: int
    tile_shape: Tuple[int, int, int]
    proj_batch: Optional[int]           # None = single chunk
    nb: int
    out: str                            # "host" | "device"
    interpret: bool
    options: Tuple[Tuple[str, object], ...] = ()
    # cross-request batch cap (service-tier rb): how many same-bucket
    # requests the BatchFormer may coalesce into one dispatch stream
    # under this config. Order-only per lane (vmap adds an axis, never
    # reassociates a lane's reductions), so it is searched even in
    # exact mode; wall_us under max_batch > 1 is AMORTIZED per request.
    max_batch: int = 1
    # numeric-precision data path: "f32" | "bf16" (bf16 samples with
    # f32 accumulators — a tolerance-contract knob like ``variant``,
    # searched only in the wide space). Pre-existing cache entries lack
    # the field -> dataclass default "f32".
    precision: str = "f32"
    # iterative-solver family ("none" = plain FDK). Solver winners are
    # measured on AMORTIZED per-iteration wall (see _measure_solver)
    # and live under their own request keys (solver is in bucket_key).
    solver: str = "none"
    wall_us: float = 0.0
    baseline_us: float = 0.0
    source: str = "heuristic"           # "measured" | "cache" | "heuristic"
    trials: int = 0
    # wall-clock stamp (time.time()) of the measurement that produced
    # or last REVALIDATED this entry. Entries older than the caller's
    # ``revalidate_s`` get a cheap baseline probe on resolve: within
    # DRIFT_RATIO of the recorded baseline the stamp refreshes, beyond
    # it the entry is invalidated and re-tuned (self-maintenance).
    # Pre-existing cache files lack the field -> 0.0 == always stale.
    tuned_at: float = 0.0

    @property
    def key(self) -> Tuple:
        """Knob identity (measurement/bookkeeping fields excluded)."""
        return (self.variant, self.schedule, self.pipeline,
                self.pipeline_depth, self.tile_shape, self.proj_batch,
                self.nb, self.out, self.interpret, self.options,
                self.max_batch, self.precision, self.solver)

    @property
    def speedup(self) -> float:
        """Measured heuristic/tuned wall ratio (>1 = tuning helped)."""
        return self.baseline_us / self.wall_us if self.wall_us else 1.0

    def build_plan(self, geom):
        """Re-plan this config (pure — the normal planner path)."""
        from repro.runtime.planner import plan_reconstruction
        return plan_reconstruction(
            geom, self.variant, tile_shape=self.tile_shape, nb=self.nb,
            proj_batch=self.proj_batch, out=self.out,
            interpret=self.interpret, schedule=self.schedule,
            request_batch=self.max_batch, precision=self.precision,
            solver=self.solver, **dict(self.options))

    def to_json(self) -> Dict:
        doc = dataclasses.asdict(self)
        doc["options"] = [list(kv) for kv in self.options]
        doc["tile_shape"] = list(self.tile_shape)
        return doc

    @classmethod
    def from_json(cls, doc: Dict) -> "TunedConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in doc.items() if k in fields}
        kw["tile_shape"] = tuple(int(v) for v in doc["tile_shape"])
        kw["options"] = tuple(
            (str(k), _tupleize(v)) for k, v in doc.get("options", []))
        pb = doc.get("proj_batch")
        kw["proj_batch"] = None if pb is None else int(pb)
        # pre-batching cache entries lack the field: default to 1
        kw["max_batch"] = int(doc.get("max_batch", 1))
        return cls(**kw)


def config_from_plan(plan, *, pipeline: str = "sync",
                     pipeline_depth: int = 2,
                     source: str = "heuristic") -> TunedConfig:
    """Snapshot a planned request as a :class:`TunedConfig` (the
    heuristic baseline every search starts from)."""
    return TunedConfig(
        variant=plan.variant, schedule=plan.schedule, pipeline=pipeline,
        pipeline_depth=int(pipeline_depth), tile_shape=plan.tile_shape,
        proj_batch=(plan.chunk_size if plan.streams_projections else None),
        nb=plan.nb, out=plan.out, interpret=plan.interpret,
        options=plan.options, source=source,
        max_batch=int(plan.request_batch), precision=plan.precision,
        solver=plan.solver)


# --------------------------------------------------------------------------
# TuningCache: persistent fingerprint-keyed winners
# --------------------------------------------------------------------------

def default_cache_path() -> str:
    """``$REPRO_TUNING_CACHE`` if set, else ``~/.cache/repro/tuning.json``."""
    env = os.environ.get("REPRO_TUNING_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "tuning.json")


# one lock per cache PATH, process-wide: distinct TuningCache
# instances over the same file (as_tuning_cache builds one per call)
# must still serialize their read-modify-write cycles
_PATH_LOCKS: Dict[str, threading.Lock] = {}
_PATH_LOCKS_GUARD = threading.Lock()


def _path_lock(path: str) -> threading.Lock:
    key = os.path.abspath(path)
    with _PATH_LOCKS_GUARD:
        return _PATH_LOCKS.setdefault(key, threading.Lock())


# parsed-document memo keyed on (mtime_ns, size): a tuning-enabled
# service resolves every request through lookup(), and the file only
# changes when a tuner stores a winner — re-parsing per request would
# be pure repeated work. Entries are treated as READ-ONLY by lookup().
_DOC_CACHE: Dict[str, Tuple[Tuple[int, int], Dict]] = {}
_DOC_CACHE_GUARD = threading.Lock()


class TuningCache:
    """On-disk JSON store of measured winners.

    Layout: ``{"version": 1, "fingerprints": {<fp>: {<request_key>:
    <TunedConfig doc>}}}``. Reads are tolerant by design — a missing
    file, unreadable JSON, a wrong version, or a malformed entry all
    behave as a cache miss (the caller falls back to heuristics), never
    as an error: a stale cache must not be able to break
    reconstruction. Writes are read-modify-write under a process-wide
    per-PATH lock with an atomic ``os.replace``, so concurrent tuners
    within one process never clobber each other's entries even through
    distinct ``TuningCache`` instances. Across PROCESSES the last
    writer wins for the load->replace window; the worst case is a
    just-stored entry dropping out, which costs one re-tune — never
    corruption (the replace is atomic) and never an error.
    """

    VERSION = 1

    def __init__(self, path: Optional[str] = None):
        self.path = str(path) if path is not None else default_cache_path()
        self._lock = _path_lock(self.path)

    # ---- tolerant IO -----------------------------------------------------

    def _load(self, memo: bool = True) -> Dict:
        """Parse the cache file (tolerantly). ``memo=True`` (the lookup
        path) serves the parsed doc from the (mtime, size)-stamped memo
        when the file is unchanged; the doc is shared read-only, so
        writers must pass ``memo=False`` for a private copy."""
        empty = {"version": self.VERSION, "fingerprints": {}}
        key = os.path.abspath(self.path)
        try:
            st = os.stat(self.path)
            stamp = (st.st_mtime_ns, st.st_size)
        except OSError:
            return empty
        if memo:
            with _DOC_CACHE_GUARD:
                hit = _DOC_CACHE.get(key)
            if hit is not None and hit[0] == stamp:
                return hit[1]
        try:
            with open(self.path) as f:
                doc = json.load(f)
        except FileNotFoundError:
            return empty
        except (OSError, ValueError, UnicodeDecodeError):
            return empty    # corrupt cache == no cache, never an error
        if (not isinstance(doc, dict) or doc.get("version") != self.VERSION
                or not isinstance(doc.get("fingerprints"), dict)):
            return empty
        if memo:
            with _DOC_CACHE_GUARD:
                _DOC_CACHE[key] = (stamp, doc)
        return doc

    def lookup(self, fp_key: str, req_key: str) -> Optional[TunedConfig]:
        """The persisted winner for (hardware, request shape), or None."""
        entry = self._load()["fingerprints"].get(fp_key, {}).get(req_key)
        if entry is None:
            return None
        try:
            return TunedConfig.from_json(entry)
        except (KeyError, TypeError, ValueError):
            return None     # malformed entry == miss

    def _write(self, doc: Dict) -> None:
        """Atomic write + memo refresh (call holding ``self._lock``)."""
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        os.replace(tmp, self.path)
        try:
            st = os.stat(self.path)
            with _DOC_CACHE_GUARD:
                _DOC_CACHE[os.path.abspath(self.path)] = \
                    ((st.st_mtime_ns, st.st_size), doc)
        except OSError:
            pass

    def store(self, fp_key: str, req_key: str, config: TunedConfig) -> None:
        with self._lock:
            doc = self._load(memo=False)   # private copy — mutated below
            doc["fingerprints"].setdefault(fp_key, {})[req_key] = \
                config.to_json()
            self._write(doc)

    def invalidate(self, fp_key: str, req_key: str) -> bool:
        """Drop one persisted winner (the self-maintenance path: a stale
        entry whose recorded baseline no longer matches this hardware).
        Returns whether an entry was removed."""
        with self._lock:
            doc = self._load(memo=False)
            bucket = doc["fingerprints"].get(fp_key)
            if not bucket or req_key not in bucket:
                return False
            del bucket[req_key]
            if not bucket:
                del doc["fingerprints"][fp_key]
            self._write(doc)
            return True

    def entries(self) -> Dict[str, Dict[str, Dict]]:
        """Raw {fingerprint: {request_key: config doc}} view —
        READ-ONLY (may be the shared memoized document)."""
        return self._load()["fingerprints"]

    def __len__(self) -> int:
        return sum(len(v) for v in self.entries().values())


def default_tuning_cache() -> TuningCache:
    """Cache at the default path (env resolved at construction, so
    ``REPRO_TUNING_CACHE`` changes take effect per instance)."""
    return TuningCache()


def as_tuning_cache(obj) -> TuningCache:
    """Coerce a façade ``tuning=`` argument: a :class:`TuningCache`,
    a filesystem path, or None (the default cache)."""
    if isinstance(obj, TuningCache):
        return obj
    if obj is None:
        return default_tuning_cache()
    return TuningCache(os.fspath(obj))


# --------------------------------------------------------------------------
# Heuristic baseline + lookup-only resolution
# --------------------------------------------------------------------------

def _base_kernel_options(variant, kernel_options: Dict) -> Dict:
    """Kernel options for the heuristic BASE plan.

    An "auto" request may carry options for variants other than the
    default the base plan is built with (e.g. ``proj_loop`` for the
    Pallas candidates): validate them against the WHOLE registry — a
    typo still fails fast — then filter to what the base variant
    accepts, so planning the base never rejects a legitimate
    cross-variant knob. Explicit-variant requests pass through
    untouched (the planner validates them as usual)."""
    if variant not in (None, "auto"):
        return dict(kernel_options)
    from repro.core.variants import REGISTRY
    known = {"nb", "interpret"}
    for spec in REGISTRY.values():
        known |= set(spec.options)
    unknown = set(kernel_options) - known
    if unknown:
        raise ValueError(
            f"variant='auto' got option(s) {sorted(unknown)} accepted "
            f"by no registered variant")
    allowed = get_spec(_DEFAULT_VARIANT).options
    return {k: v for k, v in kernel_options.items() if k in allowed}


def _request_key(variant, base_plan, kernel_options: Dict) -> str:
    """Full cache key for one request. Explicit-variant requests are
    covered by the base plan's bucket_key (its options are the resolved
    caller options); "auto" requests append the raw caller options —
    the base plan silently drops the cross-variant ones, and two auto
    requests differing only there must not collide."""
    key = request_key(base_plan, _scope(variant))
    if variant in (None, "auto") and kernel_options:
        key += f"|opts={tuple(sorted(kernel_options.items()))!r}"
    return key


def _heuristic_config(geom, variant="auto", *, nb=8, interpret=True,
                      tiling=None, memory_budget=None, proj_batch=None,
                      out=None, schedule=None, precision="f32",
                      solver="none", **kernel_options):
    """(heuristic TunedConfig, its base plan) for one façade request —
    exactly what every entry point runs today without tuning."""
    from repro.core.fdk import _build_plan
    name = _DEFAULT_VARIANT if variant in (None, "auto") else variant
    plan = _build_plan(geom, name, nb=nb, interpret=interpret,
                       tiling=tiling, memory_budget=memory_budget,
                       proj_batch=proj_batch, out=out, schedule=schedule,
                       precision=precision, solver=solver,
                       **_base_kernel_options(variant, kernel_options))
    return config_from_plan(plan), plan


def resolve_config(geom, variant: str = "auto", *, cache=None,
                   **request) -> TunedConfig:
    """LOOKUP-ONLY config resolution (never measures): the persisted
    winner for this (hardware, request shape) if one exists
    (``source == "cache"``), today's heuristics otherwise
    (``source == "heuristic"``). ``request`` takes the façade options
    (``nb``/``tiling``/``memory_budget``/``proj_batch``/``out``/
    ``schedule``/kernel options)."""
    cache = as_tuning_cache(cache)
    base_cfg, base_plan = _heuristic_config(geom, variant, **request)
    extra = {k: v for k, v in request.items()
             if k not in ("nb", "interpret", "tiling", "memory_budget",
                          "proj_batch", "out", "schedule", "precision",
                          "solver")}
    hit = cache.lookup(fingerprint_key(),
                       _request_key(variant, base_plan, extra))
    if hit is not None:
        return dataclasses.replace(hit, source="cache", trials=0)
    return base_cfg


def resolve_plan(geom, *, variant="auto", tuning=None, tile_shape=None,
                 memory_budget=None, nb=8, proj_batch=None, out="host",
                 interpret=True, schedule=None, request_batch=1,
                 precision="f32", solver="none", **kernel_options):
    """Planner-level twin of :func:`resolve_config` (planner argument
    conventions; returns the plan only — the executor-level pipeline
    choice needs :func:`resolve_config`). This is what
    ``plan_reconstruction(variant="auto" / tuning=...)`` delegates to.
    The caller's ``request_batch`` overrides a cached winner's
    ``max_batch`` on the returned plan (rb is an execution multiplicity
    the caller commits to, not a shape fact — ``bucket_key`` ignores
    it either way)."""
    from repro.runtime.planner import plan_reconstruction
    cache = as_tuning_cache(tuning)
    name = _DEFAULT_VARIANT if variant in (None, "auto") else variant
    base = plan_reconstruction(
        geom, name, tile_shape=tile_shape, memory_budget=memory_budget,
        nb=nb, proj_batch=proj_batch, out=out, interpret=interpret,
        schedule=schedule, request_batch=request_batch,
        precision=precision, solver=solver,
        **_base_kernel_options(variant, kernel_options))
    hit = cache.lookup(fingerprint_key(),
                       _request_key(variant, base, kernel_options))
    if hit is None:
        return base
    return hit.build_plan(geom).batched(int(request_batch))


# --------------------------------------------------------------------------
# Measurement
# --------------------------------------------------------------------------

def _measure_config(geom, config: TunedConfig, projections,
                    program_cache, *, iters: int = 3,
                    warmup: int = 1) -> float:
    """Median wall seconds of one full ``reconstruct`` under ``config``.

    Programs are compiled via ``PlanExecutor.warm`` BEFORE the timed
    region (the cache makes repeat candidates nearly free), then
    ``warmup`` untimed calls absorb first-call allocation effects and
    the median of ``iters`` timed calls is returned.

    ``config.max_batch > 1`` measures the BATCHED path — one
    ``execute_batch`` of max_batch copies of the projections — and
    returns wall / max_batch: the amortized per-request time, directly
    comparable against the unbatched candidates so the sweep picks the
    rb sweet spot (or rejects batching where vmap pressure eats the
    dispatch saving on this hardware).
    """
    import jax
    from repro.runtime.executor import PlanExecutor
    ex = PlanExecutor.from_config(geom, config, cache=program_cache)
    ex.warm()
    rb = max(1, int(config.max_batch))
    if rb > 1:
        if not ex.supports_request_batching:
            raise ValueError("config cannot batch (chunk-major plan)")
        ex.warm_batch(rb)
        reqs = [projections] * rb
        run = lambda: ex.execute_batch(reqs)      # noqa: E731
    else:
        run = lambda: ex.reconstruct(projections)  # noqa: E731
    for _ in range(int(warmup)):
        jax.block_until_ready(run())
    times = []
    for _ in range(max(1, int(iters))):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] / rb


def _measure_solver(geom, config: TunedConfig, projections,
                    program_cache, *, iters_per_solve: int = 3,
                    warmup: int = 1) -> float:
    """Median AMORTIZED wall seconds per solver ITERATION under
    ``config`` (``config.solver`` names the method).

    Compiles + normalizers are paid via ``IterativeExecutor.warm``
    before the timed region — the quantity a deployment cares about is
    the warm per-iteration cost the whole solve multiplies, not the
    one-time setup. Each timed sample runs a short
    ``iters_per_solve``-iteration solve and bills wall /
    iters_per_solve, so loop overhead amortizes the same way a real
    N-iteration run amortizes it.
    """
    import jax
    from repro.runtime.solvers import IterativeExecutor
    ex = IterativeExecutor(geom, config.build_plan(geom),
                           cache=program_cache)
    ex.warm()
    k = max(1, int(iters_per_solve))
    run = lambda: ex.solve(projections, n_iters=k)[0]  # noqa: E731
    for _ in range(int(warmup)):
        jax.block_until_ready(run())
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] / k


# --------------------------------------------------------------------------
# Candidate axes (greedy per-axis sweep)
# --------------------------------------------------------------------------

def _fits_budget(tile, geom, nb: int, variant: str,
                 memory_budget: Optional[int]) -> bool:
    """Prune a tile candidate with the SAME working-set model the
    planner's auto-picker uses (mirror-paired slabs billed at their
    virtual 2*tk depth)."""
    if memory_budget is None:
        return True
    ti, tj, tk = tile
    nz = geom.volume_shape_xyz[2]
    eff = min(2 * tk, nz) if (get_spec(variant).uses_symmetry
                              and tk < nz) else tk
    ws = tile_working_set_bytes((ti, tj, eff), (geom.nw, geom.nh), nb=nb)
    return ws <= int(memory_budget)


def _variant_axis(cur: TunedConfig, requested: str,
                  kernel_options: Dict) -> List[TunedConfig]:
    if requested not in (None, "auto"):
        return []
    out = []
    for name in _LADDER:
        if name == cur.variant:
            continue
        spec = get_spec(name)
        if spec.backend == "reference":
            continue
        opts = spec.resolve_options(dict(kernel_options))
        if spec.proj_loop and "proj_loop" not in opts:
            # mirror the planner's default so the candidate's key
            # matches the plan it measures (else _option_axis would
            # re-measure the identical plan under a second key)
            opts["proj_loop"] = True
        out.append(dataclasses.replace(
            cur, variant=name, options=tuple(sorted(opts.items()))))
    return out


def _option_axis(cur: TunedConfig) -> List[TunedConfig]:
    """Flip each KernelSpec-advertised tuning option (e.g. proj_loop)."""
    spec = get_spec(cur.variant)
    have = dict(cur.options)
    out = []
    for name, values in spec.tuning_space:
        for v in values:
            if have.get(name) == v:
                continue
            opts = dict(have)
            opts[name] = v
            out.append(dataclasses.replace(
                cur, options=tuple(sorted(opts.items()))))
    return out


def _tile_axis(geom, cur: TunedConfig,
               memory_budget: Optional[int]) -> List[TunedConfig]:
    nx, ny, nz = geom.volume_shape_xyz
    ti, tj, tk = cur.tile_shape
    cands = [(nx, ny, nz),                                   # untiled
             (max(1, ti // 2), max(1, tj // 2), tk),         # finer (i, j)
             (max(1, ti // 2), max(1, tj // 2), max(1, tk // 2))]
    out = []
    for tile in cands:
        if tile == cur.tile_shape:
            continue
        if not _fits_budget(tile, geom, cur.nb, cur.variant, memory_budget):
            continue
        out.append(dataclasses.replace(cur, tile_shape=tile))
    return out


def _chunk_axis(geom, cur: TunedConfig,
                memory_budget: Optional[int]) -> List[TunedConfig]:
    nb = cur.nb
    n_pad = -(-int(geom.n_proj) // nb) * nb
    cands = {None}
    half = -(-(n_pad // 2) // nb) * nb
    if nb <= half < n_pad:
        cands.add(half)
    if nb < n_pad:
        cands.add(nb)
    if memory_budget is not None:
        # an explicit budget is the caller's device-byte contract and
        # the chunk bound is part of it: never offer a LARGER chunk
        # (and None == the whole set — the same residency
        # _schedule_axis refuses "step" for)
        cap = cur.proj_batch if cur.proj_batch is not None else n_pad
        cands = {pb for pb in cands if pb is not None and pb <= cap}
    out = []
    for pb in sorted(cands, key=lambda v: -1 if v is None else v):
        if pb == cur.proj_batch:
            continue
        out.append(dataclasses.replace(cur, proj_batch=pb))
    return out


def _schedule_axis(cur: TunedConfig, memory_budget: Optional[int],
                   pinned: Optional[str] = None) -> List[TunedConfig]:
    # a schedule the caller NAMED is a contract, not a default — e.g.
    # "chunk" is chosen for its bounded device residency — so the tuner
    # never offers the other one (``pinned``); likewise an explicit
    # memory_budget is the caller's device-byte contract, which only
    # the chunk-major loop honors (the step-major scan stacks the
    # whole filtered set on device) — do not offer "step"
    if pinned is not None:
        return []
    allowed = ("chunk",) if memory_budget is not None else ("step", "chunk")
    return [dataclasses.replace(cur, schedule=s)
            for s in allowed if s != cur.schedule]


def _batch_axis(cur: TunedConfig) -> List[TunedConfig]:
    """Cross-request batch cap candidates (the service-tier rb sweet
    spot). Only step-major plans batch; per-lane output is
    bit-identical to unbatched (vmap adds an axis, never reassociates
    a lane), so this axis is searched even in exact mode. Candidates
    are measured AMORTIZED (wall / rb — see :func:`_measure_config`),
    so rb only wins where one dispatch genuinely serves rb requests
    cheaper than rb dispatches."""
    if cur.schedule != "step":
        return []
    return [dataclasses.replace(cur, max_batch=rb)
            for rb in (1, 2, 4, 8) if rb != cur.max_batch]


def _precision_axis(cur: TunedConfig) -> List[TunedConfig]:
    """Flip the reduced-precision data path (bf16 samples / f32
    accumulators). A tolerance-contract knob like ``variant`` — only
    offered in the wide (non-exact) search."""
    return [dataclasses.replace(cur, precision=p)
            for p in ("f32", "bf16") if p != cur.precision]


def _pipeline_axis(cur: TunedConfig) -> List[TunedConfig]:
    if cur.out != "host":
        return []    # the flush pipeline only exists for host placement
    combos = (("sync", 2), ("async", 2), ("async", 4))
    return [dataclasses.replace(cur, pipeline=p, pipeline_depth=d)
            for p, d in combos
            if (p, d) != (cur.pipeline, cur.pipeline_depth)]


# --------------------------------------------------------------------------
# The tuner
# --------------------------------------------------------------------------

def autotune(geom, variant: str = "auto", *, method: str = "fdk",
             nb: int = 8,
             interpret: bool = True, tiling=None,
             memory_budget: Optional[int] = None,
             proj_batch: Optional[int] = None, out: Optional[str] = None,
             schedule: Optional[str] = None, precision: str = "f32",
             budget_s: float = 20.0, iters: int = 3, warmup: int = 1,
             exact: Optional[bool] = None,
             variants: Optional[Sequence[str]] = None,
             cache=None, force: bool = False, projections=None,
             program_cache=None, revalidate_s: float = 3600.0,
             **kernel_options) -> TunedConfig:
    """Measured configuration search for one request shape.

    Returns the winning :class:`TunedConfig` and persists it in the
    :class:`TuningCache` (``cache``: a TuningCache, a path, or None for
    the default). A persisted winner for this (hardware fingerprint,
    request ``bucket_key``) short-circuits the search entirely unless
    ``force=True`` — the returned config then has ``source == "cache"``
    and ``trials == 0``.

    ``budget_s`` bounds the SEARCH wall clock: the heuristic baseline
    is always measured, then greedy per-axis candidates are measured in
    priority order until the budget is spent (a candidate's compile
    time counts against the budget — it is real wall time). ``exact``
    (default: True for an explicitly requested variant, False for
    ``variant="auto"``) restricts the search to the order-only knobs
    (``schedule``/``pipeline``) whose output is bit-identical to the
    heuristic config; the wide space adds variant, KernelSpec
    ``tuning_space`` options, and working-set-pruned tile/chunk
    candidates (``variants`` optionally restricts the ladder).
    ``projections`` supplies measurement input (default: synthetic
    random projections of the geometry's shape); ``program_cache``
    shares compiled programs with the caller (e.g. the serving layer's
    cache, so tuning doubles as warmup).

    ``method`` widens the tuner beyond FDK: a solver method ("sart" /
    "os_sart" / "cgls" / "fista_tv") measures the AMORTIZED
    per-iteration wall of a short warm solve (:func:`_measure_solver`)
    and searches subset count (the ``proj_batch`` chunk axis — the
    ordered-subset structure), ``precision`` ("f32"/"bf16"), and the
    order-only ``schedule`` knob. Solver winners persist under their
    own request keys (``solver`` sits in ``bucket_key``) and never
    collide with FDK entries.

    The cache is SELF-MAINTAINING: a hit younger than ``revalidate_s``
    wall seconds resolves with zero measurement (the fast path above);
    an older hit pays ONE cheap heuristic-baseline probe. If the probe
    lands within :data:`DRIFT_RATIO` of the entry's recorded baseline
    the entry is restamped as fresh and returned (``source ==
    "cache"``, ``trials == 0`` still); beyond it — the machine's
    performance character changed (new hardware step, contended host,
    migrated cache file) — the entry is invalidated and the full search
    re-runs. Entries written before this field existed carry
    ``tuned_at == 0`` and always revalidate on first resolve.
    """
    import numpy as np
    import jax.numpy as jnp
    from repro.runtime.executor import ProgramCache

    solver = "none" if method == "fdk" else method
    if method not in ("fdk", "sart", "os_sart", "cgls", "fista_tv"):
        raise ValueError(
            f"method must be 'fdk' or a solver "
            f"('sart'|'os_sart'|'cgls'|'fista_tv'), got {method!r}")

    def _measure(cfg, projs, pc, *, m_iters, m_warmup):
        # solver methods optimize the AMORTIZED per-iteration wall —
        # the cost a real N-iteration deployment multiplies — instead
        # of one-shot reconstruct wall
        if solver == "none":
            return _measure_config(geom, cfg, projs, pc, iters=m_iters,
                                   warmup=m_warmup)
        return _measure_solver(geom, cfg, projs, pc,
                               iters_per_solve=m_iters, warmup=m_warmup)

    tcache = as_tuning_cache(cache)
    base_cfg, base_plan = _heuristic_config(
        geom, variant, nb=nb, interpret=interpret, tiling=tiling,
        memory_budget=memory_budget, proj_batch=proj_batch, out=out,
        schedule=schedule, precision=precision, solver=solver,
        **kernel_options)
    fp = fingerprint_key()
    rkey = _request_key(variant, base_plan, kernel_options)
    if not force:
        hit = tcache.lookup(fp, rkey)
        if hit is not None:
            age = time.time() - float(hit.tuned_at)
            if age <= float(revalidate_s) or hit.baseline_us <= 0.0:
                # fresh (or unvalidatable: no recorded baseline to
                # compare against) — the zero-measurement fast path
                return dataclasses.replace(hit, source="cache", trials=0)
            # stale: one cheap baseline probe decides keep vs re-tune
            if projections is None:
                rng = np.random.RandomState(0)
                projections = jnp.asarray(rng.rand(
                    geom.n_proj, geom.nh, geom.nw).astype(np.float32))
            if program_cache is None:
                program_cache = ProgramCache()
            try:
                probe_us = _measure(
                    base_cfg, projections, program_cache,
                    m_iters=1, m_warmup=1) * 1e6
            except Exception:
                probe_us = None     # unmeasurable probe: let the full
                                    # search below re-establish reality
            if probe_us is not None and probe_us > 0.0:
                drift = max(probe_us / hit.baseline_us,
                            hit.baseline_us / probe_us)
                if drift <= DRIFT_RATIO:
                    # still believable — refresh the stamp only (the
                    # recorded baseline is kept: restamping it too
                    # would let slow drift creep under the threshold)
                    tcache.store(fp, rkey, dataclasses.replace(
                        hit, tuned_at=time.time()))
                    return dataclasses.replace(hit, source="cache",
                                               trials=0)
            tcache.invalidate(fp, rkey)
            # fall through to the full search (which re-stores)

    if exact is None:
        # solver tuning is inherently non-exact: subset count changes
        # the ITERATION (OS-SART) and precision the data path, and both
        # are the axes the search exists for
        exact = variant not in (None, "auto") and solver == "none"
    if projections is None:
        rng = np.random.RandomState(0)
        projections = jnp.asarray(
            rng.rand(geom.n_proj, geom.nh, geom.nw).astype(np.float32))
    pcache = program_cache if program_cache is not None else ProgramCache()

    t_start = time.perf_counter()
    measured: Dict[Tuple, float] = {}

    def timed(cfg: TunedConfig) -> float:
        if cfg.key not in measured:
            # one span per *measured* candidate (cache hits are free)
            with telemetry.span("autotune.candidate", cat="autotune",
                                variant=cfg.variant, key=repr(cfg.key)):
                measured[cfg.key] = _measure(cfg, projections, pcache,
                                             m_iters=iters,
                                             m_warmup=warmup)
        return measured[cfg.key]

    best = base_cfg
    best_t = baseline_t = timed(base_cfg)

    axes = []
    if solver != "none":
        # subset count (the plan's projection chunking IS the ordered-
        # subset structure) x precision x the order-only schedule knob;
        # pipeline/batch axes do not apply (device-resident volume,
        # stateful loop — no request batching, no host flush)
        axes.append(lambda c: _chunk_axis(geom, c, memory_budget))
        if not exact:
            axes.append(_precision_axis)
        axes.append(lambda c: _schedule_axis(c, memory_budget,
                                             pinned=schedule))
    else:
        if not exact:
            axes.append(lambda c: _variant_axis(c, variant,
                                                kernel_options))
            axes.append(_option_axis)
            axes.append(lambda c: _tile_axis(geom, c, memory_budget))
            axes.append(lambda c: _chunk_axis(geom, c, memory_budget))
            axes.append(_precision_axis)
        axes.append(lambda c: _schedule_axis(c, memory_budget,
                                             pinned=schedule))
        axes.append(_pipeline_axis)
        axes.append(_batch_axis)

    for axis in axes:
        for cand in axis(best):
            if variants is not None and cand.variant != best.variant \
                    and cand.variant not in variants:
                continue
            if time.perf_counter() - t_start > float(budget_s):
                break
            try:
                t = timed(cand)
            except Exception:
                continue    # an unrunnable candidate never kills tuning
            if t < best_t:
                best, best_t = cand, t

    # normalize options through a real plan (e.g. the planner's
    # proj_loop default) so the persisted config re-plans IDENTICALLY
    best = config_from_plan(
        best.build_plan(geom), pipeline=best.pipeline,
        pipeline_depth=best.pipeline_depth)
    winner = dataclasses.replace(
        best, wall_us=best_t * 1e6, baseline_us=baseline_t * 1e6,
        source="measured", trials=len(measured), tuned_at=time.time())
    tcache.store(fp, rkey, winner)
    # tuner-outcome trajectory: one record per full search, keyed by
    # fingerprint, so the portability claim is a tracked number
    telemetry.record_tuning({
        "fingerprint": fp, "bucket_key": rkey,
        "heuristic_wall": winner.baseline_us,
        "tuned_wall": winner.wall_us, "ratio": winner.speedup,
        "tuned_at": winner.tuned_at})
    return winner
