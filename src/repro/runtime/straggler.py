"""Straggler detection and mitigation.

On a synchronous SPMD mesh the slowest host sets the step time. The
monitor tracks a robust (median + MAD) model of recent step durations and
flags outliers; mitigation relies on the data pipeline's determinism:

  * **skip-ahead**: a host that fell behind on input synthesis seeks the
    pipeline forward — it never needs to replay missed batches;
  * **backup-step** (cluster mode): the supervisor reassigns a flagged
    host's data shard to a hot spare for the next step — any host can
    synthesize any shard because batch_at(step, shard) is pure.

The reconstruction fleet (``runtime.executor.PlanExecutor.execute_fleet``)
uses the same model per DEVICE: a :class:`FleetStragglerBoard` keeps one
monitor per fleet member and flags devices whose recent step times fall
behind the fleet-wide median — the signal the work-stealing victim
choice prefers, so a slow device's unclaimed ``StepWork`` migrates to
healthy ones.
"""

from __future__ import annotations

import collections
import statistics
import threading
from typing import Deque, Optional, Tuple


class StragglerMonitor:
    def __init__(self, window: int = 32, threshold: float = 3.0,
                 floor_frac: float = 0.01):
        self.durations: Deque[float] = collections.deque(maxlen=window)
        self.threshold = threshold
        self.floor_frac = floor_frac
        self.flagged_steps = []

    def record(self, step: int, duration_s: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        is_out = False
        if len(self.durations) >= 8:
            med = statistics.median(self.durations)
            mad = statistics.median([abs(d - med) for d in self.durations])
            # A near-constant window has MAD ~ 0; the old `mad or 1e-9`
            # floor turned that into a ~nanosecond outlier scale, so any
            # step a microsecond over the median flagged. Floor the
            # scale at floor_frac of the median instead (plus a tiny
            # absolute epsilon for a degenerate all-zero window): only
            # steps slower by a real fraction of the median can flag.
            scale = max(1.4826 * mad, self.floor_frac * med, 1e-9)
            if (duration_s - med) / scale > self.threshold:
                is_out = True
                self.flagged_steps.append(step)
        self.durations.append(duration_s)
        return is_out

    @property
    def median(self) -> Optional[float]:
        if not self.durations:
            return None
        return statistics.median(self.durations)


class FleetStragglerBoard:
    """Cross-device straggler flagging for the reconstruction fleet.

    One :class:`StragglerMonitor` per device records that device's step
    durations (per-device jitter model); a device is FLAGGED when its
    recent median exceeds ``ratio`` x the fleet-wide median of the last
    recordings. Flagging is sticky only while the imbalance persists: a
    device that catches back up is unflagged on its next record.
    Thread-safe — fleet workers record concurrently.
    """

    def __init__(self, n_devices: int, *, window: int = 32,
                 ratio: float = 1.5, min_samples: int = 1):
        if n_devices < 1:
            raise ValueError(f"n_devices must be >= 1, got {n_devices}")
        self.monitors = [StragglerMonitor(window=window)
                         for _ in range(n_devices)]
        self.ratio = float(ratio)
        self.min_samples = int(min_samples)
        self._all: Deque[float] = collections.deque(
            maxlen=window * n_devices)
        self._flagged = set()
        self._lock = threading.Lock()

    def record(self, device: int, step: int, duration_s: float) -> bool:
        """Record one step's duration for ``device``; returns whether
        the device is flagged as a fleet straggler after this sample."""
        with self._lock:
            self.monitors[device].record(step, duration_s)
            self._all.append(float(duration_s))
            dev_med = self.monitors[device].median
            n_dev = len(self.monitors[device].durations)
            if n_dev >= self.min_samples and len(self._all) >= 4:
                fleet_med = statistics.median(self._all)
                if dev_med > self.ratio * max(fleet_med, 1e-12):
                    self._flagged.add(device)
                else:
                    self._flagged.discard(device)
            return device in self._flagged

    @property
    def flagged(self) -> Tuple[int, ...]:
        """Currently-flagged device indices (sorted)."""
        with self._lock:
            return tuple(sorted(self._flagged))
