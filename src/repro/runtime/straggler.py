"""Straggler detection and mitigation.

On a synchronous SPMD mesh the slowest host sets the step time. The
monitor tracks a robust (median + MAD) model of recent step durations and
flags outliers; mitigation relies on the data pipeline's determinism:

  * **skip-ahead**: a host that fell behind on input synthesis seeks the
    pipeline forward — it never needs to replay missed batches;
  * **backup-step** (cluster mode): the supervisor reassigns a flagged
    host's data shard to a hot spare for the next step — any host can
    synthesize any shard because batch_at(step, shard) is pure.
"""

from __future__ import annotations

import collections
import statistics
from typing import Deque, Optional


class StragglerMonitor:
    def __init__(self, window: int = 32, threshold: float = 3.0):
        self.durations: Deque[float] = collections.deque(maxlen=window)
        self.threshold = threshold
        self.flagged_steps = []

    def record(self, step: int, duration_s: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        is_out = False
        if len(self.durations) >= 8:
            med = statistics.median(self.durations)
            mad = statistics.median(
                [abs(d - med) for d in self.durations]) or 1e-9
            if (duration_s - med) / (1.4826 * mad) > self.threshold:
                is_out = True
                self.flagged_steps.append(step)
        self.durations.append(duration_s)
        return is_out

    @property
    def median(self) -> Optional[float]:
        if not self.durations:
            return None
        return statistics.median(self.durations)
