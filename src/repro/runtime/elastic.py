"""Elastic re-meshing: continue a run on a different device count.

Because (a) parameters are checkpointed as full logical arrays (shard-
agnostic), (b) sharding rules are pure functions of (param path, mesh),
and (c) the data pipeline's global batch is host-count independent, a
restart on K' != K devices is: build new mesh -> recompute PartitionSpecs
-> device_put the restored pytree. ``remesh_plan`` picks the new mesh
shape; ``reshard_tree`` executes placement.
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import NamedSharding


def remesh_plan(n_devices: int, *, model_parallel: int) -> Tuple[int, ...]:
    """Largest (data, model) mesh fitting n_devices.

    Keeps the model axis fixed (param layouts keep working), shrinks or
    grows the data axis — the elastic dimension. Leftover devices idle
    (spares for the next failure). The reconstruction fleet uses the
    same contract at queue granularity: after a device retires, the
    NEXT run simply partitions the step schedule over the survivors
    (``runtime.planner.partition_steps`` — pure, any shard count).
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if model_parallel < 1:
        raise ValueError(
            f"model_parallel must be >= 1, got {model_parallel}")
    if n_devices < model_parallel:
        # Degraded mode: shrink model axis to the largest power-of-two
        # divisor that fits; params must be re-laid-out from checkpoint.
        mp = 1
        while mp * 2 <= n_devices:
            mp *= 2
        return (n_devices // mp, mp)
    return (n_devices // model_parallel, model_parallel)


def reshard_tree(tree, mesh, spec_fn):
    """device_put every leaf with its spec under the (new) mesh.

    spec_fn: (path_str, leaf) -> PartitionSpec. Works for both fresh
    placement and rescue-resharding after an elastic restart.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)

    def key_str(kp):
        out = []
        for k in kp:
            if hasattr(k, "key"):
                out.append(str(k.key))
            elif hasattr(k, "idx"):
                out.append(str(k.idx))
            else:
                out.append(str(k))
        return "/".join(out)

    leaves = []
    for kp, leaf in flat:
        spec = spec_fn(key_str(kp), leaf)
        leaves.append(jax.device_put(leaf, NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, leaves)
