"""Compile + execute stages of the plan/compile/execute architecture.

``runtime.planner`` produces a pure :class:`~repro.runtime.planner.ReconPlan`;
this module turns it into arrays:

  * :class:`ProgramCache` — the **compile** stage. One jitted program per
    ``(variant, call_shape, nb, dtype, interpret, options)`` key, shared
    by the tiled, untiled, and distributed executors: interior tiles of
    equal shape and repeated ``reconstruct`` calls reuse the same
    program instead of retracing. The step-major schedule adds a second
    key family: ``scan_program`` keys additionally carry the chunk-loop
    shape ``(n_chunks, chunk_size)`` and map to a ``lax.scan``
    MEGAPROGRAM that sweeps the whole projection-chunk axis on device.
    Hits/misses are introspectable (``cache.stats()``), and a
    module-level default cache persists across executors so repeated
    façade calls stay warm.

  * :class:`PlanExecutor` — the **execute** stage. The default
    (``plan.schedule == "step"``) walk is STEP-MAJOR: for each tile
    step, one scan megaprogram carries the tile accumulator across ALL
    projection chunks device-resident and the result crosses to the
    host exactly once — O(vol) device->host volume traffic and one
    dispatch per step, vs the chunk-major O(n_chunks x vol) traffic and
    O(n_chunks x n_steps) dispatches. Chunk filtering is hoisted into a
    filter-once producer that feeds every step. ``schedule == "chunk"``
    keeps the PR-2 chunk-major loop (kept as the parity oracle and for
    workloads where the filtered projection set must stay chunk-bounded
    on device), now with input-side double buffering: the next chunk's
    filtering is dispatched before the current chunk's host flush, so
    it overlaps under JAX's async dispatch. Host placement remains
    output-side double-buffered in both orders: the ``np.asarray``
    device->host copy of step ``n`` is issued only after step ``n+1``'s
    programs have been dispatched. ``pipeline="async"`` upgrades the
    host flush to a real stream in EVERY loop order — step-major,
    chunk-major, and the distributed tile walk: a depth-bounded
    :class:`_AsyncFlushQueue` flusher thread performs the
    ``block_until_ready`` + host accumulate off the dispatch thread, so
    unit N's device->host copy genuinely overlaps unit N+1's dispatch
    (the serving layer, ``runtime/service.py``, runs this by default).
    The executor can also be built straight from an autotuned winner:
    :meth:`PlanExecutor.from_config` consumes a
    ``runtime.autotune.TunedConfig`` (the measured per-hardware choice
    of schedule/pipeline/variant/tile/chunk knobs).

  * :class:`StreamingExecutor` — ONLINE execution
    (``PlanExecutor.open_stream`` on an ``ingest="stream"`` plan):
    projections are pushed as the scanner produces them and each view
    chunk is filtered + folded into the per-step device accumulators
    the moment it completes, so reconstruction wall hides behind
    acquisition; the chunk-index fold order makes ``close()``
    bit-identical to the offline chunk-major ``reconstruct``.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import queue
import threading
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import backproject as bp
from repro.core.filtering import fdk_filter_chunk
from repro.core.geometry import CTGeometry, projection_matrices
from repro.core.tiling import (
    TileSpec, make_tiles, pad_projection_batch, plan_proj_chunks,
    tile_working_set_bytes, translate_matrices,
)
from repro.core.variants import get_spec
from repro.runtime import telemetry
from repro.runtime.planner import (
    PlanStep, ReconPlan, StepMajorSchedule, build_step_major,
    partition_steps, resolve_tile_variant, step_cost,
)
from repro.runtime.straggler import FleetStragglerBoard


# --------------------------------------------------------------------------
# Compile: the keyed jit-program cache
# --------------------------------------------------------------------------

def _plan_dtype(plan: ReconPlan) -> str:
    """ProgramCache dtype key of a plan's precision axis."""
    return "bfloat16" if plan.precision == "bf16" else "float32"


def _precision_adapter(variant: str, dtype: str):
    """Input-side precision transform for one kernel program, or None.

    ``dtype == "bfloat16"`` implements the plan-level ``precision=
    "bf16"`` contract: projection samples are rounded to bfloat16 on
    the way into the kernel (the reduced-precision data path — the
    bytes every gather streams), while the per-view matrices, the
    interpolation weights derived from them, and every accumulator stay
    float32. Pure-JAX kernels receive the bf16 array directly (mixed
    bf16xf32 arithmetic promotes to f32, so the multiply-accumulate
    chain is f32 over bf16-rounded samples); Pallas kernels receive the
    bf16-rounded values upcast back to f32 — identical rounding, but
    the kernel's refs keep the dtype its block specs declare. Either
    way the program's OUTPUT is float32 (the builders re-assert it), so
    downstream accumulation never narrows.
    """
    if str(dtype) == "float32":
        return None
    if str(dtype) != "bfloat16":
        raise ValueError(
            f"unsupported program dtype {dtype!r}: 'float32' or "
            f"'bfloat16'")
    if get_spec(variant).backend == "pallas":
        return lambda img: img.astype(jnp.bfloat16).astype(jnp.float32)
    return lambda img: img.astype(jnp.bfloat16)


def _with_precision(fn, variant: str, dtype: str):
    """Wrap a kernel fn with the precision adapter (f32 = pass-through)."""
    cast = _precision_adapter(variant, dtype)
    if cast is None:
        return fn

    def wrapped(img, mat, shape, **opts):
        return fn(cast(img), mat, shape, **opts).astype(jnp.float32)

    return wrapped


class ProgramCache:
    """Keyed cache of jitted back-projection programs.

    Kernel programs are keyed ``(variant, call_shape, nb, dtype,
    interpret, options)``; the distributed executor stores its shard_map
    programs under its own key family via :meth:`get_or_build`. The
    cache is thread-safe and introspectable: ``stats()`` reports hits,
    misses (== programs built), and the live key count.
    """

    def __init__(self):
        self._programs: Dict[tuple, Callable] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get_or_build(self, key: tuple, builder: Callable[[], Callable]):
        with self._lock:
            prog = self._programs.get(key)
            if prog is not None:
                self.hits += 1
                return prog
        # build outside the lock (tracing can be slow); last writer wins.
        # The span wraps builder() and nothing else, so "compile" span
        # count == self.misses EXACTLY (both tick once per build, even
        # when two threads race on the same key).
        with telemetry.span("compile", cat="compile", key=repr(key)):
            prog = builder()
        with self._lock:
            self._programs.setdefault(key, prog)
            self.misses += 1
            return self._programs[key]

    def program(self, variant: str, call_shape: Tuple[int, int, int],
                nb: int, dtype: str, interpret: bool,
                options: Tuple = ()) -> Callable:
        """Jitted ``prog(img_t_chunk, mats_chunk) -> vol_t(call_shape)``."""
        key = ("kernel", variant, tuple(call_shape), int(nb), str(dtype),
               bool(interpret), tuple(options))

        def build():
            spec = get_spec(variant)
            opts = spec.resolve_options(
                {**dict(options), "nb": int(nb), "interpret": bool(interpret)})
            shape = tuple(call_shape)
            fn = _with_precision(spec.fn, variant, dtype)
            prog = lambda img, mat: fn(img, mat, shape, **opts)  # noqa: E731
            # non-jittable kernels (KernelSpec.jittable=False) inspect
            # concrete values at trace time; cache them un-wrapped
            return jax.jit(prog) if spec.jittable else prog

        return self.get_or_build(key, build)

    def batch_program(self, variant: str, call_shape: Tuple[int, int, int],
                      nb: int, dtype: str, interpret: bool,
                      options: Tuple = (), *, rb: int) -> Callable:
        """rb-lane chunk-kernel program: ``prog(img_b, mats) ->
        vol_b((rb,) + call_shape)`` where ``img_b`` stacks rb filtered
        projection chunks ``(rb, chunk, nw, nh)`` over ONE shared
        matrix chunk.

        The streaming service uses this to fold the SAME view chunk of
        rb concurrent scan sessions (same bucket ⇒ same geometry, same
        chunk grid, same rotation phase) with one dispatch. The leading
        ``vmap`` axis never reassociates a lane's reduction, so every
        session stays bit-identical to its solo fold — the same
        argument as :meth:`batch_scan_program`, one chunk at a time.
        Non-jittable kernels fall back to a stacked per-lane loop.
        """
        key = ("batch_kernel", variant, tuple(call_shape), int(nb),
               str(dtype), bool(interpret), tuple(options), int(rb))

        def build():
            spec = get_spec(variant)
            opts = spec.resolve_options(
                {**dict(options), "nb": int(nb), "interpret": bool(interpret)})
            shape = tuple(call_shape)
            fn = _with_precision(spec.fn, variant, dtype)
            one = lambda img, mat: fn(img, mat, shape, **opts)  # noqa: E731
            if spec.jittable:
                return jax.jit(jax.vmap(one, in_axes=(0, None)))
            return lambda img_b, mat: jnp.stack(
                [one(img_b[r], mat) for r in range(int(rb))])

        return self.get_or_build(key, build)

    def scan_program(self, variant: str, call_shape: Tuple[int, int, int],
                     nb: int, dtype: str, interpret: bool,
                     options: Tuple = (), *, n_chunks: int,
                     chunk_size: int) -> Callable:
        """Step-major megaprogram: ``prog(img_chunks, mat_chunks) ->
        vol_t(call_shape)`` where the inputs are the STACKED chunk axes
        ``(n_chunks, chunk_size, ...)``.

        One ``lax.scan`` carries the call-shape accumulator across all
        projection chunks on device — the executor emits it to host once
        per step instead of once per (step, chunk). The key gains the
        chunk-loop shape, so interior tiles of equal shape still compile
        exactly once per (variant, call_shape, chunk grid).
        """
        key = ("scan", variant, tuple(call_shape), int(nb), str(dtype),
               bool(interpret), tuple(options), int(n_chunks),
               int(chunk_size))

        def build():
            spec = get_spec(variant)
            opts = spec.resolve_options(
                {**dict(options), "nb": int(nb), "interpret": bool(interpret)})
            shape = tuple(call_shape)
            fn = _with_precision(spec.fn, variant, dtype)
            if spec.jittable:
                def prog(img_s, mat_s):
                    def body(acc, xs):
                        img_c, mat_c = xs
                        return acc + fn(img_c, mat_c, shape, **opts), None
                    acc, _ = jax.lax.scan(
                        body, jnp.zeros(shape, jnp.float32), (img_s, mat_s))
                    return acc
                return jax.jit(prog)

            # non-jittable kernels (banded_pl reads concrete matrix
            # values at trace time) cannot sit under lax.scan: fall back
            # to a python chunk loop with a DONATED device accumulator —
            # still device-resident, still one host crossing per step.
            def prog(img_s, mat_s):
                acc = None
                for c in range(int(n_chunks)):
                    part = fn(img_s[c], mat_s[c], shape, **opts)
                    acc = part if acc is None else _acc_add(acc, part)
                return acc
            return prog

        return self.get_or_build(key, build)

    def batch_scan_program(self, variant: str,
                           call_shape: Tuple[int, int, int],
                           nb: int, dtype: str, interpret: bool,
                           options: Tuple = (), *, n_chunks: int,
                           chunk_size: int, rb: int) -> Callable:
        """rb-batched step-major megaprogram: ``prog(img_b, mat_s) ->
        vol_b((rb,) + call_shape)`` where ``img_b`` stacks ``rb``
        requests' scan grids ``(rb, n_chunks, chunk_size, ...)`` and
        ``mat_s`` is the SHARED chunk-stacked matrix grid (same-bucket
        requests share the geometry, so one matrix stack serves all
        lanes).

        One leading ``vmap`` axis over projections + accumulators turns
        k queued reconstructions into ONE dispatch of the same scanned
        program — per-lane float-op order is untouched, so each lane is
        bit-identical to the single-request scan program (asserted in
        tests/test_batching.py). Non-jittable kernels (banded_pl) fall
        back to a stacked python loop over lanes with the donated-carry
        chunk walk preserved: still one executor call per step, the
        dispatch amortization just stops at the program boundary.
        """
        key = ("batch_scan", variant, tuple(call_shape), int(nb),
               str(dtype), bool(interpret), tuple(options), int(n_chunks),
               int(chunk_size), int(rb))

        def build():
            spec = get_spec(variant)
            opts = spec.resolve_options(
                {**dict(options), "nb": int(nb), "interpret": bool(interpret)})
            shape = tuple(call_shape)
            fn = _with_precision(spec.fn, variant, dtype)
            if spec.jittable:
                def one(img_s, mat_s):
                    def body(acc, xs):
                        img_c, mat_c = xs
                        return acc + fn(img_c, mat_c, shape, **opts), None
                    acc, _ = jax.lax.scan(
                        body, jnp.zeros(shape, jnp.float32), (img_s, mat_s))
                    return acc
                return jax.jit(jax.vmap(one, in_axes=(0, None)))

            def prog(img_b, mat_s):
                lanes = []
                for r in range(int(rb)):
                    acc = None
                    for c in range(int(n_chunks)):
                        part = fn(img_b[r, c], mat_s[c], shape, **opts)
                        acc = part if acc is None else _acc_add(acc, part)
                    lanes.append(acc)
                return jnp.stack(lanes)
            return prog

        return self.get_or_build(key, build)

    def fleet_program(self, variant: str, call_shape: Tuple[int, int, int],
                      nb: int, dtype: str, interpret: bool,
                      options: Tuple = (), *, n_chunks: int,
                      chunk_size: int) -> Callable:
        """Fleet step program: ``prog(img_s, mat_s, origin) ->
        vol_t(call_shape)`` — the scan megaprogram with the step origin
        as a TRACED call-time argument (``core.distributed
        .make_fleet_bp``), so one key serves every same-shape step on
        every device: work stealing and failover never add a key.
        """
        key = ("fleet", variant, tuple(call_shape), int(nb), str(dtype),
               bool(interpret), tuple(options), int(n_chunks),
               int(chunk_size))

        def build():
            from repro.core.distributed import make_fleet_bp
            return make_fleet_bp(
                variant, tuple(call_shape), nb=int(nb),
                n_chunks=int(n_chunks), chunk_size=int(chunk_size),
                options=tuple(options), interpret=bool(interpret))

        return self.get_or_build(key, build)

    def batch_fleet_program(self, variant: str,
                            call_shape: Tuple[int, int, int],
                            nb: int, dtype: str, interpret: bool,
                            options: Tuple = (), *, n_chunks: int,
                            chunk_size: int, rb: int) -> Callable:
        """rb-batched fleet step program: ``prog(img_b, mat_s, origin)
        -> vol_b((rb,) + call_shape)`` — :meth:`fleet_program`'s
        origin-traced scan with the leading request axis of
        :meth:`batch_scan_program`, so a fleet drains k batched
        requests' step schedule with one dispatch per (device, step)
        and stealing/failover still never recompile."""
        key = ("batch_fleet", variant, tuple(call_shape), int(nb),
               str(dtype), bool(interpret), tuple(options), int(n_chunks),
               int(chunk_size), int(rb))

        def build():
            from repro.core.distributed import make_fleet_bp
            return make_fleet_bp(
                variant, tuple(call_shape), nb=int(nb),
                n_chunks=int(n_chunks), chunk_size=int(chunk_size),
                options=tuple(options), interpret=bool(interpret),
                rb=int(rb))

        return self.get_or_build(key, build)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "programs": len(self._programs)}

    def clear(self) -> None:
        with self._lock:
            self._programs.clear()
            self.hits = self.misses = 0


_DEFAULT_CACHE = ProgramCache()


def default_program_cache() -> ProgramCache:
    """The process-wide cache shared by every executor (and façade)."""
    return _DEFAULT_CACHE


# --------------------------------------------------------------------------
# Execute: placement primitives
# --------------------------------------------------------------------------

# out="device" placement: donated dynamic read-add-update so each tile
# accumulates into the volume buffer in place — NOT vol.at[].add outside
# jit, which would copy the full volume once per tile.
@functools.partial(jax.jit, donate_argnums=0)
def _place_device_add(vol, tile, idx):
    org = (idx[0], idx[1], idx[2])
    cur = jax.lax.dynamic_slice(vol, org, tile.shape)
    return jax.lax.dynamic_update_slice(vol, cur + tile, org)


# donated-carry accumulation for the non-jittable scan fallback: the
# accumulator buffer is reused across chunk iterations instead of
# allocating a fresh volume per chunk.
@functools.partial(jax.jit, donate_argnums=0)
def _acc_add(acc, part):
    return acc + part


def _pad_rows(img: jnp.ndarray, mat: jnp.ndarray, n_rows: int):
    """Pad projections + matrices to ``n_rows`` leading rows — zero
    images (back-projection is linear: they add nothing) paired with
    :func:`_pad_mats`' repeated-last-matrix padding."""
    pad = int(n_rows) - img.shape[0]
    if pad <= 0:
        return img, mat
    img = jnp.concatenate(
        [img, jnp.zeros((pad,) + img.shape[1:], img.dtype)], axis=0)
    return img, _pad_mats(mat, int(n_rows))


def _stack_chunks(img_p: jnp.ndarray, mat_p: jnp.ndarray,
                  sched: StepMajorSchedule):
    """Reshape padded projections to the scan grid ``(n_chunks,
    chunk_size, ...)``, zero-padding the tail chunk's slack rows."""
    img_p, mat_p = _pad_rows(img_p, mat_p, sched.n_scan)
    img_s = img_p.reshape((sched.n_chunks, sched.chunk_size)
                          + img_p.shape[1:])
    mat_s = mat_p.reshape(sched.n_chunks, sched.chunk_size, 3, 4)
    return img_s, mat_s


class _AsyncFlushQueue:
    """Depth-bounded device->host flush pipeline (the "real streams"
    seam): step N's accumulator flush overlaps step N+1's dispatch.

    The executor enqueues one step's ``(volume slices, device piece)``
    writes right after dispatching that step's program and moves on; a
    single flusher thread dequeues in FIFO order, calls
    ``jax.block_until_ready`` — the ONLY place the pipeline blocks on
    the device — and accumulates the ``np.asarray`` copy into the host
    volume. ``depth`` bounds how many steps' device outputs may be live
    at once (double-buffered by default: the scanning step plus the
    flushing one); a full queue applies backpressure to the dispatcher.
    Exactly one thread writes the host volume, and steps write disjoint
    regions, so the result is bit-identical to the sequential flush.

    Writes are ``(slices, device piece)`` pairs into the constructor's
    volume, or ``(target volume, slices, piece)`` triples — the
    rb-batched step walk flushes one step's output into rb DIFFERENT
    per-request volumes through one queue, preserving the single-writer
    / FIFO discipline across all of them.
    """

    def __init__(self, vol: Optional[np.ndarray], depth: int = 2):
        self._vol = vol
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, int(depth)))
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._drain, name="recon-flush", daemon=True)
        self._thread.start()

    def _drain(self) -> None:
        while True:
            writes = self._q.get()
            try:
                if writes is None:
                    return
                if self._error is None:   # keep consuming after failure
                    with telemetry.span("flush", n_writes=len(writes)):
                        for w in writes:
                            tgt, sl, piece = (w if len(w) == 3
                                              else (self._vol, w[0], w[1]))
                            piece = jax.block_until_ready(piece)
                            tgt[sl] += np.asarray(piece)
            except BaseException as exc:   # surfaced at put()/close()
                self._error = exc
            finally:
                self._q.task_done()

    def put(self, writes) -> None:
        """Enqueue one step's writes; blocks only when ``depth`` steps
        are already in flight (backpressure, not device sync)."""
        if self._error is not None:
            raise self._error
        self._q.put(writes)

    def close(self) -> None:
        """Drain the queue, join the flusher, re-raise any failure."""
        self._q.put(None)
        self._thread.join()
        if self._error is not None:
            raise self._error


# 8 fused multiply-adds per voxel-view update — the same
# "ct-backproject" cost model as launch/roofline.py (model_flops =
# 8 * vol^3 * n_views), applied per tile step so trace annotations and
# the capacity model tell one arithmetic-intensity story.
_FLOPS_PER_UPDATE = 8.0


def _step_roofline(plan: ReconPlan, step: PlanStep, n_views: int) -> dict:
    """Span args for one step dispatch: modeled bytes moved (the
    planner's tile working-set model, ``core.tiling.
    tile_working_set_bytes``) and FLOPs (``_FLOPS_PER_UPDATE`` per
    voxel-view update over :func:`~repro.runtime.planner.step_cost`
    voxels), plus the resulting arithmetic intensity."""
    ws = int(tile_working_set_bytes(step.call_shape, plan.det_shape_wh,
                                    nb=plan.nb))
    flops = _FLOPS_PER_UPDATE * step_cost(step) * int(n_views)
    return {"bytes": ws, "flops": flops,
            "ai_flop_per_byte": round(flops / max(ws, 1), 3),
            "voxels": int(step_cost(step)), "n_views": int(n_views)}


def _pad_mats(mats: jnp.ndarray, n_pad: int) -> jnp.ndarray:
    """Pad (np, 3, 4) matrices to n_pad rows by repeating the last one
    (a valid geometry: no 1/z poles — pairs with zero-image padding)."""
    pad = int(n_pad) - mats.shape[0]
    if pad <= 0:
        return mats
    return jnp.concatenate(
        [mats, jnp.broadcast_to(mats[-1:], (pad, 3, 4))], axis=0)


class _FilteredChunkProducer:
    """Filter-once projection-chunk source for ``reconstruct``.

    Memoizes the filtered + transposed chunks of ``plan.chunks`` so the
    filtering cost is paid once per chunk regardless of how many
    consumers (tile steps) read it, and exposes ``prefetch`` so the
    NEXT chunk's filtering is dispatched — asynchronously, under JAX's
    lazy execution — while the current chunk's programs and host flush
    run: PR 2's output-side double buffering extended to the input
    side. ``stacked`` hoists the whole producer for the step-major
    scan: every chunk filtered exactly once, stacked onto the scan
    grid. ``drop`` releases a consumed chunk in chunk-major streaming
    so device residency stays two-chunk-bounded (the consumed chunk +
    the prefetched next one).
    """

    def __init__(self, ex: "PlanExecutor", projections: jnp.ndarray,
                 mat_p: jnp.ndarray):
        self._ex = ex
        self._projections = projections
        self._mat_p = mat_p
        self._chunks = ex.plan.chunks
        self._memo: Dict[int, tuple] = {}

    def get(self, c: int):
        """Filtered ``(img_c, mat_c)`` of chunk ``c`` (memoized)."""
        if c not in self._memo:
            s0, s1 = self._chunks[c]
            with telemetry.span("filter.chunk", chunk=c,
                                n_views=int(s1 - s0)):
                self._memo[c] = self._ex._chunk_inputs(
                    self._projections, self._mat_p, s0, s1)
        return self._memo[c]

    def prefetch(self, c: int) -> None:
        """Dispatch chunk ``c``'s filtering now (no-op out of range)."""
        if 0 <= c < len(self._chunks):
            self.get(c)

    def drop(self, c: int) -> None:
        self._memo.pop(c, None)

    def stacked(self, sched: StepMajorSchedule):
        """All chunks, filtered once each, as the scan grid stack."""
        imgs, mats = [], []
        for c in range(sched.n_chunks):
            img_c, mat_c = self.get(c)
            self.drop(c)   # the stack is the only remaining consumer
            # tail chunk -> uniform scan slot
            img_c, mat_c = _pad_rows(img_c, mat_c, sched.chunk_size)
            imgs.append(img_c)
            mats.append(mat_c)
        return jnp.stack(imgs), jnp.stack(mats)


# --------------------------------------------------------------------------
# Fleet execution: multi-device step-schedule sharding
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """How a :class:`PlanExecutor` spreads a step-major plan across
    devices (``execute_fleet``).

    devices : explicit jax devices to use; ``None`` resolves to all
        local devices at run time (``jax.local_devices()`` — under
        ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` that is
        the N forced host devices, the no-hardware CI lane).
    max_retries_per_step : failover budget PER STEP INDEX — the
        :class:`~repro.runtime.fault_tolerance.FaultTolerantLoop` retry
        contract: counted per index, never reset by successes elsewhere.
        A step that fails more than this many times across the whole
        fleet aborts the run (a poison step; skipping would corrupt the
        volume, unlike a training batch).
    device_strikes : step failures charged to one device before it is
        RETIRED: its worker exits, its unclaimed queue is drained by the
        surviving devices through the normal stealing path, and its
        already-failed steps re-run elsewhere (disjoint output boxes ⇒
        idempotent re-execution).
    straggler_window / straggler_ratio : the
        :class:`~repro.runtime.straggler.FleetStragglerBoard` knobs — a
        device whose recent median step time exceeds ``ratio`` x the
        fleet median is flagged, and idle devices steal from flagged
        queues first.
    step_hook : test seam called as ``hook(device_index, step_index)``
        before a step's program runs — raise to inject a device fault,
        sleep to simulate a straggler. ``None`` in production.
    """

    devices: Optional[Tuple] = None
    max_retries_per_step: int = 2
    device_strikes: int = 2
    straggler_window: int = 32
    straggler_ratio: float = 1.5
    step_hook: Optional[Callable[[int, int], None]] = None

    def resolve_devices(self) -> Tuple:
        return (tuple(self.devices) if self.devices
                else tuple(jax.local_devices()))


@dataclasses.dataclass(frozen=True)
class FleetReport(telemetry.EmitMixin):
    """What one ``execute_fleet`` run did: per-device completion counts,
    how many steps migrated (``stolen``), how many re-ran after a
    failure (``retried``), which devices were retired (``dead_devices``)
    and which the straggler board flagged (``flagged_devices``).
    ``as_dict()``/``emit()`` follow the shared
    :class:`~repro.runtime.telemetry.EmitMixin` report contract."""

    n_devices: int
    n_steps: int
    steps_by_device: Tuple[int, ...]
    stolen: int
    retried: int
    dead_devices: Tuple[int, ...]
    flagged_devices: Tuple[int, ...]


def as_fleet_config(devices, *, max_retries_per_step: int = 2,
                    step_hook=None) -> Optional[FleetConfig]:
    """Normalize a façade/service ``devices=`` argument.

    ``None`` -> no fleet (single-device walks); ``"all"`` -> every local
    device, resolved lazily at run time; an ``int`` N -> the first N of
    ``jax.local_devices()`` (resolved now); a sequence of jax devices ->
    exactly those; an existing :class:`FleetConfig` passes through.
    """
    if devices is None:
        return None
    if isinstance(devices, FleetConfig):
        return devices
    if devices == "all":
        devs = None
    elif isinstance(devices, int):
        local = jax.local_devices()
        if not 1 <= devices <= len(local):
            raise ValueError(
                f"devices={devices} but {len(local)} local devices "
                f"are available")
        devs = tuple(local[:devices])
    else:
        devs = tuple(devices)
        if not devs:
            raise ValueError("devices sequence must be non-empty")
    return FleetConfig(devices=devs,
                       max_retries_per_step=max_retries_per_step,
                       step_hook=step_hook)


class PlanExecutor:
    """Executes a :class:`ReconPlan` against projection data.

    One executor serves any number of calls; programs come from the
    (shared) :class:`ProgramCache`, so repeated calls and same-shape
    tiles never retrace. The loop ORDER follows ``plan.schedule``:
    step-major scanned device accumulators by default, the chunk-major
    PR-2 loop on request.

    ``pipeline`` selects the step-major flush discipline: ``"sync"``
    (the PR-3 in-thread double buffer — flush step N-1 after
    dispatching step N) or ``"async"`` (a :class:`_AsyncFlushQueue`
    flusher thread: step N's device->host accumulator copy overlaps
    step N+1's scan dispatch, ``jax.block_until_ready`` only at
    dequeue). Async only changes WHEN host adds happen, never their
    FIFO order, so output is bit-identical; it engages on host-placed
    step-major walks and is a no-op elsewhere. ``pipeline_depth``
    bounds the in-flight step outputs (2 = double buffered).
    """

    def __init__(self, geom: CTGeometry, plan: ReconPlan,
                 cache: Optional[ProgramCache] = None, *,
                 pipeline: str = "sync", pipeline_depth: int = 2,
                 tuned=None, fleet: Optional[FleetConfig] = None):
        if pipeline not in ("sync", "async"):
            raise ValueError(
                f"pipeline must be 'sync' or 'async', got {pipeline!r}")
        if fleet is not None:
            if plan.schedule != "step":
                raise ValueError(
                    "fleet execution shards the STEP schedule "
                    "(disjoint output boxes are the shard axis); plan "
                    f"with schedule='step', got {plan.schedule!r}")
            if plan.out != "host":
                raise ValueError(
                    "fleet execution accumulates per-device step "
                    "outputs into a host volume; plan with out='host', "
                    f"got {plan.out!r}")
            if plan.precision != "f32":
                raise ValueError(
                    "fleet execution does not support the reduced-"
                    "precision data path yet (the origin-traced fleet "
                    "programs are f32-only); plan with precision='f32', "
                    f"got {plan.precision!r}")
        self.geom = geom
        self.plan = plan
        self._dtype = _plan_dtype(plan)
        self.cache = cache if cache is not None else default_program_cache()
        self.pipeline = pipeline
        self.pipeline_depth = int(pipeline_depth)
        self.tuned = tuned    # TunedConfig provenance, None = heuristic
        self.fleet = fleet    # FleetConfig, None = single-device walks
        self.last_fleet_report: Optional[FleetReport] = None
        self._fleet_lock = threading.Lock()
        # accumulated across runs (the serving layer snapshots these —
        # per-run reports on a shared bucket executor would race)
        self.fleet_totals: Dict[str, int] = {
            "runs": 0, "devices": 0, "stolen": 0, "retried": 0,
            "dead_devices": 0}

    @classmethod
    def from_config(cls, geom: CTGeometry, config,
                    cache: Optional[ProgramCache] = None) -> "PlanExecutor":
        """Executor for a resolved ``runtime.autotune.TunedConfig``: the
        config plans itself (pure) and carries the executor-level knobs
        (``pipeline``/``pipeline_depth``) the plan cannot."""
        return cls(geom, config.build_plan(geom), cache=cache,
                   pipeline=config.pipeline,
                   pipeline_depth=config.pipeline_depth, tuned=config)

    # ---- compile-stage access -------------------------------------------

    def _program(self, variant: str, call_shape) -> Callable:
        return self.cache.program(variant, call_shape, self.plan.nb,
                                  self._dtype, self.plan.interpret,
                                  self.plan.options)

    def _scan_program(self, variant: str, call_shape,
                      sched: StepMajorSchedule) -> Callable:
        return self.cache.scan_program(variant, call_shape, self.plan.nb,
                                       self._dtype, self.plan.interpret,
                                       self.plan.options,
                                       n_chunks=sched.n_chunks,
                                       chunk_size=sched.chunk_size)

    def _fleet_program(self, variant: str, call_shape,
                       sched: StepMajorSchedule) -> Callable:
        return self.cache.fleet_program(variant, call_shape, self.plan.nb,
                                        self._dtype, self.plan.interpret,
                                        self.plan.options,
                                        n_chunks=sched.n_chunks,
                                        chunk_size=sched.chunk_size)

    def _batch_scan_program(self, variant: str, call_shape,
                            sched: StepMajorSchedule, rb: int) -> Callable:
        return self.cache.batch_scan_program(
            variant, call_shape, self.plan.nb, self._dtype,
            self.plan.interpret, self.plan.options,
            n_chunks=sched.n_chunks, chunk_size=sched.chunk_size, rb=rb)

    def _batch_fleet_program(self, variant: str, call_shape,
                             sched: StepMajorSchedule, rb: int) -> Callable:
        return self.cache.batch_fleet_program(
            variant, call_shape, self.plan.nb, self._dtype,
            self.plan.interpret, self.plan.options,
            n_chunks=sched.n_chunks, chunk_size=sched.chunk_size, rb=rb)

    def warm(self) -> Dict[str, int]:
        """Compile every distinct program the plan needs; return stats."""
        if self.fleet is not None:
            # one origin-traced program per (variant, shape) serves the
            # whole fleet; XLA specializes per device on first dispatch
            sched = self.plan.step_major
            for variant, shape in self.plan.program_keys:
                self._fleet_program(variant, shape, sched)
        elif self.plan.schedule == "step":
            sched = self.plan.step_major
            for variant, shape in self.plan.program_keys:
                self._scan_program(variant, shape, sched)
        else:
            for variant, shape in self.plan.program_keys:
                self._program(variant, shape)
        return self.cache.stats()

    @property
    def supports_request_batching(self) -> bool:
        """Whether :meth:`execute_batch` can coalesce k requests into
        one dispatch stream here. True for step-major plans (the scan
        megaprogram takes the leading ``vmap`` lane); chunk-major plans
        fall back to sequential execution in the service."""
        return self.plan.schedule == "step"

    def warm_batch(self, rb: int) -> Dict[str, int]:
        """Compile the rb-batched program per (variant, shape) so the
        first formed batch of ``rb`` requests compiles nothing. No-op
        for plans that don't support request batching."""
        if rb < 2 or not self.supports_request_batching:
            return self.cache.stats()
        sched = self.plan.step_major
        for variant, shape in self.plan.program_keys:
            if self.fleet is not None:
                self._batch_fleet_program(variant, shape, sched, rb)
            else:
                self._batch_scan_program(variant, shape, sched, rb)
        return self.cache.stats()

    # ---- execute-stage helpers ------------------------------------------

    def _alloc(self):
        shape = self.plan.vol_shape_xyz
        return (np.zeros(shape, np.float32) if self.plan.out == "host"
                else jnp.zeros(shape, jnp.float32))

    @staticmethod
    def _translated(mats: jnp.ndarray, step: PlanStep) -> jnp.ndarray:
        if (step.i0, step.j0, step.k_off) == (0, 0, 0):
            return mats
        return translate_matrices(mats, float(step.i0), float(step.j0),
                                  float(step.k_off))

    def _chunks_for(self, n_padded: int):
        """Chunk schedule for the ACTUAL (padded) projection count.

        ``backproject`` accepts any (np, nw, nh) input, not just
        ``geom.n_proj`` views (the plan's count): the plan contributes
        the streaming *policy* (chunk size, or all-at-once), the data
        contributes the extent."""
        plan = self.plan
        _, _, chunks = plan_proj_chunks(
            n_padded, plan.nb,
            plan.chunk_size if plan.streams_projections else None)
        return chunks

    def _single_full_call(self) -> bool:
        """One unpaired step covering the whole volume (the untiled plan)."""
        steps = self.plan.steps
        return (len(steps) == 1 and not steps[0].paired
                and steps[0].call_shape == self.plan.vol_shape_xyz
                and (steps[0].i0, steps[0].j0, steps[0].k_off) == (0, 0, 0))

    @staticmethod
    def _step_writes(step: PlanStep, out: jnp.ndarray):
        """(volume slices, device piece) pairs of one step's output."""
        isl = slice(step.i0, step.i0 + step.ni)
        jsl = slice(step.j0, step.j0 + step.nj)
        return tuple(((isl, jsl, slice(w.k0, w.k0 + w.nk)),
                      out[..., w.lo:w.hi]) for w in step.writes)

    def _open_flush(self, vol) -> Optional[_AsyncFlushQueue]:
        """The async flusher when this walk pipelines host flushes
        (``pipeline="async"`` + host placement), else None."""
        if self.pipeline == "async" and self.plan.out == "host":
            return _AsyncFlushQueue(vol, depth=self.pipeline_depth)
        return None

    def _step_span(self, step: PlanStep, n_views: int, **extra):
        """Telemetry span for one step dispatch, roofline-annotated
        (bytes / FLOPs / arithmetic intensity — the args are only
        computed when tracing is live)."""
        sp = telemetry.span("step.dispatch", xla=True)
        if sp.live:
            sp.set(variant=step.variant, call_shape=list(step.call_shape),
                   **_step_roofline(self.plan, step, n_views), **extra)
        return sp

    def _backproject_chunk(self, vol, img_c: jnp.ndarray,
                           mat_c: jnp.ndarray,
                           flush: Optional[_AsyncFlushQueue] = None):
        """Chunk-major: accumulate ONE projection chunk, all steps.

        ``flush`` (an open :class:`_AsyncFlushQueue` spanning the whole
        chunk loop) moves the host adds onto the flusher thread; enqueue
        order equals the sequential flush order, and float addition is
        performed in that same order, so output stays bit-identical.
        """
        plan = self.plan
        host = plan.out == "host"
        pending = ()   # previous step's (slices, device piece) writes
        n_views = int(img_c.shape[0])
        for step in plan.steps:
            prog = self._program(step.variant, step.call_shape)
            with self._step_span(step, n_views, schedule="chunk"):
                out = prog(img_c, self._translated(mat_c, step))
            cur = self._step_writes(step, out)
            if not host:
                for (i_s, j_s, k_s), piece in cur:
                    idx = jnp.asarray([i_s.start, j_s.start, k_s.start],
                                      jnp.int32)
                    vol = _place_device_add(vol, piece, idx)
            elif flush is not None:
                flush.put(cur)
            else:
                # double buffer: flush step n-1's device->host copies
                # only after step n's programs are dispatched, so the
                # copy overlaps compute (async dispatch)
                for sl, piece in pending:
                    vol[sl] += np.asarray(piece)
                pending = cur
        for sl, piece in pending:
            vol[sl] += np.asarray(piece)
        return vol

    def _execute_step_major(self, vol, img_s: jnp.ndarray,
                            mat_s: jnp.ndarray,
                            sched: StepMajorSchedule):
        """Step-major: per step, ONE scanned device-resident accumulator
        across all chunks, ONE (double-buffered) host emission.

        ``img_s``/``mat_s`` are the stacked scan grids ``(n_chunks,
        chunk_size, ...)``. Total device->host volume traffic is O(vol)
        — each voxel crosses once — and dispatches are O(n_steps).
        Host flushes follow ``self.pipeline``: in-thread double buffer
        (``"sync"``) or the :class:`_AsyncFlushQueue` flusher thread
        (``"async"`` — the dispatcher never blocks on a copy).
        """
        plan = self.plan
        host = plan.out == "host"
        if host and self.pipeline == "async":
            flush = _AsyncFlushQueue(vol, depth=self.pipeline_depth)
            try:
                for work in sched.steps:
                    step = work.step
                    prog = self._scan_program(step.variant, step.call_shape,
                                              sched)
                    with self._step_span(step, sched.n_chunks *
                                         sched.chunk_size, schedule="step"):
                        out = prog(img_s, self._translated(mat_s, step))
                    flush.put(self._step_writes(step, out))
            finally:
                flush.close()
            return vol
        pending = ()
        for work in sched.steps:
            step = work.step
            prog = self._scan_program(step.variant, step.call_shape, sched)
            with self._step_span(step, sched.n_chunks * sched.chunk_size,
                                 schedule="step"):
                out = prog(img_s, self._translated(mat_s, step))
            cur = self._step_writes(step, out)
            if host:
                for sl, piece in pending:
                    vol[sl] += np.asarray(piece)
                pending = cur
            else:
                for (i_s, j_s, k_s), piece in cur:
                    idx = jnp.asarray([i_s.start, j_s.start, k_s.start],
                                      jnp.int32)
                    vol = _place_device_add(vol, piece, idx)
        for sl, piece in pending:
            vol[sl] += np.asarray(piece)
        return vol

    def _execute_step_major_batch(self, vols, img_b: jnp.ndarray,
                                  mat_s: jnp.ndarray,
                                  sched: StepMajorSchedule):
        """rb-batched step-major walk: per step, ONE dispatch of the
        vmapped scan megaprogram fills this step's box in ALL ``rb``
        per-request volumes.

        ``img_b`` stacks the rb requests' scan grids ``(rb, n_chunks,
        chunk_size, ...)``; ``mat_s`` is shared (same bucket == same
        geometry). Flush discipline mirrors :meth:`_execute_step_major`
        exactly — async flusher thread or in-thread double buffer —
        with each step's writes fanned out to the rb host volumes
        (the flusher's 3-tuple ``(target, slices, piece)`` form), so
        per-lane accumulation order equals the sequential walk and the
        result is bit-identical to rb separate runs.
        """
        plan = self.plan
        host = plan.out == "host"
        rb = len(vols)

        def fanout(step, out_b):
            return tuple((vols[r], sl, piece)
                         for r in range(rb)
                         for sl, piece in self._step_writes(step, out_b[r]))

        if host and self.pipeline == "async":
            flush = _AsyncFlushQueue(None, depth=self.pipeline_depth)
            try:
                for work in sched.steps:
                    step = work.step
                    prog = self._batch_scan_program(
                        step.variant, step.call_shape, sched, rb)
                    with self._step_span(step, sched.n_chunks *
                                         sched.chunk_size, schedule="step",
                                         rb=rb):
                        out = prog(img_b, self._translated(mat_s, step))
                    flush.put(fanout(step, out))
            finally:
                flush.close()
            return vols
        pending = ()
        for work in sched.steps:
            step = work.step
            prog = self._batch_scan_program(step.variant, step.call_shape,
                                            sched, rb)
            with self._step_span(step, sched.n_chunks * sched.chunk_size,
                                 schedule="step", rb=rb):
                out = prog(img_b, self._translated(mat_s, step))
            if host:
                for tgt, sl, piece in pending:
                    tgt[sl] += np.asarray(piece)
                pending = fanout(step, out)
            else:
                for r in range(rb):
                    for (i_s, j_s, k_s), piece in self._step_writes(
                            step, out[r]):
                        idx = jnp.asarray(
                            [i_s.start, j_s.start, k_s.start], jnp.int32)
                        vols[r] = _place_device_add(vols[r], piece, idx)
        for tgt, sl, piece in pending:
            tgt[sl] += np.asarray(piece)
        return vols

    def execute_fleet(self, vol, img_s: jnp.ndarray,
                      mat_s: jnp.ndarray, sched: StepMajorSchedule, *,
                      fleet: Optional[FleetConfig] = None) -> np.ndarray:
        """Shard a step-major schedule across a device fleet.

        The step list is partitioned into per-device work queues
        (``runtime.planner.partition_steps`` — LPT-balanced on modeled
        voxel work); the filtered chunk stack is replicated onto each
        device that takes work (lazily — an idle spare pays nothing),
        and one dispatcher thread per device drains its queue through
        the shared origin-traced fleet program
        (``ProgramCache.fleet_program``). Step outputs land in the host
        volume's disjoint boxes, so completion order is irrelevant and
        the result equals the single-device step-major walk.

        **Work stealing**: an idle device first drains the fleet retry
        queue, then steals from the tail of another device's queue —
        preferring devices the :class:`FleetStragglerBoard` has flagged
        as slow, so a straggler's unclaimed steps migrate first.

        **Failover**: a failed step is requeued fleet-wide and re-run
        on whichever device takes it — re-execution is idempotent
        (disjoint, not-yet-flushed output). Failures are budgeted PER
        STEP INDEX (``max_retries_per_step`` — the FaultTolerantLoop
        contract); exceeding it raises (a poison step would corrupt the
        volume). A device accumulating ``device_strikes`` failures is
        retired and its remaining queue drains to the survivors.

        ``vol`` may be a LIST of rb host volumes (the batched path):
        ``img_s`` then carries a leading request axis and each step's
        batched output fans out to every lane's disjoint box — one
        dispatch per (device, step) serves all rb requests, and the
        stealing/failover machinery is untouched (a retried batched
        step re-runs all lanes; still idempotent, the writes were
        never flushed).
        """
        cfg = fleet if fleet is not None else (self.fleet or FleetConfig())
        vols = list(vol) if isinstance(vol, (list, tuple)) else None
        rb = len(vols) if vols is not None else None
        devices = cfg.resolve_devices()
        n_dev = len(devices)
        steps = tuple(w.step for w in sched.steps)
        n_steps = len(steps)
        if n_steps == 0:
            self._record_fleet(FleetReport(n_dev, 0, (0,) * n_dev,
                                           0, 0, (), ()))
            return vol
        fs = partition_steps(steps, n_dev)
        board = FleetStragglerBoard(n_dev, window=cfg.straggler_window,
                                    ratio=cfg.straggler_ratio)

        cond = threading.Condition()
        deques = [collections.deque(q) for q in fs.queues]
        retry: collections.deque = collections.deque()
        counts = {"outstanding": 0, "stolen": 0, "retried": 0, "done": 0}
        failures: collections.Counter = collections.Counter()  # per index
        strikes: collections.Counter = collections.Counter()   # per device
        dead: set = set()
        done_by_device = [0] * n_dev
        fatal: list = []                 # [(step index, exception)]
        flush_lock = threading.Lock()

        def take(d: int):
            """Next step index for device ``d`` (call under ``cond``):
            own queue in schedule order, then the fleet retry queue,
            then steal from the tail of the neediest victim — flagged
            (straggling) devices first, longest backlog next."""
            if deques[d]:
                return deques[d].popleft()
            if retry:
                return retry.popleft()
            flagged = set(board.flagged)
            victims = [v for v in range(n_dev) if v != d and deques[v]]
            if not victims:
                return None
            victims.sort(key=lambda v: (v not in flagged,
                                        -len(deques[v]), v))
            counts["stolen"] += 1
            telemetry.instant("fleet.steal", thief=d, victim=victims[0])
            return deques[victims[0]].pop()

        def worker(d: int) -> None:
            dev = devices[d]
            img_d = mat_d = None
            while True:
                with cond:
                    while True:
                        if fatal or d in dead:
                            return
                        idx = take(d)
                        if idx is not None:
                            counts["outstanding"] += 1
                            break
                        if counts["outstanding"] == 0 and not retry \
                                and not any(deques):
                            return      # fleet drained
                        cond.wait(0.05)
                step = steps[idx]
                t0 = time.perf_counter()
                try:
                    if cfg.step_hook is not None:
                        cfg.step_hook(d, idx)
                    if img_d is None:
                        # replicate the chunk stack onto this device
                        # once, lazily: a spare that never takes work
                        # never pays the copy
                        img_d = jax.device_put(img_s, dev)
                        mat_d = jax.device_put(mat_s, dev)
                    prog = (self._fleet_program(step.variant,
                                                step.call_shape, sched)
                            if rb is None else
                            self._batch_fleet_program(step.variant,
                                                      step.call_shape,
                                                      sched, rb))
                    origin = jax.device_put(
                        jnp.asarray([step.i0, step.j0, step.k_off],
                                    jnp.float32), dev)
                    with self._step_span(step, sched.n_chunks *
                                         sched.chunk_size, schedule="fleet",
                                         device=d, step_index=idx):
                        out = jax.block_until_ready(
                            prog(img_d, mat_d, origin))
                except Exception as exc:  # noqa: BLE001 — any step fault
                    with cond:
                        counts["outstanding"] -= 1
                        failures[idx] += 1
                        strikes[d] += 1
                        if failures[idx] > cfg.max_retries_per_step:
                            fatal.append((idx, exc))
                        else:
                            retry.append(idx)
                            counts["retried"] += 1
                            telemetry.instant("fleet.failover", device=d,
                                              step_index=idx,
                                              retries=failures[idx])
                        if strikes[d] >= cfg.device_strikes:
                            dead.add(d)
                            telemetry.instant("fleet.retire", device=d,
                                              strikes=strikes[d])
                        cond.notify_all()
                    if fatal or d in dead:
                        return
                    continue
                dur = time.perf_counter() - t0
                # flush the step's disjoint writes; order across steps
                # is irrelevant (disjoint boxes into a zeroed volume)
                with flush_lock:
                    if rb is None:
                        for sl, piece in self._step_writes(step, out):
                            vol[sl] += np.asarray(piece)
                    else:
                        for r in range(rb):
                            for sl, piece in self._step_writes(step, out[r]):
                                vols[r][sl] += np.asarray(piece)
                board.record(d, idx, dur)
                with cond:
                    counts["outstanding"] -= 1
                    done_by_device[d] += 1
                    counts["done"] += 1
                    cond.notify_all()

        threads = [threading.Thread(target=worker, args=(d,),
                                    name=f"recon-fleet-{d}", daemon=True)
                   for d in range(n_dev)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if fatal:
            idx, exc = fatal[0]
            raise RuntimeError(
                f"fleet step {idx} failed more than "
                f"max_retries_per_step={cfg.max_retries_per_step} times "
                f"across devices — poison step, volume would be "
                f"incomplete") from exc
        if counts["done"] < n_steps:
            raise RuntimeError(
                f"fleet lost all devices with {n_steps - counts['done']} "
                f"of {n_steps} steps unfinished "
                f"(retired devices: {sorted(dead)})")
        self._record_fleet(FleetReport(
            n_devices=n_dev, n_steps=n_steps,
            steps_by_device=tuple(done_by_device),
            stolen=counts["stolen"], retried=counts["retried"],
            dead_devices=tuple(sorted(dead)),
            flagged_devices=board.flagged))
        return vol

    def _record_fleet(self, report: FleetReport) -> None:
        with self._fleet_lock:
            self.last_fleet_report = report
            t = self.fleet_totals
            t["runs"] += 1
            t["devices"] = report.n_devices
            t["stolen"] += report.stolen
            t["retried"] += report.retried
            t["dead_devices"] += len(report.dead_devices)

    # ---- full-volume drivers --------------------------------------------

    def _data_step_major(self, chunks) -> StepMajorSchedule:
        """Step-major schedule over a DATA-dependent chunk list (the
        plan contributes the steps, the input contributes the extent)."""
        return build_step_major(self.plan.steps, chunks,
                                chunks[0][1] - chunks[0][0])

    def backproject(self, img_t: jnp.ndarray, mats: jnp.ndarray):
        """Back-project pre-filtered transposed projections.

        img_t: (np, nw, nh); mats: (np, 3, 4). Returns vol_t (nx, ny, nz)
        — numpy when ``plan.out == "host"``. The tail batch is padded
        ONCE here (the plan's padded count); no per-call re-padding.
        """
        plan = self.plan
        img_p, mat_p = pad_projection_batch(img_t, mats, plan.nb)
        chunks = self._chunks_for(img_p.shape[0])
        if plan.schedule == "step":
            sched = self._data_step_major(chunks)
            img_s, mat_s = _stack_chunks(img_p, mat_p, sched)
            if self.fleet is not None:
                return self.execute_fleet(self._alloc(), img_s, mat_s,
                                          sched)
            if self._single_full_call() and plan.out == "device":
                step = plan.steps[0]
                prog = self._scan_program(step.variant, step.call_shape,
                                          sched)
                with self._step_span(step, sched.n_chunks *
                                     sched.chunk_size, schedule="step"):
                    return prog(img_s, mat_s)
            return self._execute_step_major(self._alloc(), img_s, mat_s,
                                            sched)
        if self._single_full_call() and plan.out == "device":
            step = plan.steps[0]
            prog = self._program(step.variant, step.call_shape)
            acc = None
            for s0, s1 in chunks:
                with self._step_span(step, int(s1 - s0), schedule="chunk"):
                    part = prog(img_p[s0:s1], mat_p[s0:s1])
                acc = part if acc is None else acc + part
            return acc
        vol = self._alloc()
        flush = self._open_flush(vol)
        try:
            for s0, s1 in chunks:
                vol = self._backproject_chunk(vol, img_p[s0:s1],
                                              mat_p[s0:s1], flush=flush)
        finally:
            if flush is not None:
                flush.close()
        return vol

    def backproject_tile(self, img_t: jnp.ndarray, mats: jnp.ndarray,
                         tile: TileSpec) -> jnp.ndarray:
        """Back-project one arbitrary sub-box; exact for every variant
        (slab-safe fallback resolved here for non-centered boxes)."""
        plan = self.plan
        name = resolve_tile_variant(plan.variant, tile, plan.vol_shape_xyz[2])
        img_p, mat_p = pad_projection_batch(img_t, mats, plan.nb)
        mat_p = translate_matrices(mat_p, float(tile.i0), float(tile.j0),
                                   float(tile.k0))
        chunks = self._chunks_for(img_p.shape[0])
        if plan.schedule == "step":
            sched = self._data_step_major(chunks)
            img_s, mat_s = _stack_chunks(img_p, mat_p, sched)
            return self._scan_program(name, tile.shape, sched)(img_s, mat_s)
        prog = self._program(name, tile.shape)
        acc = None
        for s0, s1 in chunks:
            part = prog(img_p[s0:s1], mat_p[s0:s1])
            acc = part if acc is None else acc + part
        return acc

    # ---- streamed filtered reconstruction --------------------------------

    def _chunk_inputs(self, projections: jnp.ndarray, mat_p: jnp.ndarray,
                      s0: int, s1: int):
        """Filter + transpose the raw rows of one padded chunk [s0, s1)."""
        plan = self.plan
        raw = projections[s0:min(s1, plan.n_proj)]
        img_c = bp.transpose_projections(
            fdk_filter_chunk(raw, self.geom, plan.n_proj))
        pad = (s1 - s0) - img_c.shape[0]
        if pad > 0:   # tail chunk: zero images pair with repeated matrices
            img_c = jnp.concatenate(
                [img_c, jnp.zeros((pad,) + img_c.shape[1:], img_c.dtype)],
                axis=0)
        return img_c, mat_p[s0:s1]

    def reconstruct(self, projections: jnp.ndarray):
        """Filtered FDK: (np, nh, nw) raw -> (nz, ny, nx) volume.

        Pre-weighting + ramp filtering run inside the projection-chunk
        pipeline, each chunk filtered exactly once (the hoisted
        :class:`_FilteredChunkProducer` feeds every tile step). Under
        the default step-major schedule the filtered chunk stack rides
        on device for the scan; ``schedule="chunk"`` keeps device
        residency two-chunk-bounded — the consumed chunk plus the
        prefetched next one, whose filtering is dispatched early so it
        overlaps the current chunk's compute. Returns numpy when
        ``plan.out == "host"`` (a free transposed view of the host
        accumulator).
        """
        plan = self.plan
        if projections.shape[0] != plan.n_proj:
            raise ValueError(
                f"reconstruct expects the geometry's full scan of "
                f"{plan.n_proj} projections (the FDK angular weighting "
                f"assumes it), got {projections.shape[0]}; for arbitrary "
                f"view subsets filter upstream and call backproject()")
        mat_p = _pad_mats(projection_matrices(self.geom),
                          plan.n_proj_padded)
        producer = _FilteredChunkProducer(self, projections, mat_p)
        if plan.schedule == "step":
            sched = plan.step_major
            img_s, mat_s = producer.stacked(sched)
            if self.fleet is not None:
                vol = self.execute_fleet(self._alloc(), img_s, mat_s,
                                         sched)
                return np.transpose(vol, (2, 1, 0))
            if self._single_full_call() and plan.out == "device":
                step = plan.steps[0]
                prog = self._scan_program(step.variant, step.call_shape,
                                          sched)
                with self._step_span(step, sched.n_chunks *
                                     sched.chunk_size, schedule="step"):
                    acc = prog(img_s, mat_s)
                return bp.volume_to_native(acc)
            vol = self._execute_step_major(self._alloc(), img_s, mat_s,
                                           sched)
        elif self._single_full_call() and plan.out == "device":
            step = plan.steps[0]
            prog = self._program(step.variant, step.call_shape)
            acc = None
            for c in range(len(plan.chunks)):
                img_c, mat_c = producer.get(c)
                producer.prefetch(c + 1)   # overlaps this chunk's compute
                with self._step_span(step, int(img_c.shape[0]),
                                     schedule="chunk"):
                    part = prog(img_c, mat_c)
                acc = part if acc is None else acc + part
                producer.drop(c)
            return bp.volume_to_native(acc)
        else:
            vol = self._alloc()
            flush = self._open_flush(vol)
            try:
                for c in range(len(plan.chunks)):
                    img_c, mat_c = producer.get(c)
                    producer.prefetch(c + 1)  # overlaps this chunk's compute
                    vol = self._backproject_chunk(vol, img_c, mat_c,
                                                  flush=flush)
                    producer.drop(c)
            finally:
                if flush is not None:
                    flush.close()
        if isinstance(vol, np.ndarray):
            # out="host": the accumulator may exceed device memory —
            # transpose is a free numpy view, never round-trip it
            return np.transpose(vol, (2, 1, 0))
        return bp.volume_to_native(vol)

    def open_stream(self, *, max_pending_chunks: int = 2,
                    on_ready: Optional[Callable[[int], None]] = None
                    ) -> "StreamingExecutor":
        """Open an online (push-driven) reconstruction on this executor.

        Projections are PUSHED as the scanner produces them
        (``push(views)``); each view chunk is back-projected the moment
        it completes, so reconstruction wall hides behind acquisition,
        and ``close()`` returns a volume bit-identical to
        :meth:`reconstruct` on the assembled set (same chunk partition
        ⇒ same reduction order). Requires a chunk-major plan — build it
        with ``ingest="stream"``. See :class:`StreamingExecutor`.
        """
        return StreamingExecutor(self, max_pending_chunks=max_pending_chunks,
                                 on_ready=on_ready)

    def execute_batch(self, projections_seq: Sequence[jnp.ndarray]):
        """Reconstruct k same-bucket requests with ONE dispatch stream.

        ``projections_seq`` holds k raw projection stacks, each exactly
        what :meth:`reconstruct` takes. Per-request filtering runs
        unchanged (identical code path, identical float-op order), the
        k filtered scan grids are stacked onto a leading request axis,
        and every step of the step-major walk dispatches the rb-batched
        megaprogram once instead of k times — cross-request batching
        amortizes the per-dispatch fixed cost the same way the in-batch
        ``nb`` axis amortizes per-projection cost (paper O5, lifted to
        the service tier). The matrix stack is shared across lanes
        (same bucket == same geometry + chunk grid). Output is a list
        of k volumes, each BIT-IDENTICAL to ``reconstruct`` on that
        request alone (``vmap`` adds an axis, it never reassociates
        the per-lane reductions — asserted in tests/test_batching.py).

        Requires a step-major plan (``supports_request_batching``);
        k == 1 just delegates to :meth:`reconstruct`.
        """
        reqs = list(projections_seq)
        k = len(reqs)
        if k == 0:
            return []
        if k == 1:
            return [self.reconstruct(reqs[0])]
        plan = self.plan
        if not self.supports_request_batching:
            raise ValueError(
                "execute_batch amortizes dispatch over the step-major "
                "scan; plan with schedule='step', got "
                f"{plan.schedule!r} (callers should check "
                "supports_request_batching and fall back to sequential "
                "reconstruct calls)")
        for p in reqs:
            if p.shape[0] != plan.n_proj:
                raise ValueError(
                    f"execute_batch expects {plan.n_proj} projections "
                    f"per request (the plan's full scan), got "
                    f"{p.shape[0]}")
        mat_p = _pad_mats(projection_matrices(self.geom),
                          plan.n_proj_padded)
        sched = plan.step_major
        lanes = []
        mat_s = None
        for p in reqs:
            img_s, mat_s = _FilteredChunkProducer(
                self, p, mat_p).stacked(sched)
            lanes.append(img_s)
        img_b = jnp.stack(lanes)
        del lanes
        if self.fleet is not None:
            vols = [self._alloc() for _ in range(k)]
            self.execute_fleet(vols, img_b, mat_s, sched)
            return [np.transpose(v, (2, 1, 0)) for v in vols]
        if self._single_full_call() and plan.out == "device":
            step = plan.steps[0]
            prog = self._batch_scan_program(step.variant, step.call_shape,
                                            sched, k)
            with self._step_span(step, sched.n_chunks * sched.chunk_size,
                                 schedule="step", rb=k):
                acc = prog(img_b, mat_s)
            return [bp.volume_to_native(acc[r]) for r in range(k)]
        vols = self._execute_step_major_batch(
            [self._alloc() for _ in range(k)], img_b, mat_s, sched)
        if isinstance(vols[0], np.ndarray):
            return [np.transpose(v, (2, 1, 0)) for v in vols]
        return [bp.volume_to_native(v) for v in vols]

    # ---- cluster composition (iFDK scale-out x tiles) --------------------

    def execute_distributed(self, img_t: jnp.ndarray, mats: jnp.ndarray,
                            mesh, *, dist_variant: str = "scan"):
        """Compose (i, j)-tiles with the data/model/pod mesh.

        Each full-Z tile is reconstructed by a shard_map program from
        ``core.distributed.make_distributed_bp`` with the tile origin as
        a call-time argument — ONE program per distinct tile shape,
        cached in the shared ProgramCache, so interior tiles and
        repeated calls reuse it. Projection chunks follow the plan's
        schedule. ``pipeline="async"`` streams here too: tile N's
        device->host copy (behind its ``block_until_ready``) runs on
        the flusher thread while tile N+1's shard_map programs are
        dispatched; tiles write disjoint regions of the zeroed volume,
        so the flusher's accumulate equals the sequential assignment.
        Returns vol_t (nx, ny, nz) on host.
        """
        from repro.core.distributed import make_distributed_bp

        plan = self.plan
        nb = plan.nb
        img_p, mat_p = pad_projection_batch(img_t, mats, nb)
        # the shard_map program consumes exactly-nb batches: chunk the
        # ACTUAL padded extent by nb (any view count streams through)
        _, _, chunks = plan_proj_chunks(img_p.shape[0], nb, nb)
        nx, ny, nz = plan.vol_shape_xyz
        ti, tj, _ = plan.tile_shape
        vol = np.zeros((nx, ny, nz), np.float32)
        flush = (_AsyncFlushQueue(vol, depth=self.pipeline_depth)
                 if self.pipeline == "async" else None)
        try:
            for tile in make_tiles((nx, ny, nz), (ti, tj, nz)):
                # geom and mesh are both hashable (frozen dataclass /
                # jax Mesh): keying on their VALUES makes equal setups
                # share the program and distinct geometries never
                # collide
                key = ("dist", dist_variant, tile.shape, nb, self.geom,
                       mesh)
                prog = self.cache.get_or_build(
                    key, lambda shape=tile.shape: make_distributed_bp(
                        self.geom, mesh, nb=nb, variant=dist_variant,
                        vol_shape_xyz=shape)[0])
                origin = jnp.asarray([tile.i0, tile.j0], jnp.float32)
                acc = None
                for s0, s1 in chunks:
                    part = prog(img_p[s0:s1], mat_p[s0:s1], origin)
                    acc = part if acc is None else acc + part
                if flush is not None:
                    # unpad on device (lazy slice); the zeroed volume
                    # makes the flusher's += equal the assignment
                    flush.put(((tile.slices, acc[:tile.ni, :tile.nj]),))
                else:
                    vol[tile.slices] = np.asarray(acc)[:tile.ni, :tile.nj]
        finally:
            if flush is not None:
                flush.close()
        return vol


# --------------------------------------------------------------------------
# Online (streaming) execution: fold view chunks as they arrive
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamReport(telemetry.EmitMixin):
    """What one closed stream did, in overlap terms.

    ``acquire_s`` is first-view to last-view arrival wall (the simulated
    or real scanner rotation), ``compute_s`` the total fold + finish
    busy wall, and ``tail_s`` the wall from LAST view arrival to the
    finished volume — the end-to-end latency a streaming deployment
    actually adds on top of acquisition. ``hidden_fraction`` is the
    share of compute that ran during acquisition instead of after it.
    """

    n_views: int
    n_chunks: int
    acquire_s: float
    compute_s: float
    tail_s: float

    @property
    def hidden_fraction(self) -> float:
        if self.compute_s <= 0.0:
            return 1.0
        return max(0.0, min(1.0, 1.0 - self.tail_s / self.compute_s))


class StreamingExecutor:
    """Online reconstruction: push projections as they arrive, fold each
    view chunk the moment it completes.

    The arrival-queue contract (docs/ARCHITECTURE.md Stage 8):

      * ``push(views, start=None)`` accepts one or more raw views;
        ``start`` defaults to sequential delivery, an explicit row index
        allows ANY arrival order within a chunk (each view lands in its
        chunk buffer by row, so within-chunk permutations cannot change
        the result). Each view may arrive exactly once.
      * Chunk ``c`` becomes *ready* when all of its raw rows are
        present. Ready chunks are folded strictly in chunk-index order
        — the order the offline chunk-major loop uses — which is the
        whole exactness argument: per step, the device-side running sum
        ``((p0 + p1) + p2)…`` over chunk parts is the same
        left-associated f32 reduction the offline loop performs, so
        ``close()`` is bit-identical to ``reconstruct`` on the
        assembled set.
      * At most ``max_pending_chunks`` ready-but-unfolded chunks may
        exist; a faster-than-compute producer blocks in ``push`` until
        the folder catches up (bounded buffering, real backpressure).
        ``max_pending_seen`` records the high-water mark.
      * ``close()`` requires every view; it then waits for the final
        fold + host flush and returns the volume. ``report`` carries
        the overlap metrics afterwards.

    Two drive modes: by default an internal folder thread consumes ready
    chunks (push-and-forget for callers); with ``on_ready=`` the
    completion of each chunk is reported to the callback instead and an
    EXTERNAL driver (the service's stream worker, which batches lanes
    across sessions) runs ``fold``/``filtered``/``accept_part``/
    ``chunk_done``. Folding overlaps acquisition three ways: device
    compute of chunk c, filtering of ready chunk c+1 (dispatched early,
    async under JAX), and the final per-step host flushes through
    :class:`_AsyncFlushQueue` when the executor pipelines.
    """

    def __init__(self, ex: PlanExecutor, *, max_pending_chunks: int = 2,
                 on_ready: Optional[Callable[[int], None]] = None):
        plan = ex.plan
        if plan.schedule != "chunk":
            raise ValueError(
                "streaming folds view chunks as they arrive (chunk-major "
                "by construction); plan with ingest='stream' (or "
                f"schedule='chunk'), got schedule={plan.schedule!r}")
        if ex.fleet is not None:
            raise ValueError(
                "streaming does not compose with fleet execution yet — "
                "open the stream on a single-device executor")
        if max_pending_chunks < 1:
            raise ValueError(
                f"max_pending_chunks must be >= 1, got {max_pending_chunks}")
        self._ex = ex
        self.geom = ex.geom
        self._plan = plan
        self._chunk_bounds = plan.chunks
        self._n_chunks = len(self._chunk_bounds)
        self._n_views = plan.n_proj
        self._chunk_size = plan.chunk_size
        self._max_pending = int(max_pending_chunks)
        self._on_ready = on_ready
        self._mat_p = _pad_mats(projection_matrices(ex.geom),
                                plan.n_proj_padded)

        self._cond = threading.Condition()
        self._buffers: Dict[int, np.ndarray] = {}
        self._missing = {c: self._raw_rows(c) for c in range(self._n_chunks)}
        self._seen = np.zeros(self._n_views, bool)
        self._filtered_memo: Dict[int, tuple] = {}
        self._complete: set = set()
        self._accs: list = [None] * len(plan.steps)
        self._next_fold = 0
        self._next_row = 0
        self._rows = 0
        self._ingest_closed = False
        self._error: Optional[BaseException] = None
        self._result = None
        self._finished = threading.Event()
        self.max_pending_seen = 0

        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._t_done: Optional[float] = None
        self._busy = 0.0

        if on_ready is None:
            self._thread = threading.Thread(
                target=self._drive, name="recon-stream-fold", daemon=True)
            self._thread.start()

    # ---- ingest side ------------------------------------------------------

    def _raw_rows(self, c: int) -> int:
        """Raw (un-padded) views chunk ``c`` must receive."""
        s0, s1 = self._chunk_bounds[c]
        return min(s1, self._n_views) - s0

    def _raise_if_failed(self) -> None:
        if self._error is not None:
            raise self._error

    def push(self, views, start: Optional[int] = None) -> None:
        """Deliver view rows ``[start, start + k)`` (default: the next
        sequential rows). Blocks only for backpressure — when
        ``max_pending_chunks`` ready chunks are already waiting."""
        views = np.asarray(views, np.float32)
        if views.ndim == 2:
            views = views[None]
        if views.ndim != 3 or views.shape[1:] != (self.geom.nh,
                                                  self.geom.nw):
            raise ValueError(
                f"push expects (k, nh, nw) or (nh, nw) views of detector "
                f"shape ({self.geom.nh}, {self.geom.nw}), got "
                f"{tuple(views.shape)}")
        k = views.shape[0]
        with self._cond:
            self._raise_if_failed()
            if self._ingest_closed:
                raise RuntimeError("push() after close()")
            first = self._next_row if start is None else int(start)
            if first < 0 or first + k > self._n_views:
                raise ValueError(
                    f"views [{first}, {first + k}) outside the stream's "
                    f"[0, {self._n_views}) scan")
            if self._t_first is None:
                self._t_first = time.perf_counter()
            for off in range(k):
                r = first + off
                if self._seen[r]:
                    raise ValueError(f"view {r} pushed twice")
                c = r // self._chunk_size
                s0, _ = self._chunk_bounds[c]
                buf = self._buffers.get(c)
                if buf is None:
                    buf = np.zeros(
                        (self._raw_rows(c), self.geom.nh, self.geom.nw),
                        np.float32)
                    self._buffers[c] = buf
                buf[r - s0] = views[off]
                self._seen[r] = True
                self._rows += 1
                self._missing[c] -= 1
                if self._missing[c] == 0:
                    self._admit_ready(c)
            self._next_row = max(self._next_row, first + k)
            self._t_last = time.perf_counter()
            telemetry.instant("stream.push", first=first, k=k,
                              rows=self._rows)
            self._cond.notify_all()

    def _admit_ready(self, c: int) -> None:
        """Mark chunk ``c`` ready (under ``_cond``): backpressure first,
        then hand it to the folder (thread or ``on_ready`` callback)."""
        while (len(self._complete) >= self._max_pending
               and self._error is None):
            self._cond.wait(0.05)
        self._raise_if_failed()
        self._complete.add(c)
        self.max_pending_seen = max(self.max_pending_seen,
                                    len(self._complete))
        self._cond.notify_all()
        if self._on_ready is not None:
            # deliver OUTSIDE the lock: the callback may enqueue into
            # structures with their own locks (the service's former)
            self._cond.release()
            try:
                self._on_ready(c)
            finally:
                self._cond.acquire()

    def close(self):
        """Finish the stream: requires every view delivered; waits for
        the remaining folds + final flush, returns the volume."""
        with self._cond:
            if self._ingest_closed:
                raise RuntimeError("stream already closed")
            self._ingest_closed = True
            if self._error is None and self._rows < self._n_views:
                self._error = RuntimeError(
                    f"stream closed after {self._rows} of "
                    f"{self._n_views} views — every view must be pushed "
                    f"before close()")
                self._finished.set()
            self._cond.notify_all()
        self._finished.wait()
        with self._cond:
            self._raise_if_failed()
            return self._result

    def fail(self, exc: BaseException) -> None:
        """Poison the stream (external drivers report fold errors here);
        ``push``/``close`` re-raise it."""
        with self._cond:
            if self._error is None:
                self._error = exc
            self._finished.set()
            self._cond.notify_all()

    # ---- fold side (internal thread, or the service's stream worker) -----

    @property
    def next_fold(self) -> int:
        """Index of the next chunk that must fold (order contract)."""
        with self._cond:
            return self._next_fold

    def _filter_pair(self, buf: np.ndarray, c: int):
        """Filter + transpose one ready chunk — the same float-op path
        as the offline :meth:`PlanExecutor._chunk_inputs`."""
        s0, s1 = self._chunk_bounds[c]
        img_c = bp.transpose_projections(
            fdk_filter_chunk(jnp.asarray(buf), self.geom,
                             self._plan.n_proj))
        pad = (s1 - s0) - img_c.shape[0]
        if pad > 0:   # tail chunk: zero images pair with repeated matrices
            img_c = jnp.concatenate(
                [img_c, jnp.zeros((pad,) + img_c.shape[1:], img_c.dtype)],
                axis=0)
        return img_c, self._mat_p[s0:s1]

    def filtered(self, c: int):
        """Filtered ``(img_c, mat_c)`` of ready chunk ``c``."""
        with self._cond:
            pair = self._filtered_memo.pop(c, None)
            if pair is not None:
                return pair
            if c not in self._complete:
                raise RuntimeError(f"chunk {c} is not ready")
            buf = self._buffers[c]
        return self._filter_pair(buf, c)

    def prefilter(self, c: int) -> None:
        """Dispatch chunk ``c``'s filtering now if it is ready (lazy
        under JAX's async dispatch — overlaps the current fold)."""
        with self._cond:
            if (c >= self._n_chunks or c in self._filtered_memo
                    or c not in self._complete):
                return
            buf = self._buffers[c]
        pair = self._filter_pair(buf, c)
        with self._cond:
            self._filtered_memo.setdefault(c, pair)

    def accept_part(self, i: int, part) -> None:
        """Fold one kernel output into step ``i``'s device accumulator
        (donated add — the chunk-index running sum)."""
        acc = self._accs[i]
        self._accs[i] = part if acc is None else _acc_add(acc, part)

    def add_busy(self, seconds: float) -> None:
        with self._cond:
            self._busy += max(0.0, seconds)

    def fold(self, c: int) -> None:
        """Fold ready chunk ``c`` into every step accumulator (single
        lane; the service's batched path drives ``filtered`` /
        ``accept_part`` / ``chunk_done`` itself)."""
        t0 = time.perf_counter()
        with telemetry.span("stream.fold", chunk=c):
            img_c, mat_c = self.filtered(c)
            self.prefilter(c + 1)   # overlap next chunk's filtering
            ex = self._ex
            for i, step in enumerate(self._plan.steps):
                prog = ex._program(step.variant, step.call_shape)
                self.accept_part(i, prog(img_c, ex._translated(mat_c, step)))
            self.chunk_done(c)
        self.add_busy(time.perf_counter() - t0)

    def chunk_done(self, c: int) -> None:
        """Retire folded chunk ``c``; the LAST chunk triggers the final
        per-step volume flush."""
        with self._cond:
            if c != self._next_fold:
                raise RuntimeError(
                    f"chunk {c} folded out of order (expected "
                    f"{self._next_fold}) — the chunk-index fold order is "
                    f"the exactness contract")
            self._complete.discard(c)
            self._buffers.pop(c, None)
            self._next_fold = c + 1
            finish = self._next_fold == self._n_chunks
            self._cond.notify_all()
        if finish:
            self._finish()

    def _finish(self) -> None:
        """Place every step accumulator into the volume — the same
        placement primitives (and float-op order) as the offline
        chunk-major walk, ending in one host add per write into the
        zeroed volume."""
        with telemetry.span("stream.tail", n_chunks=self._n_chunks):
            self._finish_inner()

    def _finish_inner(self) -> None:
        ex = self._ex
        plan = self._plan
        if plan.out == "device":
            if ex._single_full_call():
                vol_t = self._accs[0]
            else:
                vol_t = jnp.zeros(plan.vol_shape_xyz, jnp.float32)
                for step, acc in zip(plan.steps, self._accs):
                    for (i_s, j_s, k_s), piece in ex._step_writes(step, acc):
                        idx = jnp.asarray(
                            [i_s.start, j_s.start, k_s.start], jnp.int32)
                        vol_t = _place_device_add(vol_t, piece, idx)
            result = bp.volume_to_native(vol_t)
        else:
            vol = np.zeros(plan.vol_shape_xyz, np.float32)
            flush = ex._open_flush(vol)
            try:
                for step, acc in zip(plan.steps, self._accs):
                    writes = ex._step_writes(step, acc)
                    if flush is not None:
                        flush.put(writes)
                    else:
                        for sl, piece in writes:
                            vol[sl] += np.asarray(piece)
            finally:
                if flush is not None:
                    flush.close()
            result = np.transpose(vol, (2, 1, 0))
        with self._cond:
            self._accs = [None] * len(plan.steps)
            self._result = result
            self._t_done = time.perf_counter()
            self._finished.set()
            self._cond.notify_all()

    def _drive(self) -> None:
        """Internal folder thread: consume ready chunks in index order."""
        try:
            for c in range(self._n_chunks):
                with self._cond:
                    while c not in self._complete and self._error is None:
                        self._cond.wait(0.1)
                    if self._error is not None:
                        return
                self.fold(c)
        except BaseException as exc:  # noqa: BLE001 — surfaced at close()
            self.fail(exc)

    # ---- introspection ----------------------------------------------------

    @property
    def report(self) -> Optional[StreamReport]:
        """Overlap metrics once the stream finished, else None."""
        with self._cond:
            if self._t_done is None:
                return None
            t_first = self._t_first if self._t_first is not None else 0.0
            t_last = (self._t_last if self._t_last is not None
                      else self._t_done)
            return StreamReport(
                n_views=self._n_views, n_chunks=self._n_chunks,
                acquire_s=max(0.0, t_last - t_first),
                compute_s=self._busy,
                tail_s=max(0.0, self._t_done - t_last))
