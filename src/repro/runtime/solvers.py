"""Iterative solvers as plan-level loops over a persistent executor.

The paper frames back-projection as the compute core that iterative
reconstruction multiplies by the iteration count (§2): a SART run is
N_iters × (forward + back) projections, so everything the engine
amortizes for one FDK call — compiled programs, schedules, normalizer
volumes — must be amortized across the WHOLE solve, not rebuilt per
iteration. This module supplies that loop level:

* :class:`IterativeExecutor` pairs the ray-driven forward projector
  (``core.forward``) with the back-projection engine
  (:class:`~repro.runtime.executor.PlanExecutor`) through one shared
  :class:`~repro.runtime.executor.ProgramCache`. Forward programs and
  the TV prox join the cache under their own key families
  (``("forward", ...)`` / ``("tv_prox", ...)``), so
  ``cache.stats()["misses"]`` counts EVERY compile a solve triggers —
  the basis of the compile-flat-after-iteration-1 contract asserted in
  tests and reported per run in :class:`SolveReport`.
* Normalizer volumes are computed once per executor: ``FP(1)`` (per-ray
  intersection lengths) and ``BP(1)`` (voxel column sums), plus the
  per-subset ``BP_s(1)`` family OS-SART needs — all cached on the
  instance, never per call.
* The solvers themselves — SART, OS-SART, CGLS, FISTA-TV — are plain
  Python loops at plan level. OS-SART's ordered subsets ARE the plan's
  projection chunks (:attr:`ReconPlan.subsets`): the tuner's
  ``proj_batch`` axis doubles as the subset-count axis, and equal-size
  subsets share one program (the tail subset compiles one extra in
  iteration 1).

Precision rides the plan: ``precision="bf16"`` routes both projectors
through the reduced-precision data path (bf16 samples, f32
accumulators) under the same tolerance contract as ``variant="auto"``.

Service integration: an :class:`IterativeExecutor` duck-types the
:class:`PlanExecutor` surface :class:`~repro.runtime.service.ReconService`
buckets rely on (``warm`` / ``reconstruct`` / ``pipeline`` /
``fleet_totals``), so solver plans form their own bucket family keyed by
``ReconPlan.solver`` and warm service traffic covers iterative jobs.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backproject as bp_mod
from repro.core.forward import _project_view_impl, march_params, view_frames
from repro.core.geometry import CTGeometry, projection_matrices

from .executor import PlanExecutor, ProgramCache, default_program_cache
from .planner import ReconPlan, plan_reconstruction

from repro.runtime import telemetry

SOLVERS = ("sart", "os_sart", "cgls", "fista_tv")

_EPS_RAY = 1e-3     # floor for FP(1) ray lengths (matches sart_step)
_EPS_VOL = 1e-12    # floor for BP(1) voxel sums


# ---------------------------------------------------------------------------
# reports


@dataclass
class SolveReport(telemetry.EmitMixin):
    """What one solve did: convergence trace + compile accounting.

    ``EmitMixin`` gives it the shared ``as_dict()``/``emit()`` contract
    the other runtime reports (service/fleet/stream) use."""

    method: str
    n_iters: int
    precision: str
    # projection-domain residual norm per iteration (OS-SART records the
    # norm seen while sweeping its subsets — Kaczmarz-style, each subset
    # measured at its visit)
    residuals: Tuple[float, ...] = ()
    # ProgramCache misses attributed to iteration 1 (includes the
    # normalizers and any warm-up) vs. iterations 2..N. The contract:
    # ``compiles_warm == 0`` — warm iterations dispatch, never compile.
    compiles_iter1: int = 0
    compiles_warm: int = 0
    wall_s: float = 0.0
    extras: Dict[str, float] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# TV prox (Chambolle dual iteration, 3-D)


def _grad3(u):
    """Forward differences per axis, Neumann (zero) at the far face."""
    gz = jnp.zeros_like(u).at[:-1].set(u[1:] - u[:-1])
    gy = jnp.zeros_like(u).at[:, :-1].set(u[:, 1:] - u[:, :-1])
    gx = jnp.zeros_like(u).at[:, :, :-1].set(u[:, :, 1:] - u[:, :, :-1])
    return jnp.stack([gz, gy, gx])


def _div3(p):
    """Adjoint of ``-_grad3``: backward differences with the matching
    boundary rows (first slice passes through, last negates)."""
    def d(q, axis):
        n = q.shape[axis]
        sl = [slice(None)] * q.ndim

        def take(a, b):
            sl2 = list(sl)
            sl2[axis] = slice(a, b)
            return q[tuple(sl2)]

        first = take(0, 1)
        mid = take(1, n - 1) - take(0, n - 2)
        last = -take(n - 2, n - 1)
        return jnp.concatenate([first, mid, last], axis=axis)

    return d(p[0], 0) + d(p[1], 1) + d(p[2], 2)


def _build_tv_prox(shape: Tuple[int, int, int], n_inner: int):
    """Jitted prox of ``lam * TV`` at unit step: Chambolle's dual fixed
    point, tau = 1/12 (the 3-D convergence bound). ``lam`` stays traced
    so one program serves every weight."""
    tau = 1.0 / 12.0

    def prox(x, lam):
        def body(_, p):
            u = x - lam * _div3(p)
            g = _grad3(u)
            mag = jnp.sqrt(jnp.sum(g * g, axis=0, keepdims=True))
            return (p - (tau / lam) * g) / (1.0 + (tau / lam) * mag)

        p0 = jnp.zeros((3,) + tuple(shape), jnp.float32)
        p = jax.lax.fori_loop(0, n_inner, body, p0)
        return x - lam * _div3(p)

    return jax.jit(prox)


# ---------------------------------------------------------------------------
# the executor


class IterativeExecutor:
    """Persistent forward+back pairing for one solver plan.

    Construct once per ``(geom, plan)`` bucket; every ``reconstruct``
    call reuses the same compiled programs and normalizer volumes.
    Duck-types the :class:`PlanExecutor` surface the serving layer
    expects from a bucket executor.
    """

    #: solver buckets never coalesce across requests — each solve is a
    #: stateful multi-pass loop, not one batched dispatch
    supports_request_batching = False

    def __init__(self, geom: CTGeometry, plan: ReconPlan,
                 cache: Optional[ProgramCache] = None, *,
                 oversample: float = 1.0,
                 pipeline: str = "sync", pipeline_depth: int = 2,
                 tuned=None):
        if plan.solver not in SOLVERS:
            raise ValueError(
                f"IterativeExecutor needs a solver plan; got "
                f"solver={plan.solver!r} (plan FDK runs with "
                f"PlanExecutor directly)")
        self.geom = geom
        self.plan = plan
        self.oversample = float(oversample)
        self.ex = PlanExecutor(geom, plan, cache=cache, pipeline=pipeline,
                               pipeline_depth=pipeline_depth, tuned=tuned)
        self.cache = self.ex.cache
        self.last_report: Optional[SolveReport] = None
        # geometry-fixed inputs, uploaded once
        self._mats = projection_matrices(geom)
        self._frames = tuple(jnp.asarray(a) for a in view_frames(geom))
        # normalizers, lazily filled (keyed by the forward oversample
        # so one bucket executor serves any request's march density):
        # FP(1) rides iteration 1's first forward program, BP(1)/
        # BP_s(1) ride the BP programs
        self._ray_len: Dict[float, jnp.ndarray] = {}
        self._bp_ones: Dict[Tuple[int, int], jnp.ndarray] = {}
        self._fista_L: Dict[float, float] = {}

    # -- PlanExecutor duck-type surface (serving layer) -------------------

    @property
    def pipeline(self):
        return self.ex.pipeline

    @property
    def tuned(self):
        return self.ex.tuned

    @property
    def fleet(self):
        return None

    @property
    def _fleet_lock(self):
        return self.ex._fleet_lock

    @property
    def fleet_totals(self):
        return self.ex.fleet_totals

    @property
    def _dtype(self):
        return self.ex._dtype

    def warm(self) -> Dict[str, int]:
        """Compile every program + normalizer one solve needs; returns
        cache stats. After ``warm()`` a solve's iteration 1 compiles
        nothing either."""
        self.ex.warm()
        self._normalizers()
        if self.plan.solver == "fista_tv":
            self._tv_prox(self._default_tv_inner)
        return self.cache.stats()

    def reconstruct(self, projections: jnp.ndarray, **solver_kw):
        """Run ``plan.solver`` on raw projections (np, nh, nw); returns
        the (nz, ny, nx) device volume. Keyword knobs: ``n_iters``,
        ``relax``, ``x0``, ``tv_weight``, ``tv_inner``."""
        vol, report = self.solve(projections, **solver_kw)
        return vol

    # -- program access (everything counted by the shared cache) ----------

    def _forward_program(self, k: int, oversample: float):
        """Vmapped forward program for a k-view chunk of THIS geometry.

        Keyed in the shared cache under the ``"forward"`` family so
        solver compiles are auditable next to BP compiles. Each key gets
        its own fresh ``jax.jit`` — cache misses == XLA compiles."""
        key = ("forward", self.geom, round(oversample, 6), int(k),
               self._dtype)

        def build():
            vmapped = jax.vmap(
                _project_view_impl,
                in_axes=(None, 0, 0, 0, 0, None, None, None, None, None,
                         None, None))
            fn = jax.jit(vmapped, static_argnames=("n_steps", "nh", "nw"))
            vo, ip, sl, tn, ns = march_params(self.geom, oversample)
            nh, nw = self.geom.nh, self.geom.nw
            sl = jnp.float32(sl)
            tn = jnp.float32(tn)
            bf16 = self._dtype == "bfloat16"

            def prog(vol_zyx, srcs, origins, usteps, vsteps):
                if bf16:   # bf16 samples; scan carry stays f32
                    vol_zyx = vol_zyx.astype(jnp.bfloat16)
                return fn(vol_zyx, srcs, origins, usteps, vsteps,
                          vo, ip, ns, nh, nw, sl, tn)

            return prog

        return self.cache.get_or_build(key, build)

    _default_tv_inner = 10

    def _tv_prox(self, n_inner: int):
        nx, ny, nz = self.plan.vol_shape_xyz
        key = ("tv_prox", (nz, ny, nx), int(n_inner))
        return self.cache.get_or_build(
            key, lambda: _build_tv_prox((nz, ny, nx), int(n_inner)))

    # -- the two half-iterations ------------------------------------------

    def _fp(self, vol_zyx, s0: Optional[int] = None,
            s1: Optional[int] = None, *,
            oversample: Optional[float] = None) -> jnp.ndarray:
        """Forward-project (all views, or the subset [s0, s1)). Walks
        the plan's projection chunks — the same bounded per-dispatch
        view set the back-projector promises."""
        ov = self.oversample if oversample is None else float(oversample)
        srcs, origins, usteps, vsteps = self._frames
        if s0 is not None:
            prog = self._forward_program(s1 - s0, ov)
            return prog(vol_zyx, srcs[s0:s1], origins[s0:s1],
                        usteps[s0:s1], vsteps[s0:s1])
        parts = []
        for c0, c1 in self.plan.subsets:
            prog = self._forward_program(c1 - c0, ov)
            parts.append(prog(vol_zyx, srcs[c0:c1], origins[c0:c1],
                              usteps[c0:c1], vsteps[c0:c1]))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)

    def _bp(self, proj, s0: Optional[int] = None,
            s1: Optional[int] = None) -> jnp.ndarray:
        """Back-project projection-domain rows into a (nz, ny, nx)
        volume through the plan's engine (any view count)."""
        mats = self._mats if s0 is None else self._mats[s0:s1]
        vol_t = self.ex.backproject(bp_mod.transpose_projections(proj), mats)
        return jnp.transpose(jnp.asarray(vol_t), (2, 1, 0))

    # -- normalizers (computed once per executor) -------------------------

    def _zeros_vol(self) -> jnp.ndarray:
        nx, ny, nz = self.plan.vol_shape_xyz
        return jnp.zeros((nz, ny, nx), jnp.float32)

    def _normalizers(self, oversample: Optional[float] = None):
        """``FP(1)`` ray lengths + full-set ``BP(1)``; idempotent."""
        ov = self.oversample if oversample is None else float(oversample)
        ray_len = self._ray_len.get(ov)
        if ray_len is None:
            ray_len = jnp.maximum(
                self._fp(jnp.ones_like(self._zeros_vol()), oversample=ov),
                _EPS_RAY)
            self._ray_len[ov] = ray_len
        self._bp_ones_for(None, None)
        return ray_len

    def _bp_ones_for(self, s0: Optional[int], s1: Optional[int]):
        key = (-1, -1) if s0 is None else (s0, s1)
        vol = self._bp_ones.get(key)
        if vol is None:
            g = self.geom
            k = g.n_proj if s0 is None else s1 - s0
            ones = jnp.ones((k, g.nh, g.nw), jnp.float32)
            vol = jnp.maximum(self._bp(ones, s0, s1), _EPS_VOL)
            self._bp_ones[key] = vol
        return vol

    # -- solve dispatch ----------------------------------------------------

    def solve(self, projections: jnp.ndarray, *, n_iters: int = 10,
              relax: float = 0.9, x0=None, tv_weight: float = 0.005,
              tv_inner: Optional[int] = None,
              oversample: Optional[float] = None
              ) -> Tuple[jnp.ndarray, SolveReport]:
        """Run the plan's solver; returns ``(volume_zyx, SolveReport)``.

        The report's compile split is read off the shared cache: misses
        during iteration 1 (normalizers included) vs. misses after —
        the latter must be zero, warm iterations only dispatch.
        """
        method = self.plan.solver
        loops = {"sart": self._solve_sart, "os_sart": self._solve_os_sart,
                 "cgls": self._solve_cgls, "fista_tv": self._solve_fista_tv}
        if method not in loops:
            raise ValueError(f"unknown solver {method!r}")
        n_iters = int(n_iters)
        if n_iters < 1:
            raise ValueError(f"n_iters must be >= 1, got {n_iters}")
        projections = jnp.asarray(projections, jnp.float32)
        g = self.geom
        if projections.shape != (g.n_proj, g.nh, g.nw):
            raise ValueError(
                f"projections {projections.shape} != geometry "
                f"{(g.n_proj, g.nh, g.nw)}")
        x = self._zeros_vol() if x0 is None else jnp.asarray(x0, jnp.float32)

        stats0 = self.cache.stats()["misses"]
        t0 = time.perf_counter()
        marks: Dict[str, int] = {}   # loop writes misses-after-iter-1
        kw = dict(n_iters=n_iters, relax=float(relax),
                  tv_weight=float(tv_weight),
                  tv_inner=self._default_tv_inner if tv_inner is None
                  else int(tv_inner),
                  oversample=self.oversample if oversample is None
                  else float(oversample))
        with telemetry.span("solve", method=method, n_iters=n_iters,
                            precision=self.plan.precision):
            x, residuals, extras = loops[method](projections, x, kw, marks)
            x = jax.block_until_ready(x)
        wall = time.perf_counter() - t0
        stats1 = self.cache.stats()["misses"]
        after_iter1 = marks.get("after_iter1", stats1)
        report = SolveReport(
            method=method, n_iters=n_iters, precision=self.plan.precision,
            residuals=tuple(residuals),
            compiles_iter1=after_iter1 - stats0,
            compiles_warm=stats1 - after_iter1,
            wall_s=wall, extras=extras)
        self.last_report = report
        return x, report

    # -- the loops ---------------------------------------------------------

    def _solve_sart(self, proj, x, kw, marks):
        """x += relax * BP((P - FP(x)) / FP(1)) / BP(1)"""
        ov = kw["oversample"]
        ray_len = self._normalizers(ov)
        norm = self._bp_ones_for(None, None)
        residuals = []
        for i in range(kw["n_iters"]):
            with telemetry.span("solve.iter", method="sart", i=i):
                est = self._fp(x, oversample=ov)
                resid = proj - est
                residuals.append(float(jnp.linalg.norm(resid)))
                x = x + kw["relax"] * self._bp(resid / ray_len) / norm
                if i == 0:
                    marks["after_iter1"] = self.cache.stats()["misses"]
        return x, residuals, {}

    def _solve_os_sart(self, proj, x, kw, marks):
        """SART restricted to each ordered subset in turn; the subsets
        are the plan's projection chunks, so subset count is the tuned
        ``proj_batch`` axis."""
        ov = kw["oversample"]
        ray_len = self._normalizers(ov)
        subsets = self.plan.subsets
        residuals = []
        for i in range(kw["n_iters"]):
            with telemetry.span("solve.iter", method="os_sart", i=i):
                sweep_sq = 0.0
                for s0, s1 in subsets:
                    est = self._fp(x, s0, s1, oversample=ov)
                    resid = proj[s0:s1] - est
                    sweep_sq += float(jnp.sum(resid * resid))
                    upd = self._bp(resid / ray_len[s0:s1], s0, s1)
                    x = x + kw["relax"] * upd / self._bp_ones_for(s0, s1)
                residuals.append(math.sqrt(sweep_sq))
                if i == 0:
                    marks["after_iter1"] = self.cache.stats()["misses"]
        return x, residuals, {"subsets": float(len(subsets))}

    def _solve_cgls(self, proj, x, kw, marks):
        """CGLS-style conjugate directions on the normal equations.

        The FP/BP pair is the standard unmatched (ray-driven /
        voxel-driven) discretization AND the voxel kernel carries FDK's
        depth weighting, so BP is a badly *scaled* transpose — the
        textbook step ``gamma/||q||^2`` would be off by the weighting's
        square. We instead take the exact line-search step
        ``<r, q>/||q||^2`` (minimizes ``||r - alpha q||`` outright, so
        the residual is monotone for ANY BP scaling) and keep the
        Fletcher–Reeves direction mix, where the scaling cancels."""
        ov = kw["oversample"]
        r = proj - self._fp(x, oversample=ov)
        s = self._bp(r)
        p = s
        gamma = jnp.sum(s * s)
        residuals = []
        for i in range(kw["n_iters"]):
            with telemetry.span("solve.iter", method="cgls", i=i):
                q = self._fp(p, oversample=ov)
                alpha = jnp.sum(r * q) / jnp.maximum(jnp.sum(q * q),
                                                    _EPS_VOL)
                x = x + alpha * p
                r = r - alpha * q
                residuals.append(float(jnp.linalg.norm(r)))
                s = self._bp(r)
                gamma_new = jnp.sum(s * s)
                p = s + (gamma_new / jnp.maximum(gamma, _EPS_VOL)) * p
                gamma = gamma_new
                if i == 0:
                    marks["after_iter1"] = self.cache.stats()["misses"]
        return x, residuals, {}

    def _solve_fista_tv(self, proj, x, kw, marks):
        """FISTA on 0.5||FP(x) - P||^2 + tv_weight * TV(x); the TV prox
        is Chambolle's dual iteration (a cached jitted program). The
        gradient Lipschitz constant L = ||A^T A|| comes from a short
        power iteration, reusing the already-compiled FP/BP programs,
        and is cached on the executor."""
        ov = kw["oversample"]
        self._normalizers(ov)
        prox = self._tv_prox(kw["tv_inner"])
        L = self._fista_L.get(ov)
        if L is None:
            v = self._bp(proj)
            nrm = float(jnp.linalg.norm(v))
            if nrm < _EPS_VOL:   # blank data: seed with ones
                v = jnp.ones_like(x)
                nrm = float(jnp.linalg.norm(v))
            L = 1.0
            for _ in range(8):
                v = self._bp(self._fp(v / nrm, oversample=ov))
                L = float(jnp.linalg.norm(v))
                nrm = max(L, _EPS_VOL)
            L = max(L, _EPS_VOL)
            self._fista_L[ov] = L
        step = 1.0 / L
        lam = jnp.float32(max(kw["tv_weight"] * step, _EPS_VOL))
        y, t = x, 1.0
        residuals = []
        for i in range(kw["n_iters"]):
            with telemetry.span("solve.iter", method="fista_tv", i=i):
                resid = self._fp(y, oversample=ov) - proj
                residuals.append(float(jnp.linalg.norm(resid)))
                x_new = prox(y - step * self._bp(resid), lam)
                t_new = 0.5 * (1.0 + math.sqrt(1.0 + 4.0 * t * t))
                y = x_new + ((t - 1.0) / t_new) * (x_new - x)
                x, t = x_new, t_new
                if i == 0:
                    marks["after_iter1"] = self.cache.stats()["misses"]
        return x, residuals, {"lipschitz": L}


# ---------------------------------------------------------------------------
# module-level executor reuse + the functional façade

_EXECUTORS: Dict[tuple, IterativeExecutor] = {}


def solver_executor(geom: CTGeometry, plan: ReconPlan,
                    cache: Optional[ProgramCache] = None, *,
                    oversample: float = 1.0,
                    pipeline: str = "sync") -> IterativeExecutor:
    """Get-or-create the persistent executor for ``(geom, plan)``.

    Keyed by the plan's bucket key + the forward-pass oversampling +
    cache identity, so repeated façade calls (``sart_step`` once per
    outer iteration, say) land on the SAME executor: normalizers and
    programs computed once, every later call warm."""
    c = cache if cache is not None else default_program_cache()
    key = (geom, plan.bucket_key, oversample, pipeline, id(c))
    ex = _EXECUTORS.get(key)
    if ex is None:
        ex = IterativeExecutor(geom, plan, c, oversample=oversample,
                               pipeline=pipeline)
        _EXECUTORS[key] = ex
    return ex


def clear_solver_executors() -> None:
    """Drop the executor cache (tests: isolate compile counting)."""
    _EXECUTORS.clear()


def solve(projections: jnp.ndarray, geom: CTGeometry,
          method: str = "sart", *, n_iters: int = 10, relax: float = 0.9,
          x0=None, tv_weight: float = 0.005, tv_inner: Optional[int] = None,
          oversample: float = 1.0, variant: str = "algorithm1_mp",
          nb: int = 8, interpret: bool = True,
          proj_batch: Optional[int] = None, schedule: Optional[str] = None,
          precision: str = "f32", cache: Optional[ProgramCache] = None,
          **kernel_options) -> Tuple[jnp.ndarray, SolveReport]:
    """One-call iterative reconstruction: plan, reuse the persistent
    executor, run the loop. Returns ``(volume_zyx, SolveReport)``."""
    if method not in SOLVERS:
        raise ValueError(f"method must be one of {SOLVERS}, got {method!r}")
    plan = plan_reconstruction(
        geom, variant, nb=nb, interpret=interpret, proj_batch=proj_batch,
        out="device", schedule=schedule, precision=precision, solver=method,
        **kernel_options)
    ex = solver_executor(geom, plan, cache, oversample=oversample)
    return ex.solve(projections, n_iters=n_iters, relax=relax, x0=x0,
                    tv_weight=tv_weight, tv_inner=tv_inner)
