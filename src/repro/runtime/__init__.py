# NOTE: the autotune FUNCTION is deliberately not re-exported here —
# it would shadow the `repro.runtime.autotune` submodule attribute
from .autotune import TunedConfig, TuningCache, resolve_config  # noqa: F401
from .executor import FleetConfig, FleetReport, StreamReport, \
    StreamingExecutor, as_fleet_config  # noqa: F401
from .fault_tolerance import FaultTolerantLoop, Heartbeat  # noqa: F401
from .elastic import remesh_plan, reshard_tree  # noqa: F401
from .engine import TiledReconstructor  # noqa: F401
from .planner import FleetSchedule, StreamSchedule, \
    partition_steps  # noqa: F401
from .service import ReconService, ServiceStats, StreamSession  # noqa: F401
from . import telemetry  # noqa: F401
from .solvers import IterativeExecutor, SolveReport, solve  # noqa: F401
from .straggler import FleetStragglerBoard, StragglerMonitor  # noqa: F401
