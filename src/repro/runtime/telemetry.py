"""Process-wide telemetry: spans, metrics, trace IDs, exporters.

One observability layer for the whole runtime (ISSUE 10). Four pieces:

* **Spans** — nestable wall-clock intervals on the monotonic clock
  (``time.perf_counter``), recorded per OS thread so the runtime's
  named worker threads (``recon-flush``, ``recon-fleet-{d}``,
  ``recon-serve-{i}``, ``recon-stream``, ``recon-stream-fold``) become
  distinct lanes in the exported trace. Tracing is OFF by default;
  :func:`span`/:func:`instant` then return a shared no-op singleton
  without allocating, so instrumented hot paths cost one attribute
  load + truth test (<< 1 µs — benchmarks/bench_smoke.py asserts the
  whole-recon overhead stays under 2%). Enable with ``REPRO_TRACE=1``
  in the environment or the :func:`tracing` context manager.

* **Metrics registry** — named counters / gauges / :class:`Histogram`
  (the streamed log-2 latency histogram formerly private to the
  serving layer lives here now). :class:`EmitMixin` gives every report
  dataclass (``ServiceStats``, ``FleetReport``, ``StreamReport``,
  ``SolveReport``) one shared ``as_dict()``/``emit()`` contract.

* **Trace IDs** — :func:`new_trace_id` mints per-request IDs that
  ``ReconService.submit``/``open_stream`` thread through to dispatch
  spans, so a k-wide batched dispatch links back to all k requests.

* **Exporters** — :func:`dump_trace` writes Chrome trace-event JSON
  (load in Perfetto / ``chrome://tracing``; ``ph:"X"`` complete events
  with per-thread ``tid`` lanes + ``ph:"M"`` thread-name metadata),
  :func:`prom_render` renders Prometheus text exposition (used by
  ``ServiceStats.export_prometheus``), and :func:`record_tuning`
  appends autotune outcomes to the ``TUNE_TRAJECTORY.json`` artifact
  (``$REPRO_TUNE_TRAJECTORY``) — the ROADMAP "portability claim is a
  tracked number" item.

Spans optionally wrap ``jax.profiler.TraceAnnotation`` (set
``REPRO_TRACE_XLA=1``) so repro spans line up with XLA profiles.

This module imports nothing from ``repro`` — every runtime layer may
import it without cycles.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import math
import os
import re
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "enabled", "enable", "disable", "tracing", "span", "instant",
    "events", "clear", "dump_trace", "open_span_count",
    "new_trace_id", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "REGISTRY", "EmitMixin", "prom_name", "prom_render",
    "record_tuning", "tune_trajectory", "dump_tune_trajectory",
]

# --------------------------------------------------------------------------
# Enablement — the no-op fast path
# --------------------------------------------------------------------------

# Checked FIRST by span()/instant(); everything else is behind it. A
# plain module global read is the cheapest gate Python offers, and the
# disabled path allocates nothing (shared _NULL singleton).
_enabled: bool = os.environ.get("REPRO_TRACE", "") not in ("", "0")

_lock = threading.Lock()
_events: List[Dict[str, Any]] = []
_dropped = 0
_MAX_EVENTS = 1_000_000          # hard cap; beyond it events are counted, not kept
_open_spans: set = set()         # span ids entered but not yet exited
_span_ids = itertools.count(1)
_tls = threading.local()         # per-thread span stack (nesting / parents)


def enabled() -> bool:
    """True when spans/instants are being recorded."""
    return _enabled


def enable(clear_events: bool = False) -> None:
    global _enabled
    if clear_events:
        clear()
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


@contextmanager
def tracing(path: Optional[str] = None, clear_events: bool = True):
    """Enable tracing for a ``with`` block; optionally dump on exit.

        with telemetry.tracing("trace.json"):
            executor.reconstruct(projections)

    Restores the previous enabled state on exit (nesting-safe), then
    writes the Chrome trace to ``path`` when given.
    """
    global _enabled
    prev = _enabled
    enable(clear_events=clear_events)
    try:
        yield
    finally:
        _enabled = prev
        if path is not None:
            dump_trace(path)


def _record(ev: Dict[str, Any]) -> None:
    global _dropped
    with _lock:
        if len(_events) < _MAX_EVENTS:
            _events.append(ev)
        else:
            _dropped += 1


def events() -> List[Dict[str, Any]]:
    """Snapshot of recorded events (internal schema, pre-export)."""
    with _lock:
        return list(_events)


def clear() -> None:
    global _dropped
    with _lock:
        _events.clear()
        _dropped = 0
        _open_spans.clear()


def open_span_count() -> int:
    """Spans entered but not yet exited (0 == every span closed)."""
    with _lock:
        return len(_open_spans)


# --------------------------------------------------------------------------
# Spans
# --------------------------------------------------------------------------

class _NullSpan:
    """Shared do-nothing span — the disabled path. ``live`` lets call
    sites skip computing expensive annotations (roofline args)."""

    __slots__ = ()
    live = False

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):
        return self


_NULL = _NullSpan()

# jax.profiler.TraceAnnotation is resolved lazily so telemetry stays
# importable (and free) when jax is absent or REPRO_TRACE_XLA is unset.
_XLA_ANNOTATE = os.environ.get("REPRO_TRACE_XLA", "") not in ("", "0")
_xla_annotation_cls: Any = None


def _xla_annotation(name: str):
    global _xla_annotation_cls
    if _xla_annotation_cls is None:
        try:
            from jax.profiler import TraceAnnotation
            _xla_annotation_cls = TraceAnnotation
        except Exception:                       # pragma: no cover - no jax
            _xla_annotation_cls = False
    return _xla_annotation_cls(name) if _xla_annotation_cls else None


class Span:
    """One live span. Use via ``with telemetry.span(...) as sp:``;
    ``sp.set(k=v)`` attaches args any time before exit."""

    __slots__ = ("name", "cat", "args", "id", "parent", "_t0", "_ann")
    live = True

    def __init__(self, name: str, cat: str, args: Dict[str, Any],
                 ann=None):
        self.name = name
        self.cat = cat
        self.args = args
        self.id = 0
        self.parent = None
        self._t0 = 0.0
        self._ann = ann

    def set(self, **args) -> "Span":
        self.args.update(args)
        return self

    def __enter__(self) -> "Span":
        stack = getattr(_tls, "stack", None)
        if stack is None:
            stack = _tls.stack = []
        self.parent = stack[-1].id if stack else None
        self.id = next(_span_ids)
        stack.append(self)
        with _lock:
            _open_spans.add(self.id)
        if self._ann is not None:
            self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
        stack = getattr(_tls, "stack", None)
        if stack and stack[-1] is self:
            stack.pop()
        args = dict(self.args)
        args["span_id"] = self.id
        args["parent_id"] = self.parent
        if exc_type is not None:
            args["error"] = exc_type.__name__
        with _lock:
            _open_spans.discard(self.id)
        _record({"ph": "X", "name": self.name, "cat": self.cat,
                 "ts": self._t0 * 1e6, "dur": (t1 - self._t0) * 1e6,
                 "tid": threading.current_thread().name, "args": args})
        return False


def span(name: str, cat: str = "recon", xla: bool = False, **args):
    """A nestable span on the calling thread's lane; no-op when
    tracing is disabled. ``xla=True`` additionally wraps the interval
    in ``jax.profiler.TraceAnnotation`` when ``REPRO_TRACE_XLA=1``."""
    if not _enabled:
        return _NULL
    ann = _xla_annotation(name) if (xla and _XLA_ANNOTATE) else None
    return Span(name, cat, args, ann)


def instant(name: str, cat: str = "recon", **args) -> None:
    """A zero-duration marker (steal / failover / submit / ...)."""
    if not _enabled:
        return
    _record({"ph": "i", "name": name, "cat": cat, "s": "t",
             "ts": time.perf_counter() * 1e6,
             "tid": threading.current_thread().name, "args": args})


# --------------------------------------------------------------------------
# Trace IDs
# --------------------------------------------------------------------------

_trace_counter = itertools.count(1)


def new_trace_id(prefix: str = "req") -> str:
    """Process-unique request/stream ID (cheap; minted even when
    tracing is off so callers can hold one unconditionally)."""
    return f"{prefix}-{os.getpid():x}-{next(_trace_counter):06d}"


# --------------------------------------------------------------------------
# Chrome trace-event exporter
# --------------------------------------------------------------------------

def dump_trace(path: str) -> str:
    """Write recorded events as Chrome trace-event JSON.

    Loadable in Perfetto (ui.perfetto.dev) or ``chrome://tracing``.
    Every distinct thread name becomes its own ``tid`` lane with a
    ``ph:"M"`` thread_name metadata event, so the flusher, fleet
    dispatchers, serving workers and stream-fold threads render as
    separate rows under one process.
    """
    with _lock:
        evs = list(_events)
        dropped = _dropped
    pid = os.getpid()
    lanes: Dict[str, int] = {}
    out: List[Dict[str, Any]] = []
    for name in sorted({e["tid"] for e in evs}):
        lanes[name] = len(lanes)
        out.append({"ph": "M", "name": "thread_name", "pid": pid,
                    "tid": lanes[name], "args": {"name": name}})
    out.append({"ph": "M", "name": "process_name", "pid": pid,
                "args": {"name": "repro-runtime"}})
    for e in evs:
        ce = dict(e)
        ce["pid"] = pid
        ce["tid"] = lanes[ce["tid"]]
        out.append(ce)
    doc = {"traceEvents": out, "displayTimeUnit": "ms",
           "otherData": {"dropped_events": dropped}}
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)
    return path


# --------------------------------------------------------------------------
# Metrics registry
# --------------------------------------------------------------------------

class Counter:
    """Monotonic counter (thread-safe)."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._v


class Gauge:
    """Last-write-wins scalar (thread-safe)."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._v


class Histogram:
    """Streamed log-2 latency histogram (O(1) memory).

    Absorbed from the serving layer (it was ``LatencyHistogram``
    there; ``repro.runtime.service`` keeps that name as an alias).
    Every completed request is recorded as it finishes — the histogram
    IS the stream, not a poll-time sample — into geometric bins
    ``[BASE_S * 2**i, BASE_S * 2**(i+1))``. Quantiles are read from the
    cumulative counts with the bin's geometric center as the estimate
    (resolution ~±41%, the standard trade for a fixed-size streamed
    histogram). Thread-safe: workers record concurrently.
    """

    BASE_S = 50e-6          # bin 0 also absorbs anything faster
    NBINS = 40              # 50 µs .. ~15 hours

    def __init__(self, name: str = ""):
        self.name = name
        self._counts = [0] * self.NBINS
        self._count = 0
        self._total_s = 0.0
        self._lock = threading.Lock()

    def record(self, seconds: float) -> None:
        s = max(float(seconds), 0.0)
        b = 0 if s < 2 * self.BASE_S else min(
            self.NBINS - 1, int(math.log2(s / self.BASE_S)))
        with self._lock:
            self._counts[b] += 1
            self._count += 1
            self._total_s += s

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def counts(self) -> List[int]:
        with self._lock:
            return list(self._counts)

    def mean(self) -> Optional[float]:
        with self._lock:
            return self._total_s / self._count if self._count else None

    def quantile(self, q: float) -> Optional[float]:
        """Approximate q-quantile in seconds (None while empty)."""
        with self._lock:
            if not self._count:
                return None
            target = max(1.0, q * self._count)
            cum = 0
            for i, c in enumerate(self._counts):
                cum += c
                if cum >= target:
                    return self.BASE_S * (2.0 ** i) * math.sqrt(2.0)
            return self.BASE_S * (2.0 ** (self.NBINS - 1))

    @staticmethod
    def merged(hists: Iterable["Histogram"]) -> "Histogram":
        out = Histogram()
        for h in hists:
            with h._lock:
                for i, c in enumerate(h._counts):
                    out._counts[i] += c
                out._count += h._count
                out._total_s += h._total_s
        return out


class MetricsRegistry:
    """Named metric store: get-or-create semantics per metric kind.

    ``REGISTRY`` is the process default; report ``emit()`` targets it
    unless handed another instance (tests use private registries).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def as_dict(self) -> Dict[str, Any]:
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, Any] = {}
        for name, m in items:
            if isinstance(m, Histogram):
                out[name] = {"count": m.count, "mean_s": m.mean(),
                             "p50_s": m.quantile(0.5),
                             "p99_s": m.quantile(0.99)}
            else:
                out[name] = m.value
        return out

    def prometheus(self, prefix: str = "repro") -> str:
        rows = []
        with self._lock:
            items = sorted(self._metrics.items())
        for name, m in items:
            mname = prom_name(f"{prefix}_{name}")
            if isinstance(m, Counter):
                rows.append((mname + "_total", "counter", name,
                             [({}, m.value)]))
            elif isinstance(m, Gauge):
                rows.append((mname, "gauge", name, [({}, m.value)]))
            else:
                rows.append((mname + "_count", "counter", name,
                             [({}, m.count)]))
        return prom_render(rows)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


REGISTRY = MetricsRegistry()


# --------------------------------------------------------------------------
# Shared report contract
# --------------------------------------------------------------------------

class EmitMixin:
    """One ``as_dict()``/``emit()`` contract for report dataclasses.

    ``as_dict()`` is ``dataclasses.asdict`` plus the class's computed
    ``@property`` values (``hit_rate``, ``hidden_fraction``, ...), so
    exporters and the BENCH trajectory see one flat schema.
    ``emit()`` pushes every numeric leaf into a metrics registry as a
    gauge named ``<prefix>.<field>``.
    """

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)          # type: ignore[call-overload]
        for klass in type(self).__mro__:
            for k, v in vars(klass).items():
                if isinstance(v, property) and k not in d:
                    try:
                        d[k] = getattr(self, k)
                    except Exception:
                        pass
        return d

    def emit(self, registry: Optional[MetricsRegistry] = None,
             prefix: Optional[str] = None) -> MetricsRegistry:
        reg = REGISTRY if registry is None else registry
        pfx = prefix if prefix is not None else type(self).__name__.lower()
        for key, v in _numeric_leaves(pfx, self.as_dict()):
            reg.gauge(key).set(v)
        return reg


def _numeric_leaves(prefix: str, obj: Any):
    if isinstance(obj, bool):
        yield prefix, float(obj)
    elif isinstance(obj, (int, float)):
        yield prefix, float(obj)
    elif isinstance(obj, dict):
        for k, v in obj.items():
            yield from _numeric_leaves(f"{prefix}.{k}", v)
    # tuples/lists/str/None: not emitted as metrics


# --------------------------------------------------------------------------
# Prometheus text exposition
# --------------------------------------------------------------------------

_PROM_BAD = re.compile(r"[^a-zA-Z0-9_:]")


def prom_name(name: str) -> str:
    """Sanitize to the Prometheus metric-name charset."""
    out = _PROM_BAD.sub("_", name)
    return "_" + out if out[:1].isdigit() else out


def _prom_value(v: Any) -> str:
    if v is None:
        return "NaN"
    return repr(float(v))


def prom_render(
    rows: Iterable[Tuple[str, str, str, List[Tuple[Dict[str, Any], Any]]]],
) -> str:
    """Render ``(name, type, help, [(labels, value), ...])`` rows as
    Prometheus text exposition format (version 0.0.4)."""
    lines: List[str] = []
    for name, mtype, help_, samples in rows:
        name = prom_name(name)
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} {mtype}")
        for labels, value in samples:
            if labels:
                lab = ",".join(
                    f'{prom_name(str(k))}="{_prom_escape(v)}"'
                    for k, v in sorted(labels.items()))
                lines.append(f"{name}{{{lab}}} {_prom_value(value)}")
            else:
                lines.append(f"{name} {_prom_value(value)}")
    return "\n".join(lines) + "\n"


def _prom_escape(v: Any) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace(
        "\n", r"\n")


# --------------------------------------------------------------------------
# Tuner-outcome trajectory (ROADMAP: "a tracked number, not an anecdote")
# --------------------------------------------------------------------------

TUNE_TRAJECTORY_ENV = "REPRO_TUNE_TRAJECTORY"

_tune_records: List[Dict[str, Any]] = []


def record_tuning(record: Dict[str, Any]) -> None:
    """Append one autotune outcome; mirrors to the JSON artifact at
    ``$REPRO_TUNE_TRAJECTORY`` when set (tier-1 stage 3 exports it so
    CI uploads ``TUNE_TRAJECTORY.json``). Never raises: the trajectory
    is evidence, not a gate."""
    rec = _jsonable(dict(record))
    with _lock:
        _tune_records.append(rec)
    path = os.environ.get(TUNE_TRAJECTORY_ENV)
    if path:
        try:
            _append_json_record(path, rec)
        except (OSError, ValueError):       # pragma: no cover - disk race
            pass


def tune_trajectory() -> List[Dict[str, Any]]:
    with _lock:
        return list(_tune_records)


def dump_tune_trajectory(path: str) -> str:
    with _lock:
        recs = list(_tune_records)
    _write_json_records(path, recs)
    return path


def _append_json_record(path: str, rec: Dict[str, Any]) -> None:
    recs: List[Dict[str, Any]] = []
    try:
        with open(path) as f:
            doc = json.load(f)
        recs = list(doc.get("records", [])) if isinstance(doc, dict) \
            else list(doc)
    except (OSError, ValueError):
        recs = []
    recs.append(rec)
    _write_json_records(path, recs)


def _write_json_records(path: str, recs: List[Dict[str, Any]]) -> None:
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump({"suite": "tune_trajectory", "records": recs}, f,
                  indent=1)
    os.replace(tmp, path)


def _jsonable(obj: Any) -> Any:
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    return str(obj)
