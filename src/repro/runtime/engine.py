"""Tiled streaming reconstruction — the plan/compile/execute façade.

Architecture (docs/ARCHITECTURE.md)
-----------------------------------
Since PR 2 every reconstruction entry point in this repo — untiled
``fdk_reconstruct``, this tiled engine, ``sart_step``, and the
distributed driver — is a thin façade over the same three-stage core:

  1. **plan** — ``runtime.planner.plan_reconstruction`` builds a pure
     :class:`~repro.runtime.planner.ReconPlan`: the (i, j)-tile x Z-slab
     schedule (mirror-paired for O3 symmetry variants, depth-bounded
     plain slabs otherwise), per-step variant resolution against the
     declarative ``KernelSpec`` registry (``core.variants.REGISTRY``),
     matrix-translation offsets, the projection-chunk schedule, and ALL
     option validation.
  2. **compile** — ``runtime.executor.ProgramCache`` maps
     ``(variant, call_shape, nb, dtype, interpret)`` keys to jitted
     programs. Interior tiles share shapes, so a plan with hundreds of
     steps compiles a handful of programs; repeated ``reconstruct``
     calls hit the shared cache and never retrace.
  3. **execute** — ``runtime.executor.PlanExecutor`` walks the plan:
     projections stream through in chunks with FDK pre-weighting + ramp
     filtering fused INTO the chunk loop (filtered projections are never
     materialized whole), and host placement is double-buffered so the
     device->host copy of tile ``n`` overlaps tile ``n+1``'s compute.

Why tiles (unchanged from PR 1)
-------------------------------
The pure-JAX ladder materializes full ``(nx, ny, nz)`` temporaries, so
nothing above toy sizes fits in device memory. The paper's locality
discipline (§3.1) applied at volume granularity — (i, j)-tiles x Z-slabs
with *translated* projection matrices (``core.tiling``) — gives every
registered variant an O(tile) working set (the blocking of Treibig et
al., arXiv:1104.5243, composed with the iFDK slab scale-out,
arXiv:1909.02724). The O3 detector-row symmetry pairs voxel ``k`` with
``nz-1-k`` about the FULL volume's Z midplane, so symmetry variants run
on mirror-paired slab calls of virtual depth ``2*bk`` (both slabs filled
by one call — the flop saving survives tiling) and fall back to their
``KernelSpec.slab_safe_fallback`` on non-pairable slabs.

Usage
-----
    from repro.runtime.engine import TiledReconstructor

    eng = TiledReconstructor(geom, variant="algorithm1_mp",
                             tile_shape=(64, 64, geom.nz), nb=8,
                             proj_batch=32)       # stream 32-proj chunks
    vol = eng.reconstruct(projections)            # filtered FDK, (nz,ny,nx)

    eng.recon_plan        # the ReconPlan (steps, chunks, program keys)
    eng.cache_stats()     # jit-program cache hits/misses

    # or pick the tile shape from a byte budget:
    eng = TiledReconstructor(geom, memory_budget=64 << 20)

    # or via the pipeline entry point:
    from repro.core import fdk_reconstruct
    vol = fdk_reconstruct(projections, geom, tiling=(64, 64, geom.nz),
                          proj_batch=32)

    # cluster scale-out: same tiles, each reconstructed over the mesh
    vol = eng.backproject_distributed(img_t, mats, mesh)
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.geometry import CTGeometry
from repro.core.tiling import TileSpec, make_tiles, plan_z_slabs, \
    plan_z_units
from repro.core.variants import get_spec
from repro.runtime.executor import PlanExecutor, ProgramCache
from repro.runtime.planner import ReconPlan, plan_reconstruction


class TiledReconstructor:
    """Streaming tile/slab back-projection around any registered variant.

    A façade: the constructor builds a :class:`ReconPlan` (all validation
    happens there) and an executor over the shared program cache.

    Parameters
    ----------
    geom : CTGeometry
    variant : registry name (``core.variants.REGISTRY``).
    tile_shape : (ti, tj, tk) maximum tile size in voxels; ``None`` picks
        it from ``memory_budget`` (or uses the full volume if neither is
        given, which degenerates to the untiled call).
    memory_budget : byte budget for one tile's working set (see
        ``core.tiling.tile_working_set_bytes``).
    nb : in-batch projection count handed to the variant (paper O5).
    proj_batch : how many projections stream through per variant call
        (rounded up to a multiple of ``nb``); ``None`` = all at once.
        With ``reconstruct`` this also bounds the *filtering* working
        set: each chunk is pre-weighted + ramp-filtered on the fly.
    out : "host" (numpy accumulator, device holds one tile) | "device".
    interpret : forwarded to the Pallas variants.
    schedule : "step" (scanned device-resident tile accumulators, one
        host crossing per step) | "chunk" (chunk-major streaming:
        filtered projections stay two-chunk-bounded on device —
        current + prefetched) | None
        (default — the planner resolves it: "chunk" when a
        ``memory_budget`` bounds device bytes, "step" otherwise).
    pipeline : "sync" (in-thread double-buffered flush) | "async" (a
        flusher thread overlaps step N's device->host accumulator copy
        with step N+1's scan dispatch; bit-identical output — see
        ``runtime.executor.PlanExecutor``).
    cache : optional private ProgramCache (default: process-shared).
    """

    def __init__(self, geom: CTGeometry, variant: str = "algorithm1_mp", *,
                 tile_shape: Optional[Sequence[int]] = None,
                 memory_budget: Optional[int] = None,
                 nb: int = 8, proj_batch: Optional[int] = None,
                 out: str = "host", interpret: bool = True,
                 schedule: Optional[str] = None,
                 pipeline: str = "sync",
                 cache: Optional[ProgramCache] = None,
                 **kernel_options):
        self.geom = geom
        self.recon_plan: ReconPlan = plan_reconstruction(
            geom, variant, tile_shape=tile_shape,
            memory_budget=memory_budget, nb=nb, proj_batch=proj_batch,
            out=out, interpret=interpret, schedule=schedule,
            **kernel_options)
        # variant="auto" resolves through the tuning cache in the
        # planner; record the resolved name for introspection
        self.variant = self.recon_plan.variant
        self._executor = PlanExecutor(geom, self.recon_plan, cache=cache,
                                      pipeline=pipeline)

    # ---- introspection ---------------------------------------------------

    @property
    def tile_shape(self) -> Tuple[int, int, int]:
        return self.recon_plan.tile_shape

    @property
    def nb(self) -> int:
        return self.recon_plan.nb

    @property
    def working_set_bytes(self) -> int:
        """Peak modeled working set over planned calls (the O(tile) bound;
        mirror-paired slabs are billed at their virtual 2*bk depth)."""
        return self.recon_plan.working_set_bytes

    def cache_stats(self) -> dict:
        """Jit-program cache hits/misses/live-programs."""
        return self._executor.cache.stats()

    def plan(self):
        """Legacy view: ((i0, j0, ni, nj) list, ZUnit list).

        The authoritative schedule is ``recon_plan.steps`` (which also
        carries per-step variant resolution); this derived view keeps
        the PR-1 introspection shape for callers that want the raw
        (i, j) x Z decomposition.
        """
        ti, tj, tk = self.recon_plan.tile_shape
        nx, ny, nz = self.geom.volume_shape_xyz
        ij = [(t.i0, t.j0, t.ni, t.nj)
              for t in make_tiles((nx, ny, 1), (ti, tj, 1))]
        z = (plan_z_units(nz, tk) if get_spec(self.variant).uses_symmetry
             else plan_z_slabs(nz, tk))
        return ij, z

    # ---- execution (delegates to the PlanExecutor) -----------------------

    def backproject(self, img_t: jnp.ndarray, mats: jnp.ndarray):
        """Full tiled back-projection of pre-filtered projections.

        img_t: (np, nw, nh) transposed projections; mats: (np, 3, 4).
        Returns vol_t (nx, ny, nz) — numpy when ``out == "host"``.
        """
        return self._executor.backproject(img_t, mats)

    def backproject_tile(self, img_t: jnp.ndarray, mats: jnp.ndarray,
                         tile: TileSpec) -> jnp.ndarray:
        """Back-project one arbitrary sub-box; exact for every variant
        (non-centered boxes run the KernelSpec slab-safe fallback)."""
        return self._executor.backproject_tile(img_t, mats, tile)

    def reconstruct(self, projections: jnp.ndarray) -> jnp.ndarray:
        """Filtered FDK through the plan: (np, nh, nw) -> (nz, ny, nx).

        Filtering streams through the projection-chunk loop; returns
        numpy when ``out == "host"`` (a free transposed view of the host
        accumulator) and a jax array otherwise.
        """
        return self._executor.reconstruct(projections)

    # ---- cluster composition (iFDK scale-out x tiles) --------------------

    def backproject_distributed(self, img_t: jnp.ndarray, mats: jnp.ndarray,
                                mesh, *, nb: Optional[int] = None,
                                dist_variant: str = "scan",
                                pipeline: Optional[str] = None):
        """Compose tiles with the data/model/pod mesh of core.distributed.

        Each (i, j)-tile (full Z — the mesh shards i/j, slabs stay whole)
        runs the shard_map program with the tile origin as a call-time
        argument: ONE cached program per distinct tile shape. Projection
        batches follow the plan's chunk schedule (tail padded).
        ``pipeline`` ("sync" | "async"; default: this engine's own
        discipline) streams tile flushes through the
        ``_AsyncFlushQueue`` flusher thread exactly like the local
        executor. Returns vol_t (nx, ny, nz) on host.
        """
        nb = self.recon_plan.nb if nb is None else int(nb)
        # the mesh program consumes exactly-nb batches: plan chunks at nb
        plan = plan_reconstruction(
            self.geom, self.variant, tile_shape=self.recon_plan.tile_shape,
            nb=nb, proj_batch=nb, out="host",
            interpret=self.recon_plan.interpret)
        ex = PlanExecutor(
            self.geom, plan, cache=self._executor.cache,
            pipeline=self._executor.pipeline if pipeline is None
            else pipeline,
            pipeline_depth=self._executor.pipeline_depth)
        return ex.execute_distributed(img_t, mats, mesh,
                                      dist_variant=dist_variant)
