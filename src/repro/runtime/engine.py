"""Tiled streaming reconstruction engine (out-of-core back-projection).

Why
---
The pure-JAX ladder materializes full ``(nx, ny, nz)`` temporaries — and
under the in-batch vmap of Algorithm 1, ``nb`` of them — so nothing above
toy sizes fits in device memory. The paper's whole point (§3.1) is that
back-projection should run out of a *bounded working set*: transposed
layouts, sub-line buffers and nb-batched accumulation keep the hot loop
inside cache. This engine applies the same discipline one level up, at
volume granularity: it decomposes the volume into ``(i, j)``-tiles x
Z-slabs and streams projection batches through ANY registered variant per
tile, so every variant gets an O(tile) working set and volumes larger
than device memory become reconstructable (the blocking of Treibig et
al., arXiv:1104.5243, composed with the iFDK slab scale-out scheme,
arXiv:1909.02724, that the authors themselves built).

How
---
The enabling identity is matrix translation (``core.tiling``): projecting
voxel ``(i+i0, j+j0, k+k0)`` equals projecting ``(i, j, k)`` under a
matrix whose constant column absorbs the offset, so the single-device
kernels — pure-JAX ladder or Pallas — reconstruct any sub-box UNCHANGED.
Two subtleties:

* the O3 detector-row symmetry pairs voxel ``k`` with ``nz-1-k`` about
  the FULL volume's Z midplane, so symmetry-carrying variants are only
  exact on Z-centered boxes. The engine schedules Z-slabs in *mirror
  pairs* (one variant call of virtual depth ``2*bk`` fills both slabs —
  the O3 flop saving survives tiling) plus a centered middle slab;
  arbitrary, non-pairable slabs fall back to the strongest symmetry-free
  member of the ladder (``variants.slab_safe_variant``);
* nb-batched variants need ``np % nb == 0``: the engine pads tail
  batches with zero images + repeated matrices (exactly zero
  contribution, no 1/z poles).

Tiles are the outer loop and projections stream innermost
(output-stationary, the nb -> np limit of the paper's O5: each tile is
written to the result volume exactly once). The accumulator volume is
host-resident (numpy) by default so the device never holds more than one
tile; pass ``out="device"`` to keep it on device.

Usage
-----
    from repro.runtime.engine import TiledReconstructor

    eng = TiledReconstructor(geom, variant="algorithm1_mp",
                             tile_shape=(64, 64, geom.nz), nb=8)
    vol = eng.reconstruct(projections)           # filtered FDK, (nz,ny,nx)

    # or pick the tile shape from a byte budget:
    eng = TiledReconstructor(geom, memory_budget=64 << 20)

    # or via the pipeline entry point:
    from repro.core import fdk_reconstruct
    vol = fdk_reconstruct(projections, geom, tiling=(64, 64, geom.nz))

    # cluster scale-out: same tiles, each reconstructed over the mesh
    vol = eng.backproject_distributed(img_t, mats, mesh)
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.geometry import CTGeometry, projection_matrices
from repro.core.tiling import (
    TileSpec, ZUnit, make_tiles, pad_projection_batch, pick_tile_shape,
    plan_z_slabs, plan_z_units, tile_working_set_bytes,
    translate_matrices,
)
from repro.core.variants import get_variant, slab_safe_variant, uses_symmetry


class TiledReconstructor:
    """Streaming tile/slab back-projection around any registered variant.

    Parameters
    ----------
    geom : CTGeometry
    variant : registry name (``core.variants.VARIANTS``).
    tile_shape : (ti, tj, tk) maximum tile size in voxels; ``None`` picks
        it from ``memory_budget`` (or uses the full volume if neither is
        given, which degenerates to the untiled call).
    memory_budget : byte budget for one tile's working set (see
        ``core.tiling.tile_working_set_bytes``).
    nb : in-batch projection count handed to the variant (paper O5).
    proj_batch : how many projections stream through per variant call
        (rounded up to a multiple of ``nb``); ``None`` = all at once.
    out : "host" (numpy accumulator, device holds one tile) | "device".
    interpret : forwarded to the Pallas variants.
    """

    def __init__(self, geom: CTGeometry, variant: str = "algorithm1_mp", *,
                 tile_shape: Optional[Sequence[int]] = None,
                 memory_budget: Optional[int] = None,
                 nb: int = 8, proj_batch: Optional[int] = None,
                 out: str = "host", interpret: bool = True):
        if out not in ("host", "device"):
            raise ValueError(f"out must be 'host' or 'device', got {out!r}")
        self.geom = geom
        self.variant = variant
        self.nb = int(nb)
        self.proj_batch = proj_batch
        self.out = out
        self.interpret = interpret
        tile_given = tile_shape is not None
        if tile_shape is None:
            if memory_budget is not None:
                tile_shape = pick_tile_shape(
                    geom.volume_shape_xyz, (geom.nw, geom.nh),
                    int(memory_budget), nb=self.nb,
                    pair_z=uses_symmetry(variant))
            else:
                tile_shape = geom.volume_shape_xyz
        ti, tj, tk = (int(v) for v in tile_shape)
        nx, ny, nz = geom.volume_shape_xyz
        self.tile_shape: Tuple[int, int, int] = (
            max(1, min(ti, nx)), max(1, min(tj, ny)), max(1, min(tk, nz)))
        if tile_given and memory_budget is not None and \
                self.working_set_bytes > int(memory_budget):
            raise ValueError(
                f"explicit tile_shape {self.tile_shape} needs "
                f"{self.working_set_bytes} B, over the memory_budget of "
                f"{int(memory_budget)} B — drop one of the two or enlarge "
                f"the budget")

    # ---- introspection ---------------------------------------------------

    @property
    def working_set_bytes(self) -> int:
        """Estimated per-call working set of one tile (the O(tile) bound).

        Models what actually runs: for symmetry variants a Z-slab of
        tk < nz is executed as a mirror-paired call of virtual depth
        2*tk, so that is the depth billed here.
        """
        ti, tj, tk = self.tile_shape
        nz = self.geom.nz
        if uses_symmetry(self.variant) and tk < nz:
            tk = min(2 * tk, nz)
        return tile_working_set_bytes(
            (ti, tj, tk), (self.geom.nw, self.geom.nh), nb=self.nb)

    def plan(self):
        """((i0, ni), (j0, nj)) x ZUnit schedule the engine will execute.

        Symmetry variants get the mirror-paired plan (its centered
        middle slab may be up to 2*tk-1 deep — billed as such by
        ``working_set_bytes``); symmetry-free variants get plain slabs
        bounded at tk, since pairing buys them nothing.
        """
        ti, tj, tk = self.tile_shape
        nx, ny, nz = self.geom.volume_shape_xyz
        ij = [(t.i0, t.j0, t.ni, t.nj)
              for t in make_tiles((nx, ny, 1), (ti, tj, 1))]
        z = (plan_z_units(nz, tk) if uses_symmetry(self.variant)
             else plan_z_slabs(nz, tk))
        return ij, z

    # ---- single-tile primitives -----------------------------------------

    def _call_variant(self, name: str, img_t, mats, shape_xyz):
        """Stream projection batches through one variant call site."""
        fn = get_variant(name)
        img_p, mat_p = pad_projection_batch(img_t, mats, self.nb)
        n_pad = img_p.shape[0]
        pb = n_pad if self.proj_batch is None else \
            -(-int(self.proj_batch) // self.nb) * self.nb
        acc = None
        for s0 in range(0, n_pad, pb):
            part = fn(img_p[s0:s0 + pb], mat_p[s0:s0 + pb], shape_xyz,
                      nb=self.nb, interpret=self.interpret)
            acc = part if acc is None else acc + part
        return acc

    def backproject_tile(self, img_t: jnp.ndarray, mats: jnp.ndarray,
                         tile: TileSpec) -> jnp.ndarray:
        """Back-project one arbitrary sub-box; exact for every variant.

        Symmetry-carrying variants are used directly when the box is
        Z-centered (this includes full-Z tiles) and swapped for their
        slab-safe fallback otherwise.
        """
        nz = self.geom.nz
        centered = (2 * tile.k0 + tile.nk == nz)
        name = self.variant if centered else slab_safe_variant(self.variant)
        mats_t = translate_matrices(mats, float(tile.i0), float(tile.j0),
                                    float(tile.k0))
        return self._call_variant(name, img_t, mats_t, tile.shape)

    def _run_z_unit(self, img_t, mats, i0, j0, ni, nj, unit: ZUnit):
        """One ((i,j)-tile, Z-unit) step -> [(k0, tile_volume), ...]."""
        if unit.paired and uses_symmetry(self.variant):
            # One symmetry call of virtual depth 2*bk fills BOTH slabs:
            # local k in [0, bk) is the direct half at k0 and [bk, 2bk)
            # is the O3 mirror, i.e. the slab at nz-k0-bk (see ZUnit).
            mats_t = translate_matrices(mats, float(i0), float(j0),
                                        float(unit.k0))
            both = self._call_variant(self.variant, img_t, mats_t,
                                      (ni, nj, 2 * unit.nk))
            return [(unit.k0, both[..., :unit.nk]),
                    (unit.mirror_k0, both[..., unit.nk:])]
        pieces = []
        slabs = [(unit.k0, unit.nk)]
        if unit.paired:
            slabs.append((unit.mirror_k0, unit.nk))
        for k0, bk in slabs:
            pieces.append((k0, self.backproject_tile(
                img_t, mats, TileSpec(i0, j0, k0, ni, nj, bk))))
        return pieces

    # ---- full-volume drivers --------------------------------------------

    def _alloc(self):
        shape = self.geom.volume_shape_xyz
        return (np.zeros(shape, np.float32) if self.out == "host"
                else jnp.zeros(shape, jnp.float32))

    # out="device" placement: donated dynamic_update_slice so each tile
    # updates the volume buffer in place — NOT vol.at[].set outside jit,
    # which would copy the full volume once per tile.
    _place_device = staticmethod(jax.jit(
        lambda vol, tile, idx: jax.lax.dynamic_update_slice(
            vol, tile, (idx[0], idx[1], idx[2])),
        donate_argnums=0))

    def _place(self, vol, i0, j0, k0, tile_vol):
        ni, nj, nk = tile_vol.shape
        if self.out == "host":
            vol[i0:i0 + ni, j0:j0 + nj, k0:k0 + nk] = np.asarray(tile_vol)
            return vol
        idx = jnp.asarray([i0, j0, k0], jnp.int32)
        return self._place_device(vol, jnp.asarray(tile_vol), idx)

    def backproject(self, img_t: jnp.ndarray, mats: jnp.ndarray):
        """Full tiled back-projection.

        img_t: (np, nw, nh) transposed projections; mats: (np, 3, 4).
        Returns vol_t (nx, ny, nz) — numpy when ``out == "host"``.
        """
        # pad the tail batch ONCE; the per-call pad in _call_variant then
        # short-circuits (it is a no-op on already-divisible inputs)
        img_t, mats = pad_projection_batch(img_t, mats, self.nb)
        vol = self._alloc()
        ij, z_units = self.plan()
        for (i0, j0, ni, nj) in ij:
            for unit in z_units:
                for k0, piece in self._run_z_unit(img_t, mats, i0, j0,
                                                  ni, nj, unit):
                    vol = self._place(vol, i0, j0, k0, piece)
        return vol

    def reconstruct(self, projections: jnp.ndarray) -> jnp.ndarray:
        """Filtered FDK through the tiled engine: (np, nh, nw) -> (nz, ny, nx).

        Returns numpy when ``out == "host"`` (a free transposed view of
        the host accumulator) and a jax array otherwise.
        """
        from repro.core import backproject as bp
        from repro.core.filtering import fdk_preweight_and_filter

        filtered = fdk_preweight_and_filter(projections, self.geom)
        img_t = bp.transpose_projections(filtered)
        mats = projection_matrices(self.geom)
        vol_t = self.backproject(img_t, mats)
        if isinstance(vol_t, np.ndarray):
            # out="host": the accumulator may exceed device memory —
            # transpose is a free numpy view, never round-trip it
            return np.transpose(vol_t, (2, 1, 0))
        return bp.volume_to_native(vol_t)

    # ---- cluster composition (iFDK scale-out x tiles) --------------------

    def backproject_distributed(self, img_t: jnp.ndarray, mats: jnp.ndarray,
                                mesh, *, nb: Optional[int] = None,
                                dist_variant: str = "scan"):
        """Compose tiles with the data/model/pod mesh of core.distributed.

        Each (i, j)-tile (full Z — the mesh shards i/j, slabs stay whole)
        is reconstructed by the existing shard_map program with the tile
        origin folded into every device's slab offset; projection batches
        stream through with tail padding. The origin is a call-time
        argument, so ONE program is built (and traced) per distinct tile
        shape — interior tiles all share it; only edge-tile shapes add
        programs. Returns vol_t (nx, ny, nz) on host.
        """
        from repro.core.distributed import make_distributed_bp

        nb = self.nb if nb is None else int(nb)
        img_p, mat_p = pad_projection_batch(img_t, mats, nb)
        n_pad = img_p.shape[0]
        ti, tj, _ = self.tile_shape
        nx, ny, nz = self.geom.volume_shape_xyz
        vol = np.zeros((nx, ny, nz), np.float32)
        programs = {}
        for tile in make_tiles((nx, ny, nz), (ti, tj, nz)):
            if tile.shape not in programs:
                programs[tile.shape], _specs = make_distributed_bp(
                    self.geom, mesh, nb=nb, variant=dist_variant,
                    vol_shape_xyz=tile.shape)
            fn = programs[tile.shape]
            origin = jnp.asarray([tile.i0, tile.j0], jnp.float32)
            acc = None
            for s0 in range(0, n_pad, nb):
                part = fn(img_p[s0:s0 + nb], mat_p[s0:s0 + nb], origin)
                acc = part if acc is None else acc + part
            vol[tile.slices] = np.asarray(acc)[:tile.ni, :tile.nj]
        return vol
