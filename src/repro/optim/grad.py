"""Gradient machinery: microbatch accumulation and int8 compression.

Accumulation applies the paper's O5 (batch to cut write traffic) to the
gradient buffer: the fp32 accumulator stays live across microbatches and
the cross-replica reduction happens ONCE per optimizer step, at the end —
1/n_micro the all-reduce traffic and one gradient-buffer HBM round-trip.

int8 error-feedback compression halves (vs bf16) the bytes on the slowest
(cross-pod) all-reduce axis; the quantization residual is fed back into
the next step so the scheme is unbiased in the long run.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


def accumulate_gradients(loss_fn: Callable, params, batches,
                         *, grad_shardings=None) -> Tuple[jnp.ndarray, Any]:
    """Mean loss/grads over a leading microbatch axis of `batches`.

    batches: pytree whose leaves have shape (n_micro, micro_batch, ...).
    The scan keeps the accumulator resident; XLA emits a single fused
    accumulation loop (one HBM gradient buffer, not n_micro of them).

    grad_shardings: optional tree of shardings for the fp32 accumulator.
    Gradients need NOT match the parameter sharding — ZeRO-1 runs keep
    TP-only hot weights while the (4x larger) fp32 grad buffer stays
    fully 2-D sharded (EXPERIMENTS.md §Perf, qwen1.5-110b iteration 3).
    """
    n_micro = jax.tree_util.tree_leaves(batches)[0].shape[0]
    grad_fn = jax.value_and_grad(loss_fn)

    def constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, grad_shardings)

    def body(carry, mb):
        loss_acc, g_acc = carry
        loss, g = grad_fn(params, mb)
        g_acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (loss_acc + loss, constrain(g_acc)), None

    g0 = constrain(jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))
    (loss_sum, g_sum), _ = jax.lax.scan(body, (jnp.float32(0.0), g0),
                                        batches)
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree_util.tree_map(
        lambda g: g * inv, g_sum)


# --------------------------------------------------------------------------
# int8 error-feedback compression
# --------------------------------------------------------------------------

def compress_int8(g: jnp.ndarray, residual: jnp.ndarray | None = None):
    """Symmetric per-tensor int8 quantization with error feedback.

    Returns (q int8, scale fp32 scalar, new_residual fp32).
    """
    gf = g.astype(jnp.float32)
    if residual is not None:
        gf = gf + residual
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_residual = gf - deq
    return q, scale, new_residual


def decompress_int8(q: jnp.ndarray, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jnp.ndarray, axis_name: str,
                    residual: jnp.ndarray | None = None):
    """psum an int8-compressed gradient along `axis_name` (shard_map ctx).

    The wire format is int8 (4x fewer bytes than fp32); the sum itself is
    carried in int32 to avoid overflow, then rescaled. Scales are maxed
    across the axis so all replicas agree on the dequant factor.
    """
    q, scale, new_residual = compress_int8(g, residual)
    scale = jax.lax.pmax(scale, axis_name)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale, new_residual
