"""AdamW (decoupled weight decay) on parameter pytrees.

Optimizer state is kept in fp32 regardless of the param dtype (mixed-
precision discipline); the sharding layer places m/v on the same mesh
axes as the parameters, so state memory scales down with both the FSDP
and TP axes (ZeRO-style for free, see launch/sharding.py).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    """Returns (new_params, new_state). lr may be a traced scalar."""
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1.0 - b1) * gf
        v2 = b2 * v + (1.0 - b2) * gf * gf
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        # Decoupled weight decay on matrices only (ndim >= 2), the usual
        # no-decay-on-norms/bias convention.
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def clip_by_global_norm(grads, max_norm: float):
    """Returns (clipped_grads, global_norm)."""
    sq = jax.tree_util.tree_map(
        lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads)
    gn = jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.float32(0)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
        grads), gn


def sgd_update(params, grads, *, lr):
    return jax.tree_util.tree_map(
        lambda p, g: (p.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
