from .optimizer import adamw_init, adamw_update, clip_by_global_norm  # noqa: F401
from .schedule import cosine_warmup  # noqa: F401
from .grad import (  # noqa: F401
    accumulate_gradients,
    compress_int8,
    decompress_int8,
)
