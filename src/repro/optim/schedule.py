"""Learning-rate schedules (pure functions of the step)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(step, *, base_lr: float, warmup_steps: int,
                  total_steps: int, min_ratio: float = 0.1):
    """Linear warmup then cosine decay to min_ratio * base_lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.maximum(warmup_steps, 1)
    warm_lr = base_lr * step / warm
    t = jnp.clip((step - warmup_steps)
                 / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos_lr = base_lr * (min_ratio + (1 - min_ratio)
                        * 0.5 * (1.0 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm_lr, cos_lr)
