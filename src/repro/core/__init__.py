"""Core library: the paper's back-projection algorithms and CT pipeline."""

from .geometry import (  # noqa: F401
    CTGeometry,
    projection_matrices,
    projection_matrix,
    standard_geometry,
)
from .baseline import backproject_rtk, bilinear_gather  # noqa: F401
from .backproject import (  # noqa: F401
    bp_share,
    bp_subline,
    bp_subline_batch,
    bp_subline_symmetry_batch,
    bp_symmetry,
    bp_transpose,
    transpose_projections,
    volume_to_native,
    volume_to_transposed,
)
from .tiling import (  # noqa: F401
    TileSpec,
    make_tiles,
    pad_projection_batch,
    pick_tile_shape,
    plan_proj_chunks,
    plan_z_slabs,
    plan_z_units,
    translate_matrices,
)
from .variants import (  # noqa: F401
    KernelSpec,
    REGISTRY,
    VARIANTS,
    get_spec,
    get_variant,
    slab_safe_variant,
)
from .fdk import fdk_reconstruct  # noqa: F401
from .phantom import ball_phantom, shepp_logan_3d  # noqa: F401
