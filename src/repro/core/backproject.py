"""The paper's optimization ladder as composable pure-JAX back-projectors.

Every variant below consumes the *transposed* layouts introduced in §3.1.1:

    img_t:  (np, nw, nh)   img_t[s][x][y]  — detector columns contiguous
    mat:    (np, 3, 4)     index-space projection matrices
    vol_t:  (nx, ny, nz)   vol_t[i][j][k]  — Z contiguous (lane axis on TPU)

and must match ``baseline.backproject_rtk`` (after layout transposes) to
RMSE < 1e-5 — the paper's own validation criterion against RTK.

Ladder (paper Table 2):

    transpose   O1: layouts only
    share       O1+O2: hoist F/W/X out of the k loop
    symmetry    O1+O2+O3: y-dot for half the k range, mirror the rest
    subline     O1+O2+O4: two-stage interpolation through sMem
    subline_symmetry_batch
                O1..O5 = the paper's Algorithm 1 (symmetry_pf analogue);
                O6 (prefetch/double-buffer) exists only in the Pallas kernel,
                where the pallas_call pipeline provides it structurally.

These pure-JAX forms are (a) the oracles for the Pallas kernels, (b) the
variants benchmarked against each other in benchmarks/ (the Fig. 7/8
analogue): the FLOP and byte reductions of O2/O3/O5 are directly visible in
``cost_analysis`` of the jitted functions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Layout helpers (O1)
# --------------------------------------------------------------------------

def transpose_projections(img: jnp.ndarray) -> jnp.ndarray:
    """(np, nh, nw) -> (np, nw, nh)."""
    return jnp.swapaxes(img, 1, 2)


def volume_to_native(vol_t: jnp.ndarray) -> jnp.ndarray:
    """(nx, ny, nz) -> (nz, ny, nx)."""
    return jnp.transpose(vol_t, (2, 1, 0))


def volume_to_transposed(vol: jnp.ndarray) -> jnp.ndarray:
    """(nz, ny, nx) -> (nx, ny, nz)."""
    return jnp.transpose(vol, (2, 1, 0))


# --------------------------------------------------------------------------
# Shared pieces
# --------------------------------------------------------------------------

def _ij_grids(ni: int, nj: int, dtype=jnp.float32):
    i = jnp.arange(ni, dtype=dtype)[:, None]   # (ni, 1)
    j = jnp.arange(nj, dtype=dtype)[None, :]   # (1, nj)
    return i, j


def hoisted_fwx(mat_s: jnp.ndarray, ni: int, nj: int):
    """O2: the k-invariant per-(i,j) quantities for one projection.

    Returns F = 1/z, W = F*F, X = x (detector column), each (ni, nj).
    Exactness relies on mat_s[0,2] == mat_s[2,2] == 0, which geometry.py
    guarantees (V axis parallel to Z).
    """
    i, j = _ij_grids(ni, nj)
    z = mat_s[2, 0] * i + mat_s[2, 1] * j + mat_s[2, 3]
    f = 1.0 / z
    x = (mat_s[0, 0] * i + mat_s[0, 1] * j + mat_s[0, 3]) * f
    return f, f * f, x, z


def _y_coeffs(mat_s: jnp.ndarray, f: jnp.ndarray, ni: int, nj: int):
    """y(i,j,k) = a + b*k with a,b per-(i,j) — affine in k (O2)."""
    i, j = _ij_grids(ni, nj)
    a = (mat_s[1, 0] * i + mat_s[1, 1] * j + mat_s[1, 3]) * f
    b = mat_s[1, 2] * f
    return a, jnp.broadcast_to(b, a.shape)


def _interp_column(sm: jnp.ndarray, y: jnp.ndarray, nh: int):
    """1-D interpolation inside the sub-line buffer (Fig. 3b).

    sm: (..., nh) sub-line values; y: (..., nk) fractional row coords.
    Returns (vals, valid) of shape (..., nk).
    """
    y0 = jnp.floor(y)
    iy = y0.astype(jnp.int32)
    dy = y - y0
    valid = (iy >= 0) & (iy <= nh - 2)
    iyc = jnp.clip(iy, 0, nh - 2)
    s0 = jnp.take_along_axis(sm, iyc, axis=-1)
    s1 = jnp.take_along_axis(sm, iyc + 1, axis=-1)
    return s0 * (1.0 - dy) + s1 * dy, valid


def _subline_buffer(img_ts: jnp.ndarray, x: jnp.ndarray, nw: int):
    """O4 stage one: blend detector columns floor(x), floor(x)+1 (Fig. 3a).

    img_ts: (nw, nh) one transposed projection; x: (ni, nj).
    Returns (sMem (ni, nj, nh), x_valid (ni, nj)).
    """
    x0 = jnp.floor(x)
    ix = x0.astype(jnp.int32)
    dx = x - x0
    x_valid = (ix >= 0) & (ix <= nw - 2)
    ixc = jnp.clip(ix, 0, nw - 2)
    col0 = jnp.take(img_ts, ixc, axis=0)       # (ni, nj, nh)
    col1 = jnp.take(img_ts, ixc + 1, axis=0)   # (ni, nj, nh)
    return col0 * (1.0 - dx)[..., None] + col1 * dx[..., None], x_valid


# --------------------------------------------------------------------------
# O1: transpose only — per-voxel math identical to the baseline
# --------------------------------------------------------------------------

def _bp_transpose_single(img_ts: jnp.ndarray, mat_s: jnp.ndarray, vol_shape_xyz):
    ni, nj, nk = vol_shape_xyz
    nw, nh = img_ts.shape
    i = jnp.arange(ni, dtype=jnp.float32)[:, None, None]
    j = jnp.arange(nj, dtype=jnp.float32)[None, :, None]
    k = jnp.arange(nk, dtype=jnp.float32)[None, None, :]
    z = mat_s[2, 0] * i + mat_s[2, 1] * j + mat_s[2, 2] * k + mat_s[2, 3]
    f = 1.0 / z
    x = (mat_s[0, 0] * i + mat_s[0, 1] * j + mat_s[0, 2] * k + mat_s[0, 3]) * f
    y = (mat_s[1, 0] * i + mat_s[1, 1] * j + mat_s[1, 2] * k + mat_s[1, 3]) * f
    # Bilinear on the transposed image: img_t[x][y].
    x0 = jnp.floor(x); y0 = jnp.floor(y)
    ix = x0.astype(jnp.int32); iy = y0.astype(jnp.int32)
    dx = x - x0; dy = y - y0
    valid = (ix >= 0) & (ix <= nw - 2) & (iy >= 0) & (iy <= nh - 2) & (z > 0)
    ixc = jnp.clip(ix, 0, nw - 2); iyc = jnp.clip(iy, 0, nh - 2)
    v00 = img_ts[ixc, iyc]
    v10 = img_ts[ixc + 1, iyc]
    v01 = img_ts[ixc, iyc + 1]
    v11 = img_ts[ixc + 1, iyc + 1]
    s0 = v00 * (1.0 - dx) + v10 * dx
    s1 = v01 * (1.0 - dx) + v11 * dx
    val = s0 * (1.0 - dy) + s1 * dy
    return jnp.where(valid, val * f * f, 0.0)


@functools.partial(jax.jit, static_argnames=("vol_shape_xyz",))
def bp_transpose(img_t, mat, vol_shape_xyz):
    def body(s, vol):
        return vol + _bp_transpose_single(img_t[s], mat[s], vol_shape_xyz)
    vol0 = jnp.zeros(vol_shape_xyz, jnp.float32)
    return jax.lax.fori_loop(0, img_t.shape[0], body, vol0)


# --------------------------------------------------------------------------
# O1+O2: hoisting F/W/X
# --------------------------------------------------------------------------

def _bp_share_single(img_ts, mat_s, vol_shape_xyz):
    ni, nj, nk = vol_shape_xyz
    nw, nh = img_ts.shape
    f, w, x, z = hoisted_fwx(mat_s, ni, nj)
    a, b = _y_coeffs(mat_s, f, ni, nj)
    k = jnp.arange(nk, dtype=jnp.float32)
    y = a[..., None] + b[..., None] * k           # (ni, nj, nk)
    # Interpolation still per-point (no subline yet): gather 4 corners.
    x0 = jnp.floor(x); ix = x0.astype(jnp.int32); dx = x - x0
    x_valid = (ix >= 0) & (ix <= nw - 2) & (z > 0)
    ixc = jnp.clip(ix, 0, nw - 2)
    y0 = jnp.floor(y); iy = y0.astype(jnp.int32); dy = y - y0
    y_valid = (iy >= 0) & (iy <= nh - 2)
    iyc = jnp.clip(iy, 0, nh - 2)
    flat = img_ts.reshape(-1)
    v00 = flat[(ixc[..., None] * nh + iyc)]
    v10 = flat[((ixc + 1)[..., None] * nh + iyc)]
    v01 = flat[(ixc[..., None] * nh + iyc + 1)]
    v11 = flat[((ixc + 1)[..., None] * nh + iyc + 1)]
    s0 = v00 * (1.0 - dx)[..., None] + v10 * dx[..., None]
    s1 = v01 * (1.0 - dx)[..., None] + v11 * dx[..., None]
    val = s0 * (1.0 - dy) + s1 * dy
    ok = x_valid[..., None] & y_valid
    return jnp.where(ok, val * w[..., None], 0.0)


@functools.partial(jax.jit, static_argnames=("vol_shape_xyz",))
def bp_share(img_t, mat, vol_shape_xyz):
    def body(s, vol):
        return vol + _bp_share_single(img_t[s], mat[s], vol_shape_xyz)
    vol0 = jnp.zeros(vol_shape_xyz, jnp.float32)
    return jax.lax.fori_loop(0, img_t.shape[0], body, vol0)


# --------------------------------------------------------------------------
# O1+O2+O4: subline interpolation
# --------------------------------------------------------------------------

def _bp_subline_single(img_ts, mat_s, vol_shape_xyz):
    ni, nj, nk = vol_shape_xyz
    nw, nh = img_ts.shape
    f, w, x, z = hoisted_fwx(mat_s, ni, nj)
    sm, x_valid = _subline_buffer(img_ts, x, nw)  # (ni, nj, nh)
    a, b = _y_coeffs(mat_s, f, ni, nj)
    k = jnp.arange(nk, dtype=jnp.float32)
    y = a[..., None] + b[..., None] * k
    val, y_valid = _interp_column(sm, y, nh)
    ok = (x_valid & (z > 0))[..., None] & y_valid
    return jnp.where(ok, val * w[..., None], 0.0)


@functools.partial(jax.jit, static_argnames=("vol_shape_xyz",))
def bp_subline(img_t, mat, vol_shape_xyz):
    def body(s, vol):
        return vol + _bp_subline_single(img_t[s], mat[s], vol_shape_xyz)
    vol0 = jnp.zeros(vol_shape_xyz, jnp.float32)
    return jax.lax.fori_loop(0, img_t.shape[0], body, vol0)


def _nb_batched_scan(single_fn, img_t, mat, vol_shape_xyz, nb):
    """Shared O5 scaffold: scan over nb-batches of projections, vmap the
    in-batch contributions (partial sums stay in registers/VMEM), update
    the volume ONCE per batch — the 1/nb write-traffic reduction of
    §3.1.3. np must be divisible by nb (pad upstream via
    tiling.pad_projection_batch)."""
    n_proj = img_t.shape[0]
    assert n_proj % nb == 0, f"np={n_proj} not divisible by nb={nb}"
    img_b = img_t.reshape(n_proj // nb, nb, *img_t.shape[1:])
    mat_b = mat.reshape(n_proj // nb, nb, 3, 4)

    def body(vol, xs):
        img_bt, mat_bt = xs
        per = jax.vmap(single_fn)(img_bt, mat_bt)
        return vol + per.sum(axis=0), None

    vol0 = jnp.zeros(vol_shape_xyz, jnp.float32)
    vol, _ = jax.lax.scan(body, vol0, (img_b, mat_b))
    return vol


@functools.partial(jax.jit, static_argnames=("vol_shape_xyz", "nb"))
def bp_subline_batch(img_t, mat, vol_shape_xyz, nb: int = 8):
    """O1+O2+O4+O5: nb-batched subline WITHOUT the O3 mirror.

    The symmetry-free member of the batched family: exact on ANY
    translated sub-box of the volume (the O3 pairing k <-> nk-1-k is
    only meaningful when the box is centered on the volume's Z midplane),
    so the tiled engine uses it as the slab-safe fallback for arbitrary
    Z-slabs.
    """
    return _nb_batched_scan(
        lambda im, mm: _bp_subline_single(im, mm, vol_shape_xyz),
        img_t, mat, vol_shape_xyz, nb)


# --------------------------------------------------------------------------
# O1+O2+O3(+O4): symmetry — y-dot for k < nz/2 only, mirror the rest
# --------------------------------------------------------------------------

def _bp_symmetry_single(img_ts, mat_s, vol_shape_xyz, *, use_subline: bool):
    ni, nj, nk = vol_shape_xyz
    # Uneven half-split (matches the Pallas kernels): k in [0, khp)
    # computed directly — including the self-mirrored middle plane when
    # nk is odd — and k in [khp, nk) filled from the O3 mirror.
    kh = nk // 2           # mirrored half
    khp = nk - kh          # direct half (== kh, or kh+1 when nk odd)
    nw, nh = img_ts.shape
    f, w, x, z = hoisted_fwx(mat_s, ni, nj)
    a, b = _y_coeffs(mat_s, f, ni, nj)
    # O3 as a hoisted affine fold. The mirror identity gives the upper
    # half's row coordinate as y'(k) = (nh-1) - y(nk-1-k), which is
    # itself affine in k with the SAME slope b:
    #     y'(k) = (nh-1) - a - b*(nk-1) + b*k = a_m + b*k.
    # So the y dot-product runs once (for ``a``), the mirrored half
    # reuses it through the k-invariant intercept a_m, and BOTH halves
    # evaluate as ONE fused select+FMA over the full k range. The
    # previous formulation (compute the lower half, flip, concatenate)
    # de-fused the XLA CPU lowering and made symmetry_mp 2x SLOWER than
    # share_mp (BENCH_PR2 0.48x); this form is exact to ~1e-11 against
    # it and removes the flip/concat entirely.
    a_m = (nh - 1.0) - a - b * (nk - 1.0)
    k = jnp.arange(nk, dtype=jnp.float32)
    direct = k < khp       # lower half + middle plane: the direct dot
    y = jnp.where(direct, a[..., None], a_m[..., None]) + b[..., None] * k
    if use_subline:
        sm, x_valid = _subline_buffer(img_ts, x, nw)
        val, y_valid = _interp_column(sm, y, nh)
    else:
        # Per-point 4-corner gathers, shared x columns.
        x0 = jnp.floor(x); ix = x0.astype(jnp.int32); dx = x - x0
        x_valid = (ix >= 0) & (ix <= nw - 2)
        ixc = jnp.clip(ix, 0, nw - 2)
        flat = img_ts.reshape(-1)
        y0 = jnp.floor(y); iy = y0.astype(jnp.int32); dy = y - y0
        y_valid = (iy >= 0) & (iy <= nh - 2)
        iyc = jnp.clip(iy, 0, nh - 2)
        v00 = flat[(ixc[..., None] * nh + iyc)]
        v10 = flat[((ixc + 1)[..., None] * nh + iyc)]
        v01 = flat[(ixc[..., None] * nh + iyc + 1)]
        v11 = flat[((ixc + 1)[..., None] * nh + iyc + 1)]
        s0 = v00 * (1.0 - dx)[..., None] + v10 * dx[..., None]
        s1 = v01 * (1.0 - dx)[..., None] + v11 * dx[..., None]
        val = s0 * (1.0 - dy) + s1 * dy
    ok = (x_valid & (z > 0))[..., None] & y_valid
    return jnp.where(ok, val * w[..., None], 0.0)


@functools.partial(jax.jit, static_argnames=("vol_shape_xyz",))
def bp_symmetry(img_t, mat, vol_shape_xyz):
    def body(s, vol):
        return vol + _bp_symmetry_single(
            img_t[s], mat[s], vol_shape_xyz, use_subline=False)
    vol0 = jnp.zeros(vol_shape_xyz, jnp.float32)
    return jax.lax.fori_loop(0, img_t.shape[0], body, vol0)


# --------------------------------------------------------------------------
# O1..O5: the paper's Algorithm 1 — subline + symmetry + nb batching
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("vol_shape_xyz", "nb"))
def bp_subline_symmetry_batch(img_t, mat, vol_shape_xyz, nb: int = 8):
    """Paper Algorithm 1 semantics in pure JAX.

    Projections are processed in batches of ``nb`` (the shared
    ``_nb_batched_scan`` scaffold); within a batch the partial sums
    accumulate in values (registers/VMEM on TPU), and the volume is
    updated ONCE per batch — the 1/nb write-traffic reduction of §3.1.3.
    """
    return _nb_batched_scan(
        lambda im, mm: _bp_symmetry_single(im, mm, vol_shape_xyz,
                                           use_subline=True),
        img_t, mat, vol_shape_xyz, nb)


@functools.partial(jax.jit, static_argnames=("vol_shape_xyz",))
def bp_subline_symmetry_scan(img_t, mat, vol_shape_xyz):
    """Algorithm 1 semantics with SEQUENTIAL per-projection accumulation.

    Identical math to bp_subline_symmetry_batch but the in-batch vmap is
    replaced by a scan: peak temporaries are one volume-sized working set
    instead of nb of them (the vmap materializes nb copies of every
    (ni,nj,nz) intermediate). Used by the distributed/multi-pod path
    where per-device HBM bytes dominate (EXPERIMENTS.md §Perf, CT cell).
    """
    def body(vol, xs):
        img_s, mat_s = xs
        return vol + _bp_symmetry_single(img_s, mat_s, vol_shape_xyz,
                                         use_subline=True), None

    vol0 = jnp.zeros(vol_shape_xyz, jnp.float32)
    vol, _ = jax.lax.scan(body, vol0, (img_t, mat))
    return vol
