"""FDK pre-weighting and ramp filtering (Feldkamp, Davis, Kress 1984).

Back-projection (the paper's kernel) is stage 3 of FDK. Stages 1-2 are:

  1. cosine pre-weighting: p'(u,v) = p(u,v) * d / sqrt(d^2 + u^2 + v^2)
     (u, v physical detector coordinates relative to the center),
  2. row-wise ramp filtering along u (zero-padded FFT, Ram-Lak kernel with
     the standard discrete-space form of Kak & Slaney, eq. 61 — NOT the
     naive |w| sampling, which biases DC).

The overall FDK scale (including the 1/2 from the full-circle scan and the
angular step) is folded in here so the back-projector stays exactly the
paper's Listing-1 kernel with weight f^2 = 1/z^2 (the d^2 of the classical
(d/z)^2 FDK weight is also folded into the filter normalization).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .geometry import CTGeometry


def _next_pow2(n: int) -> int:
    return 1 << (n - 1).bit_length()


def ramlak_kernel_spatial(n_taps: int, du: float) -> np.ndarray:
    """Discrete Ram-Lak in the spatial domain (Kak & Slaney eq. 61).

    h[0] = 1/(4 du^2); h[n] = 0 for even n; h[n] = -1/(pi n du)^2 odd n.
    """
    ns = np.arange(-n_taps, n_taps + 1)
    h = np.zeros(ns.shape, dtype=np.float64)
    h[ns == 0] = 1.0 / (4.0 * du * du)
    odd = (ns % 2) != 0
    h[odd] = -1.0 / (np.pi * ns[odd] * du) ** 2
    return h


@functools.partial(jax.jit, static_argnames=("geom", "n_proj_total"))
def fdk_filter_chunk(projections: jnp.ndarray, geom: CTGeometry,
                     n_proj_total: int) -> jnp.ndarray:
    """Pre-weight + ramp-filter a CHUNK of raw projections.

    The filter is row-wise and per-projection independent, so filtering
    any partition of the projection set chunk-by-chunk is bitwise
    identical to filtering the whole array at once — this is what lets
    the streaming executor (runtime.executor) fuse filtering into the
    projection-chunk loop and never materialize the filtered set whole.
    The only whole-set dependence is the FDK angular step ``dtheta =
    2*pi / n_proj_total``, which therefore must be passed explicitly
    (the chunk's own leading dimension would mis-scale the result).
    """
    _, nh, nw = projections.shape
    d, D = geom.sad, geom.sdd
    du, dv = geom.det_spacing
    cu = (nw - 1) / 2.0
    cv = (nh - 1) / 2.0
    u = (jnp.arange(nw, dtype=jnp.float32) - cu) * du
    v = (jnp.arange(nh, dtype=jnp.float32) - cv) * dv
    # Cosine weight at the *physical* detector (distance D from source).
    cosw = D / jnp.sqrt(D * D + u[None, :] ** 2 + v[:, None] ** 2)
    weighted = projections * cosw[None]

    # FDK is derived on the *virtual detector* at the rotation axis: the
    # ramp must be discretized at the demagnified pitch du' = du * d / D.
    du_virt = float(du) * d / D

    # Row-wise convolution with the discrete ramp via zero-padded FFT.
    pad = _next_pow2(2 * nw)
    h = ramlak_kernel_spatial(nw, du_virt)            # length 2*nw+1
    h_pad = np.zeros(pad, dtype=np.float64)
    h_pad[: nw + 1] = h[nw:]                           # causal part
    h_pad[pad - nw:] = h[:nw]                          # anti-causal wrap
    H = jnp.asarray(np.fft.rfft(h_pad).real, jnp.float32)  # real, symmetric

    x = jnp.fft.rfft(weighted, n=pad, axis=-1)
    filt = jnp.fft.irfft(x * H[None, None, :], n=pad, axis=-1)[..., :nw]

    # FDK scale: (1/2) * dtheta * du' * d^2 (d^2 folded here; BP uses 1/z^2).
    dtheta = 2.0 * math.pi / int(n_proj_total)
    scale = 0.5 * dtheta * du_virt * d * d
    return (filt * scale).astype(jnp.float32)


def fdk_preweight_and_filter(projections: jnp.ndarray,
                             geom: CTGeometry) -> jnp.ndarray:
    """(np, nh, nw) raw projections -> filtered projections, same shape.

    Whole-set form: one chunk spanning every projection (the seed path).
    """
    return fdk_filter_chunk(projections, geom, projections.shape[0])
