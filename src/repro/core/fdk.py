"""End-to-end FDK reconstruction pipeline (filter -> back-project).

This is the paper's application context: FDK calls back-projection once;
iterative algorithms (SART/MLEM/...) call forward+back projection per
iteration — either way back-projection dominates, which is why the paper
optimizes it. Both entry points here are thin façades over the repo's
plan/compile/execute core (``runtime.planner`` / ``runtime.executor``):
the planner owns scheduling and option validation, the shared program
cache owns compilation, and the executor streams projection chunks —
so the untiled, tiled, and iterative paths are one code path with
different plans.
"""

from __future__ import annotations

from typing import Optional, Sequence, Union

import numpy as np

import jax.numpy as jnp

from .geometry import CTGeometry, projection_matrices


def _build_plan(geom: CTGeometry, variant: str, *, nb: int, interpret: bool,
                tiling, memory_budget: Optional[int],
                proj_batch: Optional[int], out: Optional[str],
                schedule: Optional[str] = None, ingest: str = "offline",
                precision: str = "f32", solver: str = "none",
                tuning=None, **kernel_options):
    """Shared façade-to-planner translation (tiling= conventions)."""
    from repro.runtime.planner import plan_reconstruction

    tiled = tiling is not None or memory_budget is not None
    if tiling == "auto" and memory_budget is None:
        raise ValueError(
            "tiling='auto' needs a memory_budget (bytes) to pick the "
            "tile shape; pass one or give an explicit (ti, tj, tk)")
    tile_shape = None if tiling in (None, "auto") else tuple(tiling)
    if out is None:
        out = "host" if tiled and solver == "none" else "device"
    return plan_reconstruction(
        geom, variant, tile_shape=tile_shape, memory_budget=memory_budget,
        nb=nb, proj_batch=proj_batch, out=out, interpret=interpret,
        schedule=schedule, ingest=ingest, precision=precision,
        solver=solver, tuning=tuning, **kernel_options)


def fdk_reconstruct(projections: jnp.ndarray, geom: CTGeometry,
                    variant: str = "algorithm1_mp", *,
                    nb: int = 8, interpret: bool = True,
                    tiling: Union[None, str, Sequence[int]] = None,
                    memory_budget: Optional[int] = None,
                    proj_batch: Optional[int] = None,
                    out: Optional[str] = None,
                    schedule: Optional[str] = None,
                    pipeline: Optional[str] = None,
                    precision: str = "f32",
                    tuning=None,
                    service=None,
                    devices=None,
                    **kernel_options) -> jnp.ndarray:
    """Reconstruct volume (nz, ny, nx) from raw projections (np, nh, nw).

    ``tiling`` routes the back-projection through the tiled schedule:
    pass a (ti, tj, tk) tile shape, or "auto" with a ``memory_budget`` in
    bytes to have the tile shape picked so one tile's working set fits
    the budget. ``None`` (default) keeps the untiled single-call plan.

    ``proj_batch`` streams the projections through in chunks of that
    many views (rounded up to a multiple of ``nb``), with FDK
    pre-weighting + ramp filtering fused into the chunk pipeline —
    neither the volume NOR the filtered projection set need fit in
    memory (the latter strictly under ``schedule="chunk"``).

    ``out`` selects the accumulator placement ("host" | "device");
    the default is "host" for tiled plans (the accumulator never
    materializes on device — that is the point) and "device" for the
    untiled plan. ``schedule`` selects the loop order: "step" (scanned
    device-resident tile accumulators, one host crossing per step),
    "chunk" (the chunk-major streaming loop), or None (default — the
    planner picks "chunk" when a ``memory_budget`` bounds device bytes,
    "step" otherwise). All parameter validation happens in the planner.

    ``pipeline`` selects the host flush discipline ("sync" — the
    default — | "async" — a flusher thread overlaps each unit's
    device->host accumulator copy with the next unit's dispatch, in
    every loop order; bit-identical output). ``variant="auto"`` (or an
    explicit ``tuning=`` cache/path) resolves the whole configuration
    — variant, schedule, pipeline, tile and chunk sizes — from the
    measured autotuner's persisted winners for THIS hardware
    (``runtime.autotune``; a cache miss falls back to exactly the
    heuristics described above, and ``ReconService.warmup(tune=True)``
    or ``runtime.autotune.autotune`` populate the cache). ``service``
    routes the request through a
    :class:`repro.runtime.service.ReconService` instead of a one-shot
    executor: repeated same-shape calls land in the same bucket and
    reuse its cached plan + compiled programs (warm requests never
    retrace), and the call shares the service's bounded FIFO request
    queue with any concurrent submitters. The service's bucket
    executors own the flush discipline (``ReconService(pipeline=)``),
    so combining ``service=`` with an explicit ``pipeline=`` is an
    error rather than a silent override.

    ``devices`` shards the step schedule across a reconstruction fleet
    (``PlanExecutor.execute_fleet``): ``"all"`` uses every local
    device, an int N the first N, a sequence (or a
    ``runtime.executor.FleetConfig``) exactly those. Steps run with
    straggler-aware work stealing and per-step failover; the output
    equals the single-device walk (disjoint step boxes). Defaults
    ``out`` to "host" (the fleet accumulates on host). Device
    placement is owned by a service's buckets (``ReconService
    (devices=)``), so ``service=`` + ``devices=`` is an error.
    """
    from repro.runtime.executor import PlanExecutor, as_fleet_config

    if service is not None:
        if pipeline is not None:
            raise ValueError(
                "pipeline= is owned by the service's bucket executors "
                "(ReconService(pipeline=...)); do not pass both "
                "service= and pipeline=")
        if devices is not None:
            raise ValueError(
                "devices= is owned by the service's bucket executors "
                "(ReconService(devices=...)); do not pass both "
                "service= and devices=")
        return service.reconstruct(
            projections, geom, variant=variant, nb=nb, interpret=interpret,
            tiling=tiling, memory_budget=memory_budget,
            proj_batch=proj_batch, out=out, schedule=schedule,
            precision=precision, tuning=tuning, **kernel_options)
    fleet = as_fleet_config(devices)
    if fleet is not None:
        # the fleet accumulates per-device step outputs into a host
        # volume over the step schedule; default unset knobs to that
        # placement (explicit contrary choices fail fast in the
        # executor's validation)
        out = out or "host"
        schedule = schedule or "step"
    if variant == "auto" or tuning is not None:
        # lookup-only tuned resolution: the config also carries the
        # executor-level pipeline knobs the plan cannot
        from repro.runtime.autotune import as_tuning_cache, resolve_config
        cfg = resolve_config(
            geom, variant, cache=as_tuning_cache(tuning), nb=nb,
            interpret=interpret, tiling=tiling,
            memory_budget=memory_budget, proj_batch=proj_batch, out=out,
            schedule=schedule, precision=precision, **kernel_options)
        if pipeline is None and fleet is None:
            ex = PlanExecutor.from_config(geom, cfg)
        else:                         # explicit override beats the cache
            ex = PlanExecutor(geom, cfg.build_plan(geom),
                              pipeline=cfg.pipeline if pipeline is None
                              else pipeline,
                              pipeline_depth=cfg.pipeline_depth,
                              tuned=cfg, fleet=fleet)
        return ex.reconstruct(projections)
    plan = _build_plan(geom, variant, nb=nb, interpret=interpret,
                       tiling=tiling, memory_budget=memory_budget,
                       proj_batch=proj_batch, out=out, schedule=schedule,
                       precision=precision, **kernel_options)
    return PlanExecutor(
        geom, plan,
        pipeline="sync" if pipeline is None else pipeline,
        fleet=fleet,
    ).reconstruct(projections)


def _vol_to_native(vol_t):
    """(nx, ny, nz) -> (nz, ny, nx) for either host or device arrays."""
    if isinstance(vol_t, np.ndarray):
        return np.transpose(vol_t, (2, 1, 0))
    from . import backproject as bp
    return bp.volume_to_native(vol_t)


def sart_step(vol_zyx: jnp.ndarray, projections: jnp.ndarray,
              geom: CTGeometry, *, relax: float = 0.25,
              variant: str = "algorithm1_mp", nb: int = 8,
              oversample: float = 1.0, interpret: bool = True,
              tiling: Union[None, str, Sequence[int]] = None,
              memory_budget: Optional[int] = None,
              proj_batch: Optional[int] = None,
              schedule: Optional[str] = None,
              precision: str = "f32",
              **kernel_options) -> jnp.ndarray:
    """One SART update (demonstrates the paper's iterative-recon use).

    Standard SART (Andersen & Kak):

        x += relax * (1 / BP(1)) * BP( (P - FP(x)) / FP(1_vol) )

    FP(1_vol) are the per-ray intersection lengths (projection-domain
    row sums of the system matrix); BP(1) the voxel-domain column sums.

    Thin façade over ``runtime.solvers`` (``n_iters=1``): repeated
    calls with the same configuration land on the SAME persistent
    :class:`~repro.runtime.solvers.IterativeExecutor`, so the
    normalizers are computed once and iterations 2..N of a caller's
    outer loop dispatch warm — no per-call ``PlanExecutor`` rebuild.
    ``interpret=`` still reaches the Pallas variants and ``tiling=`` /
    ``memory_budget=`` / ``proj_batch=`` keep the bounded per-call
    working set of ``fdk_reconstruct``.
    """
    from repro.runtime.solvers import solver_executor

    # out="device" even when tiled: SART's forward projection needs the
    # volume on device every iteration anyway, so host staging of the
    # BP accumulators would only add two full-volume round-trips. The
    # tiling/proj_batch benefit here is the bounded PER-CALL working set
    # (kernel temporaries), not accumulator placement.
    plan = _build_plan(geom, variant, nb=nb, interpret=interpret,
                       tiling=tiling, memory_budget=memory_budget,
                       proj_batch=proj_batch, out="device",
                       schedule=schedule, precision=precision,
                       solver="sart", **kernel_options)
    ex = solver_executor(geom, plan, oversample=oversample)
    vol, _report = ex.solve(projections, n_iters=1, relax=relax,
                            x0=vol_zyx)
    return vol
