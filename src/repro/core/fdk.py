"""End-to-end FDK reconstruction pipeline (filter -> back-project).

This is the paper's application context: FDK calls back-projection once;
iterative algorithms (SART/MLEM/...) call forward+back projection per
iteration — either way back-projection dominates, which is why the paper
optimizes it. The pipeline is variant-parameterized so every kernel in
``core.variants`` (and the Pallas kernels) is drop-in.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import backproject as bp
from .filtering import fdk_preweight_and_filter
from .geometry import CTGeometry, projection_matrices
from .variants import get_variant


def fdk_reconstruct(projections: jnp.ndarray, geom: CTGeometry,
                    variant: str = "algorithm1_mp", *,
                    nb: int = 8, interpret: bool = True,
                    tiling=None, memory_budget: int | None = None
                    ) -> jnp.ndarray:
    """Reconstruct volume (nz, ny, nx) from raw projections (np, nh, nw).

    ``tiling`` routes the back-projection through the tiled streaming
    engine (runtime.engine.TiledReconstructor): pass a (ti, tj, tk) tile
    shape, or "auto" with a ``memory_budget`` in bytes to have the tile
    shape picked so one tile's working set fits the budget. ``None``
    (default) keeps the untiled single-call path.

    NOTE: the tiled path returns a host-resident numpy volume (the
    accumulator never materializes on device — that is the point);
    construct ``TiledReconstructor(..., out="device")`` directly if a
    device-committed result is needed.
    """
    if tiling is not None or memory_budget is not None:
        from repro.runtime.engine import TiledReconstructor

        if tiling == "auto" and memory_budget is None:
            raise ValueError(
                "tiling='auto' needs a memory_budget (bytes) to pick the "
                "tile shape; pass one or give an explicit (ti, tj, tk)")
        tile_shape = None if tiling in (None, "auto") else tuple(tiling)
        eng = TiledReconstructor(geom, variant, tile_shape=tile_shape,
                                 memory_budget=memory_budget, nb=nb,
                                 interpret=interpret)
        return eng.reconstruct(projections)
    filtered = fdk_preweight_and_filter(projections, geom)
    mats = projection_matrices(geom)
    img_t = bp.transpose_projections(filtered)
    fn = get_variant(variant)
    vol_t = fn(img_t, mats, geom.volume_shape_xyz, nb=nb, interpret=interpret)
    return bp.volume_to_native(vol_t)


def sart_step(vol_zyx: jnp.ndarray, projections: jnp.ndarray,
              geom: CTGeometry, *, relax: float = 0.25,
              variant: str = "algorithm1_mp", nb: int = 8,
              oversample: float = 1.0) -> jnp.ndarray:
    """One SART update (demonstrates the paper's iterative-recon use).

    Standard SART (Andersen & Kak):

        x += relax * (1 / BP(1)) * BP( (P - FP(x)) / FP(1_vol) )

    FP(1_vol) are the per-ray intersection lengths (projection-domain
    row sums of the system matrix); BP(1) the voxel-domain column sums.
    Both normalizers reuse the same forward/back projection kernels.
    """
    from .forward import forward_project

    mats = projection_matrices(geom)
    est = forward_project(vol_zyx, geom, oversample=oversample)
    ray_len = forward_project(jnp.ones_like(vol_zyx), geom,
                              oversample=oversample)
    resid = (projections - est) / jnp.maximum(ray_len, 1e-3)
    img_t = bp.transpose_projections(resid)
    fn = get_variant(variant)
    upd_t = fn(img_t, mats, geom.volume_shape_xyz, nb=nb)
    ones_t = bp.transpose_projections(jnp.ones_like(projections))
    norm_t = fn(ones_t, mats, geom.volume_shape_xyz, nb=nb)
    upd = bp.volume_to_native(upd_t)
    norm = bp.volume_to_native(norm_t)
    return vol_zyx + relax * upd / jnp.maximum(norm, 1e-12)
