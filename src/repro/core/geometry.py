"""Cone-beam CT (CBCT) geometry — the paper's Fig. 1 setup.

The X-ray source rotates on a circle of radius ``sad`` (source-axis
distance, the paper's ``d``) in the Z=0 plane. A flat-panel detector (FPD)
of ``nh x nw`` pixels sits at distance ``sdd`` (source-detector distance,
the paper's ``D``) from the source, perpendicular to the central ray. The
detector V axis is parallel to the world Z axis (paper §2.1.1), which is
what makes the transposition optimizations possible: a line of voxels along
Z projects onto a line of detector pixels along V.

All geometric information per view is collapsed into a 3x4 *projection
matrix* ``M`` acting on homogeneous voxel indices ``(i, j, k, 1)``:

    z      = M[2] . (i,j,k,1)        # depth along the central ray
    x_pix  = (M[0] . (i,j,k,1)) / z  # detector column (U), pixels
    y_pix  = (M[1] . (i,j,k,1)) / z  # detector row (V), pixels

Two structural facts the paper's optimizations rely on, and which hold
*exactly* for matrices built here (volume and detector centered):

  * ``M[0][2] == M[2][2] == 0`` — ``x`` and ``z`` are invariant in ``k``
    (hoisting, §3.1.2);
  * voxels mirrored about the volume's central XY plane project to
    ``y' = (nh-1) - y`` (geometric symmetry, §3.1.2 after Zhao et al.).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CTGeometry:
    """Full description of a circular-trajectory CBCT acquisition."""

    # Volume, in voxels (paper: nx, ny, nz; row-major volume[z][y][x]).
    nx: int
    ny: int
    nz: int
    # Flat-panel detector, in pixels (paper: nw wide (U), nh tall (V)).
    nw: int
    nh: int
    # Number of projections over the full circle (paper: np).
    n_proj: int
    # Source-axis distance d and source-detector distance D (world units).
    sad: float
    sdd: float
    # Physical voxel pitch (sx, sy, sz) and detector pixel pitch (du, dv).
    voxel_size: Tuple[float, float, float]
    det_spacing: Tuple[float, float]

    # ---- derived ---------------------------------------------------------
    @property
    def magnification(self) -> float:
        return self.sdd / self.sad

    @property
    def angles(self) -> np.ndarray:
        """View angles, full 2*pi circle, endpoint excluded."""
        return np.linspace(0.0, 2.0 * math.pi, self.n_proj, endpoint=False)

    @property
    def volume_shape_zyx(self) -> Tuple[int, int, int]:
        """RTK/native layout volume[nz][ny][nx]."""
        return (self.nz, self.ny, self.nx)

    @property
    def volume_shape_xyz(self) -> Tuple[int, int, int]:
        """Transposed layout volume[nx][ny][nz] (paper Algorithm 1)."""
        return (self.nx, self.ny, self.nz)

    @property
    def proj_shape_hw(self) -> Tuple[int, int, int]:
        """RTK/native layout img[np][nh][nw]."""
        return (self.n_proj, self.nh, self.nw)

    @property
    def proj_shape_wh(self) -> Tuple[int, int, int]:
        """Transposed layout img[np][nw][nh] (paper Algorithm 1)."""
        return (self.n_proj, self.nw, self.nh)

    def voxel_updates(self, n_proj: int | None = None) -> int:
        """Total voxel updates — numerator of the paper's GUPS metric."""
        n = self.n_proj if n_proj is None else n_proj
        return self.nx * self.ny * self.nz * n


def standard_geometry(
    n: int = 64,
    n_det: int | None = None,
    n_proj: int | None = None,
    *,
    sad: float = 1000.0,
    sdd: float = 1536.0,
) -> CTGeometry:
    """A well-conditioned default geometry, RabbitCT-flavoured.

    The detector is sized so the cone fully covers the volume at the given
    magnification; the volume is a cube of ``n`` voxels spanning 256 world
    units (RabbitCT's C-arm dataset uses sad~1000mm, sdd~1536mm).
    """
    n_det = n_det if n_det is not None else n
    n_proj = n_proj if n_proj is not None else n
    extent = 256.0  # world units across the volume
    vox = extent / n
    # Project the volume's circumscribing sphere onto the detector and pad.
    mag = sdd / sad
    det_extent = extent * mag * 1.25
    du = det_extent / n_det
    return CTGeometry(
        nx=n, ny=n, nz=n,
        nw=n_det, nh=n_det,
        n_proj=n_proj,
        sad=sad, sdd=sdd,
        voxel_size=(vox, vox, vox),
        det_spacing=(du, du),
    )


def projection_matrix(geom: CTGeometry, theta: float) -> np.ndarray:
    """Build the 3x4 index-space projection matrix for one view angle.

    Derivation (world frame): source s = (d cos t, d sin t, 0); optical axis
    unit vector points from source through the rotation axis; detector axes
    u_hat = (-sin t, cos t, 0), v_hat = (0,0,1) = Z (paper: V parallel Z).
    For world point p:

        z      = d - p_x cos t - p_y sin t           (paper §3.1.2)
        u_phys = D * (-p_x sin t + p_y cos t) / z
        v_phys = D * p_z / z

    with voxel index -> world mapping p = (idx - center) * pitch and pixel
    mapping x_pix = u_phys/du + (nw-1)/2, y_pix = v_phys/dv + (nh-1)/2.
    """
    d, D = geom.sad, geom.sdd
    sx, sy, sz = geom.voxel_size
    du, dv = geom.det_spacing
    cx = (geom.nx - 1) / 2.0
    cy = (geom.ny - 1) / 2.0
    cz = (geom.nz - 1) / 2.0
    cu = (geom.nw - 1) / 2.0
    cv = (geom.nh - 1) / 2.0
    ct, st = math.cos(theta), math.sin(theta)

    # Depth row: z = d - p_x ct - p_y st, p_x = (i - cx) sx, p_y = (j - cy) sy
    rz = np.array(
        [-sx * ct, -sy * st, 0.0, d + cx * sx * ct + cy * sy * st],
        dtype=np.float64,
    )
    # Physical detector u: D * (-p_x st + p_y ct)
    ru = (D / du) * np.array(
        [-sx * st, sy * ct, 0.0, cx * sx * st - cy * sy * ct],
        dtype=np.float64,
    )
    # Physical detector v: D * p_z
    rv = (D / dv) * np.array([0.0, 0.0, sz, -cz * sz], dtype=np.float64)

    m = np.stack([ru + cu * rz, rv + cv * rz, rz])
    return m.astype(np.float32)


def projection_matrices(geom: CTGeometry) -> jnp.ndarray:
    """All per-view matrices, shape (n_proj, 3, 4) float32."""
    mats = np.stack([projection_matrix(geom, t) for t in geom.angles])
    return jnp.asarray(mats)


def source_positions(geom: CTGeometry) -> np.ndarray:
    """World-space source positions per view, shape (n_proj, 3)."""
    t = geom.angles
    return np.stack(
        [geom.sad * np.cos(t), geom.sad * np.sin(t), np.zeros_like(t)], axis=-1
    ).astype(np.float32)


def detector_frame(geom: CTGeometry, theta: float):
    """(origin, u_hat*du, v_hat*dv) of the detector plane in world space.

    ``origin`` is the world position of detector pixel (0, 0) (x_pix=0,
    y_pix=0); stepping one pixel in x_pix adds ``ustep``; one pixel in
    y_pix adds ``vstep``. Used by the ray-driven forward projector.
    """
    d, D = geom.sad, geom.sdd
    du, dv = geom.det_spacing
    ct, st = math.cos(theta), math.sin(theta)
    src = np.array([d * ct, d * st, 0.0])
    axis_dir = -np.array([ct, st, 0.0])  # source -> rotation axis
    center = src + D * axis_dir  # detector center (pixel (cu, cv))
    u_hat = np.array([-st, ct, 0.0])
    v_hat = np.array([0.0, 0.0, 1.0])
    cu = (geom.nw - 1) / 2.0
    cv = (geom.nh - 1) / 2.0
    origin = center - cu * du * u_hat - cv * dv * v_hat
    return (
        origin.astype(np.float32),
        (du * u_hat).astype(np.float32),
        (dv * v_hat).astype(np.float32),
    )


def voxel_world_coords(geom: CTGeometry):
    """1-D world coordinate arrays (xs, ys, zs) of voxel centers."""
    sx, sy, sz = geom.voxel_size
    xs = (np.arange(geom.nx) - (geom.nx - 1) / 2.0) * sx
    ys = (np.arange(geom.ny) - (geom.ny - 1) / 2.0) * sy
    zs = (np.arange(geom.nz) - (geom.nz - 1) / 2.0) * sz
    return xs.astype(np.float32), ys.astype(np.float32), zs.astype(np.float32)
