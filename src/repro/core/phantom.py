"""3-D Shepp-Logan phantom — the synthetic data source for every CT test.

Standard 10-ellipsoid definition (Kak & Slaney variant with the commonly
used "modified" contrast values so soft-tissue detail is visible). The
phantom lives in the unit cube [-1, 1]^3 and is sampled at voxel centers.
"""

from __future__ import annotations

import numpy as np

# (value, x0, y0, z0, a, b, c, phi_deg) — value is *additive* density,
# (x0,y0,z0) center, (a,b,c) semi-axes, phi rotation about Z.
_ELLIPSOIDS = [
    (1.00,  0.0,    0.0,    0.0,   0.69,  0.92,  0.81,   0.0),
    (-0.80, 0.0,   -0.0184, 0.0,   0.6624, 0.874, 0.780,  0.0),
    (-0.20, 0.22,   0.0,    0.0,   0.11,  0.31,  0.22, -18.0),
    (-0.20, -0.22,  0.0,    0.0,   0.16,  0.41,  0.28,  18.0),
    (0.10,  0.0,    0.35,  -0.15,  0.21,  0.25,  0.41,   0.0),
    (0.10,  0.0,    0.1,    0.25,  0.046, 0.046, 0.05,   0.0),
    (0.10,  0.0,   -0.1,    0.25,  0.046, 0.046, 0.05,   0.0),
    (0.10, -0.08,  -0.605,  0.0,   0.046, 0.023, 0.05,   0.0),
    (0.10,  0.0,   -0.606,  0.0,   0.023, 0.023, 0.02,   0.0),
    (0.10,  0.06,  -0.605,  0.0,   0.023, 0.046, 0.02,   0.0),
]


def shepp_logan_3d(nx: int, ny: int | None = None, nz: int | None = None,
                   dtype=np.float32) -> np.ndarray:
    """Sample the phantom on an (nx, ny, nz) grid; returns volume[z][y][x]."""
    ny = ny if ny is not None else nx
    nz = nz if nz is not None else nx
    xs = np.linspace(-1.0, 1.0, nx, dtype=np.float64)
    ys = np.linspace(-1.0, 1.0, ny, dtype=np.float64)
    zs = np.linspace(-1.0, 1.0, nz, dtype=np.float64)
    Z, Y, X = np.meshgrid(zs, ys, xs, indexing="ij")
    vol = np.zeros((nz, ny, nx), dtype=np.float64)
    for (val, x0, y0, z0, a, b, c, phi_deg) in _ELLIPSOIDS:
        phi = np.deg2rad(phi_deg)
        cp, sp = np.cos(phi), np.sin(phi)
        xr = (X - x0) * cp + (Y - y0) * sp
        yr = -(X - x0) * sp + (Y - y0) * cp
        zr = Z - z0
        inside = (xr / a) ** 2 + (yr / b) ** 2 + (zr / c) ** 2 <= 1.0
        vol += val * inside
    return vol.astype(dtype)


def ball_phantom(n: int, radius: float = 0.5, dtype=np.float32) -> np.ndarray:
    """A single centered ball — analytically checkable forward projections."""
    xs = np.linspace(-1.0, 1.0, n)
    Z, Y, X = np.meshgrid(xs, xs, xs, indexing="ij")
    return (X ** 2 + Y ** 2 + Z ** 2 <= radius ** 2).astype(dtype)
