"""Volume tiling / slab decomposition shared by the engine and the mesh.

The paper's locality story (§3.1) is about bounding the *working set*:
transposed layouts make a voxel line's detector footprint contiguous,
sub-line buffers shrink the per-line image traffic, and nb-batching cuts
volume write traffic. This module supplies the geometric substrate that
lets any back-projection variant run on a *sub-box* of the volume with
unchanged kernels, which is what makes O(tile) working sets (and
larger-than-memory volumes) possible:

  * ``translate_matrices`` — shifting the voxel-index origin by
    ``(i0, j0, k0)`` folds into the constant column of the 3x4 projection
    matrix, so a kernel handed the translated matrix reconstructs the
    sub-box exactly (the iFDK slab trick, arXiv:1909.02724, extended to
    all three axes);
  * ``make_tiles`` / ``plan_z_units`` — remainder-aware decompositions of
    the volume into (i, j)-tiles x Z-slabs. Z-slabs are planned in
    *mirror pairs* about the volume center so the detector-row symmetry
    (paper O3: ``y' = (nh-1) - y`` pairs voxel ``k`` with ``nz-1-k``)
    stays exact for symmetry-carrying variants;
  * ``pick_tile_shape`` — a tile-size auto-picker from a byte budget,
    modeling the vmapped temporaries of the pure-JAX ladder;
  * ``pad_projection_batch`` — tail-batch padding (zero images + repeated
    matrices) so nb-batched variants accept any projection count.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax.numpy as jnp


def translate_matrices(mat: jnp.ndarray, i0, j0, k0=0.0) -> jnp.ndarray:
    """Shift voxel-index origin by (i0, j0, k0): fold into the const col.

    mat: (..., 3, 4). Projection of (i+i0, j+j0, k+k0, 1) under M equals
    projection of (i, j, k, 1) under M' where
    M'[:, 3] += i0*M[:, 0] + j0*M[:, 1] + k0*M[:, 2].

    The structural facts the optimizations rely on (M[0][2] == M[2][2]
    == 0) are preserved — only the constant column changes — so hoisting
    (O2) stays exact on any translated sub-box. Detector-row symmetry
    (O3) is a property of the *full* volume center: see ``plan_z_units``.
    """
    const = (mat[..., 3] + i0 * mat[..., 0] + j0 * mat[..., 1]
             + k0 * mat[..., 2])
    return jnp.concatenate([mat[..., :3], const[..., None]], axis=-1)


@dataclasses.dataclass(frozen=True)
class TileSpec:
    """One sub-box of the volume: origin (i0, j0, k0), size (ni, nj, nk)."""

    i0: int
    j0: int
    k0: int
    ni: int
    nj: int
    nk: int

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (self.ni, self.nj, self.nk)

    @property
    def slices(self) -> Tuple[slice, slice, slice]:
        return (slice(self.i0, self.i0 + self.ni),
                slice(self.j0, self.j0 + self.nj),
                slice(self.k0, self.k0 + self.nk))


@dataclasses.dataclass(frozen=True)
class ZUnit:
    """One Z-scheduling unit: a slab [k0, k0+nk), optionally *paired*.

    A paired unit covers BOTH [k0, k0+nk) and its mirror slab
    [nz-k0-nk, nz-k0): a symmetry-carrying variant called with virtual
    shape (ni, nj, 2*nk) and k-translation k0 computes the direct half
    into local k in [0, nk) and the O3-mirrored half into [nk, 2*nk),
    which after the variant's own flip corresponds exactly to the mirror
    slab (the pairing k <-> nz-1-k is the global one by construction).
    """

    k0: int
    nk: int
    paired: bool
    nz: int

    @property
    def mirror_k0(self) -> int:
        return self.nz - self.k0 - self.nk

    @property
    def centered(self) -> bool:
        """A non-paired unit symmetric about the volume Z-center."""
        return (not self.paired) and (2 * self.k0 + self.nk == self.nz)


def _axis_splits(n: int, t: int) -> List[Tuple[int, int]]:
    """[(origin, size), ...] covering [0, n) in steps of t (tail smaller)."""
    t = max(1, min(int(t), n))
    return [(o, min(t, n - o)) for o in range(0, n, t)]


def make_tiles(vol_shape_xyz: Sequence[int],
               tile_shape_xyz: Sequence[int]) -> List[TileSpec]:
    """Decompose the volume into sub-boxes of (at most) ``tile_shape_xyz``.

    Remainder-aware: tile shapes need not divide the volume; edge tiles
    shrink. The result is a disjoint exact cover of the volume.
    """
    nx, ny, nz = (int(v) for v in vol_shape_xyz)
    ti, tj, tk = (int(v) for v in tile_shape_xyz)
    return [TileSpec(i0, j0, k0, ni, nj, nk)
            for (i0, ni) in _axis_splits(nx, ti)
            for (j0, nj) in _axis_splits(ny, tj)
            for (k0, nk) in _axis_splits(nz, tk)]


def plan_z_units(nz: int, tk: int) -> List[ZUnit]:
    """Mirror-paired Z-slab plan: pairs of width ``tk`` taken from both
    ends inward, plus one centered middle slab for the remainder.

    Every unit is either *paired* (exact for symmetry variants via the
    virtual-2*nk trick, see ZUnit) or *centered* (exact directly, odd
    width allowed). The union covers [0, nz) disjointly.
    """
    nz, tk = int(nz), max(1, int(tk))
    units: List[ZUnit] = []
    lo = 0
    while nz - 2 * lo >= 2 * tk:
        units.append(ZUnit(lo, tk, True, nz))
        lo += tk
    if nz - 2 * lo > 0:
        units.append(ZUnit(lo, nz - 2 * lo, False, nz))
    return units


def plan_z_slabs(nz: int, tk: int) -> List[ZUnit]:
    """Plain (unpaired) Z-slab plan: disjoint cover with depth <= tk.

    The schedule for symmetry-FREE variants: no mirror pairing is
    needed for exactness, and unlike ``plan_z_units`` (whose centered
    middle slab may be up to ``2*tk - 1`` deep) every call is bounded
    by the requested tile depth.
    """
    nz = int(nz)
    return [ZUnit(o, s, False, nz) for o, s in _axis_splits(nz, tk)]


def tile_working_set_bytes(tile_shape_xyz: Sequence[int],
                           det_shape_wh: Sequence[int],
                           nb: int = 8, dtype_bytes: int = 4) -> int:
    """Estimated peak working set of one nb-batched variant call on a tile.

    Model (pure-JAX Algorithm 1, the worst case of the ladder): the
    in-batch vmap materializes nb copies of the (ni, nj, nh) sub-line
    buffer and the (ni, nj, nk) per-projection contribution, plus the
    tile accumulator and the resident projection batch.
    """
    ni, nj, nk = (int(v) for v in tile_shape_xyz)
    nw, nh = (int(v) for v in det_shape_wh)
    acc = ni * nj * nk
    temps = nb * ni * nj * (nk + nh)
    batch = nb * nw * nh
    return dtype_bytes * (acc + temps + batch)


def pick_tile_shape(vol_shape_xyz: Sequence[int],
                    det_shape_wh: Sequence[int],
                    budget_bytes: int, *, nb: int = 8,
                    pair_z: bool = False) -> Tuple[int, int, int]:
    """Choose the largest tile shape whose working set fits the budget.

    Strategy (paper §3.1 priorities): keep the full Z extent as long as
    possible (full-Z tiles keep the O3 symmetry free and the voxel-line
    streaming contiguous), halving the larger of (ti, tj) first; only
    when the (i, j) footprint is exhausted start halving the Z slab.

    ``pair_z``: model the mirror-paired slab schedule of symmetry
    variants — a Z-slab of tk < nz is executed as ONE variant call of
    virtual depth 2*tk (engine._run_z_unit), so that is the depth the
    budget must fit.
    """
    ni, nj, nk = (int(v) for v in vol_shape_xyz)
    ti, tj, tk = ni, nj, nk

    def cost(ti_, tj_, tk_):
        eff = min(2 * tk_, nk) if (pair_z and tk_ < nk) else tk_
        return tile_working_set_bytes((ti_, tj_, eff), det_shape_wh,
                                      nb=nb)

    while cost(ti, tj, tk) > budget_bytes:
        if ti == tj == tk == 1:
            break  # budget below the floor: return the minimal tile
        if max(ti, tj) > 1:
            if ti >= tj:
                ti = max(1, ti // 2)
            else:
                tj = max(1, tj // 2)
        else:
            tk = max(1, tk // 2)
    return (ti, tj, tk)


def plan_proj_chunks(n_proj: int, nb: int,
                     proj_batch: int | None = None
                     ) -> Tuple[int, int, List[Tuple[int, int]]]:
    """Projection-chunk schedule: (n_padded, chunk_size, [(s0, s1), ...]).

    The projection axis is padded up to a multiple of ``nb`` (see
    ``pad_projection_batch`` for the zero-image/repeated-matrix padding
    that makes this exact) and covered by disjoint chunks of
    ``proj_batch`` rounded UP to an nb multiple (``None`` = one chunk).
    Every chunk size is an nb multiple, so nb-batched variants accept
    any chunk without re-padding — the pad happens once, globally.
    """
    n_proj, nb = int(n_proj), max(1, int(nb))
    n_pad = -(-n_proj // nb) * nb
    if proj_batch is None:
        chunk = n_pad
    else:
        proj_batch = int(proj_batch)
        if proj_batch < 1:
            raise ValueError(f"proj_batch must be >= 1, got {proj_batch}")
        chunk = min(n_pad, -(-proj_batch // nb) * nb)
    return n_pad, chunk, [(s0, min(s0 + chunk, n_pad))
                          for s0 in range(0, n_pad, chunk)]


def pad_projection_batch(img_t: jnp.ndarray, mat: jnp.ndarray,
                         multiple: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pad (np, nw, nh) projections + (np, 3, 4) matrices to a multiple.

    Padding images are ZERO (back-projection is linear, so they add
    nothing); padding matrices REPEAT the last real matrix (a valid
    geometry, so no 1/z poles or NaN x 0 can leak into the volume).
    """
    n_proj = img_t.shape[0]
    multiple = max(1, int(multiple))
    rem = n_proj % multiple
    if rem == 0:
        return img_t, mat
    pad = multiple - rem
    img_pad = jnp.concatenate(
        [img_t, jnp.zeros((pad,) + img_t.shape[1:], img_t.dtype)], axis=0)
    mat_pad = jnp.concatenate(
        [mat, jnp.broadcast_to(mat[-1:], (pad, 3, 4))], axis=0)
    return img_pad, mat_pad
