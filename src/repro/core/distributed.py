"""Multi-pod distributed back-projection (iFDK-style scale-out).

Distribution scheme (DESIGN.md §4, mirrors the authors' own SC'19 iFDK):

  * volume sharded over the pod mesh: x -> "data", y -> "model"
    (each device owns an (nx/16, ny/16, nz) voxel slab);
  * a projection batch of nb images is REPLICATED within a pod and
    SHARDED over the "pod" axis (each pod back-projects a disjoint
    angle subset) — partial volumes are psum'd over "pod";
  * each device back-projects its slab with *translated* projection
    matrices: projecting voxel (i+i0, j+j0, k) equals projecting
    (i, j, k) with a matrix whose constant column absorbs the offset —
    so the single-device kernels (pure-JAX ladder or Pallas) run
    UNCHANGED inside shard_map. Locality is preserved at cluster scope:
    the inner loop is all-gather-free; only the final pod-axis
    all-reduce crosses the DCN.

The driver accumulates volume across batches: vol += step(img_batch) —
the paper's O5 batching at the cluster level (one volume buffer, one
reduction per batch).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .backproject import bp_subline_symmetry_batch, \
    bp_subline_symmetry_scan
from .geometry import CTGeometry


def translate_matrices(mat: jnp.ndarray, i0, j0) -> jnp.ndarray:
    """Shift voxel-index origin by (i0, j0): fold into the constant col.

    mat: (..., 3, 4). Projection of (i+i0, j+j0, k, 1) under M equals
    projection of (i, j, k, 1) under M' where M'[:, 3] += i0*M[:, 0] +
    j0*M[:, 1].
    """
    const = (mat[..., 3] + i0 * mat[..., 0] + j0 * mat[..., 1])
    return jnp.concatenate([mat[..., :3], const[..., None]], axis=-1)


def _pad_up(n: int, k: int) -> int:
    return ((n + k - 1) // k) * k


def make_distributed_bp(geom: CTGeometry, mesh, *, nb: int = 32,
                        variant: str = "scan", inner_nb: int = 8):
    """Build (fn, (img_spec, mat_spec, out_spec)) for one projection batch.

    fn(img_t_batch (nb, nw, nh), mat_batch (nb, 3, 4)) -> partial volume
    (nx_pad, ny_pad, nz) sharded (data, model, None). Call repeatedly over
    batches and accumulate (the driver owns the += and final unpad).
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    nd = axis_sizes.get("data", 1)
    nm = axis_sizes.get("model", 1)
    npod = axis_sizes.get("pod", 1)
    has_pod = "pod" in mesh.axis_names

    nx_pad = _pad_up(geom.nx, nd)
    ny_pad = _pad_up(geom.ny, nm)
    bi, bj = nx_pad // nd, ny_pad // nm
    nz = geom.nz

    in_specs = (P("pod" if has_pod else None, None, None),  # img over pod
                P("pod" if has_pod else None, None, None))  # mats over pod
    out_spec = P("data", "model", None)

    def shard_fn(img_t_local, mat_local):
        # slab origin from mesh coordinates
        di = jax.lax.axis_index("data")
        dj = jax.lax.axis_index("model")
        i0 = (di * bi).astype(jnp.float32)
        j0 = (dj * bj).astype(jnp.float32)
        mat_shift = translate_matrices(mat_local, i0, j0)
        if variant == "scan":
            # sequential accumulation: 1x volume-sized temporaries
            vol_local = bp_subline_symmetry_scan(
                img_t_local, mat_shift, (bi, bj, nz))
        else:
            # paper Algorithm 1 with in-batch vmap (nb-x temporaries)
            vol_local = bp_subline_symmetry_batch(
                img_t_local, mat_shift, (bi, bj, nz),
                nb=min(inner_nb, img_t_local.shape[0]))
        if has_pod:
            vol_local = jax.lax.psum(vol_local, "pod")
        return vol_local

    fn = jax.shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_spec, check_vma=False)
    return fn, (in_specs[0], in_specs[1], out_spec)


def distributed_backproject(projections_t: jnp.ndarray, mats: jnp.ndarray,
                            geom: CTGeometry, mesh, *, nb: int = 32):
    """Full distributed reconstruction loop over projection batches.

    projections_t: (np, nw, nh) transposed filtered projections.
    Returns volume (nx, ny, nz) (unpadded), sharded (data, model, None).
    """
    n_proj = projections_t.shape[0]
    assert n_proj % nb == 0
    fn, (img_spec, mat_spec, out_spec) = make_distributed_bp(
        geom, mesh, nb=nb)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    nx_pad = _pad_up(geom.nx, axis_sizes.get("data", 1))
    ny_pad = _pad_up(geom.ny, axis_sizes.get("model", 1))
    vol = jnp.zeros((nx_pad, ny_pad, geom.nz), jnp.float32)
    for s0 in range(0, n_proj, nb):
        vol = vol + fn(projections_t[s0:s0 + nb], mats[s0:s0 + nb])
    return vol[:geom.nx, :geom.ny]
