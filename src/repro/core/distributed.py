"""Multi-pod distributed back-projection (iFDK-style scale-out).

Distribution scheme (DESIGN.md §4, mirrors the authors' own SC'19 iFDK):

  * volume sharded over the pod mesh: x -> "data", y -> "model"
    (each device owns an (nx/16, ny/16, nz) voxel slab);
  * a projection batch of nb images is REPLICATED within a pod and
    SHARDED over the "pod" axis (each pod back-projects a disjoint
    angle subset) — partial volumes are psum'd over "pod";
  * each device back-projects its slab with *translated* projection
    matrices: projecting voxel (i+i0, j+j0, k) equals projecting
    (i, j, k) with a matrix whose constant column absorbs the offset —
    so the single-device kernels (pure-JAX ladder or Pallas) run
    UNCHANGED inside shard_map. Locality is preserved at cluster scope:
    the inner loop is all-gather-free; only the final pod-axis
    all-reduce crosses the DCN.

The driver accumulates volume across batches: vol += step(img_batch) —
the paper's O5 batching at the cluster level (one volume buffer, one
reduction per batch).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .backproject import bp_subline_symmetry_batch, \
    bp_subline_symmetry_scan
from .geometry import CTGeometry
from .tiling import translate_matrices  # noqa: F401  (re-export; moved)


def _shard_map(fn, mesh, in_specs, out_specs):
    """Version-portable shard_map (replication checks off: the psum over
    "pod" is the only cross-slab collective and is explicit)."""
    if hasattr(jax, "shard_map"):  # jax >= 0.6
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm  # jax 0.4.x
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def _pad_up(n: int, k: int) -> int:
    return ((n + k - 1) // k) * k


def make_distributed_bp(geom: CTGeometry, mesh, *, nb: int = 32,
                        variant: str = "scan", inner_nb: int = 8,
                        vol_shape_xyz=None):
    """Build (fn, (img_spec, mat_spec, out_spec)) for one projection batch.

    fn(img_t_batch (nb, nw, nh), mat_batch (nb, 3, 4), origin (2,) f32)
    -> partial volume (nx_pad, ny_pad, nz) sharded (data, model, None).
    Call repeatedly over batches and accumulate (the driver owns the +=
    and final unpad).

    ``vol_shape_xyz`` reconstructs a sub-box of the full volume;
    ``origin`` is the sub-box origin in global voxel indices, passed at
    CALL time (a traced (2,) array, replicated) so one compiled program
    serves every tile of the same shape: each device's slab origin is
    the tile origin plus its mesh offset, letting the tiled engine
    compose (i, j)-tiles with the data/model/pod mesh unchanged.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    nd = axis_sizes.get("data", 1)
    nm = axis_sizes.get("model", 1)
    npod = axis_sizes.get("pod", 1)
    has_pod = "pod" in mesh.axis_names

    ni, nj, nz = (geom.nx, geom.ny, geom.nz) if vol_shape_xyz is None \
        else tuple(int(v) for v in vol_shape_xyz)
    nx_pad = _pad_up(ni, nd)
    ny_pad = _pad_up(nj, nm)
    bi, bj = nx_pad // nd, ny_pad // nm

    in_specs = (P("pod" if has_pod else None, None, None),  # img over pod
                P("pod" if has_pod else None, None, None),  # mats over pod
                P(None))                                    # origin repl.
    out_spec = P("data", "model", None)

    def shard_fn(img_t_local, mat_local, origin):
        # slab origin from mesh coordinates + the (traced) tile origin
        di = jax.lax.axis_index("data")
        dj = jax.lax.axis_index("model")
        i0 = origin[0] + (di * bi).astype(jnp.float32)
        j0 = origin[1] + (dj * bj).astype(jnp.float32)
        mat_shift = translate_matrices(mat_local, i0, j0)
        if variant == "scan":
            # sequential accumulation: 1x volume-sized temporaries
            vol_local = bp_subline_symmetry_scan(
                img_t_local, mat_shift, (bi, bj, nz))
        else:
            # paper Algorithm 1 with in-batch vmap (nb-x temporaries)
            vol_local = bp_subline_symmetry_batch(
                img_t_local, mat_shift, (bi, bj, nz),
                nb=min(inner_nb, img_t_local.shape[0]))
        if has_pod:
            vol_local = jax.lax.psum(vol_local, "pod")
        return vol_local

    # jit so repeated calls (projection batches, same-shape tiles) reuse
    # one compiled program instead of re-tracing the shard_map each time
    fn = jax.jit(_shard_map(shard_fn, mesh, in_specs, out_spec))
    return fn, (in_specs[0], in_specs[1], in_specs[2], out_spec)


def make_fleet_bp(variant: str, call_shape: Tuple[int, int, int], *,
                  nb: int, n_chunks: int, chunk_size: int,
                  options=(), interpret: bool = True,
                  rb: Optional[int] = None):
    """Per-device step program for the reconstruction fleet
    (``runtime.executor.PlanExecutor.execute_fleet``).

    ``prog(img_s, mat_s, origin) -> vol_t(call_shape)`` where ``img_s``
    / ``mat_s`` are the stacked scan grids ``(n_chunks, chunk_size,
    ...)`` and ``origin`` is the step's sub-box origin ``(i0, j0,
    k_off)`` as a traced (3,) f32 array.

    ``rb`` (cross-request batching) adds a leading request axis: the
    program becomes ``prog(img_b, mat_s, origin) -> vol_b((rb,) +
    call_shape)`` with ``img_b`` of shape ``(rb, n_chunks, chunk_size,
    ...)`` — one ``vmap`` lane per batched request over the SAME
    origin-folded scan, so per-lane output is bit-identical to the
    rb=None program and one dispatch serves k requests' step.

    This is :func:`make_distributed_bp`'s translated-matrix trick lifted
    from mesh slabs to the fleet's per-device step queues: the origin
    folds into the matrices' constant column INSIDE the program
    (:func:`~repro.core.tiling.translate_matrices` under the jit), so
    ONE compiled program per (variant, call_shape, chunk grid) serves
    EVERY same-shape step on ANY device — a stolen or failed-over step
    is the same program called with a different origin on a different
    device, never a recompile. The ``lax.scan`` carries the step's
    accumulator across all projection chunks device-resident, exactly
    like the single-device step-major megaprogram.

    Non-jittable kernels (``KernelSpec.jittable=False`` — banded_pl
    reads concrete matrix values at trace time) fall back to a python
    chunk loop over concrete arrays; the origin fold and the
    one-host-crossing contract are unchanged.
    """
    from repro.core.variants import get_spec

    spec = get_spec(variant)
    opts = spec.resolve_options(
        {**dict(options), "nb": int(nb), "interpret": bool(interpret)})
    shape = tuple(call_shape)
    fn = spec.fn
    if spec.jittable:
        def one(img_s, mat_s, origin):
            mat_s = translate_matrices(mat_s, origin[0], origin[1],
                                       origin[2])

            def body(acc, xs):
                img_c, mat_c = xs
                return acc + fn(img_c, mat_c, shape, **opts), None

            acc, _ = jax.lax.scan(
                body, jnp.zeros(shape, jnp.float32), (img_s, mat_s))
            return acc
        if rb is None:
            return jax.jit(one)
        return jax.jit(jax.vmap(one, in_axes=(0, None, None)))

    def prog(img_s, mat_s, origin):
        mat_t = translate_matrices(mat_s, origin[0], origin[1], origin[2])

        def lane(img_l):
            acc = None
            for c in range(int(n_chunks)):
                part = fn(img_l[c], mat_t[c], shape, **opts)
                acc = part if acc is None else acc + part
            return acc
        if rb is None:
            return lane(img_s)
        return jnp.stack([lane(img_s[r]) for r in range(int(rb))])
    return prog


def distributed_backproject(projections_t: jnp.ndarray, mats: jnp.ndarray,
                            geom: CTGeometry, mesh, *, nb: int = 32,
                            variant: str = "scan"):
    """Full distributed reconstruction loop over projection batches.

    projections_t: (np, nw, nh) transposed filtered projections.
    Returns volume (nx, ny, nz) (unpadded), sharded (data, model, None).
    ``n_proj`` need not divide ``nb``: the tail batch is padded with zero
    images (+ repeated matrices), which contribute exactly nothing.

    The projection-chunk schedule comes from the planner's chunk
    substrate (``tiling.plan_proj_chunks``, exactly-nb batches over the
    actual padded extent), and the shard_map program is memoized in the
    shared ProgramCache, so repeated calls on one geometry + mesh never
    rebuild it. The tiled composition (``TiledReconstructor
    .backproject_distributed``) routes through a full ReconPlan.
    """
    from repro.runtime.executor import default_program_cache
    from .tiling import pad_projection_batch, plan_proj_chunks

    projections_t, mats = pad_projection_batch(projections_t, mats, nb)
    # chunk the ACTUAL padded extent by exactly-nb batches (the program's
    # batch size); geom/mesh are hashable, so the shared cache keys on
    # their values and equal setups reuse one shard_map program
    _, _, chunks = plan_proj_chunks(projections_t.shape[0], nb, nb)
    fn = default_program_cache().get_or_build(
        ("dist", variant, geom.volume_shape_xyz, nb, geom, mesh),
        lambda: make_distributed_bp(geom, mesh, nb=nb, variant=variant)[0])
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    nx_pad = _pad_up(geom.nx, axis_sizes.get("data", 1))
    ny_pad = _pad_up(geom.ny, axis_sizes.get("model", 1))
    origin = jnp.zeros((2,), jnp.float32)
    vol = jnp.zeros((nx_pad, ny_pad, geom.nz), jnp.float32)
    for s0, s1 in chunks:
        vol = vol + fn(projections_t[s0:s1], mats[s0:s1], origin)
    return vol[:geom.nx, :geom.ny]
