"""RTK-style baseline back-projection (the paper's Listing 1), in JAX.

This is the *reference semantics* every optimized variant must match to the
paper's validation bar (RMSE < 1e-5, §4.2). Layouts follow RTK exactly:

    img:    (np, nh, nw)   row-major projections, img[s][y][x]
    mat:    (np, 3, 4)     index-space projection matrices
    volume: (nz, ny, nx)   row-major volume, volume[k][j][i]

For every projection ``s`` and voxel ``(i,j,k)``:

    z = mat[s][2] . (i,j,k,1);  f = 1/z
    x = (mat[s][0] . (i,j,k,1)) * f
    y = (mat[s][1] . (i,j,k,1)) * f
    volume[k][j][i] += Bilinear(img[s], x, y) * f * f

Boundary convention (shared by ALL variants in this repo): a sample
contributes iff ``0 <= x <= nw-2+1`` is interpolable, i.e. ``floor(x)`` and
``floor(x)+1`` are both in-bounds (same for y), and ``z > 0``; otherwise the
contribution is exactly zero. Gathers are index-clamped so out-of-range
lanes read *some* valid element and are then masked — this keeps every
variant (JAX, Pallas, distributed) bit-comparable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def bilinear_gather(img: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray):
    """Bilinear interpolation of img[y][x] at fractional (x, y).

    img: (nh, nw). x, y: arbitrary (broadcastable) shapes. Returns
    (values, valid_mask) with the repo-wide boundary convention.
    """
    nh, nw = img.shape
    x0 = jnp.floor(x)
    y0 = jnp.floor(y)
    ix = x0.astype(jnp.int32)
    iy = y0.astype(jnp.int32)
    dx = x - x0
    dy = y - y0
    valid = (ix >= 0) & (ix <= nw - 2) & (iy >= 0) & (iy <= nh - 2)
    ixc = jnp.clip(ix, 0, nw - 2)
    iyc = jnp.clip(iy, 0, nh - 2)
    v00 = img[iyc, ixc]
    v01 = img[iyc, ixc + 1]
    v10 = img[iyc + 1, ixc]
    v11 = img[iyc + 1, ixc + 1]
    s0 = v00 * (1.0 - dx) + v01 * dx  # mix along x (paper's Listing 2)
    s1 = v10 * (1.0 - dx) + v11 * dx
    val = s0 * (1.0 - dy) + s1 * dy   # mix along y
    return val, valid


def _voxel_index_grid(nz: int, ny: int, nx: int, dtype=jnp.float32):
    """Homogeneous (i, j, k) coordinate grids, each (nz, ny, nx)."""
    k = jnp.arange(nz, dtype=dtype)[:, None, None]
    j = jnp.arange(ny, dtype=dtype)[None, :, None]
    i = jnp.arange(nx, dtype=dtype)[None, None, :]
    return i, j, k


def backproject_single(img_s: jnp.ndarray, mat_s: jnp.ndarray,
                       vol_shape_zyx) -> jnp.ndarray:
    """Back-project ONE projection onto a zero volume (zyx layout)."""
    nz, ny, nx = vol_shape_zyx
    i, j, k = _voxel_index_grid(nz, ny, nx)
    # dot4(mat[r], (i,j,k,1)) for the three rows.
    z = mat_s[2, 0] * i + mat_s[2, 1] * j + mat_s[2, 2] * k + mat_s[2, 3]
    f = 1.0 / z
    x = (mat_s[0, 0] * i + mat_s[0, 1] * j + mat_s[0, 2] * k + mat_s[0, 3]) * f
    y = (mat_s[1, 0] * i + mat_s[1, 1] * j + mat_s[1, 2] * k + mat_s[1, 3]) * f
    val, valid = bilinear_gather(img_s, x, y)
    w = f * f
    ok = valid & (z > 0)
    return jnp.where(ok, val * w, 0.0)


@functools.partial(jax.jit, static_argnames=("vol_shape_zyx",))
def backproject_rtk(img: jnp.ndarray, mat: jnp.ndarray,
                    vol_shape_zyx) -> jnp.ndarray:
    """Full baseline: sequential loop over projections (Listing 1 order).

    img (np, nh, nw); mat (np, 3, 4). Returns volume (nz, ny, nx) float32.
    The projection loop is a ``fori_loop`` (RTK iterates projections
    outermost, one full volume sweep per projection — maximal volume
    traffic; this is precisely the behaviour the paper's nb-batching
    removes).
    """
    nz, ny, nx = vol_shape_zyx

    def body(s, vol):
        return vol + backproject_single(img[s], mat[s], vol_shape_zyx)

    vol0 = jnp.zeros((nz, ny, nx), dtype=jnp.float32)
    return jax.lax.fori_loop(0, img.shape[0], body, vol0)
