"""Ray-driven cone-beam forward projector.

The paper synthesizes its evaluation projections with RTK's forward
projector (§4.2); we build the equivalent here so every experiment is
self-contained. For each detector pixel we march the ray from the source
to the pixel in fixed world-space steps, trilinearly sampling the volume.

This is deliberately the *dual* discretization of the back-projector
(voxel-driven BP vs ray-driven FP) — the standard unmatched pair used by
FDK pipelines. It is jitted and vmapped but NOT a performance target; the
paper's contribution is back-projection.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .geometry import CTGeometry, detector_frame, source_positions, voxel_world_coords


def trilinear_sample(vol_zyx: jnp.ndarray, px, py, pz, origin, inv_pitch):
    """Sample volume (z,y,x layout) at world points; zero outside."""
    nz, ny, nx = vol_zyx.shape
    # world -> fractional voxel index
    fx = (px - origin[0]) * inv_pitch[0]
    fy = (py - origin[1]) * inv_pitch[1]
    fz = (pz - origin[2]) * inv_pitch[2]
    x0 = jnp.floor(fx); y0 = jnp.floor(fy); z0 = jnp.floor(fz)
    ix = x0.astype(jnp.int32); iy = y0.astype(jnp.int32); iz = z0.astype(jnp.int32)
    dx = fx - x0; dy = fy - y0; dz = fz - z0
    valid = ((ix >= 0) & (ix <= nx - 2) & (iy >= 0) & (iy <= ny - 2)
             & (iz >= 0) & (iz <= nz - 2))
    ix = jnp.clip(ix, 0, nx - 2); iy = jnp.clip(iy, 0, ny - 2)
    iz = jnp.clip(iz, 0, nz - 2)
    flat = vol_zyx.reshape(-1)
    base = (iz * ny + iy) * nx + ix

    def at(dzi, dyi, dxi):
        return flat[base + (dzi * ny + dyi) * nx + dxi]

    c000 = at(0, 0, 0); c001 = at(0, 0, 1)
    c010 = at(0, 1, 0); c011 = at(0, 1, 1)
    c100 = at(1, 0, 0); c101 = at(1, 0, 1)
    c110 = at(1, 1, 0); c111 = at(1, 1, 1)
    c00 = c000 * (1 - dx) + c001 * dx
    c01 = c010 * (1 - dx) + c011 * dx
    c10 = c100 * (1 - dx) + c101 * dx
    c11 = c110 * (1 - dx) + c111 * dx
    c0 = c00 * (1 - dy) + c01 * dy
    c1 = c10 * (1 - dy) + c11 * dy
    return jnp.where(valid, c0 * (1 - dz) + c1 * dz, 0.0)


def _project_view_impl(vol_zyx, src, det_origin, ustep, vstep, vol_origin,
                       inv_pitch, n_steps: int, nh: int, nw: int, step_len,
                       t_near):
    """One projection image (nh, nw) for one view."""
    u = jnp.arange(nw, dtype=jnp.float32)
    v = jnp.arange(nh, dtype=jnp.float32)
    V, U = jnp.meshgrid(v, u, indexing="ij")       # (nh, nw)
    # Detector pixel world positions.
    px = det_origin[0] + U * ustep[0] + V * vstep[0]
    py = det_origin[1] + U * ustep[1] + V * vstep[1]
    pz = det_origin[2] + U * ustep[2] + V * vstep[2]
    dirx, diry, dirz = px - src[0], py - src[1], pz - src[2]
    norm = jnp.sqrt(dirx**2 + diry**2 + dirz**2)
    dirx, diry, dirz = dirx / norm, diry / norm, dirz / norm

    ts = t_near + (jnp.arange(n_steps, dtype=jnp.float32) + 0.5) * step_len

    def body(acc_t, t):
        sx = src[0] + dirx * t
        sy = src[1] + diry * t
        sz = src[2] + dirz * t
        return acc_t + trilinear_sample(vol_zyx, sx, sy, sz, vol_origin,
                                        inv_pitch), None

    acc, _ = jax.lax.scan(body, jnp.zeros((nh, nw), jnp.float32), ts)
    return acc * step_len


# kept under its historical name: one jitted per-view program
_project_view = jax.jit(_project_view_impl,
                        static_argnames=("n_steps", "nh", "nw"))

# one vmapped program serves a whole view chunk: the leading axis runs
# over per-view frames (src / det_origin / ustep / vstep), everything
# else — the volume, the march constants — is shared. jax.jit's own
# cache keys on (chunk length, static march grid), so equal-size chunks
# compile once; runtime.solvers additionally pins the builder behind a
# ProgramCache key so iterative compile counts stay auditable.
_project_views = jax.jit(
    jax.vmap(_project_view_impl,
             in_axes=(None, 0, 0, 0, 0, None, None, None, None, None,
                      None, None)),
    static_argnames=("n_steps", "nh", "nw"))


def march_params(geom: CTGeometry, oversample: float = 2.0):
    """Ray-march constants shared by every view of one geometry:
    ``(vol_origin, inv_pitch, step_len, t_near, n_steps)``. The march
    covers the volume's circumscribing sphere only."""
    sx, sy, sz = geom.voxel_size
    xs, ys, zs = voxel_world_coords(geom)
    vol_origin = jnp.asarray([xs[0], ys[0], zs[0]], jnp.float32)
    inv_pitch = jnp.asarray([1 / sx, 1 / sy, 1 / sz], jnp.float32)
    radius = 0.5 * float(np.sqrt((geom.nx*sx)**2 + (geom.ny*sy)**2
                                 + (geom.nz*sz)**2))
    t_near = geom.sad - radius
    t_far = geom.sad + radius
    step_len = min(sx, sy, sz) / oversample
    n_steps = int(np.ceil((t_far - t_near) / step_len))
    return vol_origin, inv_pitch, float(step_len), float(t_near), n_steps


def view_frames(geom: CTGeometry):
    """Per-view ray frames, stacked: ``(srcs, origins, usteps, vsteps)``
    each of shape (n_proj, 3) float32 — the vmapped axis of
    :data:`_project_views`."""
    srcs = source_positions(geom)
    origins = np.empty((geom.n_proj, 3), np.float32)
    usteps = np.empty((geom.n_proj, 3), np.float32)
    vsteps = np.empty((geom.n_proj, 3), np.float32)
    for p, theta in enumerate(geom.angles):
        origins[p], usteps[p], vsteps[p] = detector_frame(geom, float(theta))
    return srcs, origins, usteps, vsteps


def forward_project(vol_zyx: jnp.ndarray, geom: CTGeometry,
                    oversample: float = 2.0, *,
                    proj_batch: int | None = None,
                    views: slice | Sequence[int] | None = None
                    ) -> jnp.ndarray:
    """Project volume (nz, ny, nx) into (k, nh, nw) projections.

    ``proj_batch`` streams the views through in chunks of that many
    rays per dispatch — parity with the back-projector's view chunking,
    so a solver's forward pass works the same bounded per-call set the
    plan's ``proj_batch`` promises (one chunk's ray grid + march
    temporaries instead of all views at once). ``None`` keeps a single
    all-views dispatch. ``views`` selects a subset of view indices (a
    slice or an index sequence) — the ordered-subset forward pass; the
    default projects the full scan. Either way rows come back in the
    requested view order.
    """
    vol_origin, inv_pitch, step_len, t_near, n_steps = march_params(
        geom, oversample)
    srcs, origins, usteps, vsteps = view_frames(geom)
    idx = np.arange(geom.n_proj)[views] if views is not None \
        else np.arange(geom.n_proj)
    k = len(idx)
    if k == 0:
        return jnp.zeros((0, geom.nh, geom.nw), jnp.float32)
    chunk = k if proj_batch is None else max(1, min(int(proj_batch), k))
    out = []
    for c0 in range(0, k, chunk):
        sel = idx[c0:c0 + chunk]
        pad = chunk - len(sel) if (c0 + chunk > k and len(out) > 0) else 0
        if pad:   # tail rides the same-size program; extra rows dropped
            sel = np.concatenate([sel, np.repeat(sel[-1:], pad)])
        part = _project_views(
            vol_zyx, jnp.asarray(srcs[sel]), jnp.asarray(origins[sel]),
            jnp.asarray(usteps[sel]), jnp.asarray(vsteps[sel]),
            vol_origin, inv_pitch, n_steps, geom.nh, geom.nw,
            jnp.float32(step_len), jnp.float32(t_near))
        out.append(part[:chunk - pad] if pad else part)
    return out[0] if len(out) == 1 else jnp.concatenate(out, axis=0)
