"""Declarative registry of back-projection kernel variants (paper Table 2).

Each variant is a :class:`KernelSpec` — a capability record the planner
(``runtime.planner``) consumes to schedule work: which paper optimizations
the kernel carries, which call-time options it accepts, and which
symmetry-free member of the ladder substitutes for it on Z-slabs that are
not centered on the volume midplane (the O3 mirror pairs voxel ``k`` with
``nk-1-k`` about the FULL volume's Z center, so symmetry-carrying kernels
are only exact on centered sub-boxes or mirror-paired slab calls — see
``core.tiling.ZUnit``).

Every kernel callable has the uniform signature

    fn(img_t, mat, vol_shape_xyz, **opts) -> vol_t (nx, ny, nz)

operating on transposed layouts. The RTK baseline is exposed through the
same signature by transposing at the edges (the transposes are part of the
measured baseline cost in RTK's favor: the paper also counts its own
transposition as marginal, §3.1.1).

Names follow the paper (Table 2), with `_mp` ~ pure-JAX (the auto-vectorized
path) and `_pl` ~ Pallas kernels (the explicitly tiled path):

    baseline        RTK Listing 1 (native layouts inside)
    transpose_mp    O1
    share_mp        O1+O2
    symmetry_mp     O1+O2+O3
    subline_mp      O1+O2+O4
    subline_batch_mp O1+O2+O4+O5 (no O3 — exact on any Z-slab; the
                    planner's slab-safe fallback)
    algorithm1_mp   O1..O5 (paper Algorithm 1; nb batching)
    subline_pl      Pallas: O1..O5 + O6 (pipelined prefetch)  [kernels/]
    onehot_pl       Pallas: beyond-paper MXU interpolation    [kernels/]
    banded_pl       Pallas: beyond-paper banded prefetch      [kernels/]

``VARIANTS`` / ``OPTIMIZATIONS`` / ``SLAB_SAFE_FALLBACK`` — the three
ad-hoc dicts this registry replaces — are kept as *derived* read-only
views for existing callers; ``REGISTRY`` is the source of truth.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, Mapping, Optional, Tuple

from . import backproject as bp
from . import baseline as bl


# --------------------------------------------------------------------------
# Kernel callables (uniform signature adapters)
# --------------------------------------------------------------------------

def _baseline_adapter(img_t, mat, vol_shape_xyz, **_):
    img = bp.transpose_projections(img_t)  # back to (np, nh, nw)
    ni, nj, nk = vol_shape_xyz
    vol = bl.backproject_rtk(img, mat, (nk, nj, ni))
    return bp.volume_to_transposed(vol)


def _transpose(img_t, mat, vol_shape_xyz, **_):
    return bp.bp_transpose(img_t, mat, vol_shape_xyz)


def _share(img_t, mat, vol_shape_xyz, **_):
    return bp.bp_share(img_t, mat, vol_shape_xyz)


def _symmetry(img_t, mat, vol_shape_xyz, **_):
    return bp.bp_symmetry(img_t, mat, vol_shape_xyz)


def _subline(img_t, mat, vol_shape_xyz, **_):
    return bp.bp_subline(img_t, mat, vol_shape_xyz)


def _algorithm1(img_t, mat, vol_shape_xyz, nb: int = 8, **_):
    return bp.bp_subline_symmetry_batch(img_t, mat, vol_shape_xyz, nb=nb)


def _subline_batch(img_t, mat, vol_shape_xyz, nb: int = 8, **_):
    return bp.bp_subline_batch(img_t, mat, vol_shape_xyz, nb=nb)


def _subline_pallas(img_t, mat, vol_shape_xyz, nb: int = 8,
                    interpret: bool = True, block=(4, 8),
                    proj_loop: bool = False, **_):
    from repro.kernels import ops
    return ops.backproject_subline(img_t, mat, vol_shape_xyz, nb=nb,
                                   block=block, interpret=interpret,
                                   proj_loop=proj_loop)


def _onehot_pallas(img_t, mat, vol_shape_xyz, nb: int = 8,
                   interpret: bool = True, block=(4, 8),
                   k_chunk: int = 128, proj_loop: bool = False, **_):
    from repro.kernels import ops
    return ops.backproject_onehot(img_t, mat, vol_shape_xyz, nb=nb,
                                  block=block, k_chunk=k_chunk,
                                  interpret=interpret, proj_loop=proj_loop)


def _banded_pallas(img_t, mat, vol_shape_xyz, nb: int = 8,
                   interpret: bool = True, block=(4, 8), bw: int = 32,
                   proj_loop: bool = False, **_):
    from repro.kernels import ops
    return ops.backproject_banded(img_t, mat, vol_shape_xyz, nb=nb,
                                  block=block, bw=bw, interpret=interpret,
                                  proj_loop=proj_loop)


# --------------------------------------------------------------------------
# KernelSpec: one declarative capability record per variant
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Capability record for one back-projection kernel.

    Fields
    ------
    name : registry key (paper Table 2 naming).
    fn : kernel callable with the uniform transposed signature.
    optimizations : which paper optimizations the kernel carries
        (Table 2 columns; ``"symmetry"`` has scheduling consequences).
    options : call-time keyword options the kernel actually consumes.
        The planner filters resolved options through this set so kernels
        never see (and silently swallow) irrelevant knobs.
    slab_safe_fallback : name of the strongest symmetry-free variant with
        the same remaining optimizations — what the planner schedules on
        a Z-slab that is neither volume-centered nor mirror-paired.
        ``None`` for symmetry-free kernels (they are their own fallback).
    backend : "reference" | "jax" | "pallas" (Pallas kernels accept
        ``interpret=`` and run under the interpreter on CPU CI).
    jittable : whether the kernel tolerates traced inputs under an outer
        ``jax.jit`` (the program cache wraps jittable kernels; a kernel
        that inspects concrete matrix VALUES at trace time — e.g. the
        banded kernel's data-dependent band schedule — must opt out and
        is cached un-wrapped instead).
    proj_loop : whether the kernel supports the fused multi-batch mode —
        an in-kernel ``fori_loop`` over ``nb``-sized projection batches
        with the Z-slab accumulator held in the VMEM output ref, cutting
        per-launch output read-modify-write traffic by the batch factor
        (the paper's O1 loop order + O3 locality carried INTO the
        kernel). The planner defaults the ``proj_loop`` option ON for
        specs that advertise it.
    tuning_space : the option axes the autotuner (``runtime.autotune``)
        may flip when it searches this kernel's configuration space,
        as ``((option, (candidate values, ...)), ...)``. Declarative for
        the same reason ``options`` is: the tuner never guesses which
        knobs a kernel takes — the spec advertises them (every key must
        be in ``options``). Heuristic defaults stay with the planner;
        this only widens the MEASURED search.
    """

    name: str
    fn: Callable
    optimizations: Tuple[str, ...]
    options: FrozenSet[str] = frozenset()
    slab_safe_fallback: Optional[str] = None
    backend: str = "jax"
    jittable: bool = True
    proj_loop: bool = False
    tuning_space: Tuple[Tuple[str, Tuple], ...] = ()

    @property
    def uses_symmetry(self) -> bool:
        """Whether the kernel's math assumes the volume-centered O3 mirror."""
        return "symmetry" in self.optimizations

    @property
    def is_pallas(self) -> bool:
        return self.backend == "pallas"

    def resolve_options(self, opts: Mapping) -> Dict:
        """Filter caller options down to the ones this kernel accepts."""
        return {k: v for k, v in opts.items()
                if k in self.options and v is not None}


_PL_OPTS = frozenset({"nb", "interpret", "block", "proj_loop"})

# Pallas kernels expose the fused in-kernel projection loop as a measured
# tuning axis: the planner defaults it ON, but whether it beats the
# per-batch launch depends on the machine (VMEM vs dispatch cost) — which
# is exactly what runtime.autotune measures instead of guessing.
_PL_TUNING = (("proj_loop", (True, False)),)

REGISTRY: Dict[str, KernelSpec] = {s.name: s for s in (
    KernelSpec("baseline", _baseline_adapter, (), backend="reference"),
    KernelSpec("transpose_mp", _transpose, ("transpose",)),
    KernelSpec("share_mp", _share, ("transpose", "share")),
    KernelSpec("symmetry_mp", _symmetry,
               ("transpose", "share", "symmetry"),
               slab_safe_fallback="share_mp"),
    KernelSpec("subline_mp", _subline, ("transpose", "share", "subline")),
    KernelSpec("subline_batch_mp", _subline_batch,
               ("transpose", "share", "subline", "batch"),
               options=frozenset({"nb"})),
    KernelSpec("algorithm1_mp", _algorithm1,
               ("transpose", "share", "symmetry", "subline", "batch"),
               options=frozenset({"nb"}),
               slab_safe_fallback="subline_batch_mp"),
    KernelSpec("subline_pl", _subline_pallas,
               ("transpose", "share", "symmetry", "subline", "batch",
                "localmem", "prefetch"),
               options=_PL_OPTS,
               slab_safe_fallback="subline_batch_mp", backend="pallas",
               proj_loop=True, tuning_space=_PL_TUNING),
    KernelSpec("onehot_pl", _onehot_pallas,
               ("transpose", "share", "symmetry", "subline", "batch",
                "localmem", "prefetch", "mxu-interp"),
               options=_PL_OPTS | {"k_chunk"},
               slab_safe_fallback="subline_batch_mp", backend="pallas",
               proj_loop=True, tuning_space=_PL_TUNING),
    # jittable=False: the band schedule is computed from concrete matrix
    # values at trace time (np.asarray(mat) in the kernel wrapper)
    KernelSpec("banded_pl", _banded_pallas,
               ("transpose", "share", "symmetry", "subline", "batch",
                "localmem", "prefetch", "banded-prefetch"),
               options=_PL_OPTS | {"bw"},
               slab_safe_fallback="subline_batch_mp", backend="pallas",
               jittable=False, proj_loop=True, tuning_space=_PL_TUNING),
)}


def _validate_registry() -> None:
    for spec in REGISTRY.values():
        if spec.uses_symmetry:
            fb = spec.slab_safe_fallback
            if fb is None or fb not in REGISTRY:
                raise ValueError(
                    f"symmetry variant {spec.name!r} needs a registered "
                    f"slab_safe_fallback, got {fb!r}")
            fspec = REGISTRY[fb]
            if fspec.uses_symmetry:
                raise ValueError(
                    f"{spec.name!r} fallback {fb!r} still uses symmetry")
            if not set(fspec.optimizations) <= set(spec.optimizations):
                raise ValueError(
                    f"{spec.name!r} fallback {fb!r} adds optimizations "
                    f"the primary does not carry")
        elif spec.slab_safe_fallback is not None:
            raise ValueError(
                f"symmetry-free variant {spec.name!r} must not declare a "
                f"slab_safe_fallback")
        if spec.proj_loop and "proj_loop" not in spec.options:
            raise ValueError(
                f"{spec.name!r} advertises proj_loop but does not accept "
                f"the 'proj_loop' call option")
        bad = [k for k, _ in spec.tuning_space if k not in spec.options]
        if bad:
            raise ValueError(
                f"{spec.name!r} tuning_space keys {bad} are not accepted "
                f"call options (KernelSpec.options)")


_validate_registry()


# --------------------------------------------------------------------------
# Derived legacy views + lookups
# --------------------------------------------------------------------------

VARIANTS: Dict[str, Callable] = {n: s.fn for n, s in REGISTRY.items()}

OPTIMIZATIONS: Dict[str, tuple] = {n: s.optimizations
                                   for n, s in REGISTRY.items()}

SLAB_SAFE_FALLBACK: Dict[str, str] = {
    n: s.slab_safe_fallback for n, s in REGISTRY.items()
    if s.slab_safe_fallback is not None}


def get_spec(name: str) -> KernelSpec:
    if name not in REGISTRY:
        raise KeyError(f"unknown back-projection variant {name!r}; "
                       f"have {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_variant(name: str) -> Callable:
    return get_spec(name).fn


def uses_symmetry(name: str) -> bool:
    """Whether a variant's math assumes the volume-centered O3 mirror."""
    return get_spec(name).uses_symmetry


def slab_safe_variant(name: str) -> str:
    """Variant to run on an arbitrary (non-centered) Z-slab."""
    spec = get_spec(name)
    return spec.slab_safe_fallback if spec.uses_symmetry else name
