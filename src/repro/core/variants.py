"""Registry of back-projection kernel variants (paper Table 2).

Maps variant names to callables with the uniform signature

    fn(img_t, mat, vol_shape_xyz, **opts) -> vol_t (nx, ny, nz)

operating on transposed layouts. The RTK baseline is exposed through the
same signature by transposing at the edges (the transposes are part of the
measured baseline cost in RTK's favor: the paper also counts its own
transposition as marginal, §3.1.1).

Names follow the paper (Table 2), with `_mp` ~ pure-JAX (the auto-vectorized
path) and `_pl` ~ Pallas kernels (the explicitly tiled path):

    baseline        RTK Listing 1 (native layouts inside)
    transpose_mp    O1
    share_mp        O1+O2
    symmetry_mp     O1+O2+O3
    subline_mp      O1+O2+O4
    subline_batch_mp O1+O2+O4+O5 (no O3 — exact on any Z-slab; the
                    tiled engine's slab-safe fallback)
    algorithm1_mp   O1..O5 (paper Algorithm 1; nb batching)
    subline_pl      Pallas: O1..O5 + O6 (pipelined prefetch)  [kernels/]
    onehot_pl       Pallas: beyond-paper MXU interpolation    [kernels/]
"""

from __future__ import annotations

from typing import Callable, Dict

from . import backproject as bp
from . import baseline as bl


def _baseline_adapter(img_t, mat, vol_shape_xyz, **_):
    img = bp.transpose_projections(img_t)  # back to (np, nh, nw)
    ni, nj, nk = vol_shape_xyz
    vol = bl.backproject_rtk(img, mat, (nk, nj, ni))
    return bp.volume_to_transposed(vol)


def _transpose(img_t, mat, vol_shape_xyz, **_):
    return bp.bp_transpose(img_t, mat, vol_shape_xyz)


def _share(img_t, mat, vol_shape_xyz, **_):
    return bp.bp_share(img_t, mat, vol_shape_xyz)


def _symmetry(img_t, mat, vol_shape_xyz, **_):
    return bp.bp_symmetry(img_t, mat, vol_shape_xyz)


def _subline(img_t, mat, vol_shape_xyz, **_):
    return bp.bp_subline(img_t, mat, vol_shape_xyz)


def _algorithm1(img_t, mat, vol_shape_xyz, nb: int = 8, **_):
    return bp.bp_subline_symmetry_batch(img_t, mat, vol_shape_xyz, nb=nb)


def _subline_batch(img_t, mat, vol_shape_xyz, nb: int = 8, **_):
    return bp.bp_subline_batch(img_t, mat, vol_shape_xyz, nb=nb)


def _subline_pallas(img_t, mat, vol_shape_xyz, nb: int = 8,
                    interpret: bool = True, **_):
    from repro.kernels import ops
    return ops.backproject_subline(img_t, mat, vol_shape_xyz, nb=nb,
                                   interpret=interpret)


def _onehot_pallas(img_t, mat, vol_shape_xyz, nb: int = 8,
                   interpret: bool = True, **_):
    from repro.kernels import ops
    return ops.backproject_onehot(img_t, mat, vol_shape_xyz, nb=nb,
                                  interpret=interpret)


def _banded_pallas(img_t, mat, vol_shape_xyz, nb: int = 8,
                   interpret: bool = True, **_):
    from repro.kernels import ops
    return ops.backproject_banded(img_t, mat, vol_shape_xyz, nb=nb,
                                  interpret=interpret)


VARIANTS: Dict[str, Callable] = {
    "baseline": _baseline_adapter,
    "transpose_mp": _transpose,
    "share_mp": _share,
    "symmetry_mp": _symmetry,
    "subline_mp": _subline,
    "subline_batch_mp": _subline_batch,
    "algorithm1_mp": _algorithm1,
    "subline_pl": _subline_pallas,
    "onehot_pl": _onehot_pallas,
    "banded_pl": _banded_pallas,
}

# Which paper optimizations each variant carries (paper Table 2 columns).
OPTIMIZATIONS: Dict[str, tuple] = {
    "baseline": (),
    "transpose_mp": ("transpose",),
    "share_mp": ("transpose", "share"),
    "symmetry_mp": ("transpose", "share", "symmetry"),
    "subline_mp": ("transpose", "share", "subline"),
    "subline_batch_mp": ("transpose", "share", "subline", "batch"),
    "algorithm1_mp": ("transpose", "share", "symmetry", "subline", "batch"),
    "subline_pl": ("transpose", "share", "symmetry", "subline", "batch",
                   "localmem", "prefetch"),
    "onehot_pl": ("transpose", "share", "symmetry", "subline", "batch",
                  "localmem", "prefetch", "mxu-interp"),
    "banded_pl": ("transpose", "share", "symmetry", "subline", "batch",
                  "localmem", "prefetch", "banded-prefetch"),
}


# The O3 mirror pairs voxel k with nk-1-k about the volume's Z midplane,
# so symmetry-carrying variants are only exact on sub-boxes that are
# centered on it (or scheduled as mirror pairs, see core.tiling.ZUnit).
# For an arbitrary Z-slab the tiled engine swaps in the strongest
# symmetry-free member of the ladder with the same remaining opts.
SLAB_SAFE_FALLBACK: Dict[str, str] = {
    "symmetry_mp": "share_mp",
    "algorithm1_mp": "subline_batch_mp",
    "subline_pl": "subline_batch_mp",
    "onehot_pl": "subline_batch_mp",
    "banded_pl": "subline_batch_mp",
}


def uses_symmetry(name: str) -> bool:
    """Whether a variant's math assumes the volume-centered O3 mirror."""
    return "symmetry" in OPTIMIZATIONS.get(name, ())


def slab_safe_variant(name: str) -> str:
    """Variant to run on an arbitrary (non-centered) Z-slab."""
    return SLAB_SAFE_FALLBACK.get(name, name) if uses_symmetry(name) \
        else name


def get_variant(name: str) -> Callable:
    if name not in VARIANTS:
        raise KeyError(f"unknown back-projection variant {name!r}; "
                       f"have {sorted(VARIANTS)}")
    return VARIANTS[name]
