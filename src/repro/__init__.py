"""Performance-portable cone-beam back-projection (paper reproduction).

Top level of the public API:

    import repro
    vol = repro.reconstruct(projections, geom, method="fdk",
                            options=repro.ReconOptions(nb=8))

Everything resolves lazily (PEP 562) so ``import repro`` stays cheap —
jax and the kernel registry only load when a symbol is first touched.
"""

from typing import TYPE_CHECKING

_LAZY = {
    "reconstruct": ("repro.api", "reconstruct"),
    "ReconOptions": ("repro.api", "ReconOptions"),
    "fdk_reconstruct": ("repro.core.fdk", "fdk_reconstruct"),
    "sart_step": ("repro.core.fdk", "sart_step"),
    "forward_project": ("repro.core.forward", "forward_project"),
    "solve": ("repro.runtime.solvers", "solve"),
    "SolveReport": ("repro.runtime.solvers", "SolveReport"),
    "IterativeExecutor": ("repro.runtime.solvers", "IterativeExecutor"),
    "CTGeometry": ("repro.core.geometry", "CTGeometry"),
    "standard_geometry": ("repro.core.geometry", "standard_geometry"),
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    import importlib

    value = getattr(importlib.import_module(module), attr)
    globals()[name] = value    # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(_LAZY))


if TYPE_CHECKING:   # static importers see the real symbols
    from repro.api import ReconOptions, reconstruct  # noqa: F401
    from repro.core.fdk import fdk_reconstruct, sart_step  # noqa: F401
    from repro.core.forward import forward_project  # noqa: F401
    from repro.core.geometry import CTGeometry, standard_geometry  # noqa: F401
    from repro.runtime.solvers import (  # noqa: F401
        IterativeExecutor, SolveReport, solve)
