"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — tests
run on the single real CPU device by design (the 512-device override is
exclusive to launch/dryrun.py)."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_geom():
    from repro.core import standard_geometry
    return standard_geometry(n=16, n_det=24, n_proj=8)


@pytest.fixture(scope="session")
def small_ct_data(small_geom):
    import jax.numpy as jnp
    rng = np.random.RandomState(0)
    img = jnp.asarray(rng.rand(small_geom.n_proj, small_geom.nh,
                               small_geom.nw).astype(np.float32))
    from repro.core import projection_matrices
    return img, projection_matrices(small_geom)


def rel_rmse(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    scale = max(np.abs(b).max(), 1e-12)
    return float(np.sqrt(np.mean((a - b) ** 2))) / scale
