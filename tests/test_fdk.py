"""End-to-end FDK pipeline quality (the paper's §4.2 validation setting,
scaled to CPU)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import fdk_reconstruct, standard_geometry
from repro.core.filtering import fdk_preweight_and_filter, \
    ramlak_kernel_spatial
from repro.core.forward import forward_project
from repro.core.phantom import ball_phantom, shepp_logan_3d


@pytest.fixture(scope="module")
def recon_setup():
    n = 24
    geom = standard_geometry(n=n, n_det=36, n_proj=40)
    phantom = jnp.asarray(shepp_logan_3d(n))
    projs = forward_project(phantom, geom, oversample=2.0)
    return geom, phantom, projs


def test_forward_projector_ball_line_integral():
    """Central ray through a ball of radius r has line integral ~ 2r."""
    n = 24
    geom = standard_geometry(n=n, n_det=32, n_proj=2)
    ball = jnp.asarray(ball_phantom(n, radius=0.5))
    projs = forward_project(ball, geom, oversample=4.0)
    # ball radius 0.5 in unit cube = 0.5 * (128 world units) at n voxels
    world_diameter = 0.5 * 256.0  # radius 0.5 of [-1,1] cube ~ 128 units/2
    center = float(projs[0, geom.nh // 2, geom.nw // 2])
    assert center == pytest.approx(world_diameter, rel=0.1)


def test_ramlak_kernel_structure():
    h = ramlak_kernel_spatial(8, du=2.0)
    center = 8
    assert h[center] == pytest.approx(1.0 / (4 * 4.0))
    assert h[center + 2] == 0.0 and h[center + 4] == 0.0
    assert h[center + 1] == pytest.approx(-1.0 / (np.pi * 2.0) ** 2)
    assert h[center + 1] == h[center - 1]     # symmetric


def test_filter_zero_mean_response():
    """The ramp filter kills DC: filtering a constant gives ~0."""
    geom = standard_geometry(n=16, n_det=64, n_proj=4)
    const = jnp.ones((4, geom.nh, geom.nw), jnp.float32)
    filt = fdk_preweight_and_filter(const, geom)
    # interior columns (away from truncation edges)
    interior = np.asarray(filt)[:, :, 16:-16]
    assert np.abs(interior).max() < 0.15 * np.abs(np.asarray(filt)).max() \
        + 1e-3


def test_fdk_reconstruction_quality(recon_setup):
    geom, phantom, projs = recon_setup
    rec = fdk_reconstruct(projs, geom, variant="algorithm1_mp", nb=8)
    n = phantom.shape[0]
    sl = slice(n // 4, 3 * n // 4)
    ph = np.asarray(phantom)[sl, sl, sl]
    rc = np.asarray(rec)[sl, sl, sl]
    # mean intensity recovered (absolute FDK scaling correct)
    assert rc.mean() == pytest.approx(ph.mean(), rel=0.15)
    # structural agreement
    corr = np.corrcoef(ph.ravel(), rc.ravel())[0, 1]
    assert corr > 0.75


def test_fdk_variants_agree(recon_setup):
    geom, _, projs = recon_setup
    a = fdk_reconstruct(projs, geom, variant="baseline")
    b = fdk_reconstruct(projs, geom, variant="algorithm1_mp", nb=8)
    c = fdk_reconstruct(projs, geom, variant="subline_pl")
    scale = float(np.abs(np.asarray(a)).max())
    assert float(np.abs(b - a).max()) / scale < 1e-4
    assert float(np.abs(c - a).max()) / scale < 1e-4


def test_more_views_reduce_error():
    """Reconstruction error decreases with the number of projections."""
    n = 16
    phantom = jnp.asarray(shepp_logan_3d(n))
    errs = []
    for n_proj in (8, 32):
        geom = standard_geometry(n=n, n_det=24, n_proj=n_proj)
        projs = forward_project(phantom, geom, oversample=2.0)
        rec = fdk_reconstruct(projs, geom, variant="algorithm1_mp", nb=4)
        sl = slice(n // 4, 3 * n // 4)
        err = np.sqrt(np.mean((np.asarray(rec)[sl, sl, sl]
                               - np.asarray(phantom)[sl, sl, sl]) ** 2))
        errs.append(err)
    assert errs[1] < errs[0]


def test_sart_iteration_reduces_residual():
    """One SART step must reduce the projection-domain residual."""
    from repro.core.fdk import sart_step
    n = 12
    geom = standard_geometry(n=n, n_det=18, n_proj=8)
    phantom = jnp.asarray(ball_phantom(n, radius=0.6))
    projs = forward_project(phantom, geom, oversample=1.0)
    vol0 = jnp.zeros(geom.volume_shape_zyx, jnp.float32)
    r0 = float(jnp.mean((forward_project(vol0, geom, oversample=1.0)
                         - projs) ** 2))
    vol1 = sart_step(vol0, projs, geom, relax=0.5, nb=4, oversample=1.0)
    r1 = float(jnp.mean((forward_project(vol1, geom, oversample=1.0)
                         - projs) ** 2))
    assert r1 < r0
