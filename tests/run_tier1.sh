#!/usr/bin/env bash
# Tier-1 gate. Two stages:
#
#   1. collection smoke — EVERY test module must collect (a missing
#      optional dependency may skip a module, but an ImportError at
#      collection time must fail the gate, never silently shrink it);
#   2. the exact tier-1 command from ROADMAP.md.
#
# Usage: tests/run_tier1.sh  (or `make tier1` from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 stage 1/2: collection smoke =="
# --co exits non-zero on any collection error; -m "" disables the
# default "not slow" filter so even deselected modules must import.
python -m pytest -q --co -m "" >/dev/null || {
    echo "FATAL: test collection failed — a module no longer imports." >&2
    python -m pytest -q --co -m "" 2>&1 | tail -20 >&2
    exit 1
}

echo "== tier-1 stage 2/2: pytest -x -q =="
exec python -m pytest -x -q "$@"
