#!/usr/bin/env bash
# Tier-1 gate. Three stages:
#
#   1. collection smoke — EVERY test module must collect (a missing
#      optional dependency may skip a module, but an ImportError at
#      collection time must fail the gate, never silently shrink it);
#   2. the exact tier-1 command from ROADMAP.md;
#   3. NON-GATING perf smoke — writes the next perf-trajectory
#      snapshot (--json auto: benchmarks.bench_smoke.next_snapshot_path
#      derives BENCH_PR<N>.json from the committed sequence, so no
#      caller hardcodes the name) and diffs it against the most recent
#      committed BENCH_*.json: any per-variant wall regression beyond
#      25% is reported LOUDLY (grep for 'WARNING: perf regression') but
#      never fails the gate. TIER1_STRICT=1 (the nightly CI job)
#      escalates those warnings to a nonzero exit AND makes the whole
#      stage gating.
#
# TIER1_FAST=1 skips stage 3 entirely (`make tier1-fast` — the quick
# per-PR signal; the nightly scheduled job runs the full gate).
#
# Usage: tests/run_tier1.sh  (or `make tier1` from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 stage 1/3: collection smoke =="
# --co exits non-zero on any collection error; -m "" disables the
# default "not slow" filter so even deselected modules must import.
python -m pytest -q --co -m "" >/dev/null || {
    echo "FATAL: test collection failed — a module no longer imports." >&2
    python -m pytest -q --co -m "" 2>&1 | tail -20 >&2
    exit 1
}

echo "== tier-1 stage 2/3: pytest -x -q =="
python -m pytest -x -q "$@"

if [[ "${TIER1_FAST:-0}" == "1" ]]; then
    echo "== tier-1 stage 3/3: SKIPPED (TIER1_FAST=1) =="
    exit 0
fi

echo "== tier-1 stage 3/3: perf smoke + trajectory diff (non-gating) =="
# --diff auto picks the newest committed BENCH_*.json that is not this
# run's own output (benchmarks.bench_smoke.auto_prior — the one place
# the comparison base is defined).
# The stage also runs the bounded-budget autotune smoke (a bench_smoke
# section): winners persist in the tuning cache, kept workspace-local
# here (gitignored; CI uploads it as an artifact) so the gate never
# touches ~/.cache.
export REPRO_TUNING_CACHE="${REPRO_TUNING_CACHE:-tuning_cache.json}"
# Tuner-outcome trajectory: every full autotune search appends a
# {fingerprint, bucket_key, heuristic_wall, tuned_wall, ratio, tuned_at}
# record here (CI uploads it — the portability claim as a tracked number).
export REPRO_TUNE_TRAJECTORY="${REPRO_TUNE_TRAJECTORY:-TUNE_TRAJECTORY.json}"
if [[ "${TIER1_STRICT:-0}" == "1" ]]; then
    python -m benchmarks.bench_smoke --json auto \
        --diff auto --warn-regress 0.25 --strict
else
    python -m benchmarks.bench_smoke --json auto \
        --diff auto --warn-regress 0.25 || \
        echo "WARNING: bench-smoke failed (non-gating); see output above." >&2
fi
