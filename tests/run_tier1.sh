#!/usr/bin/env bash
# Tier-1 gate. Three stages:
#
#   1. collection smoke — EVERY test module must collect (a missing
#      optional dependency may skip a module, but an ImportError at
#      collection time must fail the gate, never silently shrink it);
#   2. the exact tier-1 command from ROADMAP.md;
#   3. NON-GATING perf smoke — `make bench-smoke` writes the
#      BENCH_PR2.json perf-trajectory snapshot; a failure is reported
#      but never fails the gate.
#
# Usage: tests/run_tier1.sh  (or `make tier1` from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 stage 1/3: collection smoke =="
# --co exits non-zero on any collection error; -m "" disables the
# default "not slow" filter so even deselected modules must import.
python -m pytest -q --co -m "" >/dev/null || {
    echo "FATAL: test collection failed — a module no longer imports." >&2
    python -m pytest -q --co -m "" 2>&1 | tail -20 >&2
    exit 1
}

echo "== tier-1 stage 2/3: pytest -x -q =="
python -m pytest -x -q "$@"

echo "== tier-1 stage 3/3: perf smoke (non-gating) =="
python -m benchmarks.bench_smoke --json BENCH_PR2.json || \
    echo "WARNING: bench-smoke failed (non-gating); see output above." >&2
