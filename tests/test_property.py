"""Property-based tests (hypothesis) on system invariants.

``hypothesis`` is an *optional* test dependency (see tests/README or the
[test] extra): when it is absent this module skips instead of breaking
collection of the whole tier-1 suite.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core import standard_geometry, projection_matrices, \
    transpose_projections
from repro.core.backproject import bp_subline
from repro.core.baseline import bilinear_gather
from repro.models.layers import chunked_cross_entropy, cross_entropy, \
    unembed

_GEOM = standard_geometry(n=8, n_det=12, n_proj=4)
_MATS = projection_matrices(_GEOM)


@settings(max_examples=20, deadline=None)
@given(st.floats(-4.0, 4.0), st.floats(-4.0, 4.0),
       st.integers(0, 2 ** 31 - 1))
def test_backprojection_is_linear(alpha, beta, seed):
    """BP(a*X + b*Y) == a*BP(X) + b*BP(Y) — the operator is linear, which
    underlies both FDK filtering correctness and gradient-through-BP."""
    rng = np.random.RandomState(seed % 2**31)
    X = jnp.asarray(rng.rand(4, 12, 12).astype(np.float32))
    Y = jnp.asarray(rng.rand(4, 12, 12).astype(np.float32))
    xt, yt = transpose_projections(X), transpose_projections(Y)
    shape = _GEOM.volume_shape_xyz
    lhs = bp_subline(alpha * xt + beta * yt, _MATS, shape)
    rhs = alpha * bp_subline(xt, _MATS, shape) + \
        beta * bp_subline(yt, _MATS, shape)
    scale = max(float(jnp.abs(rhs).max()), 1e-9)
    assert float(jnp.abs(lhs - rhs).max()) / scale < 1e-4


@settings(max_examples=25, deadline=None)
@given(st.floats(0.0, 10.9), st.floats(0.0, 10.9),
       st.integers(0, 2 ** 31 - 1))
def test_bilinear_interpolation_within_hull(x, y, seed):
    """Interpolated values never leave [min, max] of the image —
    interpolation is a convex combination."""
    rng = np.random.RandomState(seed % 2**31)
    img = jnp.asarray(rng.rand(12, 12).astype(np.float32))
    val, valid = bilinear_gather(img, jnp.float32(x), jnp.float32(y))
    if bool(valid):
        assert float(img.min()) - 1e-6 <= float(val) <= \
            float(img.max()) + 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 4))
def test_chunked_ce_equals_full_ce(seed, chunk):
    """The memory-efficient loss is a pure refactor of the plain one."""
    rng = np.random.RandomState(seed % 2**31)
    B, S, d, V = 2, 6, 8, 16
    h = jnp.asarray(rng.randn(B, S, d).astype(np.float32))
    w = jnp.asarray(rng.randn(d, V).astype(np.float32) * 0.2)
    labels = jnp.asarray(rng.randint(0, V, (B, S)), jnp.int32)
    full = cross_entropy(unembed(w, h, tied=False), labels)
    chunked = chunked_cross_entropy(h, w, labels, tied=False, chunk=chunk)
    assert float(jnp.abs(full - chunked)) < 1e-5


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_chunked_ce_ignores_masked_labels(seed):
    rng = np.random.RandomState(seed % 2**31)
    B, S, d, V = 1, 8, 4, 12
    h = jnp.asarray(rng.randn(B, S, d).astype(np.float32))
    w = jnp.asarray(rng.randn(d, V).astype(np.float32))
    labels = np.asarray(rng.randint(0, V, (B, S)), np.int32)
    labels[:, 5:] = -1
    a = chunked_cross_entropy(h, w, jnp.asarray(labels), tied=False,
                              chunk=4)
    # only the first 5 positions should matter
    h2 = h.at[:, 5:].set(123.0)
    b = chunked_cross_entropy(h2, w, jnp.asarray(labels), tied=False,
                              chunk=4)
    assert float(jnp.abs(a - b)) < 1e-5


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6),
       st.integers(1, 12))
def test_pipeline_batches_always_in_vocab(seed, step, vocab_bits):
    from repro.data import TokenPipeline
    vocab = 2 ** vocab_bits + 3
    p = TokenPipeline(vocab_size=vocab, seq_len=5, global_batch=2,
                      seed=seed % 1000)
    b = p.batch_at(step)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < vocab


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_flash_attention_rows_are_convex_combinations(seed):
    """Attention output lies in the convex hull of the value vectors
    (per head) — holds for any mask as long as one key is visible."""
    from repro.models.attention import flash_attention
    rng = np.random.RandomState(seed % 2**31)
    B, S, H, D = 1, 6, 2, 4
    q = jnp.asarray(rng.randn(B, S, H, D).astype(np.float32))
    k = jnp.asarray(rng.randn(B, S, H, D).astype(np.float32))
    v = jnp.asarray(rng.randn(B, S, H, D).astype(np.float32))
    out = flash_attention(q, k, v, causal=True, chunk=3)
    vmin = np.asarray(v).min(axis=1)    # (B, H, D)
    vmax = np.asarray(v).max(axis=1)
    o = np.asarray(out)
    for s in range(S):
        assert np.all(o[:, s] >= vmin - 1e-4)
        assert np.all(o[:, s] <= vmax + 1e-4)
