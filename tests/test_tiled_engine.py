"""Tiled streaming engine vs the untiled reference (runtime/engine.py).

Parity bar: TiledReconstructor must match the RTK baseline to
rel-RMSE < 1e-5 for EVERY registered variant, at tile configurations
that do NOT evenly divide the volume (odd (i, j)-tiles, odd Z-slabs) —
the exactness of matrix translation plus the mirror-paired Z schedule
is the whole correctness story of the engine.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (projection_matrices, standard_geometry,
                        transpose_projections)
from repro.core import backproject as bp
from repro.core.baseline import backproject_rtk
from repro.core.tiling import (TileSpec, make_tiles, pad_projection_batch,
                               pick_tile_shape, plan_z_units,
                               tile_working_set_bytes, translate_matrices)
from repro.core.variants import VARIANTS, slab_safe_variant, uses_symmetry
from repro.runtime.engine import TiledReconstructor

from conftest import rel_rmse

BAR = 1e-5

# 16^3 volume, 5x7 (i, j)-tiles and odd Z-slabs: nothing divides evenly,
# so edge tiles shrink and the Z plan mixes mirror pairs with a centered
# middle slab.  (16, 16, 3) isolates the Z-slab schedule at full (i, j).
TILE_CONFIGS = [(5, 7, 16), (5, 7, 5), (16, 16, 3)]


@pytest.fixture(scope="module")
def setup():
    geom = standard_geometry(n=16, n_det=24, n_proj=6)
    rng = np.random.RandomState(0)
    img = jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                               geom.nw).astype(np.float32))
    img_t = transpose_projections(img)
    mats = projection_matrices(geom)
    ni, nj, nk = geom.volume_shape_xyz
    ref = bp.volume_to_transposed(backproject_rtk(img, mats, (nk, nj, ni)))
    return geom, img_t, mats, np.asarray(ref)


# ---- parity: every variant x non-divisible tile configs ------------------

@pytest.mark.parametrize("variant", sorted(VARIANTS))
@pytest.mark.parametrize("tile", TILE_CONFIGS[:2])
def test_tiled_matches_untiled_reference(setup, variant, tile):
    geom, img_t, mats, ref = setup
    eng = TiledReconstructor(geom, variant, tile_shape=tile, nb=4)
    out = eng.backproject(img_t, mats)
    assert rel_rmse(out, ref) < BAR, (variant, tile)


@pytest.mark.parametrize("variant", ["algorithm1_mp", "subline_pl"])
def test_tiled_full_ij_odd_slabs(setup, variant):
    """Z-slab schedule isolated: full (i, j), odd slabs on even nz."""
    geom, img_t, mats, ref = setup
    eng = TiledReconstructor(geom, variant, tile_shape=TILE_CONFIGS[2],
                             nb=4)
    assert rel_rmse(eng.backproject(img_t, mats), ref) < BAR


def test_tiled_device_accumulator_and_proj_batching(setup):
    """out='device' + streaming projection sub-batches match too."""
    geom, img_t, mats, ref = setup
    eng = TiledReconstructor(geom, "algorithm1_mp", tile_shape=(7, 16, 16),
                             nb=2, proj_batch=4, out="device")
    out = eng.backproject(img_t, mats)
    assert isinstance(out, jnp.ndarray)
    assert rel_rmse(out, ref) < BAR


def test_engine_pipeline_entry_point(setup):
    """fdk_reconstruct(tiling=...) == fdk_reconstruct() end to end."""
    from repro.core import fdk_reconstruct
    geom, _, _, _ = setup
    rng = np.random.RandomState(1)
    projs = jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                                 geom.nw).astype(np.float32))
    untiled = fdk_reconstruct(projs, geom, variant="algorithm1_mp", nb=2)
    tiled = fdk_reconstruct(projs, geom, variant="algorithm1_mp", nb=2,
                            tiling=(5, 7, 5))
    assert rel_rmse(tiled, untiled) < BAR


# ---- property-style: any partition reassembles exactly -------------------

@pytest.mark.parametrize("tile", [(1, 16, 16), (16, 1, 7), (3, 5, 11),
                                  (4, 4, 4), (16, 16, 16)])
def test_any_tile_partition_is_exact_cover(tile):
    """make_tiles yields a disjoint exact cover for ANY tile shape."""
    shape = (16, 16, 16)
    count = np.zeros(shape, np.int32)
    for t in make_tiles(shape, tile):
        assert t.shape == tuple(s.stop - s.start for s in t.slices)
        count[t.slices] += 1
    assert (count == 1).all()


@pytest.mark.parametrize("tile", [(3, 5, 11), (6, 6, 2), (16, 16, 5)])
def test_per_tile_backprojection_reassembles_reference(setup, tile):
    """Back-projecting every sub-box with translated matrices and pasting
    the pieces reproduces the full untiled volume — the engine identity,
    checked tile-by-tile without the engine's own scheduling."""
    geom, img_t, mats, ref = setup
    vol = np.zeros(geom.volume_shape_xyz, np.float32)
    for t in make_tiles(geom.volume_shape_xyz, tile):
        mt = translate_matrices(mats, float(t.i0), float(t.j0), float(t.k0))
        vol[t.slices] = np.asarray(
            bp.bp_subline(img_t, mt, t.shape))
    assert rel_rmse(vol, ref) < BAR


def test_plain_z_slabs_bound_depth_and_cover():
    """Symmetry-free schedule: disjoint cover with every slab <= tk
    (plan_z_units' centered middle slab may reach 2*tk-1; symmetry-free
    variants must not pay that)."""
    from repro.core.tiling import plan_z_slabs
    for nz, tk in [(16, 9), (30, 8), (16, 16), (17, 4), (1, 8)]:
        cover = np.zeros(nz, np.int32)
        for u in plan_z_slabs(nz, tk):
            assert u.nk <= tk and not u.paired
            cover[u.k0:u.k0 + u.nk] += 1
        assert (cover == 1).all(), (nz, tk)


def test_symmetry_free_engine_keeps_slab_depth_bound(setup):
    """The engine schedules symmetry-free variants with plain slabs, so
    no variant call is deeper than tk — the O(tile) contract (a 9-deep
    request on nz=16 used to issue one depth-16 call)."""
    geom, img_t, mats, ref = setup
    eng = TiledReconstructor(geom, "subline_batch_mp",
                             tile_shape=(16, 16, 9), nb=2)
    _, z_units = eng.plan()
    assert all(u.nk <= 9 for u in z_units)
    assert rel_rmse(eng.backproject(img_t, mats), ref) < BAR


def test_tiling_auto_requires_budget(setup):
    from repro.core import fdk_reconstruct
    geom, _, _, _ = setup
    projs = jnp.zeros((geom.n_proj, geom.nh, geom.nw), jnp.float32)
    with pytest.raises(ValueError, match="memory_budget"):
        fdk_reconstruct(projs, geom, tiling="auto")


def test_z_plan_covers_disjointly():
    for nz, tk in [(16, 3), (16, 16), (17, 4), (15, 15), (16, 5), (1, 8)]:
        cover = np.zeros(nz, np.int32)
        for u in plan_z_units(nz, tk):
            cover[u.k0:u.k0 + u.nk] += 1
            if u.paired:
                cover[u.mirror_k0:u.mirror_k0 + u.nk] += 1
                assert u.k0 + u.nk <= u.mirror_k0      # disjoint halves
            else:
                assert u.centered                       # odd middle slab
        assert (cover == 1).all(), (nz, tk)


# ---- tail-batch padding (the distributed remainder fix) ------------------

def test_pad_projection_batch_is_exact(setup):
    """Zero-image / repeated-matrix padding contributes exactly nothing."""
    geom, img_t, mats, _ = setup
    img_p, mat_p = pad_projection_batch(img_t, mats, 4)
    assert img_p.shape[0] == 8 and mat_p.shape[0] == 8
    full = bp.bp_subline_batch(img_p, mat_p, geom.volume_shape_xyz, nb=4)
    ref = bp.bp_subline(img_t, mats, geom.volume_shape_xyz)
    assert rel_rmse(full, ref) < BAR
    # already-divisible input passes through untouched
    same_img, same_mat = pad_projection_batch(img_t, mats, 3)
    assert same_img is img_t and same_mat is mats


def test_backproject_distributed_single_device_mesh(setup):
    """Tile x mesh composition on the in-process 1-device mesh: exercises
    make_distributed_bp(vol_shape_xyz=, origin=) and the per-tile unpad
    (the 8-device version runs in test_distributed.py's subprocess)."""
    from repro.launch.mesh import make_mesh
    geom, img_t, mats, ref = setup
    eng = TiledReconstructor(geom, tile_shape=(5, 7, geom.nz), nb=2)
    mesh = make_mesh((1, 1), ("data", "model"))
    vol = eng.backproject_distributed(img_t, mats, mesh, nb=2)
    assert rel_rmse(vol, ref) < BAR


def test_distributed_backproject_non_divisible_nproj(setup):
    """Regression: n_proj % nb != 0 used to assert; now the tail batch is
    padded. Single-device mesh keeps this in-process."""
    from repro.core.distributed import distributed_backproject
    from repro.launch.mesh import make_mesh
    geom, img_t, mats, _ = setup
    mesh = make_mesh((1, 1), ("data", "model"))
    vol = distributed_backproject(img_t, mats, geom, mesh, nb=5)  # 6 % 5 != 0
    ref = bp.bp_subline(img_t, mats, geom.volume_shape_xyz)
    assert rel_rmse(vol, ref) < BAR


# ---- auto-picker / working-set model -------------------------------------

def test_pick_tile_shape_fits_budget():
    vol, det = (64, 64, 64), (96, 96)
    budget = 2 << 20
    tile = pick_tile_shape(vol, det, budget, nb=8)
    assert tile_working_set_bytes(tile, det, nb=8) <= budget
    assert all(1 <= t <= v for t, v in zip(tile, vol))
    # a generous budget keeps the full volume as one tile
    assert pick_tile_shape(vol, det, 1 << 40, nb=8) == vol
    # an impossible budget degrades to the minimal tile, never loops
    assert pick_tile_shape(vol, det, 0, nb=8) == (1, 1, 1)
    # pair_z: a symmetry-scheduled slab runs at virtual depth 2*tk, and
    # THAT is what must fit the budget
    t2 = pick_tile_shape(vol, det, budget, nb=8, pair_z=True)
    ti, tj, tk = t2
    eff = min(2 * tk, vol[2]) if tk < vol[2] else tk
    assert tile_working_set_bytes((ti, tj, eff), det, nb=8) <= budget


def test_explicit_tile_over_budget_raises(setup):
    """An explicit tile_shape is validated against memory_budget instead
    of silently dropping the budget."""
    geom, _, _, _ = setup
    with pytest.raises(ValueError, match="memory_budget"):
        TiledReconstructor(geom, "algorithm1_mp", tile_shape=(16, 16, 16),
                           memory_budget=1024, nb=4)


def test_proj_batch_rounds_up(setup):
    """proj_batch=5 with nb=2 -> batches of 6 (rounded UP per the
    documented contract), and the result stays exact."""
    geom, img_t, mats, ref = setup
    eng = TiledReconstructor(geom, "subline_batch_mp", tile_shape=(16, 16, 16),
                             nb=2, proj_batch=5)
    assert rel_rmse(eng.backproject(img_t, mats), ref) < BAR


def test_engine_budget_parity(setup):
    """memory_budget path: auto-picked tiles still reconstruct exactly,
    and the engine's reported working set honors the budget."""
    geom, img_t, mats, ref = setup
    budget = 64 << 10
    eng = TiledReconstructor(geom, "algorithm1_mp", memory_budget=budget,
                             nb=4)
    assert eng.working_set_bytes <= budget
    assert eng.tile_shape != geom.volume_shape_xyz   # budget forced tiling
    assert rel_rmse(eng.backproject(img_t, mats), ref) < BAR


# ---- fallback bookkeeping ------------------------------------------------

def test_slab_safe_fallback_strips_symmetry_only():
    from repro.core.variants import OPTIMIZATIONS
    for name in VARIANTS:
        fb = slab_safe_variant(name)
        assert not uses_symmetry(fb)
        if fb != name:
            assert uses_symmetry(name)
            # the fallback keeps every non-symmetry opt it can
            kept = set(OPTIMIZATIONS[fb])
            assert "symmetry" not in kept
            assert kept <= set(OPTIMIZATIONS[name])


def test_uncentered_slab_uses_fallback(setup):
    """A lone non-centered Z-slab through a symmetry variant must be
    exact (the engine swaps in the slab-safe fallback under the hood)."""
    geom, img_t, mats, ref = setup
    eng = TiledReconstructor(geom, "algorithm1_mp", nb=2)
    tile = TileSpec(0, 0, 3, 16, 16, 6)                # 2*3+6 != 16
    out = eng.backproject_tile(img_t, mats, tile)
    assert rel_rmse(out, ref[tile.slices]) < BAR
