"""Integration: the full training loop trains a tiny model end-to-end,
checkpoints, restarts, and resumes identically (fault-tolerance contract)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs import RunConfig, ShapeConfig, get_smoke_config
from repro.launch.train import train


def test_training_reduces_loss(tmp_path):
    cfg = get_smoke_config("qwen2.5-3b")
    run = RunConfig(steps=30, lr=3e-3, warmup_steps=5,
                    checkpoint_dir=str(tmp_path), checkpoint_every=10)
    shape = ShapeConfig("toy", "train", 32, 4)
    _, info = train(cfg, run, shape=shape, quiet=True)
    first = np.mean(info["losses"][:5])
    last = np.mean(info["losses"][-5:])
    assert last < first - 0.05, (first, last)


def test_training_resumes_from_checkpoint(tmp_path):
    cfg = get_smoke_config("stablelm-3b")
    shape = ShapeConfig("toy", "train", 16, 2)
    # run 20 steps straight through (schedule horizon pinned to 20 so
    # split runs see the same LR trajectory)
    run_a = RunConfig(steps=20, lr=1e-3, checkpoint_dir=str(tmp_path / "a"),
                      checkpoint_every=10, seed=3, schedule_horizon=20)
    state_a, info_a = train(cfg, run_a, shape=shape, quiet=True)
    # run 10 steps, "crash", then resume for 10 more
    run_b1 = RunConfig(steps=10, lr=1e-3,
                       checkpoint_dir=str(tmp_path / "b"),
                       checkpoint_every=10, seed=3, schedule_horizon=20)
    train(cfg, run_b1, shape=shape, quiet=True)
    run_b2 = RunConfig(steps=10, lr=1e-3,
                       checkpoint_dir=str(tmp_path / "b"),
                       checkpoint_every=10, seed=3, schedule_horizon=20)
    state_b, info_b = train(cfg, run_b2, shape=shape, quiet=True)
    # identical final parameters (bitwise modulo fp reorder)
    import jax
    la = jax.tree_util.tree_leaves(state_a.params)
    lb = jax.tree_util.tree_leaves(state_b.params)
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)


def test_microbatched_step_matches_full_batch():
    from repro.launch.train import make_train_step, init_state
    import jax
    cfg = get_smoke_config("deepseek-67b")
    from repro.models import build_model
    model = build_model(cfg)
    state = init_state(model, RunConfig(seed=0))
    batch = model.dummy_batch(ShapeConfig("t", "train", 16, 4))
    step_full = make_train_step(model, RunConfig(), total_steps=100)
    step_micro = make_train_step(model, RunConfig(microbatch=2),
                                 total_steps=100)
    _, m_full = jax.jit(step_full)(state, batch)
    micro_batch = jax.tree_util.tree_map(
        lambda x: x.reshape(2, 2, *x.shape[1:]), batch)
    _, m_micro = jax.jit(step_micro)(state, micro_batch)
    assert float(m_full["loss"]) == pytest.approx(
        float(m_micro["loss"]), rel=1e-4)
