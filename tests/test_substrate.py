"""Data pipeline, optimizer, checkpoint, runtime substrate tests."""

import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.data import TokenPipeline
from repro.optim import (
    accumulate_gradients, adamw_init, adamw_update, clip_by_global_norm,
    compress_int8, cosine_warmup, decompress_int8,
)
from repro.runtime import FaultTolerantLoop, StragglerMonitor, remesh_plan


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_pipeline_deterministic_and_seekable():
    p1 = TokenPipeline(vocab_size=100, seq_len=8, global_batch=4, seed=7)
    a = p1.batch_at(5)
    p2 = TokenPipeline(vocab_size=100, seq_len=8, global_batch=4, seed=7)
    p2.seek(5)
    b = next(p2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_pipeline_labels_are_shifted_tokens():
    p = TokenPipeline(vocab_size=100, seq_len=8, global_batch=2, seed=0)
    b = p.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_sharding_partitions_global_batch():
    """Concatenating shards reproduces the single-host global batch —
    the property elastic restarts rely on."""
    full = TokenPipeline(vocab_size=100, seq_len=4, global_batch=8,
                         seed=3).batch_at(2)
    parts = [TokenPipeline(vocab_size=100, seq_len=4, global_batch=8,
                           shard_index=i, num_shards=4,
                           seed=3).batch_at(2)["tokens"]
             for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full["tokens"])


def test_pipeline_prefetch_matches_sync():
    p = TokenPipeline(vocab_size=50, seq_len=4, global_batch=2, seed=1)
    sync = [p.batch_at(i)["tokens"] for i in range(3)]
    p2 = TokenPipeline(vocab_size=50, seq_len=4, global_batch=2, seed=1)
    p2.start_prefetch()
    try:
        got = [next(p2)["tokens"] for _ in range(3)]
    finally:
        p2.stop_prefetch()
    for a, b in zip(sync, got):
        np.testing.assert_array_equal(a, b)


def test_pipeline_seed_changes_stream():
    a = TokenPipeline(vocab_size=100, seq_len=8, global_batch=2,
                      seed=0).batch_at(0)["tokens"]
    b = TokenPipeline(vocab_size=100, seq_len=8, global_batch=2,
                      seed=1).batch_at(0)["tokens"]
    assert not np.array_equal(a, b)


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_matches_manual_formula():
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    st = adamw_init(p)
    lr, wd, b1, b2, eps = 0.1, 0.01, 0.9, 0.95, 1e-8
    newp, st2 = adamw_update(p, g, st, lr=lr, b1=b1, b2=b2, eps=eps,
                             weight_decay=wd)
    m = (1 - b1) * np.asarray(g["w"])
    v = (1 - b2) * np.asarray(g["w"]) ** 2
    mhat = m / (1 - b1)
    vhat = v / (1 - b2)
    expect = np.asarray(p["w"]) - lr * (
        mhat / (np.sqrt(vhat) + eps) + wd * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(newp["w"]), expect, atol=1e-6)
    assert int(st2.step) == 1


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}          # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), [0.6, 0.8],
                               atol=1e-6)
    # below threshold: unchanged
    clipped2, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), [3.0, 4.0])


def test_cosine_warmup_schedule():
    lr0 = float(cosine_warmup(0, base_lr=1.0, warmup_steps=10,
                              total_steps=100))
    lr_w = float(cosine_warmup(10, base_lr=1.0, warmup_steps=10,
                               total_steps=100))
    lr_end = float(cosine_warmup(100, base_lr=1.0, warmup_steps=10,
                                 total_steps=100))
    assert lr0 == 0.0
    assert lr_w == pytest.approx(1.0)
    assert lr_end == pytest.approx(0.1, abs=1e-6)


def test_accumulate_gradients_equals_full_batch():
    """Mean-of-microbatch grads == grad of mean loss (O5 correctness)."""
    w = {"w": jnp.asarray(np.random.RandomState(0).randn(4, 3),
                          jnp.float32)}

    def loss(p, batch):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(8, 4), jnp.float32)
    y = jnp.asarray(rng.randn(8, 3), jnp.float32)
    full_loss, full_g = jax.value_and_grad(loss)(w, {"x": x, "y": y})
    micro = {"x": x.reshape(4, 2, 4), "y": y.reshape(4, 2, 3)}
    acc_loss, acc_g = accumulate_gradients(loss, w, micro)
    assert float(acc_loss) == pytest.approx(float(full_loss), rel=1e-5)
    np.testing.assert_allclose(np.asarray(acc_g["w"]),
                               np.asarray(full_g["w"]), atol=1e-5)


def test_int8_compression_error_feedback():
    rng = np.random.RandomState(2)
    g = jnp.asarray(rng.randn(64) * 0.01, jnp.float32)
    q, scale, resid = compress_int8(g)
    deq = decompress_int8(q, scale)
    # reconstruction + residual == original (exact bookkeeping)
    np.testing.assert_allclose(np.asarray(deq) + np.asarray(resid),
                               np.asarray(g), atol=1e-7)
    # feeding the residual back reduces accumulated bias
    q2, s2, r2 = compress_int8(g, resid)
    total = np.asarray(decompress_int8(q, scale)) + \
        np.asarray(decompress_int8(q2, s2))
    np.testing.assert_allclose(total, 2 * np.asarray(g),
                               atol=2 * float(scale))


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def _tree():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.zeros((3,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(3, t, blocking=True)
    step, restored = ck.restore_latest(t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(t["params"]["w"]))
    assert restored["params"]["b"].dtype == np.asarray(
        t["params"]["b"]).dtype


def test_checkpoint_async_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_checkpoint_keeps_latest_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(), blocking=True)
    assert ck.all_steps() == [3, 4]


def test_checkpoint_ignores_partial_tmp(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(), blocking=True)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp-123"))
    assert ck.latest_step() == 5


def test_checkpoint_crc_detects_corruption(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(2, t, blocking=True)
    # corrupt one leaf file
    d = os.path.join(str(tmp_path), "step_00000002")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, victim))
    arr = np.asarray(arr).copy()
    flat = arr.reshape(-1).view(np.uint8)
    if flat.size:
        flat[0] ^= 0xFF
    np.save(os.path.join(d, victim), arr)
    with pytest.raises(IOError):
        ck.restore(2, t)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.zeros((2, 2))}, blocking=True)
    with pytest.raises(ValueError):
        ck.restore(1, {"w": jnp.zeros((3, 3))})


# --------------------------------------------------------------------------
# fault tolerance / straggler / elastic
# --------------------------------------------------------------------------

def test_ft_loop_recovers_from_transient_failure(tmp_path):
    pipeline = TokenPipeline(vocab_size=50, seq_len=4, global_batch=2,
                             seed=0)
    ck = Checkpointer(str(tmp_path))
    loop = FaultTolerantLoop(checkpointer=ck, pipeline=pipeline,
                             save_every=2, max_retries_per_step=3)
    state = {"w": jnp.zeros(()), "n": jnp.int32(0)}
    fail_once = {"armed": True}

    def step_fn(state, batch):
        if fail_once["armed"] and int(state["n"]) == 3:
            fail_once["armed"] = False
            raise RuntimeError("injected device failure")
        return ({"w": state["w"] + 1.0, "n": state["n"] + 1},
                {"loss": 1.0})

    end, final = loop.run(state, step_fn, start_step=0, num_steps=6)
    assert loop.recoveries == 1
    assert int(final["n"]) == 6 or int(final["n"]) >= 5


def test_ft_loop_skips_poison_step(tmp_path):
    pipeline = TokenPipeline(vocab_size=50, seq_len=4, global_batch=2,
                             seed=0)
    ck = Checkpointer(str(tmp_path))
    loop = FaultTolerantLoop(checkpointer=ck, pipeline=pipeline,
                             save_every=100, max_retries_per_step=1)
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if pipeline_step_is_poison(batch):
            raise RuntimeError("poison batch")
        return state, {"loss": 0.5}

    def pipeline_step_is_poison(batch):
        # poison exactly step 1's batch signature
        return int(batch["tokens"][0, 0]) == int(
            pipeline.batch_at(1)["tokens"][0, 0]) and \
            np.array_equal(batch["tokens"], pipeline.batch_at(1)["tokens"])

    end, _ = loop.run({"x": jnp.zeros(())}, step_fn, start_step=0,
                      num_steps=4)
    assert end >= 4
    assert loop.failures >= 1


def test_ft_nan_guard(tmp_path):
    pipeline = TokenPipeline(vocab_size=50, seq_len=4, global_batch=2,
                             seed=0)
    ck = Checkpointer(str(tmp_path))
    loop = FaultTolerantLoop(checkpointer=ck, pipeline=pipeline,
                             save_every=100, max_retries_per_step=0)

    def step_fn(state, batch):
        return state, {"loss": float("nan")}

    end, _ = loop.run({"x": jnp.zeros(())}, step_fn, start_step=0,
                      num_steps=2)
    assert loop.failures >= 1   # NaN treated as a fault and skipped


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(window=16, threshold=3.0)
    for i in range(12):
        assert not mon.record(i, 1.0 + 0.01 * (i % 3))
    assert mon.record(12, 10.0)          # 10x median -> straggler
    assert 12 in mon.flagged_steps


def test_remesh_plan_elastic():
    assert remesh_plan(256, model_parallel=16) == (16, 16)
    assert remesh_plan(240, model_parallel=16) == (15, 16)  # lost a host
    assert remesh_plan(8, model_parallel=16) == (1, 8)      # degraded
