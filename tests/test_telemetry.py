"""Telemetry layer tests (``runtime/telemetry.py``).

Covers the observability contracts the rest of the runtime now leans
on: the disabled path records NOTHING (shared no-op span singleton),
span trees are well-formed (every span closed, parent ends after its
children, parent/child share a thread lane) across the sync, async,
fleet, and streaming execution paths, ``compile`` spans match
ProgramCache miss counts EXACTLY, step spans carry the planner's
roofline model (bytes/FLOPs/AI — the 8-flops-per-update model of
benchmarks/bench_roofline.py), ``dump_trace`` emits valid Chrome
trace-event JSON with one lane per thread, request trace IDs link
k-wide batched dispatches back to all k submitted futures,
``ServiceStats`` survives concurrent submit+snapshot hammering without
torn reads, and the absorbed ``LatencyHistogram`` keeps its exact API.
"""

import json
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.runtime import telemetry
from repro.runtime.executor import FleetConfig, PlanExecutor, ProgramCache
from repro.runtime.planner import plan_reconstruction
from repro.runtime.service import LatencyHistogram, ReconService


# ---------------------------------------------------------------------------
# helpers


def _x_events(events=None):
    evs = telemetry.events() if events is None else events
    return [e for e in evs if e.get("ph") == "X"]


def _check_span_tree(events=None):
    """Every span closed; parent/child share a lane; parent brackets
    its children in time (same monotonic clock per thread)."""
    assert telemetry.open_span_count() == 0
    spans = {e["args"]["span_id"]: e for e in _x_events(events)}
    assert spans, "no spans recorded"
    for e in spans.values():
        pid = e["args"].get("parent_id")
        if pid is None:
            continue
        parent = spans[pid]
        assert parent["tid"] == e["tid"], \
            f"{e['name']} parented across threads"
        assert parent["ts"] <= e["ts"] + 1.0
        assert parent["ts"] + parent["dur"] >= e["ts"] + e["dur"] - 1.0
    return spans


def _small_inputs(small_geom):
    rng = np.random.RandomState(0)
    return jnp.asarray(rng.rand(small_geom.n_proj, small_geom.nh,
                                small_geom.nw).astype(np.float32))


# ---------------------------------------------------------------------------
# core span machinery


def test_disabled_records_nothing():
    telemetry.disable()
    telemetry.clear()
    s1 = telemetry.span("a", x=1)
    s2 = telemetry.span("b")
    assert s1 is s2                       # shared no-op singleton
    assert not s1.live                    # call sites skip arg building
    with s1:
        telemetry.instant("tick")
    assert telemetry.events() == []
    assert not telemetry.enabled()


def test_span_nesting_records_parent_links():
    with telemetry.tracing():
        with telemetry.span("outer"):
            with telemetry.span("inner"):
                pass
        with telemetry.span("sibling"):
            pass
    spans = {e["name"]: e for e in _x_events()}
    assert spans["inner"]["args"]["parent_id"] == \
        spans["outer"]["args"]["span_id"]
    assert spans["sibling"]["args"]["parent_id"] is None
    _check_span_tree()


def test_tracing_restores_prev_state_and_span_errors_propagate():
    telemetry.disable()
    with pytest.raises(ValueError):
        with telemetry.tracing():
            assert telemetry.enabled()
            with telemetry.span("boom"):
                raise ValueError("x")
    assert not telemetry.enabled()
    ev = next(e for e in _x_events() if e["name"] == "boom")
    assert ev["args"]["error"] == "ValueError"
    assert telemetry.open_span_count() == 0


# ---------------------------------------------------------------------------
# metrics registry + the absorbed LatencyHistogram


def test_latency_histogram_is_telemetry_histogram():
    assert LatencyHistogram is telemetry.Histogram
    h = LatencyHistogram()
    for ms in (0.1, 1.0, 10.0, 100.0):
        h.record(ms / 1e3)
    assert h.count == 4
    assert h.quantile(0.0) <= h.quantile(1.0)
    m = LatencyHistogram.merged([h, h])
    assert m.count == 8
    assert m.mean() == pytest.approx(h.mean())


def test_metrics_registry_get_or_create_and_prometheus():
    reg = telemetry.MetricsRegistry()
    c = reg.counter("reqs")
    c.inc()
    c.inc(2)
    assert reg.counter("reqs") is c
    reg.gauge("depth").set(3.5)
    reg.histogram("lat").record(0.01)
    d = reg.as_dict()
    assert d["reqs"] == 3.0 and d["depth"] == 3.5
    text = reg.prometheus(prefix="repro")
    assert "repro_reqs_total 3.0" in text
    reg.clear()
    assert reg.as_dict() == {}


def test_emit_mixin_as_dict_includes_properties(small_geom, small_ct_data):
    img, _ = small_ct_data
    with ReconService() as svc:
        svc.submit(img, small_geom).result()
        stats = svc.stats()
    d = stats.as_dict()
    assert d["requests"] == 1
    assert "hit_rate" in d                # @property values included
    # emit() lands the numeric leaves in the registry as gauges
    reg = telemetry.MetricsRegistry()
    stats.emit(registry=reg, prefix="svc")
    assert reg.as_dict()["svc.requests"] == 1.0


# ---------------------------------------------------------------------------
# instrumented paths: compile parity, roofline, span trees, lanes


def test_compile_spans_match_cache_misses_exactly(small_geom,
                                                  small_ct_data):
    img, _ = small_ct_data
    plan = plan_reconstruction(small_geom, "algorithm1_mp", nb=4)
    cache = ProgramCache()
    ex = PlanExecutor(small_geom, plan, cache)
    with telemetry.tracing():
        ex.reconstruct(img)
        cold = sum(1 for e in _x_events() if e["name"] == "compile")
        assert cold == cache.stats()["misses"] > 0
        ex.reconstruct(img)               # warm: zero new compile spans
        warm = sum(1 for e in _x_events() if e["name"] == "compile")
    assert warm == cold == cache.stats()["misses"]
    _check_span_tree()


def test_step_spans_carry_roofline_annotations(small_geom, small_ct_data):
    img, _ = small_ct_data
    plan = plan_reconstruction(small_geom, "algorithm1_mp", nb=4)
    ex = PlanExecutor(small_geom, plan, ProgramCache())
    with telemetry.tracing():
        ex.reconstruct(img)
    steps = [e for e in _x_events() if e["name"] == "step.dispatch"]
    assert steps
    for e in steps:
        a = e["args"]
        assert a["bytes"] > 0 and a["flops"] > 0
        # the paper's model: 8 flops per voxel update
        # (benchmarks/bench_roofline.py), n_views updates per voxel
        assert a["flops"] == pytest.approx(
            8.0 * a["voxels"] * a["n_views"])
        assert a["ai_flop_per_byte"] == pytest.approx(
            a["flops"] / a["bytes"], rel=1e-2)


def test_span_tree_sync_and_async_paths(small_geom, small_ct_data):
    img, _ = small_ct_data
    plan = plan_reconstruction(small_geom, "algorithm1_mp", nb=4)
    for pipeline in ("sync", "async"):
        ex = PlanExecutor(small_geom, plan, ProgramCache(),
                          pipeline=pipeline)
        with telemetry.tracing():
            ex.reconstruct(img)
        spans = _check_span_tree()
        names = {e["name"] for e in spans.values()}
        assert "step.dispatch" in names


def test_span_tree_and_lanes_async_fleet(small_geom, small_ct_data,
                                         tmp_path):
    """The acceptance-criteria trace: one traced session covering an
    async-pipeline run (flusher lane) and a fleet run (dispatcher
    lanes), exported as Chrome JSON with distinct thread lanes."""
    img, _ = small_ct_data
    dev = jax.local_devices()[0]
    kw = dict(nb=4, tile_shape=(8, 8, small_geom.nz), proj_batch=4,
              out="host", schedule="step")
    plan = plan_reconstruction(small_geom, "algorithm1_mp", **kw)
    with telemetry.tracing():
        # async pipeline: step writes flush on the recon-flush thread
        ex_async = PlanExecutor(small_geom, plan, ProgramCache(),
                                pipeline="async")
        ref = np.asarray(ex_async.reconstruct(img))
        # two-lane fleet on one real device (duplicated entry): the
        # dispatcher threads and stealing machinery are fully real
        ex_fleet = PlanExecutor(small_geom, plan, ProgramCache(),
                                fleet=FleetConfig(devices=(dev, dev)))
        vol = np.asarray(ex_fleet.reconstruct(img))
    scale = float(np.max(np.abs(ref))) or 1.0
    assert float(np.max(np.abs(vol - ref))) / scale < 1e-5
    spans = _check_span_tree()
    lanes = {e["tid"] for e in spans.values()}
    assert "recon-flush" in lanes
    assert {"recon-fleet-0", "recon-fleet-1"} <= lanes
    fleet_steps = [e for e in spans.values()
                   if e["name"] == "step.dispatch"
                   and e["args"].get("schedule") == "fleet"]
    assert len(fleet_steps) == ex_fleet.last_fleet_report.n_steps
    assert all("flops" in e["args"] for e in fleet_steps)

    # the exported trace is valid Chrome trace-event JSON with one
    # tid per thread and a thread_name metadata row per lane
    path = tmp_path / "fleet.trace.json"
    telemetry.dump_trace(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    meta_names = {e["args"]["name"] for e in evs
                  if e.get("ph") == "M" and e["name"] == "thread_name"}
    assert {"recon-flush", "recon-fleet-0", "recon-fleet-1"} <= meta_names
    tids = {e["tid"] for e in evs if e.get("ph") == "X"}
    assert len(tids) >= 3                 # distinct integer lanes
    for e in evs:
        if e.get("ph") == "X":
            assert isinstance(e["tid"], int)
            assert e["dur"] >= 0 and e["ts"] >= 0


def test_span_tree_stream_path(small_geom, small_ct_data):
    img, _ = small_ct_data
    pa = np.asarray(img)
    with telemetry.tracing():
        with ReconService() as svc:
            session = svc.open_stream(small_geom, nb=4, proj_batch=4,
                                      out="host")
            assert session.trace_id.startswith("stream-")
            for v in range(small_geom.n_proj):
                session.push(pa[v], start=v)
            session.close()
    spans = _check_span_tree()
    names = [e["name"] for e in spans.values()]
    assert "stream.fold" in names and "stream.tail" in names
    instants = [e["name"] for e in telemetry.events()
                if e.get("ph") == "i"]
    assert "stream.push" in instants and "stream.open" in instants


def test_solver_iteration_spans(small_geom, small_ct_data):
    from repro.runtime.solvers import solve
    img, _ = small_ct_data
    with telemetry.tracing():
        _, report = solve(img, small_geom, method="sart", n_iters=3)
    spans = _check_span_tree()
    iters = [e for e in spans.values() if e["name"] == "solve.iter"]
    assert len(iters) == 3
    top = next(e for e in spans.values() if e["name"] == "solve")
    assert all(e["args"]["parent_id"] == top["args"]["span_id"]
               for e in iters)
    assert report.as_dict()["n_iters"] == 3   # EmitMixin contract


# ---------------------------------------------------------------------------
# service: trace IDs, concurrent stats, Prometheus


def test_trace_ids_link_batched_dispatch(small_geom, small_ct_data):
    img, _ = small_ct_data
    with telemetry.tracing():
        with ReconService(max_inflight=1, max_batch=4,
                          max_wait_ms=50.0) as svc:
            svc.warmup([small_geom], nb=4)
            futs = [svc.submit(img, small_geom, nb=4) for _ in range(4)]
            for f in futs:
                f.result()
    submitted = {f.trace_id for f in futs}
    assert len(submitted) == 4            # unique per request
    dispatched = set()
    for e in _x_events():
        if e["name"] == "service.dispatch":
            dispatched.update(e["args"]["trace_ids"])
    assert dispatched == submitted        # every request linked to a
    #                                       dispatch span, none invented
    instants = {e["args"]["trace_id"] for e in telemetry.events()
                if e.get("name") == "request.submit"}
    assert instants == submitted


def test_service_stats_concurrent_submit_and_snapshot(small_geom,
                                                      small_ct_data):
    img, _ = small_ct_data
    n_threads, per_thread = 4, 3
    errors = []
    with ReconService(max_inflight=2, max_batch=2,
                      max_wait_ms=2.0) as svc:
        svc.warmup([small_geom], nb=4)
        stop = threading.Event()
        seen = []

        def snapshotter():
            while not stop.is_set():
                try:
                    s = svc.stats()
                    # torn reads would violate these at some snapshot
                    done = sum(b.completed for b in s.buckets)
                    assert s.requests >= done >= 0
                    s.export_prometheus()
                    seen.append(s.requests)
                except Exception as exc:  # noqa: BLE001
                    errors.append(exc)
                    return

        def hammer():
            try:
                futs = [svc.submit(img, small_geom, nb=4)
                        for _ in range(per_thread)]
                for f in futs:
                    f.result(timeout=120)
            except Exception as exc:  # noqa: BLE001
                errors.append(exc)

        snap = threading.Thread(target=snapshotter)
        snap.start()
        workers = [threading.Thread(target=hammer)
                   for _ in range(n_threads)]
        for w in workers:
            w.start()
        for w in workers:
            w.join()
        stop.set()
        snap.join()
        assert not errors
        assert seen and seen == sorted(seen)   # monotone, no going back
        stats = svc.stats()
    total = n_threads * per_thread
    assert stats.requests == total
    assert sum(b.completed for b in stats.buckets) == total
    d = stats.as_dict()
    assert d["requests"] == total


def test_prometheus_exposition_format(small_geom, small_ct_data):
    img, _ = small_ct_data
    with ReconService() as svc:
        svc.submit(img, small_geom, nb=4).result()
        text = svc.stats().export_prometheus()
    lines = text.splitlines()
    assert "repro_requests_total 1.0" in lines
    for family in ("repro_requests_total", "repro_hit_rate",
                   "repro_bucket_requests"):
        assert f"# TYPE {family} " in text and f"# HELP {family} " in text
    # sample lines parse: name{labels} value
    for ln in lines:
        if ln.startswith("#") or not ln:
            continue
        name, _, value = ln.rpartition(" ")
        assert name and (value == "NaN" or float(value) is not None)


# ---------------------------------------------------------------------------
# tuner-outcome trajectory


def test_record_tuning_appends_and_mirrors(tmp_path, monkeypatch):
    path = tmp_path / "TUNE_TRAJECTORY.json"
    monkeypatch.setenv(telemetry.TUNE_TRAJECTORY_ENV, str(path))
    rec = dict(fingerprint="cpu|x", bucket_key="algorithm1_mp|...",
               heuristic_wall=120.0, tuned_wall=80.0, ratio=1.5,
               tuned_at=1700000000.0)
    telemetry.record_tuning(rec)
    telemetry.record_tuning(dict(rec, bucket_key="share_mp|..."))
    doc = json.loads(path.read_text())
    assert doc["suite"] == "tune_trajectory"
    assert len(doc["records"]) >= 2
    tail = doc["records"][-1]
    assert set(rec) <= set(tail)
    assert tail["ratio"] == 1.5
    assert any(r["bucket_key"].startswith("algorithm1_mp")
               for r in doc["records"])
