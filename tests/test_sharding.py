"""Sharding rule unit tests (pure — no multi-device mesh needed)."""

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh


class _FakeMesh:
    """Duck-typed mesh: axis names + shape only (rules are pure)."""

    def __init__(self, shape_by_name):
        self.axis_names = tuple(shape_by_name)
        self.devices = np.empty(tuple(shape_by_name.values()))


MESH = _FakeMesh({"data": 16, "model": 16})
MESH_POD = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_attention_weight_specs():
    s = shd.spec_for_param("layers/attn/wq", (80, 8192, 8192), MESH)
    assert s == P(None, "data", "model")
    s = shd.spec_for_param("layers/attn/wo", (80, 8192, 8192), MESH)
    assert s == P(None, "model", "data")


def test_mlp_weight_specs():
    s = shd.spec_for_param("layers/mlp/wi_gate", (80, 8192, 49152), MESH)
    assert s == P(None, "data", "model")
    s = shd.spec_for_param("layers/mlp/wo", (80, 49152, 8192), MESH)
    assert s == P(None, "model", "data")


def test_embed_specs_with_divisibility_fallback():
    # 152064 divisible by 16 -> vocab sharded
    assert shd.spec_for_param("embed", (152064, 8192), MESH) == \
        P("model", "data")
    # 49155 NOT divisible by 16 -> vocab replicated, d still sharded
    assert shd.spec_for_param("embed", (49155, 1024), MESH) == \
        P(None, "data")


def test_moe_expert_parallel_specs():
    s = shd.spec_for_param("layers/moe/wi_gate", (24, 32, 1024, 512), MESH)
    assert s == P(None, "model", "data", None)
    s = shd.spec_for_param("layers/moe/wo", (24, 32, 512, 1024), MESH)
    assert s == P(None, "model", None, "data")


def test_norms_replicated():
    assert shd.spec_for_param("layers/ln_attn/scale", (24, 8192), MESH) \
        == P(None, None)
    assert shd.spec_for_param("ln_f/scale", (8192,), MESH) == P(None)


def test_pod_axis_never_in_weight_specs():
    """Weights replicate across pods (DCN-friendly): no 'pod' in specs."""
    for path, shape in [("layers/attn/wq", (80, 8192, 8192)),
                        ("embed", (152064, 8192)),
                        ("layers/moe/wi_gate", (24, 32, 1024, 512))]:
        s = shd.spec_for_param(path, shape, MESH_POD)
        assert "pod" not in jax.tree_util.tree_leaves(tuple(s)), (path, s)


def test_batch_axes_divisibility():
    assert shd._batch_axes(MESH, 256) == "data"
    assert shd._batch_axes(MESH_POD, 256) == ("pod", "data")
    assert shd._batch_axes(MESH_POD, 2) == "pod"
    assert shd._batch_axes(MESH_POD, 1) is None


def test_cache_specs_prefer_time_axis():
    import jax.numpy as jnp
    cache = {"k": jax.ShapeDtypeStruct((32, 128, 32768, 8, 128),
                                       jnp.bfloat16)}
    specs = shd.cache_specs(cache, MESH, None)
    assert specs["k"] == P(None, "data", "model", None, None)


def test_rwkv_state_spec_falls_back():
    import jax.numpy as jnp
    # default "heads" strategy: dim 3 (64) divides the model axis
    cache = {"S": jax.ShapeDtypeStruct((32, 128, 40, 64, 64),
                                       jnp.float32)}
    specs = shd.cache_specs(cache, MESH, None)
    assert specs["S"] == P(None, "data", None, "model", None)
    # "seq" strategy: H=40 not divisible, falls to the last divisible dim
    specs = shd.cache_specs(cache, MESH, None, strategy="feature")
    assert specs["S"] == P(None, "data", None, None, "model")


def test_cache_specs_heads_strategy_prefers_kv_heads():
    import jax.numpy as jnp
    # kv=32 divides model=16 -> heads axis sharded (stablelm decode D3)
    cache = {"k": jax.ShapeDtypeStruct((32, 128, 32768, 32, 80),
                                       jnp.bfloat16)}
    specs = shd.cache_specs(cache, MESH, None)
    assert specs["k"] == P(None, "data", None, "model", None)


def test_attn_fsdp_toggle():
    s = shd.spec_for_param("layers/attn/wq", (80, 8192, 8192), MESH,
                           attn_fsdp=False)
    assert s == P(None, None, "model")
    s = shd.spec_for_param("layers/attn/wk", (80, 8192, 1024), MESH,
                           attn_fsdp=False)
    # wk/wv stay FSDP; their kv out dim is never model-sharded (a split
    # inside head_dim breaks RoPE halves / perturbs GQA numerics).
    assert s == P(None, "data", None)


def test_kv_projections_never_model_sharded():
    for name in ("wk", "wv"):
        s = shd.spec_for_param(f"layers/attn/{name}", (80, 8192, 1024),
                               MESH)
        assert s == P(None, "data", None), name
    for name in ("bk", "bv"):
        s = shd.spec_for_param(f"layers/attn/{name}", (80, 1024), MESH)
        assert s == P(None, None), name


def test_zero1_optimizer_specs():
    import jax.numpy as jnp
    params = {"w": jax.ShapeDtypeStruct((8192, 512), jnp.bfloat16)}
    pspecs = {"w": P(None, "model")}
    ospecs = shd.optimizer_specs(pspecs, params, MESH, zero1=True)
    assert ospecs.m["w"] == P("data", "model")


def test_param_specs_cover_every_leaf():
    """Every leaf of every smoke model gets a valid spec (no crashes,
    correct rank)."""
    from repro.configs import get_smoke_config, list_archs
    from repro.models import build_model
    for arch in list_archs():
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        aparams = jax.eval_shape(lambda m=model: m.init(0))
        specs = shd.param_specs(aparams, MESH)
        flat_p = jax.tree_util.tree_leaves(aparams)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            assert len(spec) == len(leaf.shape), (arch, spec, leaf.shape)
