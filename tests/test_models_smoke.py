"""Per-architecture smoke tests (required by the pool assignment).

For every assigned architecture: instantiate the REDUCED config, run one
forward and one train step (loss + grads) on CPU, assert output shapes
and absence of NaNs.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig, get_config, get_smoke_config, \
    list_archs
from repro.models import build_model

SHAPE = ShapeConfig("smoke", "train", 16, 2)


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(0)
    batch = model.dummy_batch(SHAPE)

    logits, aux = model.forward(params, batch)
    assert logits.shape[0] == SHAPE.global_batch
    assert logits.shape[-1] == cfg.vocab_size
    assert not bool(jnp.isnan(logits).any())

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    # loss ~ ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < \
        2.0 * np.log(cfg.vocab_size)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "deepseek-v2-lite-16b",
                                  "recurrentgemma-9b", "rwkv6-3b",
                                  "seamless-m4t-medium"])
def test_prefill_decode_matches_teacher_forcing(arch):
    """Serving path correctness: token-by-token decode reproduces the
    teacher-forced logits (MLA absorption, ring buffers, recurrent
    states and cross-attention caches all exercised)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(0)
    shape = ShapeConfig("smoke", "train", 12, 2)
    batch = model.dummy_batch(shape)
    logits_full, _ = model.forward(params, batch)
    off = cfg.frontend_tokens if cfg.family == "vlm" else 0

    s_pre = 8
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :s_pre]
    logits_pre, cache, pos = model.prefill(params, pre, 16)
    err = float(jnp.max(jnp.abs(
        logits_full[:, off + s_pre - 1] - logits_pre[:, -1])))
    assert err < 5e-5, f"prefill mismatch {err}"

    for t in range(s_pre, 12):
        tok = batch["tokens"][:, t:t + 1]
        logits_t, cache = model.decode_step(params, cache, tok,
                                            jnp.int32(off + t))
        err = float(jnp.max(jnp.abs(logits_full[:, off + t]
                                    - logits_t[:, -1])))
        assert err < 5e-5, f"decode mismatch at {t}: {err}"


def test_full_configs_match_pool_dims():
    """The FULL configs carry the exact dims assigned in the pool."""
    expect = {
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
    }
    for arch, (L, d, H, KVH, ff, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == KVH, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == V, arch


def test_moe_configs():
    g = get_config("granite-moe-1b-a400m")
    assert g.moe.num_experts == 32 and g.moe.top_k == 8
    d = get_config("deepseek-v2-lite-16b")
    assert d.moe.num_experts == 64 and d.moe.top_k == 6
    assert d.moe.num_shared == 2
    assert d.mla.kv_lora_rank == 512


def test_param_counts_in_expected_range():
    """Analytic parameter counts should be near the advertised sizes."""
    cases = {
        "qwen1.5-110b": (90e9, 130e9),
        "deepseek-67b": (55e9, 75e9),
        "qwen2.5-3b": (2.2e9, 4.2e9),
        "granite-moe-1b-a400m": (0.8e9, 1.8e9),
        "deepseek-v2-lite-16b": (12e9, 20e9),
        "rwkv6-3b": (2.2e9, 4.5e9),
        # pool dims give 6.7B (the pool entry is [unverified]; the real
        # model's 9B includes a larger ff factor) — bound on POOL dims
        "recurrentgemma-9b": (6e9, 11e9),
    }
    for arch, (lo, hi) in cases.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_active_params_less_than_total_for_moe():
    for arch in ("granite-moe-1b-a400m", "deepseek-v2-lite-16b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < cfg.param_count()
    cfg = get_config("qwen2.5-3b")
    assert cfg.active_param_count() == cfg.param_count()
