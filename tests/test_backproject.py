"""The paper's optimization ladder: every variant must match the RTK
baseline to the paper's own validation bar (RMSE < 1e-5 relative)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    backproject_rtk, bp_share, bp_subline, bp_subline_symmetry_batch,
    bp_symmetry, bp_transpose, projection_matrices, standard_geometry,
    transpose_projections, volume_to_transposed,
)
from repro.core.variants import VARIANTS, get_variant

from conftest import rel_rmse

BAR = 1e-5  # paper §4.2


@pytest.fixture(scope="module")
def ref(small_geom, small_ct_data):
    img, mats = small_ct_data
    vol = backproject_rtk(img, mats, small_geom.volume_shape_zyx)
    return volume_to_transposed(vol)


@pytest.mark.parametrize("fn", [bp_transpose, bp_share, bp_symmetry,
                                bp_subline])
def test_ladder_matches_baseline(fn, small_geom, small_ct_data, ref):
    img, mats = small_ct_data
    img_t = transpose_projections(img)
    out = fn(img_t, mats, small_geom.volume_shape_xyz)
    assert rel_rmse(out, ref) < BAR


@pytest.mark.parametrize("nb", [1, 2, 4, 8])
def test_algorithm1_all_batch_sizes(nb, small_geom, small_ct_data, ref):
    img, mats = small_ct_data
    img_t = transpose_projections(img)
    out = bp_subline_symmetry_batch(img_t, mats,
                                    small_geom.volume_shape_xyz, nb=nb)
    assert rel_rmse(out, ref) < BAR


def test_batching_is_numerically_stable_across_nb(small_geom,
                                                  small_ct_data):
    """O5 changes only summation order: results across nb agree."""
    img, mats = small_ct_data
    img_t = transpose_projections(img)
    outs = [bp_subline_symmetry_batch(img_t, mats,
                                      small_geom.volume_shape_xyz, nb=nb)
            for nb in (1, 4, 8)]
    for o in outs[1:]:
        assert rel_rmse(o, outs[0]) < 1e-6


def test_variant_registry_complete(small_geom, small_ct_data, ref):
    img, mats = small_ct_data
    img_t = transpose_projections(img)
    for name in VARIANTS:
        fn = get_variant(name)
        out = fn(img_t, mats, small_geom.volume_shape_xyz, nb=4)
        assert rel_rmse(out, ref) < BAR, name


def test_projection_partition_additivity(small_geom, small_ct_data):
    """BP over a disjoint partition of projections sums to BP over all —
    the invariant that makes nb batching and pod-sharding correct."""
    img, mats = small_ct_data
    img_t = transpose_projections(img)
    full = bp_subline(img_t, mats, small_geom.volume_shape_xyz)
    part = (bp_subline(img_t[:3], mats[:3], small_geom.volume_shape_xyz)
            + bp_subline(img_t[3:], mats[3:], small_geom.volume_shape_xyz))
    assert rel_rmse(part, full) < 1e-6


def test_linearity_in_projections(small_geom, small_ct_data):
    img, mats = small_ct_data
    img_t = transpose_projections(img)
    shape = small_geom.volume_shape_xyz
    a = bp_subline(img_t, mats, shape)
    b = bp_subline(2.5 * img_t, mats, shape)
    assert rel_rmse(b, 2.5 * np.asarray(a)) < 1e-6


def test_zero_projections_give_zero_volume(small_geom, small_ct_data):
    img, mats = small_ct_data
    img_t = jnp.zeros_like(transpose_projections(img))
    out = bp_subline(img_t, mats, small_geom.volume_shape_xyz)
    assert float(jnp.abs(out).max()) == 0.0


def test_translated_matrices_equal_offset_volume(small_geom,
                                                 small_ct_data):
    """Distribution correctness: back-projecting a sub-slab with
    translated matrices equals the corresponding slab of the full
    volume (core.distributed relies on this)."""
    from repro.core.distributed import translate_matrices
    img, mats = small_ct_data
    img_t = transpose_projections(img)
    full = bp_subline(img_t, mats, small_geom.volume_shape_xyz)
    i0, j0 = 4, 8
    bi, bj = 8, 8
    mats_t = translate_matrices(mats, float(i0), float(j0))
    slab = bp_subline(img_t, mats_t, (bi, bj, small_geom.nz))
    assert rel_rmse(slab, np.asarray(full)[i0:i0 + bi, j0:j0 + bj]) < 1e-6
