"""Serving layer (PR 4): ReconService buckets + the async step pipeline.

Covers the serving seams the ISSUE pins down:
  * cross-request ProgramCache reuse — two same-shape requests compile
    exactly once (miss then hit), and warmup() moves every compile
    ahead of the first request;
  * mixed-shape isolation — the cache has no eviction, so interleaved
    shape classes never recompile each other;
  * async pipeline parity — ``pipeline="async"`` (flusher thread,
    ``block_until_ready`` only at dequeue) is BIT-identical to the
    sequential ``schedule="step"`` executor for >= 3 variants;
  * FIFO fairness + bounded in-flight concurrency;
  * the hashable ``ReconPlan.bucket_key`` the buckets are keyed on.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (fdk_reconstruct, standard_geometry,
                        transpose_projections)
from repro.runtime.executor import PlanExecutor, ProgramCache
from repro.runtime.planner import plan_reconstruction
from repro.runtime.service import ReconService

from conftest import rel_rmse


@pytest.fixture(scope="module")
def setup():
    geom = standard_geometry(n=16, n_det=24, n_proj=6)
    rng = np.random.RandomState(3)
    projs = jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                                 geom.nw).astype(np.float32))
    return geom, projs


OPTS = dict(variant="subline_batch_mp", nb=2, tiling=(8, 8, 16),
            proj_batch=4)


# ---- bucket_key -----------------------------------------------------------

def test_plan_is_hashable_bucket_key(setup):
    geom, _ = setup
    a = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=4)
    b = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=4)
    assert a == b and hash(a) == hash(b)          # plan itself is a key
    assert a.bucket_key == b.bucket_key
    assert hash(a.bucket_key) == hash(b.bucket_key)
    c = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=2)
    assert c.bucket_key != a.bucket_key           # chunk grid differs
    d = plan_reconstruction(geom, "share_mp", nb=2, proj_batch=4)
    assert d.bucket_key != a.bucket_key           # variant differs


# ---- cross-request ProgramCache reuse -------------------------------------

def test_same_shape_requests_compile_once(setup):
    """Two same-shape requests: miss then hit — the second request adds
    ZERO cache misses (the acceptance cache-hit assertion)."""
    geom, projs = setup
    with ReconService(max_inflight=1, cache=ProgramCache()) as svc:
        v1 = svc.reconstruct(projs, geom, **OPTS)
        after_first = svc.stats()
        assert after_first.bucket_misses == 1
        assert after_first.cache["misses"] > 0    # the cold compiles
        v2 = svc.reconstruct(projs, geom, **OPTS)
        after_second = svc.stats()
    assert after_second.cache["misses"] == after_first.cache["misses"]
    assert after_second.bucket_hits == 1
    assert after_second.cache["hits"] > after_first.cache["hits"]
    assert np.array_equal(np.asarray(v1), np.asarray(v2))


def test_warmup_precompiles_everything(setup):
    """After warmup(geometries) the first REAL request is a bucket hit
    with zero new programs built."""
    geom, projs = setup
    with ReconService(max_inflight=1, cache=ProgramCache()) as svc:
        stats = svc.warmup([geom], **OPTS)
        assert stats.bucket_misses == 1 and stats.cache["misses"] > 0
        warmed = stats.cache["misses"]
        svc.reconstruct(projs, geom, **OPTS)
        stats = svc.stats()
        assert stats.cache["misses"] == warmed    # no compile on request
        assert stats.bucket_hits == 1
        b = stats.buckets[0]
        assert (b.requests, b.hits, b.programs_built) == (1, 1, warmed)


def test_mixed_shapes_do_not_evict(setup):
    """Interleaved shape classes keep their buckets AND their compiled
    programs: re-requesting the first shape adds no cache misses."""
    geom_a, projs_a = setup
    geom_b = standard_geometry(n=8, n_det=12, n_proj=6)
    rng = np.random.RandomState(4)
    projs_b = jnp.asarray(rng.rand(geom_b.n_proj, geom_b.nh,
                                   geom_b.nw).astype(np.float32))
    with ReconService(max_inflight=1, cache=ProgramCache()) as svc:
        svc.reconstruct(projs_a, geom_a, **OPTS)
        svc.reconstruct(projs_b, geom_b, **OPTS)
        both_cold = svc.stats().cache["misses"]
        svc.reconstruct(projs_a, geom_a, **OPTS)   # back to shape A
        svc.reconstruct(projs_b, geom_b, **OPTS)   # and shape B again
        stats = svc.stats()
    assert stats.cache["misses"] == both_cold
    assert stats.bucket_misses == 2 and stats.bucket_hits == 2
    assert {b.vol_shape_xyz for b in stats.buckets} == \
        {(16, 16, 16), (8, 8, 8)}


def test_facade_service_routing(setup):
    """fdk_reconstruct(service=...) lands in the service's buckets and
    matches the one-shot façade exactly."""
    geom, projs = setup
    ref = fdk_reconstruct(projs, geom, **OPTS)
    with ReconService(max_inflight=1, cache=ProgramCache()) as svc:
        via = fdk_reconstruct(projs, geom, service=svc, **OPTS)
        assert svc.stats().bucket_misses == 1
        fdk_reconstruct(projs, geom, service=svc, **OPTS)
        assert svc.stats().bucket_hits == 1
        # the service owns the flush discipline — combining is an error
        with pytest.raises(ValueError, match="pipeline"):
            fdk_reconstruct(projs, geom, service=svc, pipeline="sync",
                            **OPTS)
    assert rel_rmse(via, ref) < 1e-6


# ---- async pipeline parity ------------------------------------------------

@pytest.mark.parametrize("variant",
                         ["algorithm1_mp", "subline_batch_mp", "share_mp",
                          "symmetry_mp"])
def test_async_pipeline_bit_identical(setup, variant):
    """pipeline="async" only moves WHEN host adds happen, never their
    FIFO order -> bit-identical to the sequential step-major executor
    (>= 3 variants per the satellite; 4 here, symmetry included)."""
    geom, projs = setup
    plan = plan_reconstruction(geom, variant, nb=2, tile_shape=(8, 8, 16),
                               proj_batch=4, out="host")
    cache = ProgramCache()
    seq = PlanExecutor(geom, plan, cache=cache,
                       pipeline="sync").reconstruct(projs)
    pip = PlanExecutor(geom, plan, cache=cache,
                       pipeline="async").reconstruct(projs)
    assert np.array_equal(np.asarray(seq), np.asarray(pip)), variant


@pytest.mark.parametrize("variant", ["algorithm1_mp", "share_mp"])
def test_async_chunk_major_parity(setup, variant):
    """The async flush now covers the CHUNK-major loop too (ROADMAP
    PR-4 follow-up): enqueue order equals the sequential flush order,
    so output stays bit-identical even though chunks re-add into the
    same volume regions."""
    geom, projs = setup
    plan = plan_reconstruction(geom, variant, nb=2, tile_shape=(8, 8, 16),
                               proj_batch=2, out="host", schedule="chunk")
    cache = ProgramCache()
    seq = PlanExecutor(geom, plan, cache=cache,
                       pipeline="sync").reconstruct(projs)
    pip = PlanExecutor(geom, plan, cache=cache,
                       pipeline="async").reconstruct(projs)
    assert np.array_equal(np.asarray(seq), np.asarray(pip)), variant
    # and the raw backproject chunk loop
    img_t = transpose_projections(projs)
    from repro.core.geometry import projection_matrices
    mats = projection_matrices(geom)
    seq = PlanExecutor(geom, plan, cache=cache,
                       pipeline="sync").backproject(img_t, mats)
    pip = PlanExecutor(geom, plan, cache=cache,
                       pipeline="async").backproject(img_t, mats)
    assert np.array_equal(np.asarray(seq), np.asarray(pip)), variant


def test_async_backproject_parity(setup):
    """The raw backproject path pipelines too (data-dependent chunks)."""
    geom, projs = setup
    img_t = transpose_projections(projs)
    from repro.core.geometry import projection_matrices
    mats = projection_matrices(geom)
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=2,
                               tile_shape=(8, 8, 16), proj_batch=2,
                               out="host")
    cache = ProgramCache()
    seq = PlanExecutor(geom, plan, cache=cache,
                       pipeline="sync").backproject(img_t, mats)
    pip = PlanExecutor(geom, plan, cache=cache,
                       pipeline="async").backproject(img_t, mats)
    assert np.array_equal(np.asarray(seq), np.asarray(pip))


def test_pipeline_validation(setup):
    geom, _ = setup
    plan = plan_reconstruction(geom, "algorithm1_mp")
    with pytest.raises(ValueError, match="pipeline"):
        PlanExecutor(geom, plan, pipeline="turbo")


# ---- FIFO fairness + bounded concurrency ----------------------------------

def test_fifo_order_and_bounded_inflight(setup, monkeypatch):
    """With max_inflight=1, requests START in submission order (FIFO
    fairness across mixed shapes) and at most one executes at a time.
    Execution order is spied on the worker side (PlanExecutor) — done-
    callback order would race the result() wakeup."""
    geom_a, projs_a = setup
    geom_b = standard_geometry(n=8, n_det=12, n_proj=6)
    rng = np.random.RandomState(5)
    projs_b = jnp.asarray(rng.rand(geom_b.n_proj, geom_b.nh,
                                   geom_b.nw).astype(np.float32))
    order = []
    real = PlanExecutor.reconstruct

    def spy(self, projections):
        order.append(id(projections))
        return real(self, projections)

    monkeypatch.setattr(PlanExecutor, "reconstruct", spy)
    with ReconService(max_inflight=1, cache=ProgramCache()) as svc:
        svc.warmup([geom_a, geom_b], **OPTS)
        inputs, futs = [], []
        for i in range(6):
            g, p = ((geom_a, projs_a) if i % 2 == 0 else (geom_b, projs_b))
            # distinct array object per request so id() tags submissions
            p = p + 0
            inputs.append(p)
            futs.append(svc.submit(p, g, **OPTS))
        for f in futs:
            f.result()
    assert order == [id(p) for p in inputs]


def test_submit_validates_in_caller(setup):
    """Bad options raise AT SUBMIT (planner validation), not in a
    worker thread via the future."""
    geom, projs = setup
    with ReconService(max_inflight=1, cache=ProgramCache()) as svc:
        with pytest.raises(ValueError, match="does not accept"):
            svc.submit(projs, geom, variant="share_mp", bogus_option=1)
        with pytest.raises(ValueError):
            svc.submit(projs, geom, out="sideways")


def test_worker_errors_surface_via_future(setup):
    """Execution errors (wrong projection count) land in the future,
    and the service keeps serving afterwards."""
    geom, projs = setup
    with ReconService(max_inflight=1, cache=ProgramCache()) as svc:
        bad = svc.submit(projs[:3], geom, **OPTS)
        with pytest.raises(ValueError, match="full scan"):
            bad.result()
        good = svc.submit(projs, geom, **OPTS)    # still alive
        assert good.result().shape == (16, 16, 16)


def test_closed_service_rejects(setup):
    geom, projs = setup
    svc = ReconService(max_inflight=1, cache=ProgramCache())
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(projs, geom, **OPTS)


# ---- streamed latency accounting ------------------------------------------

def test_latency_histogram_quantiles():
    from repro.runtime.service import LatencyHistogram
    h = LatencyHistogram()
    assert h.quantile(0.5) is None and h.mean() is None
    for ms in (1, 1, 1, 1, 1, 1, 1, 1, 1, 1000):   # 9 fast + 1 slow
        h.record(ms * 1e-3)
    assert h.count == 10
    p50, p99 = h.quantile(0.50), h.quantile(0.99)
    # log-2 bins: estimates within a bin width of the truth, ordered
    assert 0.4e-3 < p50 < 3e-3
    assert 0.5 < p99 < 2.0
    assert p50 <= p99
    assert h.mean() == pytest.approx(100.9e-3, rel=1e-6)
    merged = LatencyHistogram.merged([h, h])
    assert merged.count == 20 and merged.quantile(0.5) == p50


def test_bucket_stats_stream_latency(setup):
    """Every COMPLETED request lands in its bucket's histogram as it
    finishes (streamed, not poll-sampled): counts and quantiles are
    live after each request, and the service-level p50/p99 merge the
    bucket histograms."""
    geom, projs = setup
    with ReconService(max_inflight=1, cache=ProgramCache()) as svc:
        svc.warmup([geom], **OPTS)
        assert svc.stats().buckets[0].completed == 0   # warmup != traffic
        for i in range(3):
            svc.reconstruct(projs, geom, **OPTS)
            b = svc.stats().buckets[0]
            assert b.completed == i + 1               # streams per request
        stats = svc.stats()
        b = stats.buckets[0]
        assert b.p50_ms is not None and b.p99_ms is not None
        assert b.p50_ms <= b.p99_ms and b.mean_ms > 0
        assert stats.p50_ms == b.p50_ms               # single bucket merge
        assert b.source == "heuristic" and b.pipeline == "async"


@pytest.mark.slow
def test_clinical_size_overlap_measurement():
    """The satellite fix for the misleading smoke overlap_gain: measure
    sync-vs-async where the per-step flush is MBs. Non-gating on the
    gain value itself (machine-dependent) — this asserts the clinical
    path runs and emits the flush-bytes context."""
    from benchmarks import bench_service, common
    common.reset_records()
    gain = bench_service.run_clinical(n=64, n_det=96, n_proj=32, nb=8)
    rows = {r["name"]: r for r in common.records()}
    assert "service/pipeline_sync_clinical" in rows
    assert "service/pipeline_async_clinical" in rows
    kb = rows["service/pipeline_async_clinical"]["metrics"][
        "flush_kb_per_step"]
    assert kb > 200            # clinical flushes are real traffic
    assert gain > 0
