"""Reconstruction-fleet tests (subprocess: 8 forced host devices).

The fleet shards the planner's step-major schedule across a device mesh
(``PlanExecutor.execute_fleet``); these prove the four contracts on the
no-hardware CI lane (``XLA_FLAGS=--xla_force_host_platform_device_count
=8``, in a subprocess because the device count must be fixed before jax
initializes — the main test process keeps the default single device):

  * **parity** — the fleet reconstruction of a volume matches the
    single-device step-major walk within tolerance (the origin folds
    into the matrices INSIDE the fleet program, so float association
    may differ from the host-side fold; disjoint boxes mean nothing
    else can);
  * **failover** — with one device's steps forcibly failed, the run
    completes BIT-IDENTICALLY via re-run on surviving devices, the
    struck device is retired, and its completion count is zero;
  * **work stealing** — a straggling device's unclaimed steps migrate
    (stolen > 0) with output still bit-identical;
  * **poison step** — a step that fails everywhere exhausts its
    per-step retry budget and aborts the run (an incomplete volume must
    never be returned).

The serving layer rides the same path: ``ReconService(devices="all")``
buckets place every request across the fleet and surface steal/failover
totals in their stats.
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json, time, threading
sys.path.insert(0, "src")
import numpy as np
import jax, jax.numpy as jnp

from repro.core import standard_geometry
from repro.core.fdk import _build_plan, fdk_reconstruct
from repro.runtime.executor import (FleetConfig, PlanExecutor,
                                    default_program_cache)
from repro.runtime.service import ReconService

out = {}
out["n_devices"] = len(jax.local_devices())

geom = standard_geometry(n=32, n_det=48, n_proj=16)
rng = np.random.RandomState(0)
projs = jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                             geom.nw).astype(np.float32))
# (8, 8, nz) tiles -> 16 same-shape steps over 8 devices (2 each);
# proj_batch=8 -> a 2-chunk scan grid inside each fleet program
kw = dict(nb=8, interpret=True, tiling=(8, 8, geom.nz),
          memory_budget=None, proj_batch=8, out="host", schedule="step")

ref = np.asarray(fdk_reconstruct(
    projs, geom, tiling=(8, 8, geom.nz), proj_batch=8, out="host"))

def fleet_run(cfg):
    ex = PlanExecutor(geom, _build_plan(geom, "algorithm1_mp", **kw),
                      fleet=cfg)
    vol = ex.reconstruct(projs)
    return np.asarray(vol), ex.last_fleet_report

# ---- parity: fleet == single-device step-major ---------------------------
vol_fleet, rep = fleet_run(FleetConfig())
scale = float(np.max(np.abs(ref))) or 1.0
out["fleet_rel_err"] = float(np.max(np.abs(vol_fleet - ref))) / scale
out["fleet_devices"] = rep.n_devices
out["fleet_steps"] = rep.n_steps
out["fleet_steps_covered"] = int(sum(rep.steps_by_device))

# ---- failover: device 3's steps forcibly failed --------------------------
def fail_dev3(device, step):
    if device == 3:
        raise RuntimeError("injected device fault")

vol_fo, rep_fo = fleet_run(FleetConfig(step_hook=fail_dev3))
out["failover_bit_identical"] = bool(np.array_equal(vol_fleet, vol_fo))
out["failover_dead"] = list(rep_fo.dead_devices)
out["failover_retried"] = rep_fo.retried
out["failover_dev3_done"] = rep_fo.steps_by_device[3]
out["failover_steps_covered"] = int(sum(rep_fo.steps_by_device))

# ---- work stealing: device 0 straggles -----------------------------------
def slow_dev0(device, step):
    if device == 0:
        time.sleep(1.0)

vol_st, rep_st = fleet_run(FleetConfig(step_hook=slow_dev0))
out["steal_bit_identical"] = bool(np.array_equal(vol_fleet, vol_st))
out["steal_stolen"] = rep_st.stolen
out["steal_flagged"] = list(rep_st.flagged_devices)

# ---- poison step: fails on EVERY device -> abort, never a partial volume -
def poison_step0(device, step):
    if step == 0:
        raise RuntimeError("injected poison step")

try:
    fleet_run(FleetConfig(step_hook=poison_step0, max_retries_per_step=2))
    out["poison_raised"] = False
except RuntimeError as e:
    out["poison_raised"] = True
    out["poison_msg"] = str(e)[:120]

# ---- serving layer: buckets place requests across the fleet --------------
svc = ReconService(max_inflight=2, devices="all")
h1 = svc.submit(projs, geom, tiling=(8, 8, geom.nz), proj_batch=8)
h2 = svc.submit(projs, geom, tiling=(8, 8, geom.nz), proj_batch=8)
v1, v2 = np.asarray(h1.result()), np.asarray(h2.result())
out["service_rel_err"] = float(np.max(np.abs(v1 - ref))) / scale
out["service_repeat_identical"] = bool(np.array_equal(v1, v2)
                                       and np.array_equal(v1, vol_fleet))
stats = svc.stats()
out["service_bucket_devices"] = stats.buckets[0].devices
out["service_requests"] = stats.requests
svc.close()

print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def fleet_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))), env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):])


def test_fleet_runs_on_eight_devices(fleet_results):
    assert fleet_results["n_devices"] == 8
    assert fleet_results["fleet_devices"] == 8


def test_fleet_matches_single_device(fleet_results):
    """16 steps sharded over 8 devices reconstruct the same volume as
    the single-device step-major walk (every step covered once)."""
    assert fleet_results["fleet_rel_err"] < 1e-5
    assert fleet_results["fleet_steps_covered"] == \
        fleet_results["fleet_steps"]


def test_fleet_failover_bit_identical(fleet_results):
    """A device whose every step faults is retired after its strike
    budget; its steps re-run on survivors and the output is
    BIT-identical (disjoint boxes + identical per-step programs)."""
    assert fleet_results["failover_bit_identical"]
    assert 3 in fleet_results["failover_dead"]
    assert fleet_results["failover_retried"] >= 1
    assert fleet_results["failover_dev3_done"] == 0
    assert fleet_results["failover_steps_covered"] == \
        fleet_results["fleet_steps"]


def test_fleet_steals_from_straggler(fleet_results):
    """An idle device steals the straggling device's unclaimed steps;
    migration never changes the output."""
    assert fleet_results["steal_stolen"] >= 1
    assert fleet_results["steal_bit_identical"]


def test_fleet_poison_step_aborts(fleet_results):
    """A step failing on EVERY device exhausts max_retries_per_step and
    raises — a partial volume is never silently returned."""
    assert fleet_results["poison_raised"]
    assert "max_retries_per_step" in fleet_results.get("poison_msg", "")


def test_service_places_buckets_across_fleet(fleet_results):
    """ReconService(devices="all") routes bucket executors through
    execute_fleet: correct volumes, repeat-identical, and the bucket
    stats report the fleet width."""
    assert fleet_results["service_rel_err"] < 1e-5
    assert fleet_results["service_repeat_identical"]
    assert fleet_results["service_bucket_devices"] == 8
    assert fleet_results["service_requests"] == 2
