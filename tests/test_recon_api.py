"""The unified entry point: ``repro.reconstruct`` + ``ReconOptions``
and the legacy-kwarg deprecation shim."""

import warnings

import numpy as np
import pytest

import jax.numpy as jnp

import repro
from repro.api import ITERATIVE_METHODS, ReconOptions, _coerce_options
from repro.core.forward import forward_project
from repro.core.geometry import standard_geometry
from repro.core.phantom import shepp_logan_3d
from repro.runtime.executor import ProgramCache


@pytest.fixture(scope="module")
def api_setup():
    n = 16
    geom = standard_geometry(n=n, n_det=24, n_proj=8)
    phantom = jnp.asarray(shepp_logan_3d(n))
    projs = forward_project(phantom, geom, oversample=1.0)
    return geom, phantom, projs


# ---------------------------------------------------------------------------
# ReconOptions record


def test_options_frozen_hashable_normalized():
    o = ReconOptions(nb=4, kernel_options={"b": 2, "a": 1})
    assert o.kernel_options == (("a", 1), ("b", 2))   # dict → sorted tuple
    assert o.kernel_options_dict() == {"a": 1, "b": 2}
    assert hash(o) == hash(ReconOptions(nb=4, kernel_options=[("a", 1),
                                                              ("b", 2)]))
    with pytest.raises(Exception):
        o.nb = 8                                      # frozen
    assert ReconOptions() == ReconOptions()


def test_coerce_override_wins_silently():
    """A legacy kwarg against a DEFAULT field is silent — that's every
    historical call site."""
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        o = _coerce_options(None, {"nb": 4, "interpret": True}, "t")
    assert o.nb == 4


def test_coerce_conflict_warns_and_kwarg_wins():
    base = ReconOptions(nb=2)
    with pytest.warns(DeprecationWarning, match="nb=4 conflicts"):
        o = _coerce_options(base, {"nb": 4}, "t")
    assert o.nb == 4
    # same value twice is not a conflict
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert _coerce_options(base, {"nb": 2}, "t").nb == 2


def test_coerce_unknown_keys_become_kernel_options():
    base = ReconOptions(kernel_options={"keep": 1})
    o = _coerce_options(base, {"unroll": 2, "nb": 4}, "t")
    assert o.nb == 4
    assert o.kernel_options_dict() == {"keep": 1, "unroll": 2}


def test_coerce_rejects_non_options():
    with pytest.raises(TypeError):
        _coerce_options({"nb": 4}, {}, "t")


# ---------------------------------------------------------------------------
# reconstruct() drives all five methods


def test_reconstruct_fdk(api_setup):
    geom, _, projs = api_setup
    v_new = repro.reconstruct(projs, geom, options=ReconOptions(nb=4))
    v_old = repro.fdk_reconstruct(projs, geom, nb=4)
    assert np.allclose(np.asarray(v_new), np.asarray(v_old))


@pytest.mark.parametrize("method", ITERATIVE_METHODS)
def test_reconstruct_iterative_methods(api_setup, method):
    geom, phantom, projs = api_setup
    opts = ReconOptions(nb=4, n_iters=2, oversample=1.0, proj_batch=4)
    vol = repro.reconstruct(projs, geom, method, options=opts)
    assert vol.shape == phantom.shape
    assert np.isfinite(np.asarray(vol)).all()
    # the two-iteration estimate is already correlated with the truth
    v = np.asarray(vol).ravel()
    p = np.asarray(phantom).ravel()
    corr = np.corrcoef(v, p)[0, 1]
    assert corr > 0.4, (method, corr)


def test_reconstruct_rejects_unknown_method(api_setup):
    geom, _, projs = api_setup
    with pytest.raises(ValueError, match="method"):
        repro.reconstruct(projs, geom, "mlem")


def test_reconstruct_iterative_rejects_devices(api_setup):
    geom, _, projs = api_setup
    with pytest.raises(ValueError, match="single-device"):
        repro.reconstruct(projs, geom, "sart",
                          options=ReconOptions(devices=2))


def test_reconstruct_legacy_kwargs(api_setup):
    """No options object at all — pure legacy spelling, no warning."""
    geom, _, projs = api_setup
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        vol = repro.reconstruct(projs, geom, "sart", n_iters=1, nb=4,
                                oversample=1.0)
    assert vol.shape == (16, 16, 16)


def test_reconstruct_precision_kwarg(api_setup):
    geom, _, projs = api_setup
    v32 = repro.reconstruct(projs, geom, "sart", n_iters=1, nb=4,
                            oversample=1.0)
    v16 = repro.reconstruct(projs, geom, "sart", n_iters=1, nb=4,
                            oversample=1.0, precision="bf16")
    d = float(jnp.abs(v32 - v16).max())
    assert 0.0 < d < 0.05 * max(float(jnp.abs(v32).max()), 1e-12) + 1e-3


# ---------------------------------------------------------------------------
# service routing through the unified API


def test_reconstruct_via_service(api_setup):
    from repro.runtime.service import ReconService
    geom, _, projs = api_setup
    with ReconService() as svc:
        opts = ReconOptions(nb=4, n_iters=2, oversample=1.0, service=svc)
        v1 = repro.reconstruct(projs, geom, "sart", options=opts)
        v2 = repro.reconstruct(projs, geom, "sart", options=opts)
        assert np.allclose(np.asarray(v1), np.asarray(v2))
        vf = repro.reconstruct(projs, geom, "fdk",
                               options=ReconOptions(nb=4, service=svc))
        assert vf.shape == v1.shape
        assert len(svc.stats().buckets) == 2
        # solver knobs without solver= must be rejected service-side
        with pytest.raises(ValueError):
            svc.reconstruct(projs, geom, n_iters=2)


def test_lazy_package_exports():
    assert repro.ReconOptions is ReconOptions
    assert callable(repro.reconstruct)
    assert callable(repro.solve)
    assert callable(repro.forward_project)
    assert repro.SolveReport.__name__ == "SolveReport"
    assert repro.IterativeExecutor.__name__ == "IterativeExecutor"
    with pytest.raises(AttributeError):
        repro.not_a_symbol
