"""Autotuner (ISSUE 5): measured config search + persistent TuningCache.

Covers the seams the ISSUE pins down:
  * TuningCache robustness — fingerprint mismatch re-tunes, a corrupt
    or missing cache file degrades to the heuristics (never an error),
    winners survive the JSON round trip with hashable tuples intact;
  * exactness contract — the default (explicit-variant) search tunes
    only order-only knobs, so the tuned config's volume is
    BIT-identical to the heuristic config across >= 4 variants;
  * zero re-measurement — a persisted winner resolves as a cache hit
    with ``trials == 0`` and without ever entering ``_measure_config``
    (asserted in-process with a poisoned measurer AND across real
    processes via ``ReconService.warmup(tune=True)`` — the acceptance
    scenario);
  * end-to-end integration — ``plan_reconstruction(variant="auto" /
    tuning=...)`` and the ``fdk_reconstruct`` façade resolve the tuned
    plan; the service reports tuned-vs-heuristic per bucket.
"""

import dataclasses
import json
import os
import subprocess
import sys
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import fdk_reconstruct, standard_geometry
from repro.runtime import autotune as at
from repro.runtime.autotune import (TunedConfig, TuningCache, autotune,
                                    fingerprint_key, request_key,
                                    resolve_config)
from repro.runtime.executor import PlanExecutor, ProgramCache
from repro.runtime.planner import plan_reconstruction
from repro.runtime.service import ReconService

from conftest import rel_rmse

# one program cache for the whole module: candidates repeat across
# tests, so programs compile once and the searches stay CI-sized
_PCACHE = ProgramCache()

OPTS = dict(nb=2, tiling=(8, 8, 16), proj_batch=4)


@pytest.fixture(scope="module")
def setup():
    geom = standard_geometry(n=16, n_det=24, n_proj=6)
    rng = np.random.RandomState(3)
    projs = jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                                 geom.nw).astype(np.float32))
    return geom, projs


def _tune(geom, projs, variant, cache, **kw):
    kw.setdefault("budget_s", 30.0)
    kw.setdefault("iters", 1)
    return autotune(geom, variant, **OPTS, cache=cache,
                    program_cache=_PCACHE, projections=projs, **kw)


# ---- fingerprint + request key --------------------------------------------

def test_fingerprint_shape_and_stability():
    a, b = at.hardware_fingerprint(), at.hardware_fingerprint()
    assert a == b and len(a) == 4
    assert fingerprint_key(a) == fingerprint_key(b)
    assert fingerprint_key(a).count("|") == 3


def test_request_key_tracks_bucket_key(setup):
    geom, _ = setup
    a = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=4)
    b = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=4)
    c = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=2)
    assert request_key(a) == request_key(b)
    assert request_key(a) != request_key(c)


# ---- TuningCache robustness -----------------------------------------------

def test_cache_roundtrip_restores_tuples(setup, tmp_path):
    geom, _ = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    plan = plan_reconstruction(geom, "subline_pl", nb=2,
                               tile_shape=(8, 8, 16), proj_batch=4,
                               block=(4, 8))
    cfg = at.config_from_plan(plan, pipeline="async", pipeline_depth=4)
    cache.store("fp", "rk", cfg)
    back = cache.lookup("fp", "rk")
    assert back is not None and back.key == cfg.key
    # tuple-ness survives JSON (bucket keys must stay hashable):
    # subline_pl carries block=(4, 8) in its options
    assert dict(back.options)["block"] == (4, 8)
    assert isinstance(back.tile_shape, tuple)
    hash(back.build_plan(geom).bucket_key)    # must not raise


def test_missing_cache_file_is_heuristic_fallback(setup, tmp_path):
    geom, _ = setup
    missing = str(tmp_path / "nope" / "t.json")
    assert TuningCache(missing).lookup("fp", "rk") is None
    cfg = resolve_config(geom, "subline_batch_mp",
                         cache=TuningCache(missing), **OPTS)
    assert cfg.source == "heuristic"
    # the planner path degrades identically (plan equality, not error)
    tuned = plan_reconstruction(geom, "subline_batch_mp", nb=2,
                                tile_shape=(8, 8, 16), proj_batch=4,
                                tuning=missing)
    plain = plan_reconstruction(geom, "subline_batch_mp", nb=2,
                                tile_shape=(8, 8, 16), proj_batch=4)
    assert tuned == plain


def test_corrupt_cache_file_is_heuristic_fallback(setup, tmp_path):
    geom, _ = setup
    bad = tmp_path / "t.json"
    for garbage in ("{not json", '{"version": 99}', '[1, 2]', ""):
        bad.write_text(garbage)
        cache = TuningCache(str(bad))
        assert cache.lookup("fp", "rk") is None
        assert resolve_config(geom, "subline_batch_mp", cache=cache,
                              **OPTS).source == "heuristic"
    # a corrupt file is also recoverable: store() rewrites it whole
    bad.write_text("{not json")
    cache = TuningCache(str(bad))
    plan = plan_reconstruction(geom, "subline_batch_mp", nb=2)
    cache.store("fp", "rk", at.config_from_plan(plan))
    assert cache.lookup("fp", "rk") is not None
    json.load(open(str(bad)))                 # valid JSON again


def test_malformed_entry_is_a_miss(tmp_path):
    p = tmp_path / "t.json"
    p.write_text(json.dumps({"version": 1, "fingerprints": {
        "fp": {"rk": {"variant": "algorithm1_mp"}}}}))   # missing fields
    assert TuningCache(str(p)).lookup("fp", "rk") is None


# ---- measured search + persistence ----------------------------------------

def test_autotune_measures_then_hits_cache(setup, tmp_path, monkeypatch):
    """Fresh cache: the search measures (trials > 0, heuristic always
    included). Second resolution: cache hit with ZERO re-measurement —
    the measurer is poisoned to prove it is never entered."""
    geom, projs = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    cfg = _tune(geom, projs, "subline_batch_mp", cache)
    assert cfg.source == "measured" and cfg.trials > 0
    assert cfg.baseline_us > 0 and cfg.wall_us > 0
    assert len(cache) == 1

    def boom(*a, **k):
        raise AssertionError("cache hit must not re-measure")

    monkeypatch.setattr(at, "_measure_config", boom)
    again = _tune(geom, projs, "subline_batch_mp", cache)
    assert again.source == "cache" and again.trials == 0
    assert again.key == cfg.key               # the SAME config


def test_fingerprint_mismatch_retunes(setup, tmp_path, monkeypatch):
    """A winner recorded under different hardware is never trusted:
    the lookup misses and the search runs again."""
    geom, projs = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    _tune(geom, projs, "subline_batch_mp", cache)
    monkeypatch.setattr(at, "hardware_fingerprint",
                        lambda: ("cpu", "other-machine", 128, "9.9.9"))
    cfg = _tune(geom, projs, "subline_batch_mp", cache)
    assert cfg.source == "measured" and cfg.trials > 0
    assert len(cache) == 2                    # both fingerprints persisted


# ---- exactness contract ----------------------------------------------------

@pytest.mark.parametrize("variant", ["algorithm1_mp", "subline_batch_mp",
                                     "share_mp", "symmetry_mp"])
def test_tuned_config_bit_identical(setup, tmp_path, variant):
    """Default (exact) tuning searches only order-only knobs
    (schedule/pipeline/depth) -> the tuned config's volume is
    BIT-identical to the heuristic config, for >= 4 variants."""
    geom, projs = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    cfg = _tune(geom, projs, variant, cache)
    assert cfg.variant == variant             # exact mode never switches
    ref = fdk_reconstruct(projs, geom, variant=variant, **OPTS)
    tuned = PlanExecutor.from_config(geom, cfg,
                                     cache=_PCACHE).reconstruct(projs)
    assert np.array_equal(np.asarray(ref), np.asarray(tuned)), cfg


def test_wide_search_parity_at_tolerance(setup, tmp_path):
    """variant="auto" widens to numeric knobs (variant/tile/chunk):
    parity vs the heuristic is at tolerance, and the winner never loses
    to the measured heuristic baseline."""
    geom, projs = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    cfg = _tune(geom, projs, "auto", cache,
                variants=("algorithm1_mp", "subline_batch_mp"))
    assert cfg.wall_us <= cfg.baseline_us
    ref = fdk_reconstruct(projs, geom, variant="algorithm1_mp", **OPTS)
    tuned = PlanExecutor.from_config(geom, cfg,
                                     cache=_PCACHE).reconstruct(projs)
    assert rel_rmse(tuned, ref) < 1e-5


def test_explicit_request_never_resolves_auto_winner(setup, tmp_path):
    """An auto-tuned winner may carry a different variant; a request
    that NAMES a variant must not resolve it (scoped request keys) —
    it stays on its own (heuristic or explicitly-tuned) config."""
    geom, projs = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    cfg = _tune(geom, projs, "auto", cache,
                variants=("algorithm1_mp", "subline_batch_mp"))
    # the auto scope resolves, the explicit scope does not
    assert resolve_config(geom, "auto", cache=cache,
                          **OPTS).source == "cache"
    explicit = resolve_config(geom, "algorithm1_mp", cache=cache, **OPTS)
    assert explicit.source == "heuristic"
    assert explicit.variant == "algorithm1_mp"
    # tuning the explicit request stores its own entry alongside
    _tune(geom, projs, "algorithm1_mp", cache)
    explicit = resolve_config(geom, "algorithm1_mp", cache=cache, **OPTS)
    assert explicit.source == "cache"
    assert explicit.variant == "algorithm1_mp"
    assert cfg is not None


# ---- end-to-end resolution -------------------------------------------------

def test_facade_auto_uses_persisted_winner(setup, tmp_path):
    geom, projs = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    cfg = _tune(geom, projs, "auto", cache, exact=True)
    resolved = resolve_config(geom, "auto", cache=cache, **OPTS)
    assert resolved.source == "cache" and resolved.key == cfg.key
    ref = fdk_reconstruct(projs, geom, variant="algorithm1_mp", **OPTS)
    via = fdk_reconstruct(projs, geom, variant="auto",
                          tuning=str(tmp_path / "t.json"), **OPTS)
    assert np.array_equal(np.asarray(ref), np.asarray(via))


def test_service_reports_tuned_vs_heuristic(setup, tmp_path, monkeypatch):
    """warmup(tune=True) buckets report their choice source; plain
    requests stay heuristic; a second tuned warmup over the persisted
    cache is a pure hit (poisoned measurer)."""
    geom, projs = setup
    path = str(tmp_path / "t.json")
    with ReconService(max_inflight=1, cache=_PCACHE, tuning=path) as svc:
        stats = svc.warmup([geom], tune=True, tune_budget_s=30.0,
                           variant="subline_batch_mp", iters=1, **OPTS)
        assert stats.buckets[0].source == "tuned-measured"
        v = svc.reconstruct(projs, geom, variant="subline_batch_mp", **OPTS)
        stats = svc.stats()
        assert stats.bucket_hits == 1         # request joined the bucket
        assert stats.buckets[0].completed == 1

    def boom(*a, **k):
        raise AssertionError("persisted winner must not re-measure")

    monkeypatch.setattr(at, "_measure_config", boom)
    with ReconService(max_inflight=1, cache=_PCACHE, tuning=path) as svc:
        stats = svc.warmup([geom], tune=True,
                           variant="subline_batch_mp", **OPTS)
        b = stats.buckets[0]
        assert b.source == "tuned-cache"
        v2 = svc.reconstruct(projs, geom, variant="subline_batch_mp", **OPTS)
    assert np.array_equal(np.asarray(v), np.asarray(v2))


def test_second_process_cache_hit(setup, tmp_path):
    """The acceptance scenario, with REAL process isolation: process 1
    tunes on a fresh cache; process 2 resolves the persisted winner
    with zero measurements and picks the identical config."""
    path = str(tmp_path / "t.json")
    script = r"""
import sys, json
sys.path.insert(0, "src")
import numpy as np
import jax.numpy as jnp
from repro.core import standard_geometry
from repro.runtime import autotune as at
from repro.runtime.service import ReconService

calls = []
orig = at._measure_config
def spy(*a, **k):
    calls.append(1)
    return orig(*a, **k)
at._measure_config = spy

geom = standard_geometry(n=16, n_det=24, n_proj=6)
svc = ReconService(max_inflight=1, tuning=PATH)
stats = svc.warmup([geom], tune=True, tune_budget_s=20.0, iters=1,
                   variant="subline_batch_mp", nb=2, tiling=(8, 8, 16),
                   proj_batch=4)
b = stats.buckets[0]
key = list(svc._buckets.values())[0].config.key
print("RESULT:" + json.dumps({"measured": len(calls), "source": b.source,
                              "key": repr(key)}))
svc.close()
""".replace("PATH", repr(path))

    def run_once():
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        proc = subprocess.run([sys.executable, "-c", script],
                              capture_output=True, text=True, timeout=600,
                              cwd=os.path.dirname(os.path.dirname(
                                  os.path.abspath(__file__))), env=env)
        assert proc.returncode == 0, proc.stderr[-3000:]
        line = [ln for ln in proc.stdout.splitlines()
                if ln.startswith("RESULT:")][-1]
        return json.loads(line[len("RESULT:"):])

    first = run_once()
    assert first["measured"] > 0 and first["source"] == "tuned-measured"
    second = run_once()
    assert second["measured"] == 0            # zero re-measurement
    assert second["source"] == "tuned-cache"  # cache hit asserted
    assert second["key"] == first["key"]      # the same config


def test_default_requests_land_in_tuned_bucket(setup, tmp_path):
    """warmup(tune=True) flips the service into tuned resolution: a
    later request with DEFAULT options (no variant named) resolves
    through the same cache and hits the tuned bucket — zero new
    buckets, zero new compiles."""
    geom, projs = setup
    with ReconService(max_inflight=1, cache=_PCACHE) as svc:
        svc.warmup([geom], tune=True, tune_budget_s=30.0,
                   tuning=TuningCache(str(tmp_path / "t.json")),
                   exact=True, iters=1, **OPTS)
        misses = svc.stats().cache["misses"]
        svc.reconstruct(projs, geom, **OPTS)      # no variant named
        stats = svc.stats()
    assert stats.bucket_misses == 1 and stats.bucket_hits == 1
    assert stats.cache["misses"] == misses
    assert stats.buckets[0].source == "tuned-measured"


def test_auto_accepts_cross_variant_options(setup, tmp_path):
    """variant="auto" requests may carry options only SOME variants
    accept (e.g. proj_loop for the Pallas candidates): the base plan
    must not reject them, a registry-wide bogus option still fails
    fast, and option-differing auto requests get distinct cache keys."""
    geom, projs = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    cfg = resolve_config(geom, "auto", cache=cache, proj_loop=False, **OPTS)
    assert cfg.source == "heuristic"          # no crash, no entry yet
    v = fdk_reconstruct(projs, geom, variant="auto",
                        tuning=str(tmp_path / "t.json"), proj_loop=False,
                        **OPTS)
    assert np.asarray(v).shape == (16, 16, 16)
    with pytest.raises(ValueError, match="no registered variant"):
        resolve_config(geom, "auto", cache=cache, bogus_knob=1, **OPTS)
    # distinct keys: a winner tuned WITH the option is invisible to a
    # request without it (and vice versa)
    _tune(geom, projs, "auto", cache, exact=True, proj_loop=False)
    assert resolve_config(geom, "auto", cache=cache, proj_loop=False,
                          **OPTS).source == "cache"
    assert resolve_config(geom, "auto", cache=cache,
                          **OPTS).source == "heuristic"


def test_explicit_schedule_is_pinned(setup, tmp_path):
    """A caller-named schedule is a contract (chunk-major = bounded
    device residency): the tuner must not flip it."""
    geom, projs = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    cfg = _tune(geom, projs, "subline_batch_mp", cache, schedule="chunk")
    assert cfg.schedule == "chunk"
    assert cfg.trials > 1                     # pipeline axis still ran


def test_tuned_warmup_upgrades_existing_bucket(setup, tmp_path):
    """A heuristic bucket created by early traffic is UPGRADED in
    place when warmup(tune=True) resolves a winner with the same
    bucket_key (pipeline/depth are not part of the key) — the tuned
    choice must not be silently dropped."""
    geom, projs = setup
    path = str(tmp_path / "t.json")
    with ReconService(max_inflight=1, cache=_PCACHE) as svc:
        svc.reconstruct(projs, geom, variant="subline_batch_mp", **OPTS)
        assert svc.stats().buckets[0].source == "heuristic"
        svc.warmup([geom], tune=True, tuning=TuningCache(path), iters=1,
                   tune_budget_s=30.0, variant="subline_batch_mp", **OPTS)
        stats = svc.stats()
        b = stats.buckets[0]
        if stats.bucket_misses == 1:          # same bucket_key: upgraded
            assert b.source == "tuned-measured"
            cfg = list(svc._buckets.values())[0].config
            assert b.pipeline == cfg.pipeline
        else:                                 # winner re-planned: own bucket
            assert {x.source for x in stats.buckets} == \
                {"heuristic", "tuned-measured"}
        v = svc.reconstruct(projs, geom, variant="subline_batch_mp", **OPTS)
    ref = fdk_reconstruct(projs, geom, variant="subline_batch_mp", **OPTS)
    assert np.array_equal(np.asarray(v), np.asarray(ref))


# ---- TunedConfig mechanics -------------------------------------------------

def test_config_speedup_and_replace(setup):
    geom, _ = setup
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=2)
    cfg = at.config_from_plan(plan)
    cfg = dataclasses.replace(cfg, wall_us=50.0, baseline_us=100.0)
    assert cfg.speedup == pytest.approx(2.0)
    assert at.config_from_plan(plan).speedup == 1.0   # unmeasured


# ---- self-maintaining cache: stale-entry revalidation ----------------------

def _entry_key(cache):
    fp = list(cache.entries())[0]
    return fp, list(cache.entries()[fp])[0]


def test_stale_drifted_entry_invalidates_and_retunes(setup, tmp_path):
    """A stale entry whose recorded baseline is wildly off for this
    machine (planted: 1000x) must be invalidated on resolve and the
    full search re-run — the self-maintenance contract."""
    geom, projs = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    cfg = _tune(geom, projs, "algorithm1_mp", cache)
    assert cfg.source == "measured" and cfg.tuned_at > 0
    fp, rkey = _entry_key(cache)
    bad = dataclasses.replace(cfg, baseline_us=cfg.baseline_us / 1000.0,
                              tuned_at=time.time() - 7 * 86400)
    cache.store(fp, rkey, bad)
    redo = _tune(geom, projs, "algorithm1_mp", cache)
    assert redo.source == "measured" and redo.trials > 0
    assert cache.lookup(fp, rkey).tuned_at > time.time() - 600


def test_stale_consistent_entry_restamps_without_retune(setup, tmp_path):
    """A stale entry whose baseline still matches reality keeps its
    winner: one cheap probe, a freshness restamp, zero search trials.
    The RECORDED baseline is kept (restamping it too would let slow
    drift creep under the threshold)."""
    geom, projs = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    cfg = _tune(geom, projs, "algorithm1_mp", cache)
    fp, rkey = _entry_key(cache)
    old = dataclasses.replace(cfg, tuned_at=time.time() - 7 * 86400)
    cache.store(fp, rkey, old)
    hit = _tune(geom, projs, "algorithm1_mp", cache)
    assert hit.source == "cache" and hit.trials == 0
    restamped = cache.lookup(fp, rkey)
    assert restamped.tuned_at > time.time() - 600
    assert restamped.baseline_us == old.baseline_us


def test_fresh_entry_still_resolves_without_measuring(setup, tmp_path,
                                                      monkeypatch):
    """The revalidation probe must not tax the fast path: a FRESH hit
    (younger than revalidate_s) never enters _measure_config."""
    geom, projs = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    _tune(geom, projs, "algorithm1_mp", cache)

    def boom(*a, **k):
        raise AssertionError("fresh cache hit must not measure")

    monkeypatch.setattr(at, "_measure_config", boom)
    hit = _tune(geom, projs, "algorithm1_mp", cache)
    assert hit.source == "cache" and hit.trials == 0


def test_invalidate_and_legacy_staleness(setup, tmp_path):
    geom, projs = setup
    cache = TuningCache(str(tmp_path / "t.json"))
    cfg = _tune(geom, projs, "algorithm1_mp", cache)
    fp, rkey = _entry_key(cache)
    # documents written before the tuned_at field existed deserialize
    # as always-stale (first resolve revalidates them)
    doc = cfg.to_json()
    del doc["tuned_at"]
    assert TunedConfig.from_json(doc).tuned_at == 0.0
    assert cache.invalidate(fp, "missing-key") is False
    assert cache.invalidate(fp, rkey) is True
    assert cache.lookup(fp, rkey) is None
