"""Pallas kernels vs the pure-jnp oracle: shape sweeps + unit stages."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import projection_matrices, standard_geometry, \
    transpose_projections
from repro.kernels import backproject_onehot, backproject_ref, \
    backproject_subline
from repro.kernels.ref import subline_blend_ref

from conftest import rel_rmse

BAR = 1e-5


def _case(n, det, nproj, seed=0):
    geom = standard_geometry(n=n, n_det=det, n_proj=nproj)
    rng = np.random.RandomState(seed)
    img = jnp.asarray(rng.rand(nproj, geom.nh, geom.nw).astype(np.float32))
    img_t = transpose_projections(img)
    mats = projection_matrices(geom)
    ref = backproject_ref(img_t, mats, geom.volume_shape_xyz)
    return geom, img_t, mats, ref


# shape sweep: even/odd volumes, non-square detectors, varied np.
# Interpret-mode Pallas runs the kernel body in Python, so each case
# costs ~5-7 s: the redundant even case and the extra edge cases are
# `slow` (opt in with -m slow); the default tier-1 run keeps one even
# and the odd-everything case, which cover the padding + odd-nz paths.
SWEEP = [
    (16, 24, 6),
    pytest.param(16, 16, 4, marks=pytest.mark.slow),
    (13, 17, 5),     # odd everything (padding + odd-nz symmetry path)
    pytest.param(8, 32, 3, marks=pytest.mark.slow),
    pytest.param(20, 12, 7,          # detector smaller (heavy masking)
                 marks=pytest.mark.slow),
]


@pytest.mark.parametrize("n,det,nproj", SWEEP)
def test_subline_kernel_sweep(n, det, nproj):
    geom, img_t, mats, ref = _case(n, det, nproj)
    out = backproject_subline(img_t, mats, geom.volume_shape_xyz,
                              block=(4, 8))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=BAR * max(1e-9, float(np.abs(ref).max())),
                               rtol=0)
    assert rel_rmse(out, ref) < BAR


@pytest.mark.parametrize("n,det,nproj", SWEEP[:3])
def test_onehot_kernel_sweep(n, det, nproj):
    geom, img_t, mats, ref = _case(n, det, nproj)
    out = backproject_onehot(img_t, mats, geom.volume_shape_xyz,
                             block=(4, 8), k_chunk=8)
    assert rel_rmse(out, ref) < BAR


@pytest.mark.parametrize("block", [
    (1, 8), (2, 8),
    pytest.param((4, 16), marks=pytest.mark.slow),   # ~9 s each in
    pytest.param((8, 8), marks=pytest.mark.slow),    # interpret mode
])
def test_subline_kernel_block_shapes(block):
    geom, img_t, mats, ref = _case(16, 24, 4)
    out = backproject_subline(img_t, mats, geom.volume_shape_xyz,
                              block=block)
    assert rel_rmse(out, ref) < BAR


def test_kernels_agree_with_each_other():
    geom, img_t, mats, _ = _case(16, 24, 6, seed=7)
    a = backproject_subline(img_t, mats, geom.volume_shape_xyz)
    b = backproject_onehot(img_t, mats, geom.volume_shape_xyz, k_chunk=4)
    assert rel_rmse(a, b) < 1e-6


def test_subline_blend_stage():
    """Fig. 3a stage in isolation: blend of two detector columns."""
    rng = np.random.RandomState(1)
    img_ts = jnp.asarray(rng.rand(12, 9).astype(np.float32))
    x = jnp.asarray([0.25, 3.75, 10.999, 0.0, 11.0])
    out = subline_blend_ref(img_ts, x)
    # manual check for x = 3.75
    expected = 0.25 * np.asarray(img_ts)[3] + 0.75 * np.asarray(img_ts)[4]
    np.testing.assert_allclose(np.asarray(out)[1], expected, rtol=1e-6)


def test_kernel_against_ct_pipeline():
    """Kernel output matches the pure-JAX variant inside FDK."""
    from repro.core import fdk_reconstruct
    from repro.core.forward import forward_project
    from repro.core.phantom import shepp_logan_3d

    geom = standard_geometry(n=16, n_det=24, n_proj=12)
    vol = jnp.asarray(shepp_logan_3d(16))
    projs = forward_project(vol, geom, oversample=1.0)
    rec_jax = fdk_reconstruct(projs, geom, variant="algorithm1_mp", nb=4)
    rec_pl = fdk_reconstruct(projs, geom, variant="subline_pl")
    assert rel_rmse(rec_pl, rec_jax) < BAR


@pytest.mark.parametrize("n,det,nproj,bw", [
    (16, 24, 6, 8),
    pytest.param(16, 48, 4, 16, marks=pytest.mark.slow),
    (13, 17, 5, 8),
])
def test_banded_kernel_sweep(n, det, nproj, bw):
    """Beyond-paper banded scalar-prefetch kernel vs the oracle."""
    # import via ops: the submodule of the same name shadows the package
    # re-export once any test touches repro.kernels.backproject_banded
    from repro.kernels.ops import backproject_banded
    geom, img_t, mats, ref = _case(n, det, nproj, seed=11)
    out = backproject_banded(img_t, mats, geom.volume_shape_xyz,
                             block=(4, 8), bw=bw)
    assert rel_rmse(out, ref) < BAR


def test_banded_band_selection_covers_all_tiles():
    """Corner-derived bands must cover every tile's x-extent (linear-
    fractional extrema at corners)."""
    import numpy as np
    from repro.core import projection_matrices, standard_geometry
    from repro.kernels.backproject_banded import tile_bands
    geom = standard_geometry(n=32, n_det=48, n_proj=8)
    mats = np.asarray(projection_matrices(geom))
    bw = 16
    n_bands = -(-geom.nw // bw)
    band, span = tile_bands(mats, 32, 32, 4, 8, bw, n_bands, geom.nw)
    assert band.shape == (8, 8, 4)
    assert band.min() >= 0 and band.max() < n_bands
    # exhaustive check: every voxel's x falls inside its tile's band
    for s in range(8):
        m = mats[s].astype(np.float64)
        i = np.arange(32)[:, None]
        j = np.arange(32)[None, :]
        z = m[2, 0] * i + m[2, 1] * j + m[2, 3]
        x = (m[0, 0] * i + m[0, 1] * j + m[0, 3]) / z
        for ti in range(8):
            for tj in range(4):
                xt = x[ti * 4:(ti + 1) * 4, tj * 8:(tj + 1) * 8]
                xt = np.clip(xt, 0, geom.nw - 1)
                lo = band[s, ti, tj] * bw
                assert xt.min() >= lo - 1e-6
                assert xt.max() <= lo + 2 * bw - 1 + 1e-6
