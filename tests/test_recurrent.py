"""RWKV-6 and RG-LRU recurrence correctness."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.models.layers import KeyGen
from repro.models import rglru, rwkv


def _naive_wkv(r, k, v, w, u):
    B, T, H, hd = r.shape
    S = np.zeros((B, H, hd, hd), np.float64)
    ys = np.zeros((B, T, H, hd), np.float64)
    for t in range(T):
        for b in range(B):
            for h in range(H):
                kv = np.outer(k[b, t, h], v[b, t, h])
                ys[b, t, h] = r[b, t, h] @ (S[b, h] + u[h][:, None] * kv)
                S[b, h] = w[b, t, h][:, None] * S[b, h] + kv
    return ys, S


def test_wkv6_scan_matches_naive_loop():
    rng = np.random.RandomState(0)
    B, T, H, hd = 2, 12, 2, 4
    r = rng.randn(B, T, H, hd).astype(np.float32)
    k = rng.randn(B, T, H, hd).astype(np.float32)
    v = rng.randn(B, T, H, hd).astype(np.float32)
    w = rng.rand(B, T, H, hd).astype(np.float32) * 0.5 + 0.4
    u = rng.randn(H, hd).astype(np.float32)
    ys, S = rwkv.wkv6_scan(jnp.asarray(r), jnp.asarray(k), jnp.asarray(v),
                           jnp.asarray(w), jnp.asarray(u))
    ys_n, S_n = _naive_wkv(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(ys), ys_n, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S), S_n, atol=1e-4)


@pytest.mark.parametrize("chunk", [1, 3, 4, 12, 128])
def test_wkv6_chunking_invariance(chunk):
    """Chunk-remat must be a pure performance change."""
    rng = np.random.RandomState(1)
    B, T, H, hd = 1, 12, 2, 4
    args = [jnp.asarray(rng.randn(B, T, H, hd).astype(np.float32))
            for _ in range(3)]
    w = jnp.asarray(rng.rand(B, T, H, hd).astype(np.float32) * 0.5 + 0.4)
    u = jnp.asarray(rng.randn(H, hd).astype(np.float32))
    y1, S1 = rwkv.wkv6_scan(args[0], args[1], args[2], w, u, chunk=chunk)
    y2, S2 = rwkv.wkv6_scan(args[0], args[1], args[2], w, u, chunk=T)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S2), atol=1e-5)


def test_wkv6_gradients_finite_through_chunks():
    rng = np.random.RandomState(2)
    B, T, H, hd = 1, 8, 1, 4
    r = jnp.asarray(rng.randn(B, T, H, hd).astype(np.float32))
    k = jnp.asarray(rng.randn(B, T, H, hd).astype(np.float32))
    v = jnp.asarray(rng.randn(B, T, H, hd).astype(np.float32))
    w = jnp.asarray(rng.rand(B, T, H, hd).astype(np.float32) * 0.5 + 0.4)
    u = jnp.asarray(rng.randn(H, hd).astype(np.float32))

    def f(k):
        y, _ = rwkv.wkv6_scan(r, k, v, w, u, chunk=4)
        return jnp.sum(y ** 2)

    g = jax.grad(f)(k)
    assert bool(jnp.isfinite(g).all())
    assert float(jnp.abs(g).max()) > 0


def _rg_cfg():
    return ModelConfig(
        name="t", family="hybrid", n_layers=3, d_model=16, n_heads=2,
        n_kv_heads=1, d_ff=32, vocab_size=64, dtype="float32",
        block_pattern=("rec", "rec", "attn"), window=8, lru_width=16,
        conv_width=4)


def test_rglru_associative_scan_matches_sequential():
    cfg = _rg_cfg()
    p = rglru.init_rglru(KeyGen(0), cfg)
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(2, 10, cfg.lru_width).astype(np.float32))
    y_scan, h_last = rglru.rglru_scan(p, x, cfg)
    # sequential single steps
    h = jnp.zeros((2, cfg.lru_width), jnp.float32)
    outs = []
    for t in range(10):
        o, h = rglru.rglru_step(p, x[:, t], h, cfg)
        outs.append(np.asarray(o))
    seq = np.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), seq, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               atol=1e-5)


def test_rglru_state_carry_equals_concatenation():
    """scan(x1 ++ x2) == scan(x2 given state from scan(x1))."""
    cfg = _rg_cfg()
    p = rglru.init_rglru(KeyGen(1), cfg)
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(1, 12, cfg.lru_width).astype(np.float32))
    y_full, _ = rglru.rglru_scan(p, x, cfg)
    y1, h1 = rglru.rglru_scan(p, x[:, :5], cfg)
    y2, _ = rglru.rglru_scan(p, x[:, 5:], cfg, h0=h1)
    np.testing.assert_allclose(np.asarray(y_full[:, 5:]),
                               np.asarray(y2), atol=1e-5)


def test_rglru_decay_in_unit_interval():
    cfg = _rg_cfg()
    p = rglru.init_rglru(KeyGen(2), cfg)
    rng = np.random.RandomState(5)
    x = jnp.asarray(rng.randn(1, 4, cfg.lru_width).astype(np.float32))
    a, beta, i = rglru._gates(p, x, cfg.n_heads)
    assert float(a.min()) > 0.0 and float(a.max()) < 1.0
    # input multiplier satisfies a^2 + beta^2 = 1
    np.testing.assert_allclose(np.asarray(a) ** 2 + np.asarray(beta) ** 2,
                               1.0, atol=1e-5)


def test_causal_conv_matches_numpy():
    cfg = _rg_cfg()
    p = rglru.init_rglru(KeyGen(3), cfg)
    rng = np.random.RandomState(6)
    x = jnp.asarray(rng.randn(1, 7, cfg.lru_width).astype(np.float32))
    y, tail = rglru.causal_conv(p, x)
    w = np.asarray(p["conv_w"])  # (cw, W)
    xp = np.concatenate([np.zeros((1, 3, cfg.lru_width), np.float32),
                         np.asarray(x)], axis=1)
    expect = sum(xp[:, k:k + 7] * w[k] for k in range(4)) + \
        np.asarray(p["conv_b"])
    np.testing.assert_allclose(np.asarray(y), expect, atol=1e-5)
    np.testing.assert_allclose(np.asarray(tail), xp[:, -3:], atol=1e-6)
