"""MoE routing invariants and dispatch correctness."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import MoESettings, ModelConfig
from repro.models.layers import KeyGen
from repro.models.moe import _routing, init_moe, moe_mlp


def _cfg(E=4, k=2, cf=8.0, group=64):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=48, vocab_size=64, dtype="float32",
        moe=MoESettings(num_experts=E, top_k=k, d_ff_expert=48,
                        capacity_factor=cf, group_size=group))


def test_routing_weights_normalized_and_capacity_respected():
    rng = np.random.RandomState(0)
    T, E, k, C = 32, 4, 2, 8
    logits = jnp.asarray(rng.randn(T, E).astype(np.float32))
    combine, dispatch, aux = _routing(logits, k, C)
    assert combine.shape == (T, E, C)
    # each (expert, slot) used by at most one token
    per_slot = np.asarray(dispatch).sum(axis=0)
    assert per_slot.max() <= 1
    # per-token combined weight <= 1 (== 1 when nothing dropped)
    w = np.asarray(combine).sum(axis=(1, 2))
    assert np.all(w <= 1.0 + 1e-5)
    assert float(aux) > 0


def test_no_drops_with_generous_capacity():
    rng = np.random.RandomState(1)
    T, E, k = 16, 4, 2
    logits = jnp.asarray(rng.randn(T, E).astype(np.float32))
    combine, dispatch, _ = _routing(logits, k, capacity=T)
    w = np.asarray(combine).sum(axis=(1, 2))
    np.testing.assert_allclose(w, 1.0, atol=1e-5)


def test_moe_equals_dense_expert_sum_when_no_drops():
    """With capacity >= tokens, the dispatched computation must equal the
    explicit per-token weighted sum over top-k experts."""
    cfg = _cfg()
    m = cfg.moe
    p = init_moe(KeyGen(0), cfg)
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(2, 8, cfg.d_model).astype(np.float32))
    out, _ = moe_mlp(p, x, cfg)

    xt = np.asarray(x).reshape(-1, cfg.d_model)
    logits = xt @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    top_i = np.argsort(-probs, axis=-1)[:, :m.top_k]
    expected = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        ws = probs[t, top_i[t]]
        ws = ws / ws.sum()
        for w, e in zip(ws, top_i[t]):
            g = xt[t] @ np.asarray(p["wi_gate"][e])
            u = xt[t] @ np.asarray(p["wi_up"][e])
            h = (g / (1 + np.exp(-g))) * u
            expected[t] += w * (h @ np.asarray(p["wo"][e]))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model),
                               expected, atol=2e-4)


def test_grouping_invariance():
    """Group size must not change results when capacity is generous."""
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(2, 32, 32).astype(np.float32))
    outs = []
    for group in (16, 32, 64):
        cfg = _cfg(group=group)
        p = init_moe(KeyGen(0), cfg)
        out, _ = moe_mlp(p, x, cfg)
        outs.append(np.asarray(out))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)


def test_shared_experts_always_active():
    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=48, vocab_size=64, dtype="float32",
        moe=MoESettings(num_experts=4, top_k=2, d_ff_expert=48,
                        num_shared=2, capacity_factor=8.0))
    p = init_moe(KeyGen(0), cfg)
    # zero the ROUTED experts: output must still be nonzero via shared
    p = dict(p)
    p["wo"] = jnp.zeros_like(p["wo"])
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(1, 8, 32).astype(np.float32))
    out, _ = moe_mlp(p, x, cfg)
    assert float(jnp.abs(out).max()) > 0


def test_aux_loss_prefers_balance():
    """Uniform routing must give a lower aux loss than collapsed routing."""
    T, E, k, C = 64, 4, 1, 64
    uniform = jnp.zeros((T, E))
    collapsed = jnp.zeros((T, E)).at[:, 0].set(10.0)
    _, _, aux_u = _routing(uniform, k, C)
    _, _, aux_c = _routing(collapsed, k, C)
    assert float(aux_u) < float(aux_c)
