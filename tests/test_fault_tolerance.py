"""Regression tests for the revived failover modules.

The reconstruction fleet (tests/test_fleet.py) leans on three dormant
runtime modules whose latent bugs only bite once something actually
exercises them; these tests pin the fixes:

  * ``FaultTolerantLoop.run`` — failures are counted PER STEP INDEX.
    The old consecutive-attempt counter (``retries_here``) reset every
    time a checkpoint restore rewound the loop and the replayed steps
    succeeded, so a deterministic poison step AFTER a checkpoint
    recovered forever and skip-ahead never fired.
  * ``StragglerMonitor.record`` — the outlier scale is floored at
    ``floor_frac`` of the median. The old ``mad or 1e-9`` floor turned
    a near-constant window (MAD == 0) into a nanosecond scale, flagging
    microsecond jitter as a straggler.
  * ``Heartbeat.stale`` — gated on the first completed step, so a
    supervisor never shoots a host still inside its first jit compile.

Plus the fleet-facing contracts the tentpole added on top:
``FleetStragglerBoard`` (cross-device flagging) and ``remesh_plan``
validation / degraded-mode shapes.
"""

import time

import pytest

from repro.runtime import (FaultTolerantLoop, FleetStragglerBoard,
                           Heartbeat, StragglerMonitor, remesh_plan)


class FakePipeline:
    """batch_at(step) == step: pure, seekable, trivially re-entrant."""

    def batch_at(self, step):
        return step

    def seek(self, step):
        pass


class MemCheckpointer:
    """In-memory checkpoint store with the Checkpointer API surface."""

    def __init__(self):
        self.saved = {}

    def save(self, step, state, blocking=False):
        self.saved[step] = state

    def restore_latest(self, like):
        if not self.saved:
            return None, None
        step = max(self.saved)
        return step, self.saved[step]


# --------------------------------------------------------------------------
# FaultTolerantLoop: per-step-index failure accounting
# --------------------------------------------------------------------------

def test_poison_step_skipped_without_checkpoint():
    """A deterministic poison step exhausts its per-index budget and is
    skipped; every other step completes exactly once."""
    loop = FaultTolerantLoop(checkpointer=MemCheckpointer(),
                             pipeline=FakePipeline(), save_every=100,
                             max_retries_per_step=2)
    completed = []

    def step_fn(state, batch):
        if batch == 3:
            raise RuntimeError("poison")
        completed.append(batch)
        return state + 1, {"loss": 0.0}

    end, final = loop.run(0, step_fn, start_step=0, num_steps=6)
    assert end == 6
    assert loop.failures == 3            # max_retries + 1, then skip
    assert 3 not in completed
    assert completed == [0, 1, 2, 4, 5]


def test_poison_step_after_checkpoint_terminates():
    """THE regression: a checkpoint lands before the poison step, so
    every failure rewinds to the checkpoint and the replayed steps
    succeed. The old consecutive-attempt counter reset on each replay
    and the loop recovered forever; the per-index count survives the
    rewind, fires skip-ahead, and the run terminates."""
    ck = MemCheckpointer()
    loop = FaultTolerantLoop(checkpointer=ck, pipeline=FakePipeline(),
                             save_every=4, max_retries_per_step=2)

    def step_fn(state, batch):
        if batch == 5:                   # deterministic: fails on replay too
            raise RuntimeError("poison after checkpoint")
        return state + 1, {"loss": 0.0}

    end, final = loop.run(0, step_fn, start_step=0, num_steps=8)
    assert end == 8
    assert loop.failures == 3            # budget spent despite the rewinds
    assert loop.recoveries == 3
    assert 4 in ck.saved                 # the checkpoint that caused rewinds


def test_transient_failure_still_recovers():
    """One-shot faults keep the old behavior: restore + replay, no skip."""
    loop = FaultTolerantLoop(checkpointer=MemCheckpointer(),
                             pipeline=FakePipeline(), save_every=2,
                             max_retries_per_step=2)
    armed = {"on": True}

    def step_fn(state, batch):
        if armed["on"] and batch == 3:
            armed["on"] = False
            raise RuntimeError("transient")
        return state + 1, {"loss": 0.0}

    end, final = loop.run(0, step_fn, start_step=0, num_steps=6)
    assert end == 6
    assert loop.failures == 1
    assert final >= 5                    # no step silently skipped


# --------------------------------------------------------------------------
# Heartbeat: warmup gate
# --------------------------------------------------------------------------

def test_heartbeat_not_stale_during_first_compile():
    """Before any step beats, a long silent gap is warmup (first-step
    jit compile), not a hang — the supervisor must not flag it."""
    hb = Heartbeat(timeout_s=0.01)
    time.sleep(0.05)                     # construction-to-first-beat gap
    assert not hb.stale


def test_heartbeat_stale_after_first_beat():
    hb = Heartbeat(timeout_s=0.01)
    hb.beat(0)
    assert not hb.stale
    time.sleep(0.05)
    assert hb.stale


# --------------------------------------------------------------------------
# StragglerMonitor: relative outlier floor
# --------------------------------------------------------------------------

def test_constant_window_ignores_jitter():
    """A near-constant duration window (MAD == 0) must not flag
    microsecond jitter: the old absolute 1e-9 floor made (1e-6 / 1e-9)
    an 'outlier' of a thousand sigma."""
    mon = StragglerMonitor(window=16, threshold=3.0)
    for i in range(10):
        assert not mon.record(i, 1.0)
    assert not mon.record(10, 1.0 + 1e-6)     # jitter, not a straggler
    assert mon.flagged_steps == []


def test_constant_window_still_flags_real_straggler():
    mon = StragglerMonitor(window=16, threshold=3.0)
    for i in range(10):
        mon.record(i, 1.0)
    assert mon.record(10, 2.0)                # 2x median: a real outlier
    assert 10 in mon.flagged_steps


def test_jittery_window_flags_outlier():
    mon = StragglerMonitor(window=16, threshold=3.0)
    for i in range(12):
        mon.record(i, 1.0 + 0.01 * (i % 3))
    assert mon.record(12, 10.0)


# --------------------------------------------------------------------------
# FleetStragglerBoard: cross-device flagging
# --------------------------------------------------------------------------

def test_fleet_board_flags_slow_device():
    board = FleetStragglerBoard(4, ratio=1.5)
    for s in range(4):
        for d in range(3):
            board.record(d, s, 0.1)
    assert board.record(3, 0, 1.0)            # 10x the fleet median
    assert board.flagged == (3,)


def test_fleet_board_unflags_recovered_device():
    board = FleetStragglerBoard(2, window=4, ratio=1.5)
    for s in range(4):
        board.record(0, s, 0.1)
    board.record(1, 0, 1.0)
    assert 1 in board.flagged
    for s in range(1, 5):                     # caught back up
        board.record(1, s, 0.1)
    assert board.flagged == ()


def test_fleet_board_validates_device_count():
    with pytest.raises(ValueError, match="n_devices"):
        FleetStragglerBoard(0)


# --------------------------------------------------------------------------
# remesh_plan: validation + degraded-mode shapes
# --------------------------------------------------------------------------

def test_remesh_plan_shapes():
    assert remesh_plan(8, model_parallel=4) == (2, 4)
    assert remesh_plan(6, model_parallel=4) == (1, 4)
    assert remesh_plan(3, model_parallel=4) == (1, 2)   # degraded
    assert remesh_plan(1, model_parallel=4) == (1, 1)


def test_remesh_plan_rejects_empty_fleet():
    with pytest.raises(ValueError, match="n_devices"):
        remesh_plan(0, model_parallel=4)
    with pytest.raises(ValueError, match="model_parallel"):
        remesh_plan(4, model_parallel=0)
