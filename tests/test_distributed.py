"""Multi-device numerical tests (subprocess: 8 host devices).

The dry-run proves the distributed programs COMPILE; these prove the
shard_map back-projection and elastic resharding produce the right
NUMBERS. They run in a subprocess because the device count must be fixed
before jax initializes (the main test process keeps the default single
device, per the harness contract).
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh

out = {}

# ---- distributed back-projection == single-device -----------------------
from repro.core import (standard_geometry, projection_matrices,
                        transpose_projections)
from repro.core.backproject import bp_subline_symmetry_scan
from repro.core.distributed import distributed_backproject

geom = standard_geometry(n=16, n_det=24, n_proj=8)
rng = np.random.RandomState(0)
img = jnp.asarray(rng.rand(geom.n_proj, geom.nh, geom.nw).astype(np.float32))
img_t = transpose_projections(img)
mats = projection_matrices(geom)

ref = bp_subline_symmetry_scan(img_t, mats, geom.volume_shape_xyz)

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
# nb=6 does NOT divide n_proj=8: regression for the tail-batch padding
# (used to be `assert n_proj % nb == 0`); 6 still divides over pod=2.
vol = distributed_backproject(img_t, mats, geom, mesh, nb=6)
err = float(jnp.abs(vol - ref).max()) / float(jnp.abs(ref).max())
out["bp_rel_err"] = err

# ---- tiled engine x mesh composition (5x7 tiles do not divide 16) --------
from repro.runtime.engine import TiledReconstructor

eng = TiledReconstructor(geom, tile_shape=(5, 7, geom.nz), nb=4)
vol_t = eng.backproject_distributed(img_t, mats, mesh, nb=4)
out["tiled_dist_rel_err"] = float(
    jnp.abs(jnp.asarray(vol_t) - ref).max()) / float(jnp.abs(ref).max())

# ---- async flush over the distributed tile walk (PR-4 follow-up) ---------
# tiles write disjoint regions of the zeroed volume, so the flusher
# thread's accumulate must equal the sequential assignment bit-for-bit
vol_async = eng.backproject_distributed(img_t, mats, mesh, nb=4,
                                        pipeline="async")
out["tiled_dist_async_equal"] = bool(
    np.array_equal(np.asarray(vol_t), np.asarray(vol_async)))

# ---- elastic resharding roundtrip ----------------------------------------
from repro.launch import sharding as shd
from repro.runtime import reshard_tree

tree = {"layers": {"mlp": {"wi_gate": jnp.arange(4 * 8 * 16,
                                                 dtype=jnp.float32
                                                 ).reshape(4, 8, 16)}}}
mesh_a = make_mesh((4, 2), ("data", "model"))
mesh_b = make_mesh((2, 4), ("data", "model"))

def spec_fn_for(mesh):
    return lambda path, leaf: shd.spec_for_param(path, leaf.shape, mesh)

t_a = reshard_tree(tree, mesh_a, spec_fn_for(mesh_a))
t_b = reshard_tree(t_a, mesh_b, spec_fn_for(mesh_b))
same = bool(jnp.array_equal(t_b["layers"]["mlp"]["wi_gate"],
                            tree["layers"]["mlp"]["wi_gate"]))
out["reshard_roundtrip_equal"] = same
out["reshard_b_sharded"] = str(
    t_b["layers"]["mlp"]["wi_gate"].sharding.spec)

# ---- sharded train step == single-device step ----------------------------
from repro.configs import RunConfig, ShapeConfig, get_smoke_config
from repro.launch.train import (TrainState, init_state, make_train_step,
                                shard_train_step)
from repro.models import build_model

cfg = get_smoke_config("qwen2.5-3b")
model = build_model(cfg)
state = init_state(model, RunConfig(seed=0))
batch = model.dummy_batch(ShapeConfig("t", "train", 16, 4))
step = make_train_step(model, RunConfig(), total_steps=100)
(_, m_single) = jax.jit(step)(state, batch)

mesh2 = make_mesh((4, 2), ("data", "model"))
aparams = jax.eval_shape(lambda: model.init(0))
jit_step, state_sh = shard_train_step(step, model, mesh2, aparams, batch)
(_, m_sharded) = jit_step(state, batch)
out["train_loss_single"] = float(m_single["loss"])
out["train_loss_sharded"] = float(m_sharded["loss"])

print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def multidevice_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT],
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))), env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):])


def test_distributed_bp_matches_single_device(multidevice_results):
    assert multidevice_results["bp_rel_err"] < 1e-5


def test_tiled_engine_composes_with_mesh(multidevice_results):
    """(i, j)-tiles reconstructed THROUGH the pod/data/model shard_map
    program (make_distributed_bp(vol_shape_xyz=, origin=)) must match the
    single-device reference — including the per-tile unpad slice."""
    assert multidevice_results["tiled_dist_rel_err"] < 1e-5


def test_distributed_async_flush_bit_identical(multidevice_results):
    """execute_distributed(pipeline="async") streams tile flushes
    through the _AsyncFlushQueue thread; disjoint tile writes into the
    zeroed volume keep it bit-identical to the sequential walk."""
    assert multidevice_results["tiled_dist_async_equal"]


def test_elastic_reshard_roundtrip(multidevice_results):
    assert multidevice_results["reshard_roundtrip_equal"]
    assert "model" in multidevice_results["reshard_b_sharded"]


def test_sharded_train_step_matches_single(multidevice_results):
    a = multidevice_results["train_loss_single"]
    b = multidevice_results["train_loss_sharded"]
    assert abs(a - b) / abs(a) < 1e-4, (a, b)
