"""Step-major streamed execution (PR 3).

Covers the schedule-inversion seams:
  * StepMajorSchedule structure — every step carries the FULL chunk
    work list, the scan grid covers the padded projection count, tail
    chunks keep their true extent;
  * scan-vs-loop parity — ``schedule="step"`` (scan-carried
    device-resident accumulators) matches the PR-2 chunk-major loop for
    ALL registered variants, including non-divisible tail chunks and
    both accumulator placements;
  * ProgramCache under the chunk-loop key — interior tiles of equal
    shape compile exactly once per (variant, call_shape, chunk grid);
  * the filtered-chunk producer — filtering runs once per chunk no
    matter how many steps consume it, in both schedules;
  * proj_loop — planner resolution per variant and fused-kernel parity
    for the three Pallas kernels.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (fdk_reconstruct, projection_matrices,
                        standard_geometry, transpose_projections)
from repro.core import backproject as bp
from repro.core.variants import REGISTRY, VARIANTS, get_spec
from repro.runtime.executor import PlanExecutor, ProgramCache
from repro.runtime.planner import (build_step_major, plan_reconstruction)

from conftest import rel_rmse

BAR = 1e-5


@pytest.fixture(scope="module")
def setup():
    geom = standard_geometry(n=16, n_det=24, n_proj=6)
    rng = np.random.RandomState(7)
    projs = jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                                 geom.nw).astype(np.float32))
    img_t = transpose_projections(projs)  # raw reuse for backproject paths
    mats = projection_matrices(geom)
    return geom, projs, img_t, mats


# ---- schedule structure ---------------------------------------------------

def test_step_major_schedule_structure(setup):
    geom, *_ = setup
    # 6 projections, nb=2 -> padded 6; proj_batch=4 -> chunks (0,4),(4,6)
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=4,
                               tile_shape=(8, 8, 16))
    sched = plan.step_major
    assert sched.n_chunks == len(plan.chunks) == 2
    assert sched.chunk_size == plan.chunk_size == 4
    assert sched.n_scan == 8 >= plan.n_proj_padded
    assert len(sched.steps) == len(plan.steps)
    for work, step in zip(sched.steps, plan.steps):
        assert work.step is step
        # every step scans the FULL chunk list (filter-once invariant)
        assert [(c.index, c.s0, c.s1) for c in work.chunks] == \
            [(0, 0, 4), (1, 4, 6)]
    tail = sched.steps[0].chunks[-1]
    assert tail.size == 2  # true extent, not the scan slot


def test_build_step_major_uniform_chunks():
    sched = build_step_major((), [(0, 4), (4, 8), (8, 12)], 4)
    assert (sched.n_chunks, sched.chunk_size, sched.n_scan) == (3, 4, 12)
    assert sched.steps == ()


def test_planner_schedule_validation(setup):
    geom, *_ = setup
    with pytest.raises(ValueError, match="schedule"):
        plan_reconstruction(geom, "algorithm1_mp", schedule="sideways")
    assert plan_reconstruction(geom, "algorithm1_mp").schedule == "step"
    assert plan_reconstruction(geom, "algorithm1_mp",
                               schedule="chunk").schedule == "chunk"


def test_memory_budget_resolves_to_chunk_major(setup):
    """An explicit memory_budget is a device-byte contract the per-call
    working-set model only describes under chunk-major execution (the
    step-major scan stacks the whole filtered set on device) — so the
    planner resolves schedule=None to "chunk" there, and an explicit
    schedule still wins."""
    geom, *_ = setup
    budget = plan_reconstruction(geom, "algorithm1_mp", nb=2,
                                 memory_budget=1 << 20)
    assert budget.schedule == "chunk"
    forced = plan_reconstruction(geom, "algorithm1_mp", nb=2,
                                 memory_budget=1 << 20, schedule="step")
    assert forced.schedule == "step"


# ---- scan-vs-loop parity --------------------------------------------------

@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_scan_vs_chunk_loop_parity(setup, variant):
    """Acceptance bar: streamed+tiled FDK under the step-major scan
    matches the PR-2 chunk-major loop for ALL registered variants, with
    a non-divisible tail chunk (6 padded views, proj_batch=4)."""
    geom, projs, *_ = setup
    kw = dict(variant=variant, nb=2, tiling=(5, 16, 5), proj_batch=4)
    step = fdk_reconstruct(projs, geom, **kw)
    chunk = fdk_reconstruct(projs, geom, schedule="chunk", **kw)
    assert rel_rmse(step, chunk) < BAR, variant
    # and both match the untiled whole-filter seed path
    seed = fdk_reconstruct(projs, geom, variant=variant, nb=2)
    assert rel_rmse(step, seed) < BAR, variant


@pytest.mark.parametrize("out", ["host", "device"])
def test_scan_parity_both_placements(setup, out):
    geom, projs, *_ = setup
    kw = dict(variant="algorithm1_mp", nb=2, tiling=(8, 8, 4),
              proj_batch=2, out=out)
    step = fdk_reconstruct(projs, geom, **kw)
    chunk = fdk_reconstruct(projs, geom, schedule="chunk", **kw)
    assert isinstance(step, np.ndarray) == (out == "host")
    assert rel_rmse(step, chunk) < BAR


def test_backproject_any_view_count_step_major(setup):
    """The scan grid follows the DATA extent: view counts that are
    neither the geometry's count nor chunk-divisible stream exactly."""
    geom, _, img_t, mats = setup
    rng = np.random.RandomState(8)
    extra = jnp.asarray(rng.rand(4, geom.nw, geom.nh).astype(np.float32))
    img10 = jnp.concatenate([img_t, extra], axis=0)
    mats10 = jnp.concatenate([mats, mats[:4]], axis=0)
    want = np.asarray(bp.bp_subline(img10, mats10, geom.volume_shape_xyz))
    plan = plan_reconstruction(geom, "subline_batch_mp",
                               tile_shape=(8, 8, 16), nb=4, proj_batch=4)
    got = PlanExecutor(geom, plan, cache=ProgramCache()).backproject(
        img10, mats10)
    assert rel_rmse(got, want) < BAR


# ---- program cache under the chunk-loop key -------------------------------

def test_scan_programs_compile_interior_tiles_once(setup):
    """4 interior (8, 8, 16) tiles x 3 chunks -> ONE scan program build
    (the chunk-loop key is shared), three hits; a second call all hits."""
    geom, projs, *_ = setup
    cache = ProgramCache()
    plan = plan_reconstruction(geom, "subline_batch_mp",
                               tile_shape=(8, 8, 16), nb=2, proj_batch=2)
    assert len(plan.chunks) == 3 and len(plan.steps) == 4
    ex = PlanExecutor(geom, plan, cache=cache)
    ex.reconstruct(projs)
    stats = cache.stats()
    assert stats["misses"] == 1 and stats["programs"] == 1
    assert stats["hits"] == 3
    ex.reconstruct(projs)
    stats = cache.stats()
    assert stats["misses"] == 1 and stats["hits"] == 7


def test_scan_key_distinct_from_kernel_key(setup):
    """The same (variant, shape) under a different chunk grid is a new
    program; the chunk-major loop's per-chunk key family is untouched."""
    geom, _, img_t, mats = setup
    cache = ProgramCache()
    plan2 = plan_reconstruction(geom, "subline_batch_mp",
                                tile_shape=(8, 8, 16), nb=2, proj_batch=2)
    plan3 = plan_reconstruction(geom, "subline_batch_mp",
                                tile_shape=(8, 8, 16), nb=2, proj_batch=3)
    PlanExecutor(geom, plan2, cache=cache).backproject(img_t, mats)
    assert cache.stats()["programs"] == 1
    PlanExecutor(geom, plan3, cache=cache).backproject(img_t, mats)
    assert cache.stats()["programs"] == 2  # different (n_chunks, size)


# ---- filter-once producer -------------------------------------------------

@pytest.mark.parametrize("schedule", ["step", "chunk"])
def test_filtering_runs_once_per_chunk(setup, schedule, monkeypatch):
    """Satellite: filtering cost is paid once per chunk regardless of
    the step count (4 tiles consume every chunk)."""
    geom, projs, *_ = setup
    plan = plan_reconstruction(geom, "subline_batch_mp",
                               tile_shape=(8, 8, 16), nb=2, proj_batch=2,
                               schedule=schedule)
    assert len(plan.steps) == 4 and len(plan.chunks) == 3
    ex = PlanExecutor(geom, plan, cache=ProgramCache())
    ref = fdk_reconstruct(projs, geom, variant="subline_batch_mp", nb=2)
    calls = []
    real = PlanExecutor._chunk_inputs

    def counting(self, projections, mat_p, s0, s1):
        calls.append((s0, s1))
        return real(self, projections, mat_p, s0, s1)

    monkeypatch.setattr(PlanExecutor, "_chunk_inputs", counting)
    got = ex.reconstruct(projs)
    assert sorted(calls) == [(0, 2), (2, 4), (4, 6)]
    assert rel_rmse(got, ref) < BAR


# ---- proj_loop capability -------------------------------------------------

def test_proj_loop_resolved_per_variant(setup):
    geom, *_ = setup
    for name, spec in REGISTRY.items():
        plan = plan_reconstruction(geom, name, nb=2)
        opts = plan.kernel_options()
        if spec.proj_loop:
            assert opts.get("proj_loop") is True, name
        else:
            assert "proj_loop" not in opts, name
    # explicit override wins
    plan = plan_reconstruction(geom, "subline_pl", nb=2, proj_loop=False)
    assert plan.kernel_options()["proj_loop"] is False


def test_proj_loop_spec_advertised():
    for name in ("subline_pl", "onehot_pl", "banded_pl"):
        spec = get_spec(name)
        assert spec.proj_loop and "proj_loop" in spec.options, name


@pytest.mark.parametrize("name", ["subline_pl", "onehot_pl", "banded_pl"])
def test_fused_kernel_parity(setup, name):
    """proj_loop=True (in-kernel fori_loop over nb-batches) is exact
    against the per-projection grid, odd volume shapes included."""
    geom, _, img_t, mats = setup
    fn = get_spec(name).fn
    for shape in [geom.volume_shape_xyz, (13, 17, 5)]:
        ref = fn(img_t, mats, shape, nb=3, proj_loop=False)
        got = fn(img_t, mats, shape, nb=3, proj_loop=True)
        assert float(jnp.max(jnp.abs(ref - got))) < 1e-5, (name, shape)


def test_fused_kernel_falls_back_on_indivisible(setup):
    """proj_loop with np % nb != 0 silently runs the per-projection
    grid (raw-caller safety; planned paths pad globally)."""
    geom, _, img_t, mats = setup
    fn = get_spec("subline_pl").fn
    ref = fn(img_t, mats, geom.volume_shape_xyz, nb=4, proj_loop=False)
    got = fn(img_t, mats, geom.volume_shape_xyz, nb=4, proj_loop=True)
    assert float(jnp.max(jnp.abs(ref - got))) < 1e-5
