"""Iterative solver subsystem (runtime/solvers.py): convergence,
precision contract, and the compile-flat-after-iteration-1 guarantee."""

import math

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.forward import forward_project
from repro.core.geometry import standard_geometry
from repro.core.phantom import shepp_logan_3d
from repro.runtime.executor import ProgramCache
from repro.runtime.planner import plan_reconstruction
from repro.runtime.solvers import (IterativeExecutor, solve,
                                   solver_executor)


@pytest.fixture(scope="module")
def solver_setup():
    n = 16
    geom = standard_geometry(n=n, n_det=24, n_proj=12)
    phantom = jnp.asarray(shepp_logan_3d(n))
    projs = forward_project(phantom, geom, oversample=1.0)
    return geom, phantom, projs


def _psnr(x, ref):
    x = np.asarray(x, np.float64)
    ref = np.asarray(ref, np.float64)
    mse = np.mean((x - ref) ** 2)
    peak = ref.max() - ref.min()
    return 10.0 * math.log10(peak * peak / max(mse, 1e-30))


# ---------------------------------------------------------------------------
# convergence


@pytest.mark.parametrize("method,kw", [
    ("sart", {}),
    ("os_sart", {"proj_batch": 4}),
    ("cgls", {}),
])
def test_monotone_residual(solver_setup, method, kw):
    """SART / OS-SART / CGLS drive the data residual down every
    iteration on consistent Shepp-Logan data."""
    geom, _, projs = solver_setup
    _, rep = solve(projs, geom, method, n_iters=5, oversample=1.0,
                   nb=4, cache=ProgramCache(), **kw)
    assert len(rep.residuals) == 5
    for a, b in zip(rep.residuals, rep.residuals[1:]):
        assert b < a * 1.001, rep.residuals   # monotone (tiny tolerance)
    assert rep.residuals[-1] < 0.5 * rep.residuals[0]


def test_os_sart_converges_faster_per_pass(solver_setup):
    """Ordered subsets: one pass applies an update per subset, so the
    residual after k passes is below plain SART's after k iterations."""
    geom, _, projs = solver_setup
    _, sart = solve(projs, geom, "sart", n_iters=4, oversample=1.0,
                    nb=4, cache=ProgramCache())
    _, ossart = solve(projs, geom, "os_sart", n_iters=4, oversample=1.0,
                      nb=4, proj_batch=4, cache=ProgramCache())
    assert ossart.residuals[-1] < sart.residuals[-1]
    assert ossart.extras["subsets"] == 3.0      # 12 views / 4


def test_fista_tv_beats_sart_psnr_sparse_view(solver_setup):
    """With few views + noise, the TV prior wins reconstruction quality
    at equal iteration count."""
    n = 16
    geom = standard_geometry(n=n, n_det=24, n_proj=8)   # sparse views
    phantom = jnp.asarray(shepp_logan_3d(n))
    projs = forward_project(phantom, geom, oversample=1.0)
    rng = np.random.RandomState(7)
    noisy = projs + jnp.asarray(
        (0.02 * float(jnp.abs(projs).max())
         * rng.randn(*projs.shape)).astype(np.float32))
    vol_sart, _ = solve(noisy, geom, "sart", n_iters=8, oversample=1.0,
                        nb=4, cache=ProgramCache())
    vol_tv, _ = solve(noisy, geom, "fista_tv", n_iters=8, oversample=1.0,
                      nb=4, tv_weight=0.01, cache=ProgramCache())
    assert _psnr(vol_tv, phantom) > _psnr(vol_sart, phantom)


# ---------------------------------------------------------------------------
# precision contract


def test_bf16_within_tolerance_of_f32(solver_setup):
    """bf16 compute / f32 accumulate tracks the f32 solve within the
    reduced-precision tolerance contract."""
    geom, _, projs = solver_setup
    x32, r32 = solve(projs, geom, "sart", n_iters=3, oversample=1.0,
                     nb=4, precision="f32", cache=ProgramCache())
    x16, r16 = solve(projs, geom, "sart", n_iters=3, oversample=1.0,
                     nb=4, precision="bf16", cache=ProgramCache())
    assert r16.precision == "bf16"
    scale = float(jnp.abs(x32).max())
    rel = float(jnp.sqrt(jnp.mean((x16 - x32) ** 2))) / max(scale, 1e-12)
    assert rel < 2e-2, rel
    # and the bf16 residual trajectory still falls monotonically
    for a, b in zip(r16.residuals, r16.residuals[1:]):
        assert b < a * 1.001


def test_bf16_is_not_f32(solver_setup):
    """The reduced-precision path must actually reduce precision
    (guards against the adapter silently being a no-op)."""
    geom, _, projs = solver_setup
    x32, _ = solve(projs, geom, "sart", n_iters=2, oversample=1.0,
                   nb=4, precision="f32", cache=ProgramCache())
    x16, _ = solve(projs, geom, "sart", n_iters=2, oversample=1.0,
                   nb=4, precision="bf16", cache=ProgramCache())
    assert float(jnp.abs(x16 - x32).max()) > 0.0


def test_precision_in_bucket_key(solver_setup):
    geom, _, _ = solver_setup
    a = plan_reconstruction(geom, "algorithm1_mp", out="device")
    b = plan_reconstruction(geom, "algorithm1_mp", out="device",
                            precision="bf16")
    c = plan_reconstruction(geom, "algorithm1_mp", out="device",
                            solver="sart")
    assert a.bucket_key != b.bucket_key
    assert a.bucket_key != c.bucket_key
    with pytest.raises(ValueError):
        plan_reconstruction(geom, "algorithm1_mp", out="device",
                            precision="f64")


# ---------------------------------------------------------------------------
# compile accounting: warm iterations compile NOTHING


@pytest.mark.parametrize("method,kw", [
    ("sart", {}),
    ("os_sart", {"proj_batch": 4}),
    ("cgls", {}),
    ("fista_tv", {}),
])
def test_compile_flat_after_iter1(solver_setup, method, kw):
    """Every program a solve needs compiles in iteration 1 (normalizers
    included); iterations 2..N dispatch warm. Asserted per solver on
    the shared ProgramCache miss count."""
    geom, _, projs = solver_setup
    cache = ProgramCache()
    _, rep = solve(projs, geom, method, n_iters=4, oversample=1.0,
                   nb=4, cache=cache, **kw)
    assert rep.compiles_iter1 > 0
    assert rep.compiles_warm == 0, (method, rep)
    # a SECOND solve on the persistent executor compiles nothing at all
    m0 = cache.stats()["misses"]
    _, rep2 = solve(projs, geom, method, n_iters=2, oversample=1.0,
                    nb=4, cache=cache, **kw)
    assert cache.stats()["misses"] == m0
    assert rep2.compiles_iter1 == 0 and rep2.compiles_warm == 0


def test_subsets_clip_to_n_proj(solver_setup):
    """The ordered-subset view ranges never cover the nb padding."""
    geom, _, _ = solver_setup
    plan = plan_reconstruction(geom, "algorithm1_mp", out="device",
                               nb=8, proj_batch=8, solver="os_sart")
    assert plan.n_proj == 12
    subs = plan.subsets
    assert subs[-1][1] == 12                      # clipped, not padded
    assert all(s1 > s0 for s0, s1 in subs)


def test_solver_plan_validation(solver_setup):
    geom, _, _ = solver_setup
    with pytest.raises(ValueError):
        plan_reconstruction(geom, "algorithm1_mp", solver="sart",
                            out="host")
    with pytest.raises(ValueError):
        plan_reconstruction(geom, "algorithm1_mp", solver="nope",
                            out="device")
    with pytest.raises(ValueError):
        plan_reconstruction(geom, "algorithm1_mp", solver="sart",
                            out="device", ingest="stream")


def test_executor_reuse_and_duck_type(solver_setup):
    """solver_executor returns the SAME executor for the same request,
    and the executor exposes the PlanExecutor surface the serving
    layer's buckets rely on."""
    geom, _, projs = solver_setup
    cache = ProgramCache()
    plan = plan_reconstruction(geom, "algorithm1_mp", out="device",
                               solver="sart")
    a = solver_executor(geom, plan, cache, oversample=1.0)
    b = solver_executor(geom, plan, cache, oversample=1.0)
    assert a is b
    assert a.supports_request_batching is False
    assert a.pipeline in ("sync", "async")
    assert isinstance(a.fleet_totals, dict)
    with a._fleet_lock:
        pass
    vol = a.reconstruct(projs, n_iters=1, oversample=1.0)
    assert vol.shape == (16, 16, 16)


def test_forward_chunking_parity(solver_setup):
    """forward_project(proj_batch=) and views= match the single
    all-views dispatch."""
    geom, phantom, _ = solver_setup
    full = forward_project(phantom, geom, oversample=1.0)
    chunked = forward_project(phantom, geom, oversample=1.0,
                              proj_batch=5)
    assert np.allclose(np.asarray(full), np.asarray(chunked), atol=1e-5)
    sub = forward_project(phantom, geom, oversample=1.0,
                          views=slice(2, 9))
    assert np.allclose(np.asarray(full)[2:9], np.asarray(sub), atol=1e-5)


# ---------------------------------------------------------------------------
# service integration


def test_service_solver_bucket(solver_setup):
    """Solver requests form their own bucket family; the second request
    is a bucket hit that compiles nothing."""
    from repro.runtime.service import ReconService
    geom, _, projs = solver_setup
    with ReconService() as svc:
        v1 = svc.reconstruct(projs, geom, solver="sart", n_iters=2,
                             nb=4, oversample=1.0)
        m1 = svc.cache.stats()["misses"]
        v2 = svc.reconstruct(projs, geom, solver="sart", n_iters=2,
                             nb=4, oversample=1.0)
        assert svc.cache.stats()["misses"] == m1
        assert np.allclose(np.asarray(v1), np.asarray(v2))
        vf = svc.reconstruct(projs, geom, nb=4)        # FDK bucket
        st = svc.stats()
        assert len(st.buckets) == 2
        assert vf.shape == v1.shape
        with pytest.raises(ValueError):
            svc.reconstruct(projs, geom, n_iters=3)    # knobs need solver=


def test_sart_step_facade_delegates(solver_setup):
    """The legacy one-step façade rides the persistent executor: same
    fixed point, and the second call compiles nothing."""
    from repro.core.fdk import sart_step
    from repro.runtime.executor import default_program_cache
    geom, _, projs = solver_setup
    x = jnp.zeros((16, 16, 16), jnp.float32)
    x1 = sart_step(x, projs, geom, nb=4, oversample=1.0)
    m0 = default_program_cache().stats()["misses"]
    x2 = sart_step(x1, projs, geom, nb=4, oversample=1.0)
    assert default_program_cache().stats()["misses"] == m0
    # the update moves toward the data
    r0 = float(jnp.linalg.norm(
        projs - forward_project(x, geom, oversample=1.0)))
    r2 = float(jnp.linalg.norm(
        projs - forward_project(x2, geom, oversample=1.0)))
    assert r2 < r0


# ---------------------------------------------------------------------------
# solver autotuning


def test_autotune_solver_method(solver_setup, tmp_path):
    """autotune(method=...) measures amortized per-iteration wall and
    persists a solver-scoped winner (cache hit: zero trials)."""
    from repro.runtime.autotune import autotune
    geom, _, projs = solver_setup
    path = tmp_path / "tuning.json"
    cfg = autotune(geom, method="sart", budget_s=25.0, iters=2, nb=4,
                   cache=str(path), projections=projs,
                   program_cache=ProgramCache())
    assert cfg.solver == "sart"
    assert cfg.source == "measured" and cfg.trials >= 1
    assert cfg.wall_us > 0
    hit = autotune(geom, method="sart", nb=4, cache=str(path),
                   projections=projs, program_cache=ProgramCache())
    assert hit.source == "cache" and hit.trials == 0
    assert hit.solver == "sart" and hit.precision == cfg.precision
