"""Online (streaming) ingestion: exactness and behavior.

The streaming contract under test: pushing views as they "arrive" and
folding each view-chunk the moment it completes produces output
BIT-IDENTICAL to the offline chunk-major reconstruction of the same
views — same chunk partition, same per-step device adds in chunk-index
order, same final host/device accumulation. The suite covers the
partition edge cases (arrival-order permutations within a chunk, a
ragged tail chunk), the producer/consumer races (slow producer starves
the folder; fast producer hits the bounded arrival queue), ≥4 variants
including a Pallas kernel, and the service session layer (concurrent
same-bucket sessions batched per rotation phase).
"""

import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.geometry import standard_geometry
from repro.runtime.executor import PlanExecutor, ProgramCache
from repro.runtime.planner import plan_reconstruction
from repro.runtime.service import ReconService

# shared across the module: streaming must reuse, not recompile
_PCACHE = ProgramCache()

GEOM = standard_geometry(n=16, n_det=24, n_proj=8)
PROJS = np.random.default_rng(11).normal(
    size=(GEOM.n_proj, GEOM.nh, GEOM.nw)).astype(np.float32)


def _stream_plan(geom=GEOM, variant="algorithm1_mp", *, nb=2,
                 proj_batch=2, **kw):
    return plan_reconstruction(geom, variant, nb=nb, proj_batch=proj_batch,
                               ingest="stream", **kw)


def _push_all(se, projs, order=None, group=1, dt=0.0):
    """Feed rows one-by-one (or ``group`` at a time) in ``order``."""
    n = projs.shape[0]
    order = list(range(n)) if order is None else list(order)
    for i in range(0, n, group):
        rows = order[i:i + group]
        for r in rows:
            se.push(projs[r], start=r)
        if dt:
            time.sleep(dt)


# ---------------------------------------------------------------------------
# plan-level: the ingest axis
# ---------------------------------------------------------------------------

def test_stream_plan_is_chunk_major_and_bucketed_apart():
    plan = _stream_plan()
    off = plan_reconstruction(GEOM, "algorithm1_mp", nb=2, proj_batch=2,
                              schedule="chunk")
    assert plan.ingest == "stream" and plan.schedule == "chunk"
    assert off.ingest == "offline"
    # same chunk partition (the exactness precondition) ...
    assert plan.chunks == off.chunks
    # ... but stream sessions must never share a bucket with requests
    assert plan.bucket_key != off.bucket_key


def test_stream_plan_rejects_step_schedule():
    with pytest.raises(ValueError, match="stream"):
        plan_reconstruction(GEOM, "algorithm1_mp", nb=2, proj_batch=2,
                            ingest="stream", schedule="step")
    with pytest.raises(ValueError, match="ingest"):
        plan_reconstruction(GEOM, "algorithm1_mp", ingest="bogus")


def test_stream_schedule_lists_per_chunk_work():
    plan = _stream_plan(proj_batch=2)   # 8 views / chunk_size 2
    s = plan.stream
    assert s.n_views == GEOM.n_proj
    assert s.n_chunks == len(plan.chunks) == 4
    assert [f.chunk.index for f in s.folds] == [0, 1, 2, 3]
    assert all(f.steps == plan.steps for f in s.folds)


# ---------------------------------------------------------------------------
# executor-level parity: streamed == offline, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", [
    "algorithm1_mp", "subline_batch_mp", "symmetry_mp", "subline_pl"])
def test_stream_parity_across_variants(variant):
    plan = _stream_plan(variant=variant)
    ex = PlanExecutor(GEOM, plan, cache=_PCACHE)
    ref = np.asarray(ex.reconstruct(jnp.asarray(PROJS)))
    se = ex.open_stream()
    _push_all(se, PROJS)
    assert np.array_equal(np.asarray(se.close()), ref)


def test_stream_parity_tiled_async_host_out():
    plan = _stream_plan(tile_shape=(8, 8, 16), out="host")
    ex = PlanExecutor(GEOM, plan, cache=_PCACHE, pipeline="async")
    ref = np.asarray(ex.reconstruct(jnp.asarray(PROJS)))
    se = ex.open_stream()
    _push_all(se, PROJS, group=3)       # pushes need not align to chunks
    assert np.array_equal(np.asarray(se.close()), ref)


def test_stream_parity_device_out():
    plan = _stream_plan(out="device")
    ex = PlanExecutor(GEOM, plan, cache=_PCACHE)
    ref = np.asarray(ex.reconstruct(jnp.asarray(PROJS)))
    se = ex.open_stream()
    _push_all(se, PROJS)
    assert np.array_equal(np.asarray(se.close()), ref)


def test_stream_parity_under_within_chunk_permutation():
    # arrival order inside a chunk must not matter: the chunk buffer is
    # assembled by row index, and filtering/folding only start once the
    # chunk is COMPLETE
    plan = _stream_plan(proj_batch=4)   # chunks of 4 views
    ex = PlanExecutor(GEOM, plan, cache=_PCACHE)
    ref = np.asarray(ex.reconstruct(jnp.asarray(PROJS)))
    order = [2, 0, 3, 1, 6, 5, 4, 7]    # permuted within each chunk
    se = ex.open_stream()
    _push_all(se, PROJS, order=order)
    assert np.array_equal(np.asarray(se.close()), ref)


def test_stream_parity_ragged_tail_chunk():
    geom = standard_geometry(n=16, n_det=24, n_proj=10)
    projs = np.random.default_rng(5).normal(
        size=(10, geom.nh, geom.nw)).astype(np.float32)
    # chunk_size 8 over n_proj_padded -> tail chunk holds 2 raw views
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=4, proj_batch=8,
                               ingest="stream")
    assert plan.chunks[-1][1] > geom.n_proj  # the tail IS ragged
    ex = PlanExecutor(geom, plan, cache=_PCACHE)
    ref = np.asarray(ex.reconstruct(jnp.asarray(projs)))
    se = ex.open_stream()
    _push_all(se, projs, group=3)       # 3 never divides either chunk
    assert np.array_equal(np.asarray(se.close()), ref)


def test_stream_slow_producer_starves_folder():
    # folder idles between arrivals; every chunk still folds in order
    plan = _stream_plan(proj_batch=2)
    ex = PlanExecutor(GEOM, plan, cache=_PCACHE)
    ref = np.asarray(ex.reconstruct(jnp.asarray(PROJS)))
    se = ex.open_stream()
    _push_all(se, PROJS, dt=0.02)
    assert np.array_equal(np.asarray(se.close()), ref)


def test_stream_fast_producer_hits_backpressure():
    # a producer faster than the folder blocks on the bounded arrival
    # queue instead of buffering the whole scan
    plan = _stream_plan(proj_batch=2)
    ex = PlanExecutor(GEOM, plan, cache=_PCACHE)
    ref = np.asarray(ex.reconstruct(jnp.asarray(PROJS)))
    se = ex.open_stream(max_pending_chunks=1)
    _push_all(se, PROJS)                # as fast as push() admits
    assert np.array_equal(np.asarray(se.close()), ref)
    assert se.max_pending_seen <= 1


def test_stream_push_errors():
    ex = PlanExecutor(GEOM, _stream_plan(), cache=_PCACHE)
    se = ex.open_stream()
    se.push(PROJS[0], start=0)
    with pytest.raises(ValueError, match="twice"):
        se.push(PROJS[0], start=0)
    with pytest.raises(ValueError):
        se.push(PROJS[0], start=GEOM.n_proj + 3)
    with pytest.raises(RuntimeError, match="closed"):
        se.close()                      # 1 of 8 views delivered
    with pytest.raises(RuntimeError):
        se.push(PROJS[1], start=1)      # stream already failed/closed


# ---------------------------------------------------------------------------
# service sessions
# ---------------------------------------------------------------------------

def test_service_stream_session_parity_and_stats():
    projs2 = np.random.default_rng(7).normal(
        size=PROJS.shape).astype(np.float32)
    svc = ReconService(max_inflight=1, max_batch=2, max_wait_ms=150.0,
                       cache=_PCACHE)
    try:
        s1 = svc.open_stream(GEOM, nb=2, proj_batch=2)
        s2 = svc.open_stream(GEOM, nb=2, proj_batch=2)
        for v in range(GEOM.n_proj):    # lockstep: same rotation phase
            s1.push(PROJS[v], start=v)
            s2.push(projs2[v], start=v)
        v1, v2 = s1.close(), s2.close()
        bucket = next(b for b in svc._buckets.values()
                      if b.plan.ingest == "stream")
        oracle = PlanExecutor(GEOM, bucket.plan, cache=_PCACHE)
        assert np.array_equal(np.asarray(v1),
                              np.asarray(oracle.reconstruct(
                                  jnp.asarray(PROJS))))
        assert np.array_equal(np.asarray(v2),
                              np.asarray(oracle.reconstruct(
                                  jnp.asarray(projs2))))
        st = svc.stats()
        assert st.streams == 2
        assert st.stream_tail_ms is not None
        assert st.stream_hidden_fraction is not None
        row = next(b for b in st.buckets if b.streams)
        assert row.streams == 2 and row.streams_closed == 2
        # 4 chunks/session: fully batched = 4 dispatches, worst case 8
        assert 4 <= row.stream_dispatches <= 8
        assert row.stream_mean_lanes >= 1.0
    finally:
        svc.close()


def test_service_stream_defaults_single_session():
    svc = ReconService(cache=_PCACHE)
    try:
        with svc.open_stream(GEOM) as sess:
            _push_all(sess, PROJS)
            vol = sess.close()
        bucket = next(b for b in svc._buckets.values()
                      if b.plan.ingest == "stream")
        ref = PlanExecutor(GEOM, bucket.plan, cache=_PCACHE).reconstruct(
            jnp.asarray(PROJS))
        assert np.array_equal(np.asarray(vol), np.asarray(ref))
        assert sess.report is not None
        assert 0.0 <= sess.report.hidden_fraction <= 1.0
    finally:
        svc.close()


def test_service_stream_rejects_fleet():
    svc = ReconService(cache=_PCACHE, devices=1)
    try:
        with pytest.raises(ValueError, match="fleet"):
            svc.open_stream(GEOM)
    finally:
        svc.close()


def test_service_stream_concurrent_feeders():
    # two producer threads at different paces; the shared stream worker
    # must respect each session's own fold order
    projs2 = np.random.default_rng(3).normal(
        size=PROJS.shape).astype(np.float32)
    svc = ReconService(max_inflight=1, max_batch=2, max_wait_ms=20.0,
                       cache=_PCACHE)
    try:
        s1 = svc.open_stream(GEOM, nb=2, proj_batch=2)
        s2 = svc.open_stream(GEOM, nb=2, proj_batch=2)
        t1 = threading.Thread(target=_push_all, args=(s1, PROJS),
                              kwargs=dict(dt=0.005))
        t2 = threading.Thread(target=_push_all, args=(s2, projs2))
        t1.start(); t2.start(); t1.join(); t2.join()
        v1, v2 = s1.close(), s2.close()
        bucket = next(b for b in svc._buckets.values()
                      if b.plan.ingest == "stream")
        oracle = PlanExecutor(GEOM, bucket.plan, cache=_PCACHE)
        assert np.array_equal(np.asarray(v1),
                              np.asarray(oracle.reconstruct(
                                  jnp.asarray(PROJS))))
        assert np.array_equal(np.asarray(v2),
                              np.asarray(oracle.reconstruct(
                                  jnp.asarray(projs2))))
    finally:
        svc.close()
