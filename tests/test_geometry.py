"""Geometry invariants the paper's optimizations depend on."""

import math

import numpy as np
import pytest

from repro.core import projection_matrix, standard_geometry
from repro.core.geometry import detector_frame, source_positions


@pytest.fixture(scope="module")
def geom():
    return standard_geometry(n=32, n_det=48, n_proj=16)


def test_k_invariance_of_x_and_z(geom):
    """O2 hoisting exactness: rows 0 and 2 have zero k coefficient."""
    for theta in np.linspace(0, 2 * math.pi, 7):
        m = projection_matrix(geom, float(theta))
        assert m[0, 2] == 0.0
        assert m[2, 2] == 0.0


def test_center_voxel_projects_to_detector_center(geom):
    c = np.array([(geom.nx - 1) / 2, (geom.ny - 1) / 2,
                  (geom.nz - 1) / 2, 1.0])
    for theta in np.linspace(0, 2 * math.pi, 5):
        m = projection_matrix(geom, float(theta)).astype(np.float64)
        z = m[2] @ c
        assert z == pytest.approx(geom.sad, rel=1e-5)
        assert (m[0] @ c) / z == pytest.approx((geom.nw - 1) / 2, abs=1e-3)
        assert (m[1] @ c) / z == pytest.approx((geom.nh - 1) / 2, abs=1e-3)


def test_geometric_symmetry_exact(geom):
    """O3: voxels mirrored about the central XY plane project to
    y' = (nh-1) - y, exactly (paper §3.1.2, Zhao et al.)."""
    rng = np.random.RandomState(3)
    m = projection_matrix(geom, 1.234).astype(np.float64)
    for _ in range(50):
        i = rng.randint(0, geom.nx)
        j = rng.randint(0, geom.ny)
        k = rng.randint(0, geom.nz)
        k_m = geom.nz - 1 - k
        v1 = np.array([i, j, k, 1.0])
        v2 = np.array([i, j, k_m, 1.0])
        z1, z2 = m[2] @ v1, m[2] @ v2
        assert z1 == pytest.approx(z2)          # depth is k-invariant
        y1 = (m[1] @ v1) / z1
        y2 = (m[1] @ v2) / z2
        # exact in exact arithmetic; float32 matrix entries leave ~1e-6
        # of round-off (far below the half-pixel that would matter)
        assert y2 == pytest.approx((geom.nh - 1) - y1, abs=1e-4)
        x1 = (m[0] @ v1) / z1
        x2 = (m[0] @ v2) / z2
        assert x1 == pytest.approx(x2, abs=1e-9)  # x is k-invariant


def test_detector_frame_consistent_with_matrix(geom):
    """World-space detector frame and index-space matrix must agree:
    a world point on the detector at pixel (u,v) projects back to (u,v)."""
    theta = 0.77
    origin, ustep, vstep = detector_frame(geom, theta)
    m = projection_matrix(geom, theta).astype(np.float64)
    src = source_positions(geom)[0]  # theta=0 entry not used; recompute
    src = np.array([geom.sad * math.cos(theta),
                    geom.sad * math.sin(theta), 0.0])
    sx, sy, sz = geom.voxel_size
    for (u_pix, v_pix) in [(0, 0), (10, 20), (47, 13)]:
        p_world = origin + u_pix * ustep + v_pix * vstep
        # convert the world point to fractional voxel index space
        idx = np.array([
            p_world[0] / sx + (geom.nx - 1) / 2,
            p_world[1] / sy + (geom.ny - 1) / 2,
            p_world[2] / sz + (geom.nz - 1) / 2,
            1.0,
        ])
        z = m[2] @ idx
        x = (m[0] @ idx) / z
        y = (m[1] @ idx) / z
        assert x == pytest.approx(u_pix, abs=5e-2)
        assert y == pytest.approx(v_pix, abs=5e-2)


def test_magnification(geom):
    assert geom.magnification == pytest.approx(geom.sdd / geom.sad)
