"""Cross-request batching (PR 7): execute_batch + the BatchFormer.

Covers the batching seams the ISSUE pins down:
  * bit-parity — ``PlanExecutor.execute_batch`` output is BIT-identical
    to k sequential ``reconstruct`` calls for >= 4 variants including a
    Pallas kernel and the non-jittable stacked fallback (vmap adds a
    lane axis, it never reassociates a lane's reductions), on the
    async host path, the device path, and the (single-device) fleet;
  * the planner's ``request_batch`` axis — excluded from ``bucket_key``
    by design (k same-bucket requests must land in ONE bucket), but
    scaling the working-set model and the tile auto-picker's budget;
  * BatchFormer semantics — FIFO degeneration at cap 1, same-bucket
    gathering that never reorders other buckets, tail batches when k is
    not a multiple of ``max_batch``, deadline-bypass (a request whose
    deadline can't absorb the wait ships immediately), priority > 0
    never waiting, and mixed-bucket bursts never cross-batching;
  * service integration — occupancy/amortized stats, the sequential
    fallback for chunk-major buckets, and the tuned ``max_batch`` cap;
  * ``TunedConfig.max_batch`` — JSON round-trip incl. pre-batching
    cache documents, and the tuner's batch axis gating.
"""

import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import standard_geometry
from repro.runtime.executor import FleetConfig, PlanExecutor, ProgramCache
from repro.runtime.planner import plan_reconstruction
from repro.runtime.service import ReconService, _BatchFormer, _Request


@pytest.fixture(scope="module")
def setup():
    geom = standard_geometry(n=16, n_det=24, n_proj=6)
    rng = np.random.RandomState(7)
    reqs = [jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                                 geom.nw).astype(np.float32))
            for _ in range(3)]
    return geom, reqs


def _assert_bit_identical(seq, bat):
    assert len(seq) == len(bat)
    for a, b in zip(seq, bat):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape
        assert (a == b).all()


# ---- executor: batched vs sequential bit-parity ---------------------------

@pytest.mark.parametrize("variant,kw", [
    ("algorithm1_mp", {}),                              # untiled pure-JAX
    ("subline_batch_mp", dict(tile_shape=(8, 8, 16))),  # tiled
    ("share_mp", dict(tile_shape=(8, 8, 8))),       # mirror-paired slabs
    ("subline_pl", {}),                             # Pallas (interpret)
    ("banded_pl", {}),                    # non-jittable stacked fallback
])
def test_execute_batch_bit_identical(setup, variant, kw):
    geom, reqs = setup
    plan = plan_reconstruction(geom, variant, nb=2, proj_batch=4, **kw)
    ex = PlanExecutor(geom, plan, cache=ProgramCache(), pipeline="async")
    seq = [ex.reconstruct(p) for p in reqs]
    bat = ex.execute_batch(reqs)
    _assert_bit_identical(seq, bat)


def test_execute_batch_device_out(setup):
    geom, reqs = setup
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=4,
                               out="device")
    ex = PlanExecutor(geom, plan, cache=ProgramCache())
    seq = [ex.reconstruct(p) for p in reqs]
    bat = ex.execute_batch(reqs)
    _assert_bit_identical(seq, bat)


def test_execute_batch_fleet(setup):
    geom, reqs = setup
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=4,
                               tile_shape=(8, 8, 16))
    ex = PlanExecutor(geom, plan, cache=ProgramCache(),
                      fleet=FleetConfig())
    seq = [ex.reconstruct(p) for p in reqs]
    bat = ex.execute_batch(reqs)
    _assert_bit_identical(seq, bat)
    assert ex.last_fleet_report is not None


def test_execute_batch_edges(setup):
    geom, reqs = setup
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=4)
    ex = PlanExecutor(geom, plan, cache=ProgramCache())
    assert ex.execute_batch([]) == []
    one = ex.execute_batch(reqs[:1])                 # delegates
    _assert_bit_identical([ex.reconstruct(reqs[0])], one)
    with pytest.raises(ValueError, match="projections"):
        ex.execute_batch([reqs[0], reqs[1][:3]])     # wrong view count
    chunk = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=4,
                                schedule="chunk")
    cex = PlanExecutor(geom, chunk, cache=ProgramCache())
    assert not cex.supports_request_batching
    with pytest.raises(ValueError, match="step"):
        cex.execute_batch(reqs)
    assert ex.supports_request_batching


def test_warm_batch_precompiles(setup):
    geom, _ = setup
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=4)
    cache = ProgramCache()
    ex = PlanExecutor(geom, plan, cache=cache)
    ex.warm()
    before = cache.stats()["misses"]
    ex.warm_batch(3)
    assert cache.stats()["misses"] == before + 1     # the rb=3 program
    ex.warm_batch(3)                                 # idempotent: a hit
    assert cache.stats()["misses"] == before + 1


# ---- planner: the rb axis -------------------------------------------------

def test_request_batch_not_in_bucket_key(setup):
    geom, _ = setup
    a = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=4)
    b = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=4,
                            request_batch=4)
    assert b.request_batch == 4
    assert a.bucket_key == b.bucket_key      # rb is NOT bucket identity
    assert b.working_set_bytes == 4 * a.working_set_bytes
    assert a.batched(4) == b
    assert b.batched(4) is b
    with pytest.raises(ValueError, match="request_batch"):
        a.batched(0)
    with pytest.raises(ValueError, match="request_batch"):
        plan_reconstruction(geom, "algorithm1_mp", request_batch=0)


def test_request_batch_scales_tile_budget(setup):
    geom, _ = setup
    budget = 1 << 20
    solo = plan_reconstruction(geom, "algorithm1_mp", nb=2,
                               memory_budget=budget)
    batched = plan_reconstruction(geom, "algorithm1_mp", nb=2,
                                  memory_budget=budget, request_batch=8)
    # rb working sets must fit TOGETHER: the auto-picked tile shrinks
    # (or stays) and the rb-scaled working set honors the byte contract
    assert np.prod(batched.tile_shape) <= np.prod(solo.tile_shape)
    assert batched.working_set_bytes <= budget


# ---- BatchFormer semantics ------------------------------------------------

def _req(key, deadline_s=None, priority=0):
    return _Request(fut=Future(), projections=None, geom=None, plan=None,
                    config=None, key=key, deadline_s=deadline_s,
                    priority=priority)


def test_former_cap1_is_fifo():
    f = _BatchFormer(max_wait_s=0.0, cap_fn=lambda r: 1)
    for key in ("a", "b", "a"):
        f.put(_req(key))
    assert [f.take()[0].key for _ in range(3)] == ["a", "b", "a"]
    f.close()
    assert f.take() is None


def test_former_gathers_same_bucket_only():
    f = _BatchFormer(max_wait_s=0.0, cap_fn=lambda r: 4)
    for key in ("a", "b", "a", "c", "a", "b"):
        f.put(_req(key))
    batch = f.take()
    assert [r.key for r in batch] == ["a", "a", "a"]   # never cross-batch
    # other buckets keep their relative FIFO order
    assert [r.key for r in f.take()] == ["b", "b"]
    assert [r.key for r in f.take()] == ["c"]


def test_former_tail_batch_respects_cap():
    f = _BatchFormer(max_wait_s=0.0, cap_fn=lambda r: 4)
    for _ in range(6):
        f.put(_req("a"))
    assert len(f.take()) == 4
    assert len(f.take()) == 2                # the tail, k % cap != 0


def test_former_waits_for_late_peer():
    f = _BatchFormer(max_wait_s=5.0, cap_fn=lambda r: 2)
    out = []
    t = threading.Thread(target=lambda: out.append(f.take()))
    f.put(_req("a"))
    t.start()
    time.sleep(0.15)
    f.put(_req("a"))                         # the late peer
    t.join(timeout=3.0)
    assert not t.is_alive()
    assert len(out[0]) == 2                  # coalesced, not two takes


def test_former_deadline_bypass():
    f = _BatchFormer(max_wait_s=30.0, cap_fn=lambda r: 4,
                     est_fn=lambda r: 0.0)
    f.put(_req("a", deadline_s=time.perf_counter() + 0.05))
    t0 = time.perf_counter()
    batch = f.take()                         # must NOT wait 30 s
    assert time.perf_counter() - t0 < 5.0
    assert len(batch) == 1


def test_former_priority_never_waits():
    f = _BatchFormer(max_wait_s=30.0, cap_fn=lambda r: 4)
    f.put(_req("a", priority=1))
    t0 = time.perf_counter()
    assert len(f.take()) == 1
    assert time.perf_counter() - t0 < 5.0


def test_former_est_consumes_deadline_headroom():
    # headroom 10 s but the bucket's running estimate is 9.99 s: the
    # wait budget is ~0 — the deadline cannot absorb waiting
    f = _BatchFormer(max_wait_s=30.0, cap_fn=lambda r: 4,
                     est_fn=lambda r: 9.99)
    f.put(_req("a", deadline_s=time.perf_counter() + 10.0))
    t0 = time.perf_counter()
    assert len(f.take()) == 1
    assert time.perf_counter() - t0 < 5.0


def test_former_put_after_close_raises():
    f = _BatchFormer(max_wait_s=0.0, cap_fn=lambda r: 1)
    f.close()
    with pytest.raises(RuntimeError, match="closed"):
        f.put(_req("a"))


# ---- service integration --------------------------------------------------

OPTS = dict(variant="algorithm1_mp", nb=2, proj_batch=4)


def test_service_batched_burst_bit_identical(setup):
    geom, reqs = setup
    ref_svc = ReconService(max_inflight=1, cache=ProgramCache())
    ref = [np.asarray(ref_svc.reconstruct(p, geom, **OPTS)) for p in reqs]
    ref_svc.close()

    svc = ReconService(max_inflight=1, max_batch=4, cache=ProgramCache())
    svc.warmup([geom], **OPTS)
    futs = [svc.submit(p, geom, **OPTS) for p in reqs + reqs]  # k=6
    out = [np.asarray(f.result()) for f in futs]
    _assert_bit_identical(ref + ref, out)
    st = svc.stats()
    b = st.buckets[0]
    assert b.completed == 6
    # tail batch: 6 = 4 + 2 under cap 4 (the single worker dispatches
    # at most twice; the first take may catch fewer if the burst was
    # still enqueueing, so bound rather than pin the count)
    assert b.dispatches < 6
    assert b.max_batch == 4
    assert b.mean_occupancy > 1.0
    assert b.amortized_us_per_request is not None
    assert b.batch_p50_ms is not None
    assert st.mean_occupancy == b.mean_occupancy
    svc.close()


def test_service_mixed_buckets_never_cross_batch(setup):
    geom, reqs = setup
    geom_b = standard_geometry(n=8, n_det=12, n_proj=6)
    rng = np.random.RandomState(11)
    reqs_b = [jnp.asarray(rng.rand(6, 12, 12).astype(np.float32))
              for _ in range(3)]
    ref_svc = ReconService(max_inflight=1, cache=ProgramCache())
    ref_a = [np.asarray(ref_svc.reconstruct(p, geom, **OPTS))
             for p in reqs]
    ref_b = [np.asarray(ref_svc.reconstruct(p, geom_b, **OPTS))
             for p in reqs_b]
    ref_svc.close()

    svc = ReconService(max_inflight=1, max_batch=4, cache=ProgramCache())
    svc.warmup([geom, geom_b], **OPTS)
    futs = []
    for pa, pb in zip(reqs, reqs_b):         # interleaved A B A B A B
        futs.append((svc.submit(pa, geom, **OPTS), "a"))
        futs.append((svc.submit(pb, geom_b, **OPTS), "b"))
    out_a = [np.asarray(f.result()) for f, tag in futs if tag == "a"]
    out_b = [np.asarray(f.result()) for f, tag in futs if tag == "b"]
    # volumes of different shapes through one interleaved burst: every
    # result is bit-identical to its own bucket's unbatched run, so no
    # batch ever mixed buckets (shape or content would differ)
    _assert_bit_identical(ref_a, out_a)
    _assert_bit_identical(ref_b, out_b)
    st = svc.stats()
    assert len(st.buckets) == 2
    assert all(b.completed == 3 for b in st.buckets)
    svc.close()


def test_service_deadline_and_priority_bypass(setup):
    geom, reqs = setup
    # max_wait is 60 s: only the bypass paths let these finish fast
    svc = ReconService(max_inflight=1, max_batch=4, max_wait_ms=60_000.0,
                       cache=ProgramCache())
    svc.warmup([geom], **OPTS)
    t0 = time.perf_counter()
    svc.submit(reqs[0], geom, deadline_ms=50.0, **OPTS).result(timeout=30)
    svc.submit(reqs[1], geom, priority=1, **OPTS).result(timeout=30)
    assert time.perf_counter() - t0 < 30.0
    with pytest.raises(ValueError, match="deadline_ms"):
        svc.submit(reqs[0], geom, deadline_ms=-1.0, **OPTS)
    svc.close()


def test_service_chunk_major_falls_back_sequential(setup):
    geom, reqs = setup
    opts = dict(OPTS, schedule="chunk")
    ref_svc = ReconService(max_inflight=1, cache=ProgramCache())
    ref = [np.asarray(ref_svc.reconstruct(p, geom, **opts)) for p in reqs]
    ref_svc.close()
    svc = ReconService(max_inflight=1, max_batch=4, cache=ProgramCache())
    svc.warmup([geom], **opts)
    assert not next(iter(svc._buckets.values())) \
        .executor.supports_request_batching
    futs = [svc.submit(p, geom, **opts) for p in reqs]
    out = [np.asarray(f.result()) for f in futs]
    _assert_bit_identical(ref, out)          # formed, then run one-by-one
    svc.close()


def test_service_validates_batch_knobs():
    with pytest.raises(ValueError, match="max_batch"):
        ReconService(max_batch=0)
    with pytest.raises(ValueError, match="max_wait_ms"):
        ReconService(max_wait_ms=-1.0)


def test_tuned_max_batch_caps_bucket(setup):
    from repro.runtime.autotune import TunedConfig
    svc = ReconService(max_inflight=1, max_batch=8, cache=ProgramCache())
    measured = TunedConfig(
        variant="algorithm1_mp", schedule="step", pipeline="async",
        pipeline_depth=2, tile_shape=(16, 16, 16), proj_batch=4, nb=2,
        out="host", interpret=True, max_batch=2, source="measured")
    heur = dataclasses_replace(measured, source="heuristic", max_batch=1)
    assert svc._effective_cap(measured) == 2     # measured winner caps
    assert svc._effective_cap(heur) == 8         # heuristic: default cap
    assert svc._effective_cap(None) == 8
    svc.close()
    one = ReconService(max_inflight=1, max_batch=1, cache=ProgramCache())
    assert one._effective_cap(measured) == 1     # batching disabled
    one.close()


def dataclasses_replace(cfg, **kw):
    import dataclasses
    return dataclasses.replace(cfg, **kw)


# ---- TunedConfig.max_batch round-trip -------------------------------------

def test_tuned_config_max_batch_roundtrip(setup):
    from repro.runtime.autotune import TunedConfig, _batch_axis
    geom, _ = setup
    cfg = TunedConfig(
        variant="algorithm1_mp", schedule="step", pipeline="async",
        pipeline_depth=2, tile_shape=(16, 16, 16), proj_batch=4, nb=2,
        out="host", interpret=True, max_batch=4)
    back = TunedConfig.from_json(cfg.to_json())
    assert back == cfg and back.max_batch == 4
    assert cfg.key != dataclasses_replace(cfg, max_batch=1).key
    # pre-batching cache documents (no max_batch field) default to 1
    doc = cfg.to_json()
    del doc["max_batch"]
    assert TunedConfig.from_json(doc).max_batch == 1
    # the tuner's batch axis: step-major only, candidates exclude cur
    cands = _batch_axis(cfg)
    assert sorted(c.max_batch for c in cands) == [1, 2, 8]
    assert _batch_axis(dataclasses_replace(cfg, schedule="chunk")) == []
    # the config re-plans with its rb baked into the working-set model
    plan = cfg.build_plan(geom)
    assert plan.request_batch == 4


# ---- cold-start wait policy (no estimate -> no deadline wait) --------------

def test_former_cold_start_deadline_ships_immediately():
    """Before a bucket has ANY completed traffic its latency estimate
    is None; a deadline-carrying partial batch must ship immediately
    rather than waiting out its whole deadline against a fictitious
    estimate of 0 (the pre-fix behavior: headroom = deadline - 0)."""
    f = _BatchFormer(max_wait_s=30.0, cap_fn=lambda r: 4)  # default est_fn
    f.put(_req("a", deadline_s=time.perf_counter() + 25.0))
    t0 = time.perf_counter()
    batch = f.take()
    assert [r.key for r in batch] == ["a"]
    assert time.perf_counter() - t0 < 1.0     # not the 25 s headroom


def test_service_estimate_none_until_traffic(setup):
    geom, reqs = setup
    svc = ReconService(max_inflight=1, cache=ProgramCache())
    try:
        plan, cfg, _skw = svc._plan(geom, dict(OPTS))
        probe = _Request(fut=Future(), projections=None, geom=geom,
                         plan=plan, config=cfg, key=(geom, plan.bucket_key))
        assert svc._run_estimate(probe) is None      # cold start
        svc.reconstruct(reqs[0], geom, **OPTS)
        assert svc._run_estimate(probe) is not None  # traffic -> estimate
    finally:
        svc.close()
