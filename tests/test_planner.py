"""Plan/compile/execute layer: planner purity, program cache, streaming.

Covers the PR-2 architecture seams:
  * planner unit tests — schedule shapes (steps cover the volume
    disjointly, chunks cover the padded projection range), per-step
    slab-safe fallback resolution, mirror-pair structure off-center,
    validation (ONE place for every façade);
  * KernelSpec registry — legacy dicts are derived views, Pallas option
    sets match kernels.ops.ACCEPTED_OPTIONS (cross-layer contract);
  * streamed filtering — chunked fdk_filter_chunk == whole-array filter,
    and full streamed+tiled FDK matches the seed (whole-filter, untiled)
    path to rel-RMSE < 1e-5 for ALL registered variants;
  * program cache — interior tiles of equal shape compile exactly once.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (fdk_reconstruct, projection_matrices,
                        standard_geometry, transpose_projections)
from repro.core import backproject as bp
from repro.core.baseline import backproject_rtk
from repro.core.filtering import fdk_filter_chunk, fdk_preweight_and_filter
from repro.core.variants import (OPTIMIZATIONS, REGISTRY, SLAB_SAFE_FALLBACK,
                                 VARIANTS, get_spec)
from repro.runtime.executor import PlanExecutor, ProgramCache
from repro.runtime.planner import plan_reconstruction, resolve_tile_variant
from repro.core.tiling import TileSpec

from conftest import rel_rmse

BAR = 1e-5


@pytest.fixture(scope="module")
def setup():
    geom = standard_geometry(n=16, n_det=24, n_proj=6)
    rng = np.random.RandomState(0)
    img = jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                               geom.nw).astype(np.float32))
    img_t = transpose_projections(img)
    mats = projection_matrices(geom)
    ni, nj, nk = geom.volume_shape_xyz
    ref = bp.volume_to_transposed(backproject_rtk(img, mats, (nk, nj, ni)))
    return geom, img_t, mats, np.asarray(ref)


# ---- KernelSpec registry -------------------------------------------------

def test_legacy_dicts_are_derived_views():
    assert set(VARIANTS) == set(REGISTRY)
    for name, spec in REGISTRY.items():
        assert VARIANTS[name] is spec.fn
        assert OPTIMIZATIONS[name] == spec.optimizations
        if spec.uses_symmetry:
            assert SLAB_SAFE_FALLBACK[name] == spec.slab_safe_fallback
        else:
            assert name not in SLAB_SAFE_FALLBACK


def test_pallas_specs_match_ops_accepted_options():
    """KernelSpec.options must agree with what kernels.ops consumes —
    a new kernel knob cannot bypass the planner's option filter."""
    from repro.kernels import ops
    wrapper = {"subline_pl": "backproject_subline",
               "onehot_pl": "backproject_onehot",
               "banded_pl": "backproject_banded"}
    for variant, fn_name in wrapper.items():
        assert REGISTRY[variant].options == ops.ACCEPTED_OPTIONS[fn_name], \
            variant


def test_spec_option_filtering():
    spec = get_spec("algorithm1_mp")
    assert spec.resolve_options({"nb": 4, "interpret": True,
                                 "bw": 9}) == {"nb": 4}
    assert get_spec("banded_pl").resolve_options(
        {"nb": 4, "interpret": False, "bw": 9}) == \
        {"nb": 4, "interpret": False, "bw": 9}


# ---- planner: schedule shapes --------------------------------------------

@pytest.mark.parametrize("variant,tile", [
    ("algorithm1_mp", (5, 7, 5)),     # symmetry: mirror pairs + middle
    ("subline_batch_mp", (5, 7, 5)),  # symmetry-free: plain slabs
    ("algorithm1_mp", (16, 16, 3)),
    ("subline_pl", (4, 4, 16)),
])
def test_plan_steps_cover_volume_disjointly(setup, variant, tile):
    geom, *_ = setup
    plan = plan_reconstruction(geom, variant, tile_shape=tile, nb=4)
    count = np.zeros(plan.vol_shape_xyz, np.int32)
    for s in plan.steps:
        for w in s.writes:
            count[s.i0:s.i0 + s.ni, s.j0:s.j0 + s.nj,
                  w.k0:w.k0 + w.nk] += 1
            assert w.hi - w.lo == w.nk and w.hi <= s.call_nk
    assert (count == 1).all(), (variant, tile)


def test_plan_chunks_cover_padded_range(setup):
    geom, *_ = setup
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=4, proj_batch=5)
    # 6 projections, nb=4 -> padded to 8; proj_batch=5 -> chunk 8? no:
    # round UP to nb multiple = 8 capped at padded count
    assert plan.n_proj_padded == 8
    assert plan.chunk_size % plan.nb == 0
    cover = np.zeros(plan.n_proj_padded, np.int32)
    for s0, s1 in plan.chunks:
        assert s1 > s0
        cover[s0:s1] += 1
    assert (cover == 1).all()
    # nb-divisible streaming really chunks
    plan2 = plan_reconstruction(geom, "algorithm1_mp", nb=2, proj_batch=2)
    assert plan2.streams_projections and len(plan2.chunks) == 3


def test_untiled_plan_is_single_step_single_chunk(setup):
    geom, *_ = setup
    plan = plan_reconstruction(geom, "algorithm1_mp", nb=2)
    assert len(plan.steps) == 1 and len(plan.chunks) == 1
    assert plan.steps[0].call_shape == geom.volume_shape_xyz
    assert not plan.streams_projections
    assert plan.program_keys == (("algorithm1_mp",
                                  geom.volume_shape_xyz),)


# ---- planner: fallback resolution + mirror pairs -------------------------

def test_fallback_resolution_per_step(setup):
    """Symmetry variants: paired steps keep the variant (virtual 2*nk
    call); any unpaired non-centered slab would get the fallback. The
    symmetry-free fallback never appears in paired form."""
    geom, *_ = setup
    plan = plan_reconstruction(geom, "algorithm1_mp",
                               tile_shape=(16, 16, 5), nb=2)
    paired = [s for s in plan.steps if s.paired]
    plain = [s for s in plan.steps if not s.paired]
    assert paired and plain
    for s in paired:
        assert s.variant == "algorithm1_mp"
        assert s.call_nk == 2 * s.writes[0].nk
        lo, hi = s.writes
        # mirror structure: halves land symmetric about the midplane
        assert lo.k0 + lo.nk <= hi.k0
        assert lo.k0 + (hi.k0 + hi.nk) == geom.nz
    for s in plain:  # centered middle slab: symmetry stays exact
        assert 2 * s.writes[0].k0 + s.writes[0].nk == geom.nz
        assert s.variant == "algorithm1_mp"


def test_resolve_tile_variant_off_center():
    assert resolve_tile_variant("algorithm1_mp",
                                TileSpec(0, 0, 3, 8, 8, 6), 16) == \
        "subline_batch_mp"
    assert resolve_tile_variant("algorithm1_mp",
                                TileSpec(0, 0, 5, 8, 8, 6), 16) == \
        "algorithm1_mp"
    assert resolve_tile_variant("subline_batch_mp",
                                TileSpec(0, 0, 3, 8, 8, 6), 16) == \
        "subline_batch_mp"


def test_mirror_pair_exactness_off_center(setup):
    """One paired step executed in isolation writes BOTH mirror slabs
    exactly — the O3 saving survives tiling off-center."""
    import dataclasses
    geom, img_t, mats, ref = setup
    plan = plan_reconstruction(geom, "algorithm1_mp",
                               tile_shape=(16, 16, 4), nb=2)
    step = next(s for s in plan.steps if s.paired and s.k_off > 0)
    # run ONLY this step via a single-step plan view
    sub = dataclasses.replace(plan, steps=(step,))
    vol = PlanExecutor(geom, sub, cache=ProgramCache()).backproject(
        img_t, mats)
    for w in step.writes:
        got = vol[:, :, w.k0:w.k0 + w.nk]
        want = ref[:, :, w.k0:w.k0 + w.nk]
        assert rel_rmse(got, want) < BAR, w


# ---- planner: validation (one place for every façade) --------------------

def test_planner_validation(setup):
    geom, *_ = setup
    with pytest.raises(ValueError, match="out"):
        plan_reconstruction(geom, "algorithm1_mp", out="gpu")
    with pytest.raises(ValueError, match="nb"):
        plan_reconstruction(geom, "algorithm1_mp", nb=0)
    with pytest.raises(ValueError, match="proj_batch"):
        plan_reconstruction(geom, "algorithm1_mp", proj_batch=0)
    with pytest.raises(KeyError, match="unknown"):
        plan_reconstruction(geom, "no_such_variant")
    with pytest.raises(ValueError, match="does not accept"):
        plan_reconstruction(geom, "algorithm1_mp", bw=9)
    with pytest.raises(ValueError, match="memory_budget"):
        plan_reconstruction(geom, "algorithm1_mp", tile_shape=(16, 16, 16),
                            memory_budget=1024)


def test_fdk_facade_exposes_proj_batch_and_out(setup):
    """Regression: fdk_reconstruct(tiling=...) used to silently ignore
    proj_batch and out."""
    geom, *_ = setup
    rng = np.random.RandomState(3)
    projs = jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                                 geom.nw).astype(np.float32))
    ref = fdk_reconstruct(projs, geom, variant="algorithm1_mp", nb=2)
    dev = fdk_reconstruct(projs, geom, variant="algorithm1_mp", nb=2,
                          tiling=(5, 7, 5), proj_batch=2, out="device")
    assert isinstance(dev, jnp.ndarray)
    assert rel_rmse(dev, ref) < BAR
    host = fdk_reconstruct(projs, geom, variant="algorithm1_mp", nb=2,
                           tiling=(5, 7, 5), proj_batch=2)
    assert isinstance(host, np.ndarray)
    assert rel_rmse(host, ref) < BAR
    with pytest.raises(ValueError, match="proj_batch"):
        fdk_reconstruct(projs, geom, tiling=(5, 7, 5), proj_batch=-1)
    with pytest.raises(ValueError, match="out"):
        fdk_reconstruct(projs, geom, tiling=(5, 7, 5), out="nowhere")


# ---- streamed filtering --------------------------------------------------

def test_chunked_filter_matches_whole_array(setup):
    geom, *_ = setup
    rng = np.random.RandomState(1)
    projs = jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                                 geom.nw).astype(np.float32))
    whole = np.asarray(fdk_preweight_and_filter(projs, geom))
    for chunk in (1, 2, 4, 5):
        parts = [np.asarray(fdk_filter_chunk(projs[s0:s0 + chunk], geom,
                                             geom.n_proj))
                 for s0 in range(0, geom.n_proj, chunk)]
        got = np.concatenate(parts, axis=0)
        assert np.allclose(got, whole, rtol=1e-6, atol=1e-7), chunk


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_streamed_tiled_fdk_matches_seed_path(setup, variant):
    """Acceptance bar: tiled reconstruction with streamed filtering
    (proj_batch chunks, filter fused in the loop) matches the seed path
    (whole-array filter + untiled call) to rel-RMSE < 1e-5 for ALL
    registered variants."""
    geom, *_ = setup
    rng = np.random.RandomState(2)
    projs = jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                                 geom.nw).astype(np.float32))
    seed = fdk_reconstruct(projs, geom, variant=variant, nb=2)
    streamed = fdk_reconstruct(projs, geom, variant=variant, nb=2,
                               tiling=(5, 16, 5), proj_batch=2)
    assert rel_rmse(streamed, seed) < BAR, variant


# ---- program cache -------------------------------------------------------

def test_program_cache_compiles_interior_tiles_once(setup):
    """4 interior (8, 8, 16) tiles -> ONE compile, three hits."""
    geom, img_t, mats, ref = setup
    cache = ProgramCache()
    plan = plan_reconstruction(geom, "subline_batch_mp",
                               tile_shape=(8, 8, 16), nb=2)
    ex = PlanExecutor(geom, plan, cache=cache)
    assert rel_rmse(ex.backproject(img_t, mats), ref) < BAR
    stats = cache.stats()
    assert stats["misses"] == 1 and stats["programs"] == 1
    assert stats["hits"] == 3
    # a second full call is all hits
    ex.backproject(img_t, mats)
    stats = cache.stats()
    assert stats["misses"] == 1 and stats["hits"] == 7


def test_program_cache_mirror_paired_slabs_share_program(setup):
    geom, img_t, mats, ref = setup
    cache = ProgramCache()
    # nz=16, tk=4 -> two paired units, both calling shape (16, 16, 8)
    plan = plan_reconstruction(geom, "algorithm1_mp",
                               tile_shape=(16, 16, 4), nb=2)
    assert len(plan.steps) == 2
    assert plan.program_keys == (("algorithm1_mp", (16, 16, 8)),)
    ex = PlanExecutor(geom, plan, cache=cache)
    assert rel_rmse(ex.backproject(img_t, mats), ref) < BAR
    assert cache.stats()["misses"] == 1


def test_warm_compiles_every_program_key(setup):
    geom, *_ = setup
    cache = ProgramCache()
    plan = plan_reconstruction(geom, "algorithm1_mp",
                               tile_shape=(5, 7, 5), nb=2)
    ex = PlanExecutor(geom, plan, cache=cache)
    ex.warm()
    assert cache.stats()["programs"] == len(plan.program_keys)
    ex.warm()  # idempotent: all hits
    assert cache.stats()["programs"] == len(plan.program_keys)


def test_backproject_accepts_any_view_count(setup):
    """Regression: the chunk schedule must follow the ACTUAL input
    length, not geom.n_proj — extra views were silently dropped."""
    geom, img_t, mats, _ = setup
    rng = np.random.RandomState(5)
    extra = jnp.asarray(rng.rand(4, geom.nw, geom.nh).astype(np.float32))
    img10 = jnp.concatenate([img_t, extra], axis=0)
    mats10 = jnp.concatenate([mats, mats[:4]], axis=0)
    want = np.asarray(bp.bp_subline(img10, mats10, geom.volume_shape_xyz))
    plan = plan_reconstruction(geom, "subline_batch_mp",
                               tile_shape=(8, 8, 16), nb=4, proj_batch=4)
    got = PlanExecutor(geom, plan, cache=ProgramCache()).backproject(
        img10, mats10)
    assert rel_rmse(got, want) < BAR
    # fewer views than the geometry also stream exactly
    got6 = PlanExecutor(geom, plan, cache=ProgramCache()).backproject(
        img_t, mats)
    ref6 = np.asarray(bp.bp_subline(img_t, mats, geom.volume_shape_xyz))
    assert rel_rmse(got6, ref6) < BAR


def test_reconstruct_rejects_wrong_view_count(setup):
    """reconstruct's FDK weighting assumes the geometry's full scan."""
    geom, *_ = setup
    projs = jnp.zeros((geom.n_proj + 2, geom.nh, geom.nw), jnp.float32)
    with pytest.raises(ValueError, match="full scan"):
        fdk_reconstruct(projs, geom, variant="subline_batch_mp", nb=2)


def test_facades_share_default_cache(setup):
    """Repeated façade calls hit the process-wide cache (no retrace)."""
    from repro.runtime.executor import default_program_cache
    geom, *_ = setup
    rng = np.random.RandomState(4)
    projs = jnp.asarray(rng.rand(geom.n_proj, geom.nh,
                                 geom.nw).astype(np.float32))
    fdk_reconstruct(projs, geom, variant="subline_batch_mp", nb=2)
    before = default_program_cache().stats()
    fdk_reconstruct(projs, geom, variant="subline_batch_mp", nb=2)
    after = default_program_cache().stats()
    assert after["misses"] == before["misses"]
    assert after["hits"] > before["hits"]


# --------------------------------------------------------------------------
# fleet partition: the step schedule's shard axis
# --------------------------------------------------------------------------

def _fleet_steps(geom, n_tiles=4):
    plan = plan_reconstruction(geom, "algorithm1_mp", tile_shape=(8, 8, 16),
                               nb=4, proj_batch=4)
    return plan, plan.steps


def test_partition_steps_covers_disjointly(setup):
    """Every step index lands in exactly one shard queue — the fleet's
    correctness precondition (each output box written once)."""
    from repro.runtime.planner import partition_steps
    geom, *_ = setup
    _, steps = _fleet_steps(geom)
    for n_shards in (1, 2, 3, len(steps), len(steps) + 3):
        fs = partition_steps(steps, n_shards)
        seen = [i for q in fs.queues for i in q]
        assert sorted(seen) == list(range(len(steps)))
        assert fs.n_steps == len(steps)
        assert len(fs.queues) == n_shards


def test_partition_steps_deterministic_and_balanced(setup):
    """Pure function of (steps, n_shards): same queues every call; LPT
    keeps modeled per-shard load within one max-step of even."""
    from repro.runtime.planner import partition_steps, step_cost
    geom, *_ = setup
    _, steps = _fleet_steps(geom)
    a = partition_steps(steps, 3)
    b = partition_steps(steps, 3)
    assert a == b
    worst = max(step_cost(s) for s in steps)
    assert max(a.loads) - min(a.loads) <= worst


def test_partition_more_shards_than_steps(setup):
    """Spare devices get empty queues (they idle, stealing if work
    appears) — never an error."""
    from repro.runtime.planner import partition_steps
    geom, *_ = setup
    _, steps = _fleet_steps(geom)
    fs = partition_steps(steps, len(steps) + 5)
    assert sum(len(q) for q in fs.queues) == len(steps)
    assert any(len(q) == 0 for q in fs.queues)


def test_partition_validates_shard_count(setup):
    from repro.runtime.planner import partition_steps
    geom, *_ = setup
    _, steps = _fleet_steps(geom)
    with pytest.raises(ValueError, match="n_shards"):
        partition_steps(steps, 0)


def test_step_major_schedule_exposes_fleet(setup):
    """StepMajorSchedule.fleet(n) is the executor's entry: it shards
    the SAME StepWork list the single-device walk consumes."""
    geom, *_ = setup
    plan, steps = _fleet_steps(geom)
    fs = plan.step_major.fleet(2)
    assert fs.n_shards == 2
    assert fs.n_steps == len(plan.step_major.steps)
