"""Flash attention (custom VJP) vs the reference S^2 oracle."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attention_ref, decode_attention, decode_attention_window,
    flash_attention,
)


def _qkv(B=2, Sq=16, Skv=16, H=4, KVH=2, D=8, seed=0, dtype=jnp.float32):
    rng = np.random.RandomState(seed)
    q = jnp.asarray(rng.randn(B, Sq, H, D).astype(np.float32), dtype)
    k = jnp.asarray(rng.randn(B, Skv, KVH, D).astype(np.float32), dtype)
    v = jnp.asarray(rng.randn(B, Skv, KVH, D).astype(np.float32), dtype)
    return q, k, v


@pytest.mark.parametrize("chunk", [4, 7, 16, 64])
def test_flash_matches_ref_causal(chunk):
    q, k, v = _qkv()
    out = flash_attention(q, k, v, causal=True, chunk=chunk)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5)


@pytest.mark.parametrize("window", [1, 4, 9])
def test_flash_matches_ref_window(window):
    q, k, v = _qkv(seed=1)
    out = flash_attention(q, k, v, causal=True, window=window, chunk=8)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5)


def test_flash_noncausal():
    q, k, v = _qkv(Sq=8, Skv=24, seed=2)
    out = flash_attention(q, k, v, causal=False, chunk=8)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5)


def test_flash_custom_vjp_matches_ref_grads():
    """The FlashAttention-2 backward must equal autodiff-through-ref."""
    q, k, v = _qkv(B=1, Sq=8, Skv=8, H=2, KVH=1, D=4, seed=3)

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True, chunk=4)**2)

    def loss_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=True)**2)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, err_msg=f"d{name}")


def test_flash_grad_window():
    q, k, v = _qkv(B=1, Sq=10, Skv=10, H=2, KVH=2, D=4, seed=4)

    def lf(q, k, v):
        return jnp.sum(
            flash_attention(q, k, v, causal=True, window=3, chunk=4) ** 2)

    def lr(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=True, window=3) ** 2)

    gf = jax.grad(lf, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_decode_attention_matches_prefill_row():
    """Decoding position p over a cache equals row p of full attention."""
    B, S, H, KVH, D = 2, 12, 4, 2, 8
    q, k, v = _qkv(B=B, Sq=S, Skv=S, H=H, KVH=KVH, D=D, seed=5)
    full = attention_ref(q, k, v, causal=True)
    p = 7
    out = decode_attention(q[:, p:p + 1], k, v, jnp.int32(p))
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, p]), atol=2e-5)


def test_decode_window_ring_buffer():
    """Ring-buffer decode equals windowed attention at the same position."""
    B, S, H, KVH, D, W = 1, 20, 2, 1, 4, 8
    q, k, v = _qkv(B=B, Sq=S, Skv=S, H=H, KVH=KVH, D=D, seed=6)
    pos = 13
    full = attention_ref(q, k, v, causal=True, window=W)
    k_ring = jnp.zeros((B, W, KVH, D))
    v_ring = jnp.zeros((B, W, KVH, D))
    for p in range(pos + 1):
        k_ring = k_ring.at[:, p % W].set(k[:, p])
        v_ring = v_ring.at[:, p % W].set(v[:, p])
    out = decode_attention_window(q[:, pos:pos + 1], k_ring, v_ring,
                                  jnp.int32(pos), W)
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, pos]), atol=2e-5)


def test_flash_bf16_accumulates_fp32():
    q, k, v = _qkv(dtype=jnp.bfloat16, seed=7)
    out = flash_attention(q, k, v, causal=True, chunk=8)
    assert out.dtype == jnp.bfloat16
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=3e-2)
