#!/usr/bin/env bash
# Process-level serving preset for ReconService deployments.
#
# The engine-level optimizations (step-major scan, async flush, fleet,
# cross-request batching) all live inside the process; this script owns
# the knobs OUTSIDE it — allocator, logging, and XLA host-device layout
# — so `make serve` (or any entrypoint sourcing this file) starts from
# a known-good runtime. Usage:
#
#   scripts/serve_env.sh python examples/serve_recon.py   # exec a cmd
#   source scripts/serve_env.sh                           # just the env
#
# Every knob is override-able: set it before invoking and the preset
# keeps your value.

# --- allocator: tcmalloc when present -----------------------------------
# CPU reconstruction is large-allocation heavy (volume accumulators,
# stacked filtered chunk grids); glibc malloc's page-faulting hurts the
# streaming paths. Preload tcmalloc when the host has it; silently keep
# the default allocator otherwise (CI containers often lack it).
if [ -z "${LD_PRELOAD:-}" ]; then
    for _tcm in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
                /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4; do
        if [ -e "${_tcm}" ]; then
            export LD_PRELOAD="${_tcm}"
            break
        fi
    done
    unset _tcm
fi
# volumes are legitimately huge: suppress tcmalloc's large-alloc report
# (60 GB threshold) so serving logs stay signal-only
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"

# --- logging: errors only ----------------------------------------------
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# --- precision: f32 by default, no silent x64 promotion ----------------
export JAX_ENABLE_X64="${JAX_ENABLE_X64:-0}"
export JAX_DEFAULT_DTYPE_BITS="${JAX_DEFAULT_DTYPE_BITS:-32}"

# --- XLA host-device layout --------------------------------------------
# RECON_DEVICES=N splits the host CPU into N XLA devices so
# ReconService(devices=...) / PlanExecutor.execute_fleet can shard the
# step schedule (the multidevice CI lane runs with 8). Unset = XLA's
# single host device; deployments pair this with the service's
# max_inflight/max_batch so fleet width x inflight stays <= cores.
if [ -n "${RECON_DEVICES:-}" ]; then
    export XLA_FLAGS="--xla_force_host_platform_device_count=${RECON_DEVICES} ${XLA_FLAGS:-}"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# exec the wrapped command when invoked with one (no-op when sourced)
if [ "$#" -gt 0 ]; then
    exec "$@"
fi
